"""JAX version-compatibility shims.

The codebase targets the modern mesh/collective API surface —
``jax.shard_map(..., axis_names=..., check_vma=...)``,
``jax.set_mesh(mesh)`` and ``jax.sharding.get_abstract_mesh()`` — but must
also run on JAX 0.4.x, where the same functionality lives under
``jax.experimental.shard_map`` (``auto=`` / ``check_rep=`` spelling), the
thread-local mesh is set by entering the ``Mesh`` context manager, and the
current mesh is read from ``jax._src.mesh.thread_resources``.

Every call site in the repo goes through this module instead of touching the
moving APIs directly, so a JAX upgrade (or downgrade) is a one-file audit.
"""

from __future__ import annotations

import contextlib
from typing import Optional, Set

import jax

# Feature probes are done once at import; all of these are plain attribute
# existence checks (jax's deprecation module raises AttributeError for
# removed/not-yet-added names, so hasattr is reliable in both directions).
_HAS_TOPLEVEL_SHARD_MAP = hasattr(jax, "shard_map")
_HAS_SET_MESH = hasattr(jax, "set_mesh")
_HAS_GET_ABSTRACT_MESH = hasattr(jax.sharding, "get_abstract_mesh")


def get_abstract_mesh():
    """The mesh currently in scope, as an object exposing ``.empty``,
    ``.axis_names`` and ``.shape`` (a name->size mapping).

    New JAX: ``jax.sharding.get_abstract_mesh()``.  JAX 0.4.x: the
    thread-local *physical* mesh installed by entering a ``Mesh`` context
    (``with mesh:``), which satisfies the same read-only interface.
    """
    if _HAS_GET_ABSTRACT_MESH:
        return jax.sharding.get_abstract_mesh()
    from jax._src import mesh as _mesh_src
    return _mesh_src.thread_resources.env.physical_mesh


def set_mesh(mesh):
    """Context manager scoping ``mesh`` as the ambient mesh.

    New JAX: ``jax.set_mesh(mesh)``.  JAX 0.4.x: ``Mesh`` is itself a
    context manager that installs the thread-local resource env consumed by
    ``with_sharding_constraint`` and :func:`get_abstract_mesh` above.
    """
    if _HAS_SET_MESH:
        return jax.set_mesh(mesh)
    if mesh is None:
        return contextlib.nullcontext()
    return mesh


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict on any JAX.

    JAX 0.4.x returns a one-element list of per-computation dicts; newer JAX
    returns the dict directly.  Returns ``{}`` when the backend reports
    nothing.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        return ca[0] if ca else {}
    return ca or {}


# JAX 0.4.x ships an XLA whose SPMD partitioner hard-crashes
# (``Check failed: sharding.IsManualSubgroup()``) when a ``lax.scan`` iterates
# over xs sharded on an *auto* (GSPMD) mesh axis inside a partially-manual
# shard_map — exactly the layer-stack scan of a tensor-parallel model inside
# the dp-manual train step.  Fixed upstream; callers (tests, launchers) gate
# dp x tp runs on this flag.
PARTIAL_AUTO_SCAN_OK = _HAS_TOPLEVEL_SHARD_MAP


def mesh_axis_types(mesh) -> dict:
    """``{axis_name: axis_type}`` for meshes that carry axis types.

    Returns ``{}`` on JAX versions (or meshes) without type annotations —
    callers treat unknown as "no axis is known to be Auto", which degrades
    to the conservative single-shard_map path.
    """
    types = getattr(mesh, "axis_types", None)
    if types is None:
        return {}
    try:
        return dict(zip(mesh.axis_names, types))
    except TypeError:
        return {}


def shard_map(f, *, mesh, in_specs, out_specs,
              axis_names: Optional[Set[str]] = None, check_vma: bool = False):
    """``jax.shard_map`` with the modern keyword spelling on any JAX.

    ``axis_names`` is the set of mesh axes the body is *manual* over (the
    rest stay auto/GSPMD); ``check_vma`` is the replication-checker toggle
    (named ``check_rep`` on 0.4.x).  ``None`` axis_names means manual over
    every mesh axis, matching upstream semantics.
    """
    if _HAS_TOPLEVEL_SHARD_MAP:
        kwargs = {}
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma,
                             **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    if axis_names is None:
        auto = frozenset()
    else:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma, auto=auto)
