"""InternVL2-2B language backbone (InternLM2-1.8B-style) with a stubbed
vision frontend (per assignment): ``input_specs`` supplies precomputed
InternViT patch embeddings (B, n_patches, d_model) that are prepended to the
token embeddings. Loss is masked to the text positions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm


def init_params(cfg, key):
    return tfm.init_params(cfg, key)


def forward(cfg, params, tokens, prefix_embeds=None, remat: bool = True):
    logits = tfm.forward(cfg, params, tokens, prefix_embeds=prefix_embeds,
                         remat=remat)
    return logits, {}


def init_caches(cfg, batch: int, max_len: int):
    # cache must also hold the vision prefix
    return tfm.init_caches(cfg, batch, max_len + cfg.n_patches)


def prefill(cfg, params, tokens, max_len=None, prefix_embeds=None,
            remat: bool = True):
    max_len = (max_len or tokens.shape[1]) + cfg.n_patches
    return tfm.prefill(cfg, params, tokens, max_len=max_len,
                       prefix_embeds=prefix_embeds, remat=remat)


def decode_step(cfg, params, caches, token, pos, prefix_embeds=None):
    # pos is the absolute position incl. the vision prefix
    return tfm.decode_step(cfg, params, caches, token, pos)
