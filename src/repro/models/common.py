"""Shared building blocks: norms, RoPE, GQA attention (blocked / windowed /
decode-with-cache), losses, init + sharding-spec helpers.

Memory discipline: training/prefill attention never materialises the full
(S, S) score matrix — ``blocked_attention`` runs an online-softmax scan over
KV blocks (the jnp analogue of the Pallas flash kernel; identical FLOPs/bytes
at roofline granularity). Sliding-window layers slice only the in-window KV
blocks so local attention costs O(S*W), not O(S^2).
"""

from __future__ import annotations

import math
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Per-layer apply decomposition (layer-streamed FSDP engine, DESIGN.md §11)
# ---------------------------------------------------------------------------

class LayeredModel(NamedTuple):
    """Per-layer apply decomposition of a model.

    The layer-streamed FSDP execution engine (core/streaming.py) consumes
    parameters one **span** (scan unit — a superblock for the dense
    family) at a time, so the model must expose its forward as
    stem -> span* -> head over a *layered* param tree

        {"stem": {...}, "layers": (span_0, ..., span_{n-1}), "head": {...}}

    produced by ``split`` (pure slicing of the canonical stacked tree;
    ``merge`` is its exact inverse).  ``stem(stem_tree, batch) ->
    (carry, aux)`` — ``carry`` is the differentiable activation threaded
    through the spans, ``aux`` is non-differentiable side data (positions);
    ``span(k, span_tree, carry, aux) -> carry`` applies span k;
    ``head_loss(head_tree, stem_tree, carry, aux, batch) ->
    (loss, metrics)`` mirrors the registry loss bit-for-bit (the stem tree
    is passed through for tied unembeddings).  The composition
    ``head_loss(..., span(n-1, ..., span(0, ..., stem(...))))`` must equal
    ``ModelAPI.loss`` exactly — the streamed/gather-all differential tests
    pin it.
    """
    n_spans: int
    split: Callable                 # params -> layered tree
    merge: Callable                 # layered tree -> params (exact inverse)
    stem: Callable                  # (stem_tree, batch) -> (carry, aux)
    span: Callable                  # (k, span_tree, carry, aux, remat=True) -> carry
    head_loss: Callable             # (head, stem, carry, aux, batch) -> (loss, metrics)


def wsc(x, *spec):
    """with_sharding_constraint that (a) no-ops when no mesh is set (CPU
    tests) or named axes are absent, and (b) drops spec entries whose dim is
    not divisible by the mesh axis (e.g. 4 KV heads on a 16-way model axis —
    constraining those forces involuntary remat in the SPMD partitioner)."""
    mesh = compat.get_abstract_mesh()
    if mesh.empty:
        return x
    names = set(mesh.axis_names)
    used = {s for s in jax.tree.leaves(list(spec)) if isinstance(s, str)}
    if not used.issubset(names):
        return x
    fixed = []
    for i, entry in enumerate(spec):
        if entry is None:
            fixed.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        fixed.append(entry if x.shape[i] % n == 0 and x.shape[i] >= n else None)
    return jax.lax.with_sharding_constraint(x, P(*fixed))


# ---------------------------------------------------------------------------
# Param init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def split(key, n):
    return jax.random.split(key, n)


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x, positions, theta: float):
    """x: (..., T, H, hd); positions: (..., T) int."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., T, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blocked online-softmax attention (train / prefill)
# ---------------------------------------------------------------------------

def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    b, t, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, t, h, n_rep, d)
                            ).reshape(b, t, h * n_rep, d)


def blocked_attention(q, k, v, *, causal: bool = True,
                      window: Optional[int] = None,
                      block_q: int = 512, block_k: int = 1024,
                      q_offset: int = 0):
    """Online-softmax attention; q (B,Sq,H,hd), k/v (B,Sk,KH,hd).

    ``window``: sliding-window width (None = full). For windowed layers only
    the KV blocks intersecting [q_pos - window + 1, q_pos] are visited, via a
    scan over a *relative* block range and ``dynamic_slice`` — O(S*W) FLOPs.
    ``q_offset``: absolute position of q[0] (prefill continuation / decode).
    """
    b, sq, h, hd = q.shape
    sk, kh = k.shape[1], k.shape[2]
    n_rep = h // kh
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)

    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    # pad seq dims to block multiples
    pq = (-sq) % block_q
    pk = (-sk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = qp.shape[1] // block_q, kp.shape[1] // block_k
    scale = 1.0 / math.sqrt(hd)

    q_blocks = qp.reshape(b, nq, block_q, h, hd).transpose(1, 0, 3, 2, 4)
    k_all = kp.transpose(0, 2, 1, 3)                    # (B,H,Sk,hd)
    v_all = vp.transpose(0, 2, 1, 3)
    q_blocks = wsc(q_blocks, None, None, "model", None, None)  # heads on TP
    k_all = wsc(k_all, None, "model", None, None)
    v_all = wsc(v_all, None, "model", None, None)

    if window is not None:
        # visit only ceil((window+block_q)/block_k)+1 KV blocks per q block
        n_vis = (window + block_q) // block_k + 1
    else:
        n_vis = nk

    def q_block_body(qi, qblk):
        # qblk: (B,H,block_q,hd)
        q_pos = q_offset + qi * block_q + jnp.arange(block_q)

        def kv_step(carry, kj_rel):
            m, l, acc = carry
            if window is not None:
                # first visited block starts at the window's left edge
                first = jnp.maximum(
                    (q_offset + qi * block_q - (window - 1)) // block_k, 0)
                kj_unclipped = first + kj_rel
            else:
                kj_unclipped = kj_rel
            kj = jnp.clip(kj_unclipped, 0, nk - 1)
            visit_ok = kj_unclipped < nk                        # guard clip dup
            kblk = jax.lax.dynamic_slice_in_dim(k_all, kj * block_k, block_k, 2)
            vblk = jax.lax.dynamic_slice_in_dim(v_all, kj * block_k, block_k, 2)
            s = jnp.einsum("bhqd,bhkd->bhqk", qblk.astype(jnp.float32),
                           kblk.astype(jnp.float32)) * scale
            k_pos = kj * block_k + jnp.arange(block_k)
            mask = (k_pos[None, :] < sk) & visit_ok             # padding/dup
            if causal:
                mask = mask & (k_pos[None, :] <= q_pos[:, None])
            if window is not None:
                mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p, vblk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, block_q), jnp.float32)
        a0 = jnp.zeros((b, h, block_q, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(n_vis))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out                                           # (B,H,block_q,hd)

    outs = jax.lax.map(lambda args: q_block_body(*args),
                       (jnp.arange(nq), q_blocks))           # (nq,B,H,bq,hd)
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, nq * block_q, h, hd)
    return out[:, :sq].astype(q.dtype)


def decode_attention(q, k_cache, v_cache, *, length, window: Optional[int] = None,
                     pos=None):
    """One-token attention against a cache. q (B,1,H,hd); cache (B,S,KH,hd).

    ``length``: number of valid cache entries (traced ok). For ring-buffer
    window caches, S == window and all entries < length are valid.
    """
    b, _, h, hd = q.shape
    s, kh = k_cache.shape[1], k_cache.shape[2]
    n_rep = h // kh
    k = _repeat_kv(k_cache, n_rep)
    v = _repeat_kv(v_cache, n_rep)
    scale = 1.0 / math.sqrt(hd)
    sc = jnp.einsum("bohd,bshd->bhos", q.astype(jnp.float32),
                    k.astype(jnp.float32)) * scale          # (B,H,1,S)
    idx = jnp.arange(s)
    valid = idx[None, None, None, :] < length
    sc = jnp.where(valid, sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhos,bshd->bohd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------

def init_kv_cache(n_layers: int, batch: int, max_len: int, n_kv: int, hd: int,
                  dtype) -> dict:
    shape = (n_layers, batch, max_len, n_kv, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_update(cache_k, cache_v, k_new, v_new, pos, ring: bool = False):
    """Insert (B,1,KH,hd) at position pos (ring-buffer modulo for windows)."""
    s = cache_k.shape[1]
    idx = jnp.mod(pos, s) if ring else pos
    ck = jax.lax.dynamic_update_slice_in_dim(cache_k, k_new, idx, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache_v, v_new, idx, axis=1)
    return ck, cv


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def softmax_cross_entropy(logits, labels, mask=None):
    """logits (B,S,V) [model-axis shardable], labels (B,S) int."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    lab = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - lab
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


# ---------------------------------------------------------------------------
# Sharding-spec rules (model axis; dp handled by the step builder)
# ---------------------------------------------------------------------------

def shard_rules(path_leaf_shapes, model_axis: str = "model"):
    """Build a PartitionSpec tree from param path names.

    Conventions (path component -> placement):
      emb / lm_head     (V, d)        -> (model, None)       vocab-sharded
      wq/wk/wv          (d, H*hd)     -> (None, model)       head-sharded
      wo                (H*hd, d)     -> (model, None)
      w1/w3 (mlp up)    (d, ff)       -> (None, model)
      w2   (mlp down)   (ff, d)       -> (model, None)
      experts.*w1       (E, d, ff)    -> (model, None, None)  expert-parallel
      experts.*w2       (E, ff, d)    -> (model, None, None)
      scan-stacked params get a leading None prepended automatically
      everything else replicated
    """
    raise NotImplementedError("use spec_for_param per-model instead")


# base (unstacked) rank and model-axis placement per param name; spec entries
# apply to the TRAILING dims, leading scan-stack dims get None automatically.
_PARAM_RULES = {
    # name: (base_rank, spec_on_base_dims)
    "emb": (2, ("model", None)),          # vocab-sharded (logits matmul)
    "lm_head": (2, ("model", None)),
    "src_emb": (2, ("model", None)),
    "enc_pos": (2, (None, None)),
    "wq": (2, (None, "model")),
    "wk": (2, (None, "model")),
    "wv": (2, (None, "model")),
    "wo": (2, ("model", None)),
    "w1": (2, (None, "model")),
    "w3": (2, (None, "model")),
    "w2": (2, ("model", None)),
    "w_up": (2, (None, "model")),
    "w_down": (2, ("model", None)),
    "wg": (2, (None, "model")),
    "wif": (2, (None, None)),
    "w_x": (2, (None, "model")),
    "w_gate": (2, (None, "model")),
    "w_r": (2, (None, None)),             # lru gates: square (w,w); keep rep
    "w_i": (2, (None, None)),
    "w_out": (2, ("model", None)),
    "conv_w": (2, (None, "model")),
    "router": (2, (None, "model")),
    "we1": (3, ("model", None, None)),    # experts (E, d, ff): expert-parallel
    "we2": (3, ("model", None, None)),
    "we3": (3, ("model", None, None)),
    "r": (3, (None, None, None)),         # slstm per-head recurrent
}


def spec_for_param(path: str, shape: Tuple[int, ...],
                   model_axis: str = "model") -> P:
    """Model-axis placement by param name; leading stack dims -> None."""
    name = path.split("/")[-1]
    rule = _PARAM_RULES.get(name)
    if rule is None:
        return P(*([None] * len(shape)))
    base_rank, spec = rule
    lead = len(shape) - base_rank
    if lead < 0:
        return P(*([None] * len(shape)))
    entries = [None] * lead + [model_axis if s == "model" else None
                               for s in spec]
    # drop non-divisible placements (e.g. 36 heads * hd not % 16 is still ok
    # on the flattened dim; but guard tiny dims)
    return P(*entries)


def tree_specs(params_or_shapes, model_axis="model"):
    """PartitionSpec tree matching a params tree (rank-aware stacking)."""
    import jax.tree_util as jtu

    def visit(path, leaf):
        keys = [str(getattr(k, "key", getattr(k, "idx", None))) for k in path]
        return spec_for_param("/".join(keys), leaf.shape, model_axis)

    return jtu.tree_map_with_path(visit, params_or_shapes)
