"""Encoder-decoder transformer: whisper-medium backbone + transformer_wmt.

Per the assignment, the whisper *modality frontend* (mel-spectrogram + conv
feature extractor) is a STUB: ``input_specs`` provides precomputed frame
embeddings (B, encoder_frames, d_model). For transformer_wmt (the paper's own
61M model) the encoder consumes source-token embeddings instead.

Decoder self-attention uses RoPE (deviation from whisper's learned positions,
noted in DESIGN.md) so decode_32k's 32k-position decoder context needs no
position table. Cross-attention K/V are computed once at prefill and cached.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models import transformer as tfm


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_cross(cfg, key, dtype):
    d, h, kh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = cm.split(key, 4)
    return {
        "wq": cm.dense_init(ks[0], d, h * hd, dtype),
        "wk": cm.dense_init(ks[1], d, kh * hd, dtype),
        "wv": cm.dense_init(ks[2], d, kh * hd, dtype),
        "wo": cm.dense_init(ks[3], h * hd, d, dtype),
    }


def init_dec_layer(cfg, key, dtype):
    k1, k2 = cm.split(key, 2)
    p = tfm.init_layer(cfg, k1, dtype)
    p["cross"] = init_cross(cfg, k2, dtype)
    p["ln_x"] = tfm._norm_init(cfg, cfg.d_model, dtype)
    return p


def init_params(cfg, key):
    dtype = jnp.dtype(cfg.dtype)
    ks = cm.split(key, 6)
    enc_cfg = cfg.variant(causal=False)
    params = {
        "enc_blocks": jax.vmap(lambda k: tfm.init_layer(enc_cfg, k, dtype))(
            cm.split(ks[0], cfg.encoder_layers)),
        "dec_blocks": jax.vmap(lambda k: init_dec_layer(cfg, k, dtype))(
            cm.split(ks[1], cfg.n_layers)),
        "emb": cm.embed_init(ks[2], cfg.vocab_padded, cfg.d_model, dtype),
        "enc_pos": (jax.random.normal(ks[3], (cfg.encoder_frames or 4096,
                                               cfg.d_model), jnp.float32)
                    * 0.02).astype(dtype),
        "ln_enc": tfm._norm_init(cfg, cfg.d_model, dtype),
        "ln_f": tfm._norm_init(cfg, cfg.d_model, dtype),
    }
    if cfg.encoder_frames == 0:           # wmt: token encoder
        params["src_emb"] = cm.embed_init(ks[4], cfg.vocab_padded, cfg.d_model, dtype)
    return params


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------

def encode(cfg, params, enc_input, remat: bool = True):
    """enc_input: frame embeddings (B,F,d) [audio stub] or tokens (B,F) [wmt]."""
    if enc_input.ndim == 2:
        x = params["src_emb"][enc_input]
    else:
        x = enc_input.astype(jnp.dtype(cfg.dtype))
    f = x.shape[1]
    x = x + params["enc_pos"][:f]
    positions = jnp.broadcast_to(jnp.arange(f), x.shape[:2])
    enc_cfg = cfg.variant(causal=False)

    def layer(x, p):
        return tfm.attn_layer(enc_cfg, p, x, positions, None), None

    body = jax.remat(lambda x, p: layer(x, p)) if remat else layer
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return tfm.norm_apply(cfg, x, params["ln_enc"])


# ---------------------------------------------------------------------------
# Decoder
# ---------------------------------------------------------------------------

def _cross_attn(cfg, p, x, enc_kv):
    b, s, _ = x.shape
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, cfg.hd)
    ek, ev = enc_kv
    out = cm.blocked_attention(q, ek, ev, causal=False,
                               block_q=cfg.attn_block_q,
                               block_k=cfg.attn_block_k)
    return out.reshape(b, s, -1) @ p["wo"]


def _enc_kv(cfg, p, enc_out):
    b, f, _ = enc_out.shape
    ek = (enc_out @ p["wk"]).reshape(b, f, cfg.n_kv_heads, cfg.hd)
    ev = (enc_out @ p["wv"]).reshape(b, f, cfg.n_kv_heads, cfg.hd)
    return ek, ev


def dec_layer(cfg, p, x, positions, enc_out):
    h = tfm.norm_apply(cfg, x, p["ln1"])
    q, k, v = tfm._qkv(cfg, p["attn"], h)
    q = cm.apply_rope(q, positions, cfg.rope_theta)
    k = cm.apply_rope(k, positions, cfg.rope_theta)
    out = cm.blocked_attention(q, k, v, causal=True,
                               block_q=cfg.attn_block_q,
                               block_k=cfg.attn_block_k)
    b, s = x.shape[:2]
    x = x + out.reshape(b, s, -1) @ p["attn"]["wo"]
    hx = tfm.norm_apply(cfg, x, p["ln_x"])
    x = x + _cross_attn(cfg, p["cross"], hx, _enc_kv(cfg, p["cross"], enc_out))
    x = x + tfm.mlp(cfg, p["mlp"], tfm.norm_apply(cfg, x, p["ln2"]))
    return x


def forward(cfg, params, tokens, enc_input=None, prefix_embeds=None,
            remat: bool = True):
    """(enc_input, dec tokens) -> decoder logits. prefix_embeds aliases
    enc_input for the uniform registry API (audio stub embeddings)."""
    enc_input = enc_input if enc_input is not None else prefix_embeds
    enc_out = encode(cfg, params, enc_input, remat=remat)
    x = tfm.embed(cfg, params, tokens)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def layer(x, p):
        return dec_layer(cfg, p, x, positions, enc_out), None

    body = jax.remat(lambda x, p: layer(x, p)) if remat else layer
    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = tfm.norm_apply(cfg, x, params["ln_f"])
    return tfm.unembed(cfg, params, x), {}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_caches(cfg, batch: int, max_len: int):
    dtype = jnp.dtype(cfg.dtype)
    f = cfg.encoder_frames or 128
    self_cache = cm.init_kv_cache(cfg.n_layers, batch, max_len,
                                  cfg.n_kv_heads, cfg.hd, dtype)
    cross = cm.init_kv_cache(cfg.n_layers, batch, f,
                             cfg.n_kv_heads, cfg.hd, dtype)
    return {"self": self_cache, "cross": cross}


def prefill(cfg, params, tokens, enc_input=None, max_len=None,
            prefix_embeds=None, remat: bool = True):
    """Encode source, precompute cross K/V, consume prompt tokens (B,S)."""
    enc_input = enc_input if enc_input is not None else prefix_embeds
    enc_out = encode(cfg, params, enc_input, remat=remat)
    x = tfm.embed(cfg, params, tokens)
    b, s, _ = x.shape
    max_len = max_len or s
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def layer(x, p):
        h = tfm.norm_apply(cfg, x, p["ln1"])
        q, k, v = tfm._qkv(cfg, p["attn"], h)
        q = cm.apply_rope(q, positions, cfg.rope_theta)
        k = cm.apply_rope(k, positions, cfg.rope_theta)
        out = cm.blocked_attention(q, k, v, causal=True,
                                   block_q=cfg.attn_block_q,
                                   block_k=cfg.attn_block_k)
        x = x + out.reshape(b, s, -1) @ p["attn"]["wo"]
        hx = tfm.norm_apply(cfg, x, p["ln_x"])
        ek, ev = _enc_kv(cfg, p["cross"], enc_out)
        x = x + _cross_attn(cfg, p["cross"], hx, (ek, ev))
        x = x + tfm.mlp(cfg, p["mlp"], tfm.norm_apply(cfg, x, p["ln2"]))
        if max_len > s:
            pad = ((0, 0), (0, max_len - s), (0, 0), (0, 0))
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        return x, (k, v, ek, ev)

    body = jax.remat(layer) if remat else layer
    x, (k, v, ek, ev) = jax.lax.scan(lambda c, p: body(c, p), x,
                                     params["dec_blocks"])
    x = tfm.norm_apply(cfg, x, params["ln_f"])
    logits = tfm.unembed(cfg, params, x[:, -1:])
    return logits, {"self": {"k": k, "v": v}, "cross": {"k": ek, "v": ev}}


def decode_step(cfg, params, caches, token, pos, prefix_embeds=None):
    x = tfm.embed(cfg, params, token)
    b = x.shape[0]

    def layer(x, args):
        p, ck, cv, xk, xv = args
        h = tfm.norm_apply(cfg, x, p["ln1"])
        q, k, v = tfm._qkv(cfg, p["attn"], h)
        posv = jnp.broadcast_to(pos[None], (b, 1)) if jnp.ndim(pos) == 0 else pos
        q = cm.apply_rope(q, posv, cfg.rope_theta)
        k = cm.apply_rope(k, posv, cfg.rope_theta)
        ck, cv = cm.cache_update(ck, cv, k, v, pos)
        out = cm.decode_attention(q, ck, cv, length=pos + 1)
        x = x + out.reshape(b, 1, -1) @ p["attn"]["wo"]
        hx = tfm.norm_apply(cfg, x, p["ln_x"])
        qx = (hx @ p["cross"]["wq"]).reshape(b, 1, cfg.n_heads, cfg.hd)
        xo = cm.decode_attention(qx, xk, xv, length=xk.shape[1])
        x = x + xo.reshape(b, 1, -1) @ p["cross"]["wo"]
        x = x + tfm.mlp(cfg, p["mlp"], tfm.norm_apply(cfg, x, p["ln2"]))
        return x, (ck, cv)

    x, (ck, cv) = jax.lax.scan(
        layer, x, (params["dec_blocks"], caches["self"]["k"],
                   caches["self"]["v"], caches["cross"]["k"],
                   caches["cross"]["v"]))
    x = tfm.norm_apply(cfg, x, params["ln_f"])
    return tfm.unembed(cfg, params, x), {"self": {"k": ck, "v": cv},
                                         "cross": caches["cross"]}
