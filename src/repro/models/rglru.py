"""RecurrentGemma / Griffin (arXiv:2402.19427): RG-LRU + local attention, 1:2.

26 layers, pattern (recurrent, recurrent, local-attention) x 8 + a trailing
(recurrent, recurrent) pair. Each residual block = temporal mixing + gated MLP.

RG-LRU recurrence (linear, gated):
    r_t = sigmoid(W_r u_t);  i_t = sigmoid(W_i u_t)
    a_t = exp(-c * softplus(Lambda) * r_t)              (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

Training uses ``jax.lax.associative_scan`` over time (the recurrence is linear
in h — O(log S) depth on TPU); decode keeps (h, conv) state — ``long_500k``
runs natively. A Pallas kernel for the scan lives in kernels/rglru_scan.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models import transformer as tfm

C_FACTOR = 8.0
ATTN_WINDOW = 2048    # Griffin's local attention window


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_recurrent(cfg, key, dtype):
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = cm.split(key, 7)
    return {
        "ln": {"scale": jnp.zeros((d,), dtype)},
        "w_x": cm.dense_init(ks[0], d, w, dtype),          # recurrence branch
        "w_gate": cm.dense_init(ks[1], d, w, dtype),       # gelu gate branch
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, w), jnp.float32)
                   * 0.1).astype(dtype),
        "w_r": cm.dense_init(ks[3], w, w, dtype, scale=0.01),
        "w_i": cm.dense_init(ks[4], w, w, dtype, scale=0.01),
        "lam": jnp.linspace(0.9, 0.999, w).astype(jnp.float32),  # Lambda param
        "w_out": cm.dense_init(ks[5], w, d, dtype),
        "mlp": _mlp_init(cfg, ks[6], dtype),
    }


def _mlp_init(cfg, key, dtype):
    ks = cm.split(key, 3)
    return {
        "ln": {"scale": jnp.zeros((cfg.d_model,), dtype)},
        "w1": cm.dense_init(ks[0], cfg.d_model, cfg.d_ff, dtype),
        "w3": cm.dense_init(ks[1], cfg.d_model, cfg.d_ff, dtype),
        "w2": cm.dense_init(ks[2], cfg.d_ff, cfg.d_model, dtype),
    }


def init_attn(cfg, key, dtype):
    k1, k2 = cm.split(key, 2)
    p = tfm.init_layer(cfg, k1, dtype)
    return p


def init_params(cfg, key):
    dtype = jnp.dtype(cfg.dtype)
    n_sb = cfg.n_layers // 3                # 8 full (rec, rec, attn) blocks
    tail = cfg.n_layers - 3 * n_sb          # 2 trailing recurrent blocks
    ks = cm.split(key, 5)
    params = {
        "emb": cm.embed_init(ks[0], cfg.vocab_padded, cfg.d_model, dtype),
        "blocks": {
            "rec1": jax.vmap(lambda k: init_recurrent(cfg, k, dtype))(cm.split(ks[1], n_sb)),
            "rec2": jax.vmap(lambda k: init_recurrent(cfg, k, dtype))(cm.split(ks[2], n_sb)),
            "attn": jax.vmap(lambda k: init_attn(cfg, k, dtype))(cm.split(ks[3], n_sb)),
        },
        "ln_f": {"scale": jnp.zeros((cfg.d_model,), dtype)},
    }
    if tail:
        params["tail"] = jax.vmap(
            lambda k: init_recurrent(cfg, k, dtype))(cm.split(ks[4], tail))
    return params


# ---------------------------------------------------------------------------
# RG-LRU core
# ---------------------------------------------------------------------------

def _gates(p, u):
    r = jax.nn.sigmoid((u @ p["w_r"]).astype(jnp.float32))
    i = jax.nn.sigmoid((u @ p["w_i"]).astype(jnp.float32))
    log_a = -C_FACTOR * jax.nn.softplus(p["lam"]) * r       # (B,S,w) fp32
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12, 1.0)) \
        * (i * u.astype(jnp.float32))
    return a, gated_in


def rglru_scan(a, x, h0=None):
    """h_t = a_t h_{t-1} + x_t via associative scan; a,x (B,S,w) fp32."""
    if h0 is not None:
        x = x.at[:, 0].add(a[:, 0] * h0)
    def op(ca, cb):
        a1, b1 = ca
        a2, b2 = cb
        return a1 * a2, a2 * b1 + b2
    _, h = jax.lax.associative_scan(op, (a, x), axis=1)
    return h


def conv1d_causal(u, w, state=None):
    """Depthwise causal conv, width K. u (B,S,w); state (B,K-1,w) history."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((u.shape[0], K - 1, u.shape[2]), u.dtype)
    else:
        pad = state.astype(u.dtype)
    up = jnp.concatenate([pad, u], axis=1)
    out = sum(up[:, k:k + u.shape[1]] * w[k] for k in range(K))
    new_state = up[:, -(K - 1):]
    return out, new_state


def recurrent_block(cfg, p, x, state=None):
    """state = (h (B,w) fp32, conv (B,K-1,w)) or None. Returns (x, state)."""
    h = cm.rms_norm(x, p["ln"]["scale"], cfg.norm_eps)
    gate = jax.nn.gelu(h @ p["w_gate"])
    u = h @ p["w_x"]
    h0, conv_state = (None, None) if state is None else state
    u, conv_state = conv1d_causal(u, p["conv_w"], conv_state)
    a, gin = _gates(p, u)
    hs = rglru_scan(a, gin, h0)                             # (B,S,w) fp32
    y = (hs.astype(x.dtype) * gate) @ p["w_out"]
    x = x + y
    x = x + tfm.mlp(cfg, p["mlp"], cm.rms_norm(x, p["mlp"]["ln"]["scale"],
                                               cfg.norm_eps))
    new_state = (hs[:, -1], conv_state)
    return x, new_state


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def forward(cfg, params, tokens, prefix_embeds=None, remat: bool = True,
            return_hidden: bool = False):
    x = tfm.embed(cfg, params, tokens)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def sb(x, bp):
        x, _ = recurrent_block(cfg, bp["rec1"], x)
        x, _ = recurrent_block(cfg, bp["rec2"], x)
        x = tfm.attn_layer(cfg, bp["attn"], x, positions, ATTN_WINDOW)
        return x, None

    body = jax.remat(lambda c, bp: sb(c, bp)) if remat else sb
    x, _ = jax.lax.scan(body, x, params["blocks"])
    if "tail" in params:
        def tail_body(x, tp):
            x, _ = recurrent_block(cfg, tp, x)
            return x, None
        x, _ = jax.lax.scan(tail_body, x, params["tail"])
    x = cm.rms_norm(x, params["ln_f"]["scale"], cfg.norm_eps)
    if return_hidden:
        return x, {}
    return tfm.unembed(cfg, params, x), {}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_caches(cfg, batch: int, max_len: int):
    n_sb = cfg.n_layers // 3
    tail = cfg.n_layers - 3 * n_sb
    w = cfg.lru_width or cfg.d_model
    K = cfg.conv_width
    dtype = jnp.dtype(cfg.dtype)

    def rec_state(n):
        return (jnp.zeros((n, batch, w), jnp.float32),
                jnp.zeros((n, batch, K - 1, w), dtype))

    win = min(ATTN_WINDOW, max_len)
    caches = {
        "rec1": rec_state(n_sb),
        "rec2": rec_state(n_sb),
        "attn": cm.init_kv_cache(n_sb, batch, win, cfg.n_kv_heads, cfg.hd, dtype),
    }
    if tail:
        caches["tail"] = rec_state(tail)
    return caches


def decode_step(cfg, params, caches, token, pos, prefix_embeds=None):
    x = tfm.embed(cfg, params, token)

    def sb(x, args):
        bp, r1, r2, ck, cv = args
        x, r1 = recurrent_block(cfg, bp["rec1"], x, state=r1)
        x, r2 = recurrent_block(cfg, bp["rec2"], x, state=r2)
        x, ck, cv = tfm._decode_layer(cfg, bp["attn"], x, ck, cv, pos,
                                      ATTN_WINDOW)
        return x, (r1, r2, ck, cv)

    x, (r1, r2, ck, cv) = jax.lax.scan(
        sb, x, (params["blocks"], caches["rec1"], caches["rec2"],
                caches["attn"]["k"], caches["attn"]["v"]))
    new = {"rec1": r1, "rec2": r2, "attn": {"k": ck, "v": cv}}
    if "tail" in params:
        def tail_body(x, args):
            tp, st = args
            x, st = recurrent_block(cfg, tp, x, state=st)
            return x, st
        x, ts = jax.lax.scan(tail_body, x, (params["tail"], caches["tail"]))
        new["tail"] = ts
    x = cm.rms_norm(x, params["ln_f"]["scale"], cfg.norm_eps)
    return tfm.unembed(cfg, params, x), new


def prefill(cfg, params, tokens, max_len=None, prefix_embeds=None,
            remat: bool = True):
    x = tfm.embed(cfg, params, tokens)
    b, s, _ = x.shape
    max_len = max_len or s
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    win = min(ATTN_WINDOW, max_len)

    def capture_attn(p, x):
        h = tfm.norm_apply(cfg, x, p["ln1"])
        q, k, v = tfm._qkv(cfg, p["attn"], h)
        q = cm.apply_rope(q, positions, cfg.rope_theta)
        k = cm.apply_rope(k, positions, cfg.rope_theta)
        out = cm.blocked_attention(q, k, v, causal=True, window=ATTN_WINDOW,
                                   block_q=cfg.attn_block_q,
                                   block_k=cfg.attn_block_k)
        x = x + out.reshape(b, s, -1) @ p["attn"]["wo"]
        x = x + tfm.mlp(cfg, p["mlp"], tfm.norm_apply(cfg, x, p["ln2"]))
        j = jnp.arange(win)
        p_j = (s - 1) - ((s - 1 - j) % win)
        valid = (p_j >= 0)[None, :, None, None]
        kw = jnp.where(valid, jnp.take(k, jnp.clip(p_j, 0, s - 1), axis=1), 0)
        vw = jnp.where(valid, jnp.take(v, jnp.clip(p_j, 0, s - 1), axis=1), 0)
        return x, kw, vw

    body = jax.remat(capture_attn) if remat else capture_attn

    def sb(x, bp):
        x, r1 = recurrent_block(cfg, bp["rec1"], x)
        x, r2 = recurrent_block(cfg, bp["rec2"], x)
        x, kw, vw = body(bp["attn"], x)
        return x, (r1, r2, kw, vw)

    x, (r1, r2, kw, vw) = jax.lax.scan(sb, x, params["blocks"])
    caches = {"rec1": r1, "rec2": r2, "attn": {"k": kw, "v": vw}}
    if "tail" in params:
        def tail_body(x, tp):
            x, st = recurrent_block(cfg, tp, x)
            return x, st
        x, ts = jax.lax.scan(tail_body, x, params["tail"])
        caches["tail"] = ts
    x = cm.rms_norm(x, params["ln_f"]["scale"], cfg.norm_eps)
    return tfm.unembed(cfg, params, x[:, -1:]), caches
