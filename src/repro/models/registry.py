"""Uniform model API over the zoo: build_model(cfg) -> ModelAPI.

Batch format (produced by data/ and launch/input_specs):
    dense/moe/ssm/hybrid : {tokens (B,S), labels (B,S)}
    vlm                  : + {patches (B,Np,d)}  — labels cover text positions
    audio (whisper)      : {frames (B,F,d), tokens, labels}
    encdec (wmt)         : {src (B,F), tokens, labels}

``loss(params, batch)`` returns (scalar_loss, metrics) and folds MoE aux
losses in with cfg.router_aux_coef.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax.numpy as jnp

from repro.models import common as cm
from repro.models import encdec, moe, rglru, transformer as tfm, vlm, xlstm


class ModelAPI(NamedTuple):
    cfg: Any
    init: Callable                  # key -> params
    forward: Callable               # (params, batch, remat=True) -> (logits, aux)
    loss: Callable                  # (params, batch, remat=True) -> (loss, metrics)
    init_caches: Callable           # (batch, max_len) -> caches
    prefill: Callable               # (params, batch, max_len) -> (logits, caches)
    decode_step: Callable           # (params, caches, token, pos) -> (logits, caches)
    # per-layer apply decomposition for the layer-streamed FSDP engine
    # (DESIGN.md §11); None for families without one (the streamed train
    # step requires it and raises otherwise)
    layered: Optional[cm.LayeredModel] = None


def _chunked_ce(cfg, unembed_params, hidden, labels, mask):
    """Big-vocab memory saver: the (B,S,V) fp32 logits of a 262k vocab
    dominate the training live-set (~13 GiB/device on gemma3-12b), so
    the CE runs over rematerialised sequence chunks — the full logits
    tensor never exists.  ``unembed_params`` is any tree ``tfm.unembed``
    reads (the full params, or the stem/head slices of a layered tree)."""
    import jax
    B, S = labels.shape
    chunks = 8
    while S % chunks:
        chunks -= 1
    Sc = S // chunks
    xs = hidden.reshape(B, chunks, Sc, -1).swapaxes(0, 1)   # (c,B,Sc,D)
    ls = labels.reshape(B, chunks, Sc).swapaxes(0, 1)
    ms = (mask.reshape(B, chunks, Sc).swapaxes(0, 1) if mask is not None
          else jnp.ones((chunks, B, Sc), jnp.float32))

    def body(carry, inp):
        xc, lc, mc = inp
        logits = tfm.unembed(cfg, unembed_params, xc).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        lab = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (lse - lab) * mc
        tot, cnt = carry
        return (tot + nll.sum(), cnt + mc.sum()), None

    (tot, cnt), _ = jax.lax.scan(
        jax.remat(body),
        (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)


def _dense_layered(cfg, use_chunked_ce: bool) -> cm.LayeredModel:
    """Layered decomposition of the dense family (one span = superblock).

    ``head_loss`` mirrors ``build_model``'s dense loss branch bit-for-bit:
    final norm, (chunked) unembed + CE, ``{"ce", "loss"}`` metrics — the
    streamed engine's composition must be indistinguishable from
    ``ModelAPI.loss`` (dense has no aux losses).
    """
    n_sb, _, _ = tfm.superblock_layout(cfg)

    def stem(stem_tree, batch):
        return tfm.stem_apply(cfg, stem_tree, batch["tokens"])

    def span(k, span_tree, x, positions, remat=True):
        return tfm.span_apply(cfg, span_tree, x, positions, remat=remat)

    def head_loss(head_tree, stem_tree, x, positions, batch):
        x = tfm.norm_apply(cfg, x, head_tree["ln_f"])
        up = tfm.head_params_for_unembed(stem_tree, head_tree)
        if use_chunked_ce:
            ce = _chunked_ce(cfg, up, x, batch["labels"], batch.get("mask"))
        else:
            logits = tfm.unembed(cfg, up, x)
            ce = cm.softmax_cross_entropy(logits, batch["labels"],
                                          batch.get("mask"))
        return ce, {"ce": ce, "loss": ce}

    return cm.LayeredModel(
        n_spans=n_sb,
        split=lambda params: tfm.split_layered(cfg, params),
        merge=lambda layered: tfm.merge_layered(cfg, layered),
        stem=stem, span=span, head_loss=head_loss)


def _dense_fwd(mod):
    def fwd(cfg, params, batch, remat=True):
        logits = mod.forward(cfg, params, batch["tokens"], remat=remat)
        if isinstance(logits, tuple):
            return logits
        return logits, {}
    return fwd


def build_model(cfg) -> ModelAPI:
    fam = cfg.family

    if fam in ("dense",):
        mod, fwd = tfm, _dense_fwd(tfm)
        pf = lambda cfg, p, b, ml, remat=True: tfm.prefill(
            cfg, p, b["tokens"], max_len=ml, remat=remat)
        dec = lambda cfg, p, c, tok, pos: tfm.decode_step(cfg, p, c, tok, pos)
        caches = tfm.init_caches
        text_slice = None
    elif fam == "moe":
        mod = moe
        fwd = lambda cfg, p, b, remat=True: moe.forward(
            cfg, p, b["tokens"], remat=remat)
        pf = lambda cfg, p, b, ml, remat=True: moe.prefill(
            cfg, p, b["tokens"], max_len=ml, remat=remat)
        dec = lambda cfg, p, c, tok, pos: moe.decode_step(cfg, p, c, tok, pos)
        caches = moe.init_caches
        text_slice = None
    elif fam == "ssm":
        mod = xlstm
        fwd = lambda cfg, p, b, remat=True: xlstm.forward(
            cfg, p, b["tokens"], remat=remat)
        pf = lambda cfg, p, b, ml, remat=True: xlstm.prefill(
            cfg, p, b["tokens"], remat=remat)
        dec = lambda cfg, p, c, tok, pos: xlstm.decode_step(cfg, p, c, tok, pos)
        caches = xlstm.init_caches
        text_slice = None
    elif fam == "hybrid":
        mod = rglru
        fwd = lambda cfg, p, b, remat=True: rglru.forward(
            cfg, p, b["tokens"], remat=remat)
        pf = lambda cfg, p, b, ml, remat=True: rglru.prefill(
            cfg, p, b["tokens"], max_len=ml, remat=remat)
        dec = lambda cfg, p, c, tok, pos: rglru.decode_step(cfg, p, c, tok, pos)
        caches = rglru.init_caches
        text_slice = None
    elif fam == "audio":
        mod = encdec
        fwd = lambda cfg, p, b, remat=True: encdec.forward(
            cfg, p, b["tokens"], enc_input=b.get("frames", b.get("src")),
            remat=remat)
        pf = lambda cfg, p, b, ml, remat=True: encdec.prefill(
            cfg, p, b["tokens"], enc_input=b.get("frames", b.get("src")),
            max_len=ml, remat=remat)
        dec = lambda cfg, p, c, tok, pos: encdec.decode_step(cfg, p, c, tok, pos)
        caches = encdec.init_caches
        text_slice = None
    elif fam == "vlm":
        mod = vlm
        fwd = lambda cfg, p, b, remat=True: vlm.forward(
            cfg, p, b["tokens"], prefix_embeds=b["patches"], remat=remat)
        pf = lambda cfg, p, b, ml, remat=True: vlm.prefill(
            cfg, p, b["tokens"], max_len=ml, prefix_embeds=b["patches"],
            remat=remat)
        dec = lambda cfg, p, c, tok, pos: vlm.decode_step(cfg, p, c, tok, pos)
        caches = vlm.init_caches
        text_slice = cfg.n_patches
    else:
        raise ValueError(f"unknown family {fam!r}")

    # big-vocab families where forward can hand back hidden states
    chunked_families = {"dense", "moe", "hybrid", "vlm"}
    use_chunked_ce = fam in chunked_families and cfg.vocab_padded >= 65536

    def loss_fn(params, batch, remat=True):
        if use_chunked_ce:
            if fam == "moe":
                hidden, aux = moe.forward(cfg, params, batch["tokens"],
                                          remat=remat, return_hidden=True)
            elif fam == "hybrid":
                hidden, aux = rglru.forward(cfg, params, batch["tokens"],
                                            remat=remat, return_hidden=True)
            elif fam == "vlm":
                hidden = tfm.forward(cfg, params, batch["tokens"],
                                     prefix_embeds=batch["patches"],
                                     remat=remat, return_hidden=True)
                aux = {}
            else:
                hidden = tfm.forward(cfg, params, batch["tokens"],
                                     remat=remat, return_hidden=True)
                aux = {}
            if text_slice:
                hidden = hidden[:, text_slice:]
            ce = _chunked_ce(cfg, params, hidden, batch["labels"],
                             batch.get("mask"))
        else:
            logits, aux = fwd(cfg, params, batch, remat=remat)
            if text_slice:
                logits = logits[:, text_slice:]
            ce = cm.softmax_cross_entropy(logits, batch["labels"],
                                          batch.get("mask"))
        total = ce
        metrics = {"ce": ce}
        for name in ("load_balance", "router_z"):
            if name in aux:
                total = total + cfg.router_aux_coef * aux[name]
                metrics[name] = aux[name]
        if "dropped" in aux:
            metrics["moe_dropped"] = aux["dropped"]
        metrics["loss"] = total
        return total, metrics

    return ModelAPI(
        cfg=cfg,
        init=lambda key: mod.init_params(cfg, key),
        forward=lambda params, batch, remat=True: fwd(cfg, params, batch, remat),
        loss=loss_fn,
        init_caches=lambda batch, max_len: caches(cfg, batch, max_len),
        prefill=(lambda params, batch, max_len, remat=True:
                 pf(cfg, params, batch, max_len, remat)) if pf else None,
        decode_step=lambda params, c, tok, pos: dec(cfg, params, c, tok, pos),
        layered=_dense_layered(cfg, use_chunked_ce) if fam == "dense" else None,
    )
