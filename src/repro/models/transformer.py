"""Dense decoder-only transformer family.

Covers qwen3 (qk-norm GQA), starcoder2 (LN + plain-gelu MLP), tinyllama,
gemma3 (5-local:1-global sliding-window pattern), the internvl2 language
backbone, and the uniform-`swa` long-context variants.

Layer stacks compile as ``lax.scan`` over *super-blocks* so the HLO stays
compact on 61-layer models:

    local_per_global == 0, no window  -> super-block = 1 global layer
    sliding_window, local_per_global==0 -> super-block = 1 windowed layer
    local_per_global == k             -> super-block = k windowed + 1 global

The module exposes three entry points used by train/serve:
    init_params(cfg, key)
    forward(cfg, params, tokens, prefix_embeds=None) -> logits
    prefill(cfg, params, tokens)  -> (last_logits, caches)
    decode_step(cfg, params, caches, token, pos) -> (logits, caches)
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import common as cm


# ---------------------------------------------------------------------------
# Structure helpers
# ---------------------------------------------------------------------------

def superblock_layout(cfg):
    """(n_superblocks, locals_per_block, has_global) covering cfg.n_layers."""
    if cfg.local_per_global > 0:
        k = cfg.local_per_global
        assert cfg.n_layers % (k + 1) == 0, (cfg.n_layers, k)
        return cfg.n_layers // (k + 1), k, True
    if cfg.sliding_window is not None:
        return cfg.n_layers, 1, False       # uniform windowed
    return cfg.n_layers, 0, True            # uniform global


def norm_apply(cfg, x, p):
    if cfg.norm == "ln":
        x32 = x.astype(jnp.float32)
        mu = x32.mean(-1, keepdims=True)
        var = x32.var(-1, keepdims=True)
        out = (x32 - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        return (out * (1.0 + p["scale"].astype(jnp.float32))
                + p["bias"].astype(jnp.float32)).astype(x.dtype)
    return cm.rms_norm(x, p["scale"], cfg.norm_eps)


def _norm_init(cfg, d, dtype):
    p = {"scale": jnp.zeros((d,), dtype)}
    if cfg.norm == "ln":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_layer(cfg, key, dtype):
    d, h, kh, hd, ff = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.d_ff
    ks = cm.split(key, 8)
    p = {
        "ln1": _norm_init(cfg, d, dtype),
        "ln2": _norm_init(cfg, d, dtype),
        "attn": {
            "wq": cm.dense_init(ks[0], d, h * hd, dtype),
            "wk": cm.dense_init(ks[1], d, kh * hd, dtype),
            "wv": cm.dense_init(ks[2], d, kh * hd, dtype),
            "wo": cm.dense_init(ks[3], h * hd, d, dtype),
        },
        "mlp": {
            "w1": cm.dense_init(ks[4], d, ff, dtype),
            "w2": cm.dense_init(ks[5], ff, d, dtype),
        },
    }
    if cfg.gated_mlp:
        p["mlp"]["w3"] = cm.dense_init(ks[6], d, ff, dtype)
    if cfg.qk_norm:
        p["attn"]["q_norm"] = jnp.zeros((hd,), dtype)
        p["attn"]["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def init_params(cfg, key):
    dtype = jnp.dtype(cfg.dtype)
    n_sb, n_local, has_global = superblock_layout(cfg)
    keys = cm.split(key, 4)

    def stack_layers(key, n):
        return jax.vmap(lambda k: init_layer(cfg, k, dtype))(cm.split(key, n))

    blocks = {}
    if n_local:
        # (n_sb, n_local, ...) stacked local layers
        blocks["local"] = jax.vmap(
            lambda k: stack_layers(k, n_local))(cm.split(keys[0], n_sb))
    if has_global:
        blocks["global"] = stack_layers(keys[1], n_sb)

    params = {
        "emb": cm.embed_init(keys[2], cfg.vocab_padded, cfg.d_model, dtype),
        "blocks": blocks,
        "ln_f": _norm_init(cfg, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = cm.embed_init(keys[3], cfg.vocab_padded, cfg.d_model, dtype)
    return params


# ---------------------------------------------------------------------------
# Layer compute
# ---------------------------------------------------------------------------

def _qkv(cfg, p, h):
    b, s, d = h.shape
    q = (h @ p["wq"]).reshape(b, s, cfg.n_heads, cfg.hd)
    k = (h @ p["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.hd)
    v = (h @ p["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.hd)
    q = cm.wsc(q, None, None, "model", None)   # head-sharded (Megatron col.)
    k = cm.wsc(k, None, None, "model", None)
    v = cm.wsc(v, None, None, "model", None)
    if cfg.qk_norm:
        q = cm.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = cm.rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def attn_layer(cfg, p, x, positions, window: Optional[int]):
    h = norm_apply(cfg, x, p["ln1"])
    q, k, v = _qkv(cfg, p["attn"], h)
    q = cm.apply_rope(q, positions, cfg.rope_theta)
    k = cm.apply_rope(k, positions, cfg.rope_theta)
    out = cm.blocked_attention(q, k, v, causal=cfg.causal, window=window,
                               block_q=cfg.attn_block_q,
                               block_k=cfg.attn_block_k)
    b, s = x.shape[:2]
    x = x + out.reshape(b, s, -1) @ p["attn"]["wo"]
    x = cm.wsc(x, None, None, None)          # replicated between blocks
    x = x + mlp(cfg, p["mlp"], norm_apply(cfg, x, p["ln2"]))
    x = cm.wsc(x, None, None, None)
    return x


def mlp(cfg, p, h):
    act = cm.act_fn(cfg.act)
    if cfg.gated_mlp:
        return (act(h @ p["w1"]) * (h @ p["w3"])) @ p["w2"]
    return act(h @ p["w1"]) @ p["w2"]


def _superblock(cfg, bp, x, positions, n_local, has_global):
    if n_local:
        def local_body(x, lp):
            return attn_layer(cfg, lp, x, positions, cfg.sliding_window), None
        x, _ = jax.lax.scan(local_body, x, bp["local"])
    if has_global:
        x = attn_layer(cfg, bp["global"], x, positions, None)
    return x


# ---------------------------------------------------------------------------
# Forward (train / scoring)
# ---------------------------------------------------------------------------

def embed(cfg, params, tokens):
    x = params["emb"][tokens]
    if cfg.emb_scale:
        x = x * jnp.asarray(jnp.sqrt(float(cfg.d_model)), x.dtype)
    return x


def unembed(cfg, params, x):
    table = params.get("lm_head", params["emb"])
    logits = jnp.einsum("bsd,vd->bsv", x, table)
    return cm.wsc(logits, None, None, "model")


def forward(cfg, params, tokens, prefix_embeds=None, remat: bool = True,
            return_hidden: bool = False):
    """tokens (B,S) -> logits (B,S',V); prefix_embeds (B,Np,d) prepended.
    return_hidden=True returns the final-norm hidden states instead of
    logits (the chunked-CE loss path unembeds per sequence chunk)."""
    x = embed(cfg, params, tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    n_sb, n_local, has_global = superblock_layout(cfg)

    body = functools.partial(_superblock, cfg, n_local=n_local,
                             has_global=has_global)
    if remat:
        body = jax.remat(body, static_argnums=())

    def scan_body(x, bp):
        return body(bp, x, positions), None

    x, _ = jax.lax.scan(scan_body, x, params["blocks"])
    x = norm_apply(cfg, x, params["ln_f"])
    if return_hidden:
        return x
    return unembed(cfg, params, x)


# ---------------------------------------------------------------------------
# Layered decomposition (layer-streamed FSDP execution, DESIGN.md §11)
# ---------------------------------------------------------------------------

def split_layered(cfg, params):
    """Full param tree -> ``{"stem", "layers", "head"}`` (pure slicing).

    One span per superblock — the same unit ``forward``'s scan consumes —
    so ``span_apply(k, ...)`` composed over k reproduces the scan exactly.
    Exact inverse of :func:`merge_layered`.
    """
    n_sb, _, _ = superblock_layout(cfg)
    spans = tuple(jax.tree.map(lambda a: a[k], params["blocks"])
                  for k in range(n_sb))
    head = {"ln_f": params["ln_f"]}
    if "lm_head" in params:
        head["lm_head"] = params["lm_head"]
    return {"stem": {"emb": params["emb"]}, "layers": spans, "head": head}


def merge_layered(cfg, layered):
    """``{"stem", "layers", "head"}`` -> the canonical stacked param tree."""
    blocks = jax.tree.map(lambda *xs: jnp.stack(xs), *layered["layers"])
    params = {"emb": layered["stem"]["emb"], "blocks": blocks,
              "ln_f": layered["head"]["ln_f"]}
    if "lm_head" in layered["head"]:
        params["lm_head"] = layered["head"]["lm_head"]
    return params


def stem_apply(cfg, stem, tokens, prefix_embeds=None):
    """Embedding stem: tokens -> (x, positions) — ``forward``'s prologue."""
    x = embed(cfg, {"emb": stem["emb"]}, tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    return x, positions


def span_apply(cfg, span_params, x, positions, remat: bool = True):
    """Apply ONE superblock — the body ``forward``'s scan runs per slice.

    The streamed engine threads the train step's ``remat`` flag through to
    its backward per-span VJPs: remat does not change values, but it DOES
    change which fused reductions XLA emits for the parameter gradients
    (probed: ~1e-6 drift on qk-norm/w* grads remat vs not), so streamed
    bwd must remat exactly when the gather-all reference path
    (``model.loss(remat=True)``'s scan body) does to stay bit-identical.
    """
    n_sb, n_local, has_global = superblock_layout(cfg)
    body = functools.partial(_superblock, cfg, n_local=n_local,
                             has_global=has_global)
    if remat:
        body = jax.remat(body, static_argnums=())
    return body(span_params, x, positions)


def head_params_for_unembed(stem, head):
    """Pseudo param tree :func:`unembed` reads (tied or explicit lm_head)."""
    up = {"emb": stem["emb"]}
    if "lm_head" in head:
        up["lm_head"] = head["lm_head"]
    return up


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode with KV caches
# ---------------------------------------------------------------------------

def init_caches(cfg, batch: int, max_len: int):
    """Per-superblock caches: ring buffers for local, full for global."""
    dtype = jnp.dtype(cfg.dtype)
    n_sb, n_local, has_global = superblock_layout(cfg)
    caches = {}
    if n_local:
        w = min(cfg.sliding_window, max_len)
        caches["local"] = cm.init_kv_cache(
            n_sb * n_local, batch, w, cfg.n_kv_heads, cfg.hd, dtype)
        caches["local"] = jax.tree.map(
            lambda a: a.reshape((n_sb, n_local) + a.shape[1:]), caches["local"])
    if has_global:
        caches["global"] = cm.init_kv_cache(
            n_sb, batch, max_len, cfg.n_kv_heads, cfg.hd, dtype)
    return caches


def _decode_layer(cfg, p, x, ck, cv, pos, window: Optional[int]):
    """One decode layer; x (B,1,d); cache (B,S,KH,hd). Returns x, ck, cv."""
    h = norm_apply(cfg, x, p["ln1"])
    q, k, v = _qkv(cfg, p["attn"], h)
    b = x.shape[0]
    posv = jnp.broadcast_to(pos[None], (b, 1)) if jnp.ndim(pos) == 0 else pos
    q = cm.apply_rope(q, posv, cfg.rope_theta)
    k = cm.apply_rope(k, posv, cfg.rope_theta)
    ring = window is not None
    ck, cv = cm.cache_update(ck, cv, k, v, pos, ring=ring)
    length = jnp.minimum(pos + 1, ck.shape[1])
    out = cm.decode_attention(q, ck, cv, length=length, window=window)
    x = x + out.reshape(b, 1, -1) @ p["attn"]["wo"]
    x = x + mlp(cfg, p["mlp"], norm_apply(cfg, x, p["ln2"]))
    return x, ck, cv


def decode_step(cfg, params, caches, token, pos, prefix_embeds=None):
    """token (B,1) int, pos scalar int -> (logits (B,1,V), caches)."""
    x = embed(cfg, params, token)
    n_sb, n_local, has_global = superblock_layout(cfg)

    def sb_body(x, inputs):
        bp, cache = inputs
        new_cache = {}
        if n_local:
            def loc(xc, args):
                lp, lck, lcv = args
                x, ck, cv = _decode_layer(cfg, lp, xc, lck, lcv, pos,
                                          cfg.sliding_window)
                return x, (ck, cv)
            x, (lk, lv) = jax.lax.scan(
                loc, x, (bp["local"], cache["local"]["k"], cache["local"]["v"]))
            new_cache["local"] = {"k": lk, "v": lv}
        if has_global:
            x, gk, gv = _decode_layer(cfg, bp["global"], x,
                                      cache["global"]["k"], cache["global"]["v"],
                                      pos, None)
            new_cache["global"] = {"k": gk, "v": gv}
        return x, new_cache

    x, new_caches = jax.lax.scan(sb_body, x, (params["blocks"], caches))
    x = norm_apply(cfg, x, params["ln_f"])
    return unembed(cfg, params, x), new_caches


def prefill(cfg, params, tokens, max_len: Optional[int] = None,
            prefix_embeds=None, remat: bool = True):
    """Fill caches for tokens (B,S); returns (last-token logits, caches).

    Runs the blocked forward while capturing each layer's K/V (the cache is
    the product of prefill). Local layers keep only the trailing window.
    """
    x = embed(cfg, params, tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    max_len = max_len or s
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    n_sb, n_local, has_global = superblock_layout(cfg)

    def capture_layer(p, x, window):
        h = norm_apply(cfg, x, p["ln1"])
        q, k, v = _qkv(cfg, p["attn"], h)
        q = cm.apply_rope(q, positions, cfg.rope_theta)
        k = cm.apply_rope(k, positions, cfg.rope_theta)
        out = cm.blocked_attention(q, k, v, causal=True, window=window,
                                   block_q=cfg.attn_block_q,
                                   block_k=cfg.attn_block_k)
        x = x + out.reshape(b, s, -1) @ p["attn"]["wo"]
        x = x + mlp(cfg, p["mlp"], norm_apply(cfg, x, p["ln2"]))
        if window is not None:
            w = min(window, max_len)
            # ring order: slot j holds the latest position p with p % w == j,
            # i.e. p_j = s-1 - ((s-1-j) % w); slots without a position yet
            # (s < w) are zeroed and masked by `length` during decode.
            j = jnp.arange(w)
            p_j = (s - 1) - ((s - 1 - j) % w)
            valid = (p_j >= 0)[None, :, None, None]
            kw = jnp.where(valid, jnp.take(k, jnp.clip(p_j, 0, s - 1), axis=1), 0)
            vw = jnp.where(valid, jnp.take(v, jnp.clip(p_j, 0, s - 1), axis=1), 0)
            return x, kw, vw
        if max_len > s:
            pad = ((0, 0), (0, max_len - s), (0, 0), (0, 0))
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        return x, k, v

    body = jax.remat(capture_layer, static_argnums=(2,)) if remat else capture_layer

    def sb_body(x, bp):
        cache = {}
        if n_local:
            def loc(xc, lp):
                x, kw, vw = body(lp, xc, cfg.sliding_window)
                return x, {"k": kw, "v": vw}
            x, cache["local"] = jax.lax.scan(loc, x, bp["local"])
        if has_global:
            x, gk, gv = body(bp["global"], x, None)
            cache["global"] = {"k": gk, "v": gv}
        return x, cache

    x, caches = jax.lax.scan(sb_body, x, params["blocks"])
    x = norm_apply(cfg, x, params["ln_f"])
    logits = unembed(cfg, params, x[:, -1:])
    return logits, caches
