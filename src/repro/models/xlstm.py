"""xLSTM (arXiv:2405.04517): alternating mLSTM / sLSTM blocks.

Config ``xlstm-350m``: 24 layers, d_model=1024, 4 heads, no FFN (d_ff=0) —
the block-internal up/down projections carry the MLP role.

* mLSTM — matrix-memory LSTM with exponential gating. State per head:
  C (dh x dh), n (dh), m (scalar stabiliser). Implemented as a sequential
  ``lax.scan`` over time (compact HLO; the chunked-parallel/MXU form is the
  §Perf / Pallas follow-up — see DESIGN.md).
* sLSTM — scalar-memory LSTM with recurrent (per-head block-diagonal) weights;
  inherently sequential (the paper's own point), scanned over time.

Decode carries the recurrent state — ``long_500k`` runs natively with O(1)
state per token.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models import transformer as tfm

PROJ_FACTOR = 2   # mLSTM inner width = 2 * d_model


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_mlstm(cfg, key, dtype):
    d = cfg.d_model
    di = PROJ_FACTOR * d
    ks = cm.split(key, 7)
    return {
        "ln": {"scale": jnp.zeros((d,), dtype)},
        "w_up": cm.dense_init(ks[0], d, 2 * di, dtype),    # [inner | z gate]
        "wq": cm.dense_init(ks[1], di, di, dtype),
        "wk": cm.dense_init(ks[2], di, di, dtype),
        "wv": cm.dense_init(ks[3], di, di, dtype),
        "wif": cm.dense_init(ks[4], di, 2 * cfg.n_heads, dtype, scale=0.01),
        "bif": jnp.tile(jnp.asarray([0.0, 3.0], jnp.float32), cfg.n_heads),
        "w_down": cm.dense_init(ks[5], di, d, dtype),
    }


def init_slstm(cfg, key, dtype):
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    ks = cm.split(key, 3)
    return {
        "ln": {"scale": jnp.zeros((d,), dtype)},
        "wg": cm.dense_init(ks[0], d, 4 * d, dtype),       # z,i,f,o gates
        "r": (jax.random.normal(ks[1], (H, dh, 4 * dh), jnp.float32)
              * (1.0 / jnp.sqrt(dh))).astype(dtype),       # recurrent, per head
        "bg": jnp.concatenate([jnp.zeros((2 * d,), jnp.float32),
                               jnp.full((d,), 3.0, jnp.float32),
                               jnp.zeros((d,), jnp.float32)]),
        "w_down": cm.dense_init(ks[2], d, d, dtype),
    }


def init_params(cfg, key):
    dtype = jnp.dtype(cfg.dtype)
    assert cfg.n_layers % 2 == 0
    n_sb = cfg.n_layers // 2
    ks = cm.split(key, 3)
    blocks = {
        "mlstm": jax.vmap(lambda k: init_mlstm(cfg, k, dtype))(cm.split(ks[0], n_sb)),
        "slstm": jax.vmap(lambda k: init_slstm(cfg, k, dtype))(cm.split(ks[1], n_sb)),
    }
    return {
        "emb": cm.embed_init(ks[2], cfg.vocab_padded, cfg.d_model, dtype),
        "blocks": blocks,
        "ln_f": {"scale": jnp.zeros((cfg.d_model,), dtype)},
    }


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def _mlstm_preacts(cfg, p, x):
    b, s, d = x.shape
    H = cfg.n_heads
    di = PROJ_FACTOR * d
    dh = di // H
    h = cm.rms_norm(x, p["ln"]["scale"], cfg.norm_eps)
    up = h @ p["w_up"]
    inner, z = jnp.split(up, 2, axis=-1)
    q = (inner @ p["wq"]).reshape(b, s, H, dh)
    k = (inner @ p["wk"]).reshape(b, s, H, dh) / jnp.sqrt(float(dh)).astype(x.dtype)
    v = (inner @ p["wv"]).reshape(b, s, H, dh)
    gates = (inner @ p["wif"]).astype(jnp.float32) + p["bif"]
    i_pre, f_pre = gates.reshape(b, s, H, 2)[..., 0], gates.reshape(b, s, H, 2)[..., 1]
    return q, k, v, i_pre, f_pre, z


def mlstm_step(state, qkvif):
    """One timestep; state = (C (B,H,dh,dh), n (B,H,dh), m (B,H))."""
    C, n, m = state
    q, k, v, i_pre, f_pre = qkvif
    logf = jax.nn.log_sigmoid(f_pre)                       # (B,H)
    m_new = jnp.maximum(logf + m, i_pre)
    decay = jnp.exp(logf + m - m_new)
    inp = jnp.exp(i_pre - m_new)
    q32, k32, v32 = (a.astype(jnp.float32) for a in (q, k, v))
    C = decay[..., None, None] * C + inp[..., None, None] * (
        v32[..., :, None] * k32[..., None, :])             # v outer k
    n = decay[..., None] * n + inp[..., None] * k32
    num = jnp.einsum("bhij,bhj->bhi", C, q32)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n, q32)),
                      jnp.exp(-m_new))
    h = num / den[..., None]
    return (C, n, m_new), h


def mlstm_block(cfg, p, x, state=None):
    """x (B,S,d) -> (out, final_state). Sequential scan over time."""
    b, s, d = x.shape
    H = cfg.n_heads
    dh = PROJ_FACTOR * d // H
    q, k, v, i_pre, f_pre, z = _mlstm_preacts(cfg, p, x)
    if state is None:
        state = (jnp.zeros((b, H, dh, dh), jnp.float32),
                 jnp.zeros((b, H, dh), jnp.float32),
                 jnp.full((b, H), -1e30, jnp.float32))
    xs = jax.tree.map(lambda a: jnp.moveaxis(a, 1, 0),
                      (q, k, v, i_pre, f_pre))
    state, hs = jax.lax.scan(mlstm_step, state, xs)
    hs = jnp.moveaxis(hs, 0, 1).reshape(b, s, -1)          # (B,S,di)
    out = (hs.astype(x.dtype) * jax.nn.silu(z)) @ p["w_down"]
    return x + out, state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_step_fn(p, H, dh):
    r = p["r"].astype(jnp.float32)

    def step(state, x_gates):
        c, n, m, h_prev = state                            # (B,H,dh) x3, h (B,H,dh)
        rec = jnp.einsum("bhd,hdf->bhf", h_prev, r)        # (B,H,4dh)
        g = x_gates + rec
        z, i_pre, f_pre, o_pre = jnp.split(g, 4, axis=-1)
        logf = jax.nn.log_sigmoid(f_pre)
        m_new = jnp.maximum(logf + m, i_pre)
        decay = jnp.exp(logf + m - m_new)
        inp = jnp.exp(i_pre - m_new)
        c = decay * c + inp * jnp.tanh(z)
        n = decay * n + inp
        h = jax.nn.sigmoid(o_pre) * c / jnp.maximum(n, 1e-6)
        return (c, n, m_new, h), h

    return step


def slstm_block(cfg, p, x, state=None):
    b, s, d = x.shape
    H = cfg.n_heads
    dh = d // H
    hnorm = cm.rms_norm(x, p["ln"]["scale"], cfg.norm_eps)
    gates = (hnorm @ p["wg"]).astype(jnp.float32) + p["bg"]
    gates = gates.reshape(b, s, H, 4 * dh)
    if state is None:
        zero = jnp.zeros((b, H, dh), jnp.float32)
        state = (zero, zero, jnp.full((b, H, dh), -1e30, jnp.float32), zero)
    xs = jnp.moveaxis(gates, 1, 0)
    state, hs = jax.lax.scan(slstm_step_fn(p, H, dh), state, xs)
    hs = jnp.moveaxis(hs, 0, 1).reshape(b, s, d)
    return x + hs.astype(x.dtype) @ p["w_down"], state


# ---------------------------------------------------------------------------
# Forward / serving
# ---------------------------------------------------------------------------

def forward(cfg, params, tokens, prefix_embeds=None, remat: bool = True):
    x = tfm.embed(cfg, params, tokens)

    def sb(x, bp):
        x, _ = mlstm_block(cfg, bp["mlstm"], x)
        x, _ = slstm_block(cfg, bp["slstm"], x)
        return x, None

    body = jax.remat(lambda x, bp: sb(x, bp)) if remat else sb
    x, _ = jax.lax.scan(lambda c, b_: body(c, b_), x, params["blocks"])
    x = cm.rms_norm(x, params["ln_f"]["scale"], cfg.norm_eps)
    return tfm.unembed(cfg, params, x), {}


def init_caches(cfg, batch: int, max_len: int):
    """Recurrent state; max_len irrelevant (O(1) per-token state)."""
    n_sb = cfg.n_layers // 2
    H = cfg.n_heads
    d = cfg.d_model
    dhm = PROJ_FACTOR * d // H
    dhs = d // H
    zero = lambda *shape: jnp.zeros((n_sb,) + shape, jnp.float32)
    return {
        "mlstm": (zero(batch, H, dhm, dhm), zero(batch, H, dhm),
                  jnp.full((n_sb, batch, H), -1e30, jnp.float32)),
        "slstm": (zero(batch, H, dhs), zero(batch, H, dhs),
                  jnp.full((n_sb, batch, H, dhs), -1e30, jnp.float32),
                  zero(batch, H, dhs)),
    }


def decode_step(cfg, params, caches, token, pos, prefix_embeds=None):
    x = tfm.embed(cfg, params, token)      # (B,1,d)

    def sb(x, args):
        bp, ms, ss = args
        x, ms = mlstm_block(cfg, bp["mlstm"], x, state=ms)
        x, ss = slstm_block(cfg, bp["slstm"], x, state=ss)
        return x, (ms, ss)

    x, (ms, ss) = jax.lax.scan(
        sb, x, (params["blocks"], caches["mlstm"], caches["slstm"]))
    x = cm.rms_norm(x, params["ln_f"]["scale"], cfg.norm_eps)
    return tfm.unembed(cfg, params, x), {"mlstm": ms, "slstm": ss}


def prefill(cfg, params, tokens, max_len=None, prefix_embeds=None,
            remat: bool = True):
    """Run the prompt through, returning final state as the 'cache'."""
    x = tfm.embed(cfg, params, tokens)

    def sb(x, bp):
        x, ms = mlstm_block(cfg, bp["mlstm"], x)
        x, ss = slstm_block(cfg, bp["slstm"], x)
        return x, (ms, ss)

    body = jax.remat(sb) if remat else sb
    x, (ms, ss) = jax.lax.scan(lambda c, b_: body(c, b_), x, params["blocks"])
    x = cm.rms_norm(x, params["ln_f"]["scale"], cfg.norm_eps)
    logits = tfm.unembed(cfg, params, x[:, -1:])
    return logits, {"mlstm": ms, "slstm": ss}
