"""Functional JAX model zoo (params = nested dicts; scan-over-layers HLO)."""

from repro.models.registry import build_model

__all__ = ["build_model"]
