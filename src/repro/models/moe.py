"""Mixture-of-Experts decoder (llama4-maverick 128e top-1, kimi-k2 384e top-8).

Expert-parallel design: expert weight tensors (E, d, ff) are sharded over the
`model` mesh axis (E/16 experts per device). Token dispatch is capacity-based
(Switch-style) but *chunked*: tokens are processed in ``cfg.moe_chunks``
sequential chunks with a running per-expert slot counter carried through a
``lax.scan``, so the dispatch one-hot and gather/scatter temporaries stay
O(T/chunks) instead of O(T). Combine gathers per top-k choice (k small,
unrolled) to avoid a (T*k, d) transient.

GSPMD turns the scatter/gather against the expert-sharded buffer into
mask+psum collectives over the model axis — the all-to-all-equivalent traffic
the paper's Table I archs pay; the §Perf log iterates on it.

Layer layout:
  llama4: moe_every=2  -> super-block = (dense layer, moe layer), scanned
  kimi:   first_dense=1 -> 1 unrolled dense layer + scan over moe layers
Both use a shared expert (always-on) added to the routed output.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.models import common as cm
from repro.models import transformer as tfm


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_moe_ffn(cfg, key, dtype):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = cm.split(key, 7)
    p = {
        "router": cm.dense_init(ks[0], d, E, jnp.float32, scale=0.02),
        "we1": jax.vmap(lambda k: cm.dense_init(k, d, ff, dtype))(cm.split(ks[1], E)),
        "we3": jax.vmap(lambda k: cm.dense_init(k, d, ff, dtype))(cm.split(ks[2], E)),
        "we2": jax.vmap(lambda k: cm.dense_init(k, ff, d, dtype))(cm.split(ks[3], E)),
    }
    if cfg.shared_expert:
        p["shared"] = {
            "w1": cm.dense_init(ks[4], d, ff, dtype),
            "w3": cm.dense_init(ks[5], d, ff, dtype),
            "w2": cm.dense_init(ks[6], ff, d, dtype),
        }
    return p


def init_moe_layer(cfg, key, dtype):
    """Attention block + MoE FFN."""
    k1, k2 = cm.split(key, 2)
    p = tfm.init_layer(cfg, k1, dtype)
    del p["mlp"]
    p["moe"] = init_moe_ffn(cfg, k2, dtype)
    return p


def init_params(cfg, key):
    dtype = jnp.dtype(cfg.dtype)
    keys = cm.split(key, 5)
    blocks = {}
    if cfg.moe_every == 2:
        n_sb = (cfg.n_layers - cfg.first_dense) // 2
        blocks["dense"] = jax.vmap(
            lambda k: tfm.init_layer(cfg, k, dtype))(cm.split(keys[0], n_sb))
        blocks["moe"] = jax.vmap(
            lambda k: init_moe_layer(cfg, k, dtype))(cm.split(keys[1], n_sb))
    else:
        n_sb = cfg.n_layers - cfg.first_dense
        blocks["moe"] = jax.vmap(
            lambda k: init_moe_layer(cfg, k, dtype))(cm.split(keys[1], n_sb))
    params = {
        "emb": cm.embed_init(keys[2], cfg.vocab_padded, cfg.d_model, dtype),
        "blocks": blocks,
        "ln_f": {"scale": jnp.zeros((cfg.d_model,), dtype)},
    }
    if cfg.first_dense:
        params["first"] = jax.vmap(
            lambda k: tfm.init_layer(cfg, k, dtype))(cm.split(keys[3], cfg.first_dense))
    if not cfg.tie_embeddings:
        params["lm_head"] = cm.embed_init(keys[4], cfg.vocab_padded, cfg.d_model, dtype)
    return params


# ---------------------------------------------------------------------------
# Routing + dispatch
# ---------------------------------------------------------------------------

def router_topk(cfg, logits):
    """logits (T,E) fp32 -> (idx (T,k), gate (T,k), aux losses dict)."""
    E, k = cfg.n_experts, cfg.top_k
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss + router z-loss
    f = jnp.zeros((E,), jnp.float32)
    f = f.at[idx.reshape(-1)].add(1.0) / (logits.shape[0] * k)
    pmean = probs.mean(0)
    aux = {
        "load_balance": E * jnp.sum(f * pmean),
        "router_z": jnp.mean(jnp.square(jax.nn.logsumexp(logits, -1))),
    }
    return idx, gate.astype(jnp.float32), aux


def moe_ffn_slotmap(cfg, p, h, capacity: Optional[int] = None):
    """Slot-map MoE dispatch/combine (§Perf iteration, the default).

    The onehot_scatter baseline below lets GSPMD partition token-indexed
    scatters/gathers against the expert-sharded buffer, which it lowers to
    full-buffer all-reduces and a per-layer all-gather of the expert weights
    (measured: 94 TiB/step on kimi prefill_32k). This formulation routes via
    a tiny (E, C) *slot map* of token indices instead:

      dispatch: buf[e,c,:] = x[slotmap[e,c]]      gather from the replicated
                activations — each device materialises only its local
                experts' rows: ZERO communication;
      combine:  y.at[slotmap].add(obuf * gate)    scatter-add of expert-
                sharded rows into a replicated (Tc,d) accumulator — GSPMD
                merges the per-device partials with ONE all-reduce of
                (Tc, d) per chunk, the information-theoretic floor for
                replicated-token expert parallelism.

    Capacity semantics (first-come-first-served in flat order, drops beyond
    C) are identical to the baseline, so the two paths agree numerically
    whenever nothing is dropped (pinned by tests/test_moe_impls.py).
    """
    b, s, d = h.shape
    T = b * s
    E, k = cfg.n_experts, cfg.top_k
    x = h.reshape(T, d)
    n_chunks = min(cfg.moe_chunks, T) if T >= cfg.moe_chunks else 1
    while T % n_chunks:
        n_chunks -= 1
    Tc = T // n_chunks
    if capacity is None:
        capacity = max(int(T * k / E * cfg.capacity_factor), 8)

    logits = x.astype(jnp.float32) @ p["router"]
    idx, gate, aux = router_topk(cfg, logits)

    xc = x.reshape(n_chunks, Tc, d)
    idxc = idx.reshape(n_chunks, Tc, k)
    gatec = gate.reshape(n_chunks, Tc, k)
    act = cm.act_fn(cfg.act)
    we1, we2, we3 = p["we1"], p["we2"], p["we3"]

    def chunk_body(counts, inp):
        xi, ei, gi = inp                           # (Tc,d), (Tc,k), (Tc,k)
        # --- routing bookkeeping (tiny tensors, fully replicated) ----------
        oh = jax.nn.one_hot(ei.reshape(-1), E, dtype=jnp.int32)   # (Tc*k, E)
        within = jnp.cumsum(oh, axis=0) - oh
        pos = (within * oh).sum(-1).reshape(Tc, k) + counts[ei]
        keep = pos < capacity
        posc = jnp.clip(pos, 0, capacity - 1)
        flat_tok = jnp.broadcast_to(jnp.arange(Tc)[:, None], (Tc, k))
        # slot map (E, C): token index feeding each expert slot (-1 empty)
        slot_tok = jnp.full((E, capacity), 0, jnp.int32)
        slot_val = jnp.zeros((E, capacity), jnp.float32)
        eflat = ei.reshape(-1)
        kflat = keep.reshape(-1)
        # dropped assignments get an out-of-bounds slot -> mode="drop"
        # discards them (clipping would overwrite the slot's real occupant)
        pflat = jnp.where(kflat, posc.reshape(-1), capacity)
        slot_tok = slot_tok.at[eflat, pflat].set(
            flat_tok.reshape(-1), mode="drop")
        slot_val = slot_val.at[eflat, pflat].set(
            gi.reshape(-1).astype(jnp.float32), mode="drop")
        # --- dispatch: local gather into the expert-sharded buffer ---------
        buf = xi[slot_tok] * (slot_val > 0)[..., None].astype(xi.dtype)
        buf = cm.wsc(buf, "model", None, None)
        # --- expert compute (expert-parallel) -------------------------------
        hbuf = act(jnp.einsum("ecd,edf->ecf", buf, we1)) \
            * jnp.einsum("ecd,edf->ecf", buf, we3)
        hbuf = cm.wsc(hbuf, "model", None, None)
        obuf = jnp.einsum("ecf,efd->ecd", hbuf, we2)
        obuf = cm.wsc(obuf, "model", None, None)
        # --- combine: weighted scatter-add, one psum of (Tc,d) -------------
        # keep the expert axis explicit through the scatter (flattening it
        # gave GSPMD a conflicted [8,2] update sharding -> full-buffer ARs)
        contrib = obuf.astype(jnp.float32) * slot_val[..., None]
        contrib = cm.wsc(contrib, "model", None, None)
        y = jnp.zeros((Tc, d), jnp.float32)
        y = y.at[slot_tok].add(contrib, mode="drop")
        y = cm.wsc(y, None, None)
        counts = counts + oh.sum(0)
        dropped = 1.0 - kflat.mean()
        return counts, (y, dropped)

    counts0 = jnp.zeros((E,), jnp.int32)
    _, (yc, dropc) = jax.lax.scan(chunk_body, counts0, (xc, idxc, gatec))
    out = yc.reshape(b, s, d).astype(h.dtype)

    if cfg.shared_expert:
        sp = p["shared"]
        out = out + (act(x @ sp["w1"]) * (x @ sp["w3"]) @ sp["w2"]
                     ).reshape(b, s, d)
    aux = dict(aux, dropped=dropc.mean())
    return out, aux


def _shardmap_available(cfg):
    mesh = compat.get_abstract_mesh()
    return (not mesh.empty and "model" in mesh.axis_names
            and mesh.shape["model"] > 1
            and cfg.n_experts % mesh.shape["model"] == 0)


def moe_ffn_shardmap(cfg, p, h, capacity: Optional[int] = None):
    """Expert-parallel MoE with *explicit* collectives (§Perf iterations 3-4).

    GSPMD's scatter/gather partitioning of the expert buffer produced
    full-buffer all-reduces even in the slotmap formulation (measured 8.5 TiB
    residual on kimi prefill_32k). This path nests a ``shard_map`` that is
    manual over the ``model`` axis AND over any data-parallel axes that are
    still auto (the pure-pjit serve path — in the train path they are already
    manual in the outer shard_map): routing is *per data shard* (as in
    training), each model shard gathers/computes/combines only its E/16
    experts, and the token outputs are merged with exactly ONE fp32 psum of
    (Tc_local, d) per chunk — the information-theoretic floor for
    replicated-token expert parallelism.
    """
    if not _shardmap_available(cfg):
        return moe_ffn_slotmap(cfg, p, h, capacity)
    from jax.sharding import PartitionSpec as P

    mesh = compat.get_abstract_mesh()
    types = compat.mesh_axis_types(mesh)
    b, s, d = h.shape
    T = b * s
    E, k = cfg.n_experts, cfg.top_k
    n_model = mesh.shape["model"]
    E_loc = E // n_model
    # dp axes still auto (serve path) -> make them manual here, with tokens
    # sharded across them; in the train path they are already Manual.
    dp_auto = tuple(a for a in ("pod", "data")
                    if a in types and str(types[a]).endswith("Auto")
                    and mesh.shape[a] > 1)
    n_dp = 1
    for a in dp_auto:
        n_dp *= mesh.shape[a]
    if T % n_dp or T < n_dp:
        dp_auto, n_dp = (), 1
    T_loc = T // n_dp
    dp_spec = (dp_auto if len(dp_auto) > 1 else dp_auto[0]) if dp_auto else None

    x = h.reshape(T, d)
    # router + aux stay outside (small auto matmul over the model axis)
    logits = x.astype(jnp.float32) @ p["router"]
    idx, gate, aux = router_topk(cfg, logits)

    n_chunks = min(cfg.moe_chunks, T_loc) if T_loc >= cfg.moe_chunks else 1
    while T_loc % n_chunks:
        n_chunks -= 1
    Tc = T_loc // n_chunks
    if capacity is None:
        capacity = max(int(T_loc * k / E * cfg.capacity_factor), 8)
    act = cm.act_fn(cfg.act)

    def experts_inner(w1, w3, w2, stok_all, sval_all, xc):
        """Manual over 'model': stok/sval_all (n_chunks, E_loc, C) arrive
        pre-sharded via in_specs; one fp32 psum of (Tc, d) per chunk."""
        def chunk(_, inp):
            stok, sval, xi = inp
            buf = xi[stok] * (sval > 0)[..., None].astype(xi.dtype)
            hb = act(jnp.einsum("ecd,edf->ecf", buf, w1)) \
                * jnp.einsum("ecd,edf->ecf", buf, w3)
            ob = jnp.einsum("ecf,efd->ecd", hb, w2)
            contrib = ob.astype(jnp.float32) * sval[..., None]
            y = jnp.zeros((Tc, d), jnp.float32)
            y = y.at[stok].add(contrib, mode="drop")
            return None, jax.lax.psum(y, "model")

        _, yc = jax.lax.scan(chunk, None, (stok_all, sval_all, xc))
        return yc

    def routed(xl, il, gl, w1, w3, w2):
        """Per-dp-shard routing + expert compute. xl (T_loc, d)."""
        xc = xl.reshape(n_chunks, Tc, d)
        ic = il.reshape(n_chunks, Tc, k)
        gc = gl.reshape(n_chunks, Tc, k)

        def bookkeep(counts, inp):
            ei, gi = inp
            oh = jax.nn.one_hot(ei.reshape(-1), E, dtype=jnp.int32)
            within = jnp.cumsum(oh, axis=0) - oh
            pos = (within * oh).sum(-1).reshape(Tc, k) + counts[ei]
            keep = pos < capacity
            posc = jnp.clip(pos, 0, capacity - 1)
            flat_tok = jnp.broadcast_to(jnp.arange(Tc)[:, None], (Tc, k))
            eflat = ei.reshape(-1)
            kflat = keep.reshape(-1)
            pflat = jnp.where(kflat, posc.reshape(-1), capacity)
            stok = jnp.zeros((E, capacity), jnp.int32).at[eflat, pflat].set(
                flat_tok.reshape(-1), mode="drop")
            sval = jnp.zeros((E, capacity), jnp.float32).at[eflat, pflat].set(
                gi.reshape(-1).astype(jnp.float32), mode="drop")
            return counts + oh.sum(0), (stok, sval, 1.0 - kflat.mean())

        _, (stok_all, sval_all, dropc) = jax.lax.scan(
            bookkeep, jnp.zeros((E,), jnp.int32), (ic, gc))

        inner = compat.shard_map(
            experts_inner, mesh=compat.get_abstract_mesh(),
            in_specs=(P("model", None, None), P("model", None, None),
                      P("model", None, None), P(None, "model", None),
                      P(None, "model", None), P(None, None, None)),
            out_specs=P(None, None, None),
            axis_names={"model"}, check_vma=False)
        yc = inner(w1, w3, w2, stok_all, sval_all, xc)
        return yc.reshape(T_loc, d), dropc.mean()

    if dp_auto:
        sm = compat.shard_map(
            routed, mesh=mesh,
            in_specs=(P(dp_spec, None), P(dp_spec, None), P(dp_spec, None),
                      P(None, None, None), P(None, None, None),
                      P(None, None, None)),
            out_specs=(P(dp_spec, None), P()),
            axis_names=set(dp_auto), check_vma=False)
        y, dropped = sm(x, idx, gate, p["we1"], p["we3"], p["we2"])
        y = y.reshape(T, d)
    else:
        y, dropped = routed(x, idx, gate, p["we1"], p["we3"], p["we2"])

    out = y.reshape(b, s, d).astype(h.dtype)
    if cfg.shared_expert:
        sp = p["shared"]
        out = out + (act(x @ sp["w1"]) * (x @ sp["w3"]) @ sp["w2"]
                     ).reshape(b, s, d)
    return out, dict(aux, dropped=dropped)


def moe_ffn(cfg, p, h, capacity: Optional[int] = None):
    """h (B,S,d) -> (out (B,S,d), aux). Chunked capacity dispatch."""
    if cfg.moe_impl == "shardmap":
        return moe_ffn_shardmap(cfg, p, h, capacity)
    if cfg.moe_impl == "slotmap":
        return moe_ffn_slotmap(cfg, p, h, capacity)
    b, s, d = h.shape
    T = b * s
    E, k = cfg.n_experts, cfg.top_k
    x = h.reshape(T, d)
    n_chunks = min(cfg.moe_chunks, T) if T >= cfg.moe_chunks else 1
    while T % n_chunks:
        n_chunks -= 1
    Tc = T // n_chunks
    if capacity is None:
        capacity = max(int(T * k / E * cfg.capacity_factor), 8)

    logits = x.astype(jnp.float32) @ p["router"]
    logits = cm.wsc(logits, None, "model")
    idx, gate, aux = router_topk(cfg, logits)

    xc = x.reshape(n_chunks, Tc, d)
    idxc = idx.reshape(n_chunks, Tc, k)
    gatec = gate.reshape(n_chunks, Tc, k)

    # --- dispatch: scan over chunks, carry (per-expert counts, buffer) -----
    def dispatch(carry, inp):
        counts, buf = carry
        xi, ei = inp                                   # (Tc,d), (Tc,k)
        oh = jax.nn.one_hot(ei.reshape(-1), E, dtype=jnp.int32)   # (Tc*k, E)
        within = jnp.cumsum(oh, axis=0) - oh
        pos = (within * oh).sum(-1).reshape(Tc, k) + counts[ei]   # (Tc,k)
        keep = pos < capacity
        posc = jnp.clip(pos, 0, capacity - 1)
        for j in range(k):                             # k small: unrolled
            upd = jnp.where(keep[:, j, None], xi, 0).astype(buf.dtype)
            buf = buf.at[ei[:, j], posc[:, j]].add(upd, mode="drop")
        counts = counts + oh.sum(0)
        return (counts, buf), (posc, keep)

    buf0 = jnp.zeros((E, capacity, d), h.dtype)
    buf0 = cm.wsc(buf0, "model", None, None)
    counts0 = jnp.zeros((E,), jnp.int32)
    (counts, buf), (pos_all, keep_all) = jax.lax.scan(
        dispatch, (counts0, buf0), (xc, idxc))

    # --- expert compute (expert-parallel over the model axis) --------------
    act = cm.act_fn(cfg.act)
    hbuf = act(jnp.einsum("ecd,edf->ecf", buf, p["we1"])) \
        * jnp.einsum("ecd,edf->ecf", buf, p["we3"])
    obuf = jnp.einsum("ecf,efd->ecd", hbuf, p["we2"])
    obuf = cm.wsc(obuf, "model", None, None)

    # --- combine: gather per chunk, weight per choice -----------------------
    def combine(_, inp):
        ei, posi, keepi, gi = inp                      # (Tc,k) each
        y = jnp.zeros((Tc, d), jnp.float32)
        for j in range(k):
            got = obuf[ei[:, j], posi[:, j]].astype(jnp.float32)
            y = y + got * (gi[:, j] * keepi[:, j])[:, None]
        return None, y

    _, yc = jax.lax.scan(combine, None, (idxc, pos_all, keep_all, gatec))
    out = yc.reshape(b, s, d).astype(h.dtype)

    if cfg.shared_expert:
        sp = p["shared"]
        out = out + (act(x @ sp["w1"]) * (x @ sp["w3"]) @ sp["w2"]
                     ).reshape(b, s, d)

    frac_dropped = 1.0 - (keep_all.sum() / (T * k))
    aux = dict(aux, dropped=frac_dropped)
    return out, aux


# ---------------------------------------------------------------------------
# Layers / forward
# ---------------------------------------------------------------------------

def moe_attn_layer(cfg, p, x, positions, capacity=None):
    h = tfm.norm_apply(cfg, x, p["ln1"])
    q, kk, v = tfm._qkv(cfg, p["attn"], h)
    q = cm.apply_rope(q, positions, cfg.rope_theta)
    kk = cm.apply_rope(kk, positions, cfg.rope_theta)
    out = cm.blocked_attention(q, kk, v, causal=True,
                               window=cfg.sliding_window,
                               block_q=cfg.attn_block_q,
                               block_k=cfg.attn_block_k)
    b, s = x.shape[:2]
    x = x + out.reshape(b, s, -1) @ p["attn"]["wo"]
    ffn_in = tfm.norm_apply(cfg, x, p["ln2"])
    y, aux = moe_ffn(cfg, p["moe"], ffn_in, capacity)
    return x + y, aux


def forward(cfg, params, tokens, prefix_embeds=None, remat: bool = True,
            return_hidden: bool = False):
    x = tfm.embed(cfg, params, tokens)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def dense_body(p, x):
        return tfm.attn_layer(cfg, p, x, positions, cfg.sliding_window)

    def moe_body(p, x):
        return moe_attn_layer(cfg, p, x, positions)

    d_body = jax.remat(dense_body) if remat else dense_body
    m_body = jax.remat(moe_body) if remat else moe_body

    if cfg.first_dense:
        def first_scan(x, p):
            return d_body(p, x), None
        x, _ = jax.lax.scan(first_scan, x, params["first"])

    def sb(x, bp):
        if "dense" in bp:
            x = d_body(bp["dense"], x)
        x, aux = m_body(bp["moe"], x)
        return x, aux

    x, auxs = jax.lax.scan(sb, x, params["blocks"])
    x = tfm.norm_apply(cfg, x, params["ln_f"])
    aux = {k: v.mean() for k, v in auxs.items()}
    if return_hidden:
        return x, aux
    return tfm.unembed(cfg, params, x), aux


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_caches(cfg, batch: int, max_len: int):
    dtype = jnp.dtype(cfg.dtype)
    w = min(cfg.sliding_window, max_len) if cfg.sliding_window else max_len
    caches = {}
    if cfg.first_dense:
        caches["first"] = cm.init_kv_cache(cfg.first_dense, batch, w,
                                           cfg.n_kv_heads, cfg.hd, dtype)
    n_sb = (cfg.n_layers - cfg.first_dense) // (2 if cfg.moe_every == 2 else 1)
    per = 2 if cfg.moe_every == 2 else 1
    caches["blocks"] = cm.init_kv_cache(n_sb * per, batch, w,
                                        cfg.n_kv_heads, cfg.hd, dtype)
    caches["blocks"] = jax.tree.map(
        lambda a: a.reshape((n_sb, per) + a.shape[1:]), caches["blocks"])
    return caches


def _decode_one(cfg, p, x, ck, cv, pos, moe: bool):
    h = tfm.norm_apply(cfg, x, p["ln1"])
    q, kk, v = tfm._qkv(cfg, p["attn"], h)
    b = x.shape[0]
    posv = jnp.broadcast_to(pos[None], (b, 1)) if jnp.ndim(pos) == 0 else pos
    q = cm.apply_rope(q, posv, cfg.rope_theta)
    kk = cm.apply_rope(kk, posv, cfg.rope_theta)
    ring = cfg.sliding_window is not None
    ck, cv = cm.cache_update(ck, cv, kk, v, pos, ring=ring)
    length = jnp.minimum(pos + 1, ck.shape[1])
    out = cm.decode_attention(q, ck, cv, length=length)
    x = x + out.reshape(b, 1, -1) @ p["attn"]["wo"]
    h2 = tfm.norm_apply(cfg, x, p["ln2"])
    if moe:
        y, _ = moe_ffn(cfg, p["moe"], h2, capacity=max(x.shape[0], 8))
    else:
        y = tfm.mlp(cfg, p["mlp"], h2)
    return x + y, ck, cv


def prefill(cfg, params, tokens, max_len=None, prefix_embeds=None,
            remat: bool = True):
    """Forward over the prompt capturing per-layer K/V caches."""
    x = tfm.embed(cfg, params, tokens)
    b, s, _ = x.shape
    max_len = max_len or s
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    w = min(cfg.sliding_window, max_len) if cfg.sliding_window else max_len

    def capture(p, x, moe_layer):
        h = tfm.norm_apply(cfg, x, p["ln1"])
        q, kk, v = tfm._qkv(cfg, p["attn"], h)
        q = cm.apply_rope(q, positions, cfg.rope_theta)
        kk = cm.apply_rope(kk, positions, cfg.rope_theta)
        out = cm.blocked_attention(q, kk, v, causal=True,
                                   window=cfg.sliding_window,
                                   block_q=cfg.attn_block_q,
                                   block_k=cfg.attn_block_k)
        x = x + out.reshape(b, s, -1) @ p["attn"]["wo"]
        h2 = tfm.norm_apply(cfg, x, p["ln2"])
        if moe_layer:
            y, _ = moe_ffn(cfg, p["moe"], h2)
        else:
            y = tfm.mlp(cfg, p["mlp"], h2)
        x = x + y
        if cfg.sliding_window:
            j = jnp.arange(w)
            p_j = (s - 1) - ((s - 1 - j) % w)
            valid = (p_j >= 0)[None, :, None, None]
            kk = jnp.where(valid, jnp.take(kk, jnp.clip(p_j, 0, s - 1), axis=1), 0)
            v = jnp.where(valid, jnp.take(v, jnp.clip(p_j, 0, s - 1), axis=1), 0)
        elif max_len > s:
            pad = ((0, 0), (0, max_len - s), (0, 0), (0, 0))
            kk, v = jnp.pad(kk, pad), jnp.pad(v, pad)
        return x, kk, v

    body = jax.remat(capture, static_argnums=(2,)) if remat else capture

    if cfg.first_dense:
        def first(x, p):
            x, kk, v = body(p, x, False)
            return x, {"k": kk, "v": v}
        x, first_cache = jax.lax.scan(first, x, params["first"])

    per = 2 if cfg.moe_every == 2 else 1

    def sb(x, bp):
        ks, vs = [], []
        if per == 2:
            x, kk, v = body(bp["dense"], x, False)
            ks.append(kk)
            vs.append(v)
        x, kk, v = body(bp["moe"], x, True)
        ks.append(kk)
        vs.append(v)
        return x, (jnp.stack(ks), jnp.stack(vs))

    x, (bk, bv) = jax.lax.scan(sb, x, params["blocks"])
    caches = {"blocks": {"k": bk, "v": bv}}
    if cfg.first_dense:
        caches["first"] = first_cache
    x = tfm.norm_apply(cfg, x, params["ln_f"])
    return tfm.unembed(cfg, params, x[:, -1:]), caches


def decode_step(cfg, params, caches, token, pos, prefix_embeds=None):
    x = tfm.embed(cfg, params, token)
    per = 2 if cfg.moe_every == 2 else 1

    if cfg.first_dense:
        def first(xc, args):
            p, ck, cv = args
            x, ck, cv = _decode_one(cfg, p, xc, ck, cv, pos, moe=False)
            return x, (ck, cv)
        x, (fk, fv) = jax.lax.scan(
            first, x, (params["first"], caches["first"]["k"],
                       caches["first"]["v"]))
        new_first = {"k": fk, "v": fv}

    def sb(xc, args):
        bp, ck, cv = args                              # ck (per,B,S,KH,hd)
        i = 0
        if per == 2:
            xc, k0, v0 = _decode_one(cfg, bp["dense"], xc, ck[0], cv[0], pos,
                                     moe=False)
            i = 1
        xc, k1, v1 = _decode_one(cfg, bp["moe"], xc, ck[i], cv[i], pos,
                                 moe=True)
        nk = jnp.stack([k0, k1]) if per == 2 else k1[None]
        nv = jnp.stack([v0, v1]) if per == 2 else v1[None]
        return xc, (nk, nv)

    x, (bk, bv) = jax.lax.scan(
        sb, x, (params["blocks"], caches["blocks"]["k"], caches["blocks"]["v"]))
    new_caches = {"blocks": {"k": bk, "v": bv}}
    if cfg.first_dense:
        new_caches["first"] = new_first
    x = tfm.norm_apply(cfg, x, params["ln_f"])
    return tfm.unembed(cfg, params, x), new_caches
