"""WAGMA-SGD (paper Algorithm 2) — distributed averager + configuration.

The training step (train/train_step.py) is, per data-parallel replica:

    G     = grad(loss)(W, local_batch)          # local gradients, NO dp psum
    W'    = W + U(G)                            # local optimiser step
    if (t+1) % tau != 0:
        W <- plan.average(W', phase(t))         # wait-avoiding group allreduce
    else:
        W <- plan.sync(W')                      # synchronous allreduce (line 16)

The dynamic group pattern of iteration t is static per compiled step variant
(XLA collectives need static permutations); ``WagmaAverager`` exposes
``n_phases`` variants and the host loop picks ``phase_for_step(t)``.

As of the plan redesign (DESIGN.md §9) the averager is a thin host-side
wrapper around a compiled :class:`~repro.core.plan.AveragingPlan`: it owns
the phase/sync bookkeeping, and delegates every collective to the plan the
:class:`~repro.core.plan.Topology` compiles to for the current tree
structure.  Pass ``topology=Topology.hierarchical(...)`` for pod-aware
ICI/DCN grouping with per-link-class bucket budgets; the default flat
topology reproduces the legacy single-budget behaviour.

``WagmaConfig`` is an alias of :class:`plan.AveragingConfig` — the old
kwarg names (``fused``/``bucket_bytes``/``use_pallas``/``overlap``) are
now plan-compilation inputs rather than per-call arguments.
"""

from __future__ import annotations

from typing import Optional, Sequence

from repro.core import group_allreduce, grouping
from repro.core import plan as plan_mod
from repro.core.replica import REPLICATED, ShardingPolicy

# Backwards-compatible alias: WagmaConfig(group_size=..., tau=..., fused=...)
# is the plan's compilation config.
WagmaConfig = plan_mod.AveragingConfig


class WagmaAverager:
    """The paper's contribution as a composable averaging strategy."""

    name = "wagma"
    grad_comm = False   # averages *models*, not gradients

    def __init__(self, dp_axis_names: Sequence[str], dp_axis_sizes: Sequence[int],
                 cfg: WagmaConfig = WagmaConfig(),
                 topology: Optional[plan_mod.Topology] = None,
                 sharding: ShardingPolicy = REPLICATED):
        # minor-to-major layout (see group_allreduce.dp_axis_layout)
        self.axis_names = tuple(dp_axis_names)
        self.axis_sizes = tuple(int(s) for s in dp_axis_sizes)
        if topology is None:
            topology = plan_mod.Topology.flat(self.axis_names, self.axis_sizes)
        if (topology.axis_names != self.axis_names
                or topology.axis_sizes != self.axis_sizes):
            raise ValueError(
                f"topology axes {topology.axis_names}/{topology.axis_sizes} "
                f"do not match dp axes {self.axis_names}/{self.axis_sizes}")
        self.topology = topology
        self.sharding = sharding
        self.P = topology.P
        # Under fsdp_within_pod the shard axis's ranks share weights and
        # act as one logical WAGMA worker: grouping runs over the
        # effective (pod-level) replica space (DESIGN.md §10).
        if sharding.is_sharded:
            self.P_eff = topology.drop_axis(sharding.shard_axis).P
        else:
            self.P_eff = self.P
        self.S = cfg.group_size or grouping.default_group_size(self.P_eff)
        if self.S > self.P_eff:
            raise ValueError(f"group size {self.S} exceeds replica world "
                             f"{self.P_eff}")
        self.cfg = cfg
        if cfg.dynamic_groups:
            self.offsets = grouping.distinct_offsets(self.P_eff, self.S)
        else:
            self.offsets = (0,)   # ablation 2: fixed groups

    # -- compiled-variant bookkeeping -------------------------------------
    @property
    def n_phases(self) -> int:
        return len(self.offsets)

    def phase_for_step(self, t: int) -> int:
        if not self.cfg.dynamic_groups:
            return 0
        return self.offsets.index(
            grouping.phase_offset(self.P_eff, self.S, t))

    def sync_due(self, t: int) -> bool:
        return (t + 1) % self.cfg.tau == 0

    # -- the compiled plan --------------------------------------------------
    def plan_for(self, tree) -> plan_mod.AveragingPlan:
        """The compiled plan for this tree structure (cached by compile).

        Under ``fsdp_within_pod``, ``tree`` may be either the FULL local
        tree (first compile, at state-init time) or the plan's own
        shard-buffer tuple (inside the train step) — ``compile_plan``
        resolves the latter through its shard-structure registry.
        """
        return plan_mod.compile_plan(self.topology, tree, self.cfg,
                                     self.sharding)

    # -- collective bodies (call inside shard_map, manual over dp axes) ---
    def comm(self, tree, phase: int):
        """Wait-avoiding group model averaging (Alg. 2 line 9 + 11)."""
        return self.plan_for(tree).average(tree, phase)

    def sync(self, tree):
        """Synchronous global allreduce (Alg. 2 line 16)."""
        return self.plan_for(tree).sync(tree)

    # -- analysis ----------------------------------------------------------
    def comm_bytes_per_step(self, payload_bytes: int) -> float:
        """Average per-device collective bytes/step incl. the tau-sync."""
        tau = self.cfg.tau
        group = group_allreduce.collective_bytes_per_device(
            payload_bytes, self.P, self.S, "wagma")
        # tau-sync modelled as bandwidth-optimal global ring allreduce
        sync = group_allreduce.collective_bytes_per_device(
            payload_bytes, self.P, self.S, "ring_allreduce")
        return ((tau - 1) * group + sync) / tau

    def comm_time_per_step(self, payload_bytes: int, *, n_buckets: int = 1,
                           alpha: float = group_allreduce.DEFAULT_ALPHA,
                           beta: float = group_allreduce.DEFAULT_BETA,
                           gamma: float = 0.0,
                           overlap: Optional[bool] = None) -> float:
        """Average per-device alpha-beta collective seconds/step.

        Single-link-class model (legacy); for the hierarchical per-class
        composition use ``plan_for(tree).modeled_step_seconds()`` or
        ``plan.modeled_wagma_step_seconds`` with this averager's topology.
        """
        return group_allreduce.wagma_step_time(
            payload_bytes, self.P, self.S, tau=self.cfg.tau,
            n_buckets=n_buckets, alpha=alpha, beta=beta, gamma=gamma,
            overlap=self.cfg.overlap if overlap is None else overlap)
