"""WAGMA-SGD (paper Algorithm 2) — distributed averager + configuration.

The training step (train/train_step.py) is, per data-parallel replica:

    G     = grad(loss)(W, local_batch)          # local gradients, NO dp psum
    W'    = W + U(G)                            # local optimiser step
    if (t+1) % tau != 0:
        W <- group_average(W', groups(t))       # wait-avoiding group allreduce
    else:
        W <- global_average(W')                 # synchronous allreduce (line 16)

The dynamic group pattern of iteration t is static per compiled step variant
(XLA collectives need static permutations); ``WagmaAverager`` exposes
``n_phases`` variants and the host loop picks ``phase_for_step(t)``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.core import bucketing, group_allreduce, grouping


@dataclass(frozen=True)
class WagmaConfig:
    group_size: Optional[int] = None      # None -> sqrt(P) rounded to pow2 (paper)
    tau: int = 10                         # global sync period (paper §V-B)
    average_dtype: Optional[str] = "float32"   # accumulation dtype for averaging
    dynamic_groups: bool = True           # False -> fixed groups (paper ablation 2)
    fused: bool = True                    # bucketed flat-buffer averaging path
    bucket_bytes: Optional[int] = None    # None -> modeled-optimal budget
    use_pallas: Optional[bool] = None     # None -> Pallas combine when fused
    overlap: bool = True                  # wavefront bucket pipeline (DESIGN §8)


class WagmaAverager:
    """The paper's contribution as a composable averaging strategy."""

    name = "wagma"
    grad_comm = False   # averages *models*, not gradients

    def __init__(self, dp_axis_names: Sequence[str], dp_axis_sizes: Sequence[int],
                 cfg: WagmaConfig = WagmaConfig()):
        # minor-to-major layout (see group_allreduce.dp_axis_layout)
        self.axis_names = tuple(dp_axis_names)
        self.axis_sizes = tuple(dp_axis_sizes)
        self.P = 1
        for s in self.axis_sizes:
            self.P *= s
        self.S = cfg.group_size or grouping.default_group_size(self.P)
        if self.S > self.P:
            raise ValueError(f"group size {self.S} exceeds dp world {self.P}")
        self.cfg = cfg
        if cfg.dynamic_groups:
            self.offsets: Tuple[int, ...] = grouping.distinct_offsets(self.P, self.S)
        else:
            self.offsets = (0,)   # ablation 2: fixed groups

    # -- compiled-variant bookkeeping -------------------------------------
    @property
    def n_phases(self) -> int:
        return len(self.offsets)

    def phase_for_step(self, t: int) -> int:
        if not self.cfg.dynamic_groups:
            return 0
        return self.offsets.index(grouping.phase_offset(self.P, self.S, t))

    def sync_due(self, t: int) -> bool:
        return (t + 1) % self.cfg.tau == 0

    # -- collective bodies (call inside shard_map, manual over dp axes) ---
    def comm(self, tree, phase: int):
        """Wait-avoiding group model averaging (Alg. 2 line 9 + 11)."""
        dtype = jnp.dtype(self.cfg.average_dtype) if self.cfg.average_dtype else None
        return group_allreduce.group_average(
            tree, offset=self.offsets[phase], P=self.P, S=self.S,
            axis_names=self.axis_names, axis_sizes=self.axis_sizes,
            average_dtype=dtype, fused=self.cfg.fused,
            bucket_bytes=self.cfg.bucket_bytes,
            use_pallas=self.cfg.use_pallas,
            overlap=self.cfg.overlap, tau=self.cfg.tau)

    def sync(self, tree):
        """Synchronous global allreduce (Alg. 2 line 16)."""
        return group_allreduce.global_average(
            tree, self.axis_names, fused=self.cfg.fused,
            bucket_bytes=self.cfg.bucket_bytes)

    # -- analysis ----------------------------------------------------------
    def comm_bytes_per_step(self, payload_bytes: int) -> float:
        """Average per-device collective bytes/step incl. the tau-sync."""
        tau = self.cfg.tau
        group = group_allreduce.collective_bytes_per_device(
            payload_bytes, self.P, self.S, "wagma")
        # tau-sync modelled as bandwidth-optimal global ring allreduce
        sync = group_allreduce.collective_bytes_per_device(
            payload_bytes, self.P, self.S, "ring_allreduce")
        return ((tau - 1) * group + sync) / tau

    def comm_time_per_step(self, payload_bytes: int, *, n_buckets: int = 1,
                           alpha: float = group_allreduce.DEFAULT_ALPHA,
                           beta: float = group_allreduce.DEFAULT_BETA,
                           gamma: float = 0.0,
                           overlap: Optional[bool] = None) -> float:
        """Average per-device alpha-beta collective seconds/step.

        ``n_buckets`` is the launch count per stage: the bucketed fused path
        uses the layout's bucket count; pass the leaf count to model the
        per-leaf path (the bucketing win is this ratio in the alpha term).
        ``gamma`` adds the per-stage combine cost; ``overlap`` (default: the
        config's setting) hides it behind the wire per DESIGN.md §8.
        """
        return group_allreduce.wagma_step_time(
            payload_bytes, self.P, self.S, tau=self.cfg.tau,
            n_buckets=n_buckets, alpha=alpha, beta=beta, gamma=gamma,
            overlap=self.cfg.overlap if overlap is None else overlap)
