"""Replica state & sharding policy — the object every layer operates on.

DESIGN.md §10.  WAGMA needs *divergent* per-replica weights, which until
this module meant every dp replica held a full copy of params + optimiser
state (the §2 memory tension: a fully-sharded replica cannot locally
average with a partner holding different shards).  The resolution pairs
the hierarchical Topology (§9) with the Layered-SGD worker structure:

* ``ShardingPolicy.replicated()`` — the legacy layout.  Params/opt carry a
  leading dp-replica axis of size P_dp; every device holds a full copy.
* ``ShardingPolicy.fsdp_within_pod(shard_axis)`` — replicas inside a pod
  *share* weights and shard them ZeRO/FSDP-style over the intra-pod
  (ICI) mesh axis ``shard_axis``: between averaging steps each device
  holds only its 1/pod_size slice of every flat bucket (param + opt
  memory ÷ pod size), the forward/backward all-gathers parameters per
  bucket on ICI, gradients reduce-scatter back (pod members form ONE
  logical WAGMA worker whose gradient is the pod mean), the optimiser
  updates only the owned shard, and group averaging runs pod-to-pod on
  the shard slices directly (DCN traffic also ÷ pod size).

:class:`ReplicaState` is THE pytree the train step, averager,
checkpointing, and cost model operate on: ``params``, ``opt_state``, and
the averager ``step``/``phase`` bookkeeping, in whichever layout the
policy dictates.  Under ``replicated`` the params are the familiar
(P_dp, ...)-stacked leaf tree; under ``fsdp_within_pod`` they are a tuple
of (P_pods, bucket_elems) flat shard buckets laid out by the compiled
plan's shard-aligned :class:`~repro.core.bucketing.BucketLayout` (every
bucket is padded to pod_size x 128 elements so each device owns an equal,
lane-aligned contiguous slice).

Because the per-element arithmetic of every collective is unchanged (the
butterfly exchanges each shard slice with the same slice in the partner
pod), the sharded execution stays bit-identical to the replicated
reference and the stacked simulator on every phase offset — pinned by
tests/test_replica.py.

Host-side conversion helpers translate whole states between policies
(checkpoint portability: save from a sharded run, restore into a
replicated run and vice versa) and consolidate either layout into the
single post-training consensus model.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bucketing

REPLICATED_KIND = "replicated"
FSDP_KIND = "fsdp_within_pod"


@dataclass(frozen=True)
class ShardingPolicy:
    """Frozen description of how divergent replicas lay out their state.

    ``kind`` is ``"replicated"`` or ``"fsdp_within_pod"``; for the latter,
    ``shard_axis`` names the dp mesh axis parameters shard over (must be an
    intra-pod/ICI axis of the plan's Topology — validated at compile time).
    Part of the plan-compilation cache key, so a plan owns exactly one
    sharded execution realisation.

    ``streamed`` (fsdp only, DESIGN.md §11) selects the layer-streamed
    state layout: the shard buckets are laid out over the model's
    *layered* param tree (``{"stem", "layers", "head"}`` — see
    ``models/common.LayeredModel``) with a layer-aware
    :class:`~repro.core.bucketing.BucketLayout`, so the train step can
    all-gather one layer span's buckets while the previous span computes
    instead of gathering the whole tree up front.
    """
    kind: str = REPLICATED_KIND
    shard_axis: Optional[str] = None
    streamed: bool = False

    def __post_init__(self):
        if self.kind not in (REPLICATED_KIND, FSDP_KIND):
            raise ValueError(f"unknown sharding kind {self.kind!r}")
        if self.kind == FSDP_KIND and not self.shard_axis:
            raise ValueError("fsdp_within_pod needs a shard_axis")
        if self.kind == REPLICATED_KIND and self.shard_axis is not None:
            raise ValueError("replicated policy takes no shard_axis")
        if self.streamed and self.kind != FSDP_KIND:
            raise ValueError("streamed layout requires fsdp_within_pod")

    @classmethod
    def replicated(cls) -> "ShardingPolicy":
        return cls(REPLICATED_KIND)

    @classmethod
    def fsdp_within_pod(cls, shard_axis: str,
                        streamed: bool = False) -> "ShardingPolicy":
        return cls(FSDP_KIND, shard_axis, streamed)

    @property
    def is_sharded(self) -> bool:
        return self.kind == FSDP_KIND

    def describe(self) -> str:
        if self.is_sharded:
            return (f"fsdp_within_pod(shard_axis={self.shard_axis!r}"
                    + (", streamed" if self.streamed else "") + ")")
        return "replicated"


REPLICATED = ShardingPolicy.replicated()


# ---------------------------------------------------------------------------
# ReplicaState pytree
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclass
class ReplicaState:
    """Params + optimiser state + averager step/phase bookkeeping.

    A plain pytree (all four fields are dynamic leaves/subtrees), so it
    jits, donates, shards, and checkpoints as one object.  ``step`` is the
    global training step (int32 scalar, incremented by the train step);
    ``phase`` records the butterfly phase index the last group-averaging
    step executed (-1 before any averaging / after a sync) so a restored
    run can verify its compiled-variant dispatch against the checkpoint.
    """
    params: object
    opt_state: object
    step: jax.Array
    phase: jax.Array

    def tree_flatten(self):
        return (self.params, self.opt_state, self.step, self.phase), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @classmethod
    def create(cls, params, opt_state, *, step: int = 0,
               phase: int = -1) -> "ReplicaState":
        return cls(params, opt_state, jnp.asarray(step, jnp.int32),
                   jnp.asarray(phase, jnp.int32))


# ---------------------------------------------------------------------------
# Host-side layout conversion (checkpoint portability, consolidation)
# ---------------------------------------------------------------------------

def effective_rank_map(axis_sizes: Tuple[int, ...],
                       shard_axis_index: int) -> np.ndarray:
    """``eff_of_rank[dp_rank] -> logical (pod) replica index``.

    ``axis_sizes`` is minor-to-major (``dp_axis_layout`` order).  Dropping
    the shard axis's coordinate from a dp rank's mixed-radix decomposition
    yields the rank in the effective (pod-level) replica space, with the
    remaining axes keeping their minor-to-major order.
    """
    sizes = [int(s) for s in axis_sizes]
    P = int(np.prod(sizes))
    eff = np.zeros((P,), np.int64)
    for rank in range(P):
        rem, coords = rank, []
        for s in sizes:
            coords.append(rem % s)
            rem //= s
        stride, e = 1, 0
        for ax, (s, c) in enumerate(zip(sizes, coords)):
            if ax == shard_axis_index:
                continue
            e += c * stride
            stride *= s
        eff[rank] = e
    return eff


def _pack_rows(stacked_tree, layout, n_rows: int, dtype=None) -> tuple:
    """(R, ...)-stacked leaves -> tuple of (R, bucket_elems) buffers."""
    host = jax.tree.map(np.asarray, stacked_tree)    # one transfer, not R
    rows = []
    for r in range(n_rows):
        row_tree = jax.tree.map(lambda a: a[r], host)
        rows.append(bucketing.pack(row_tree, layout, dtype=dtype))
    return tuple(jnp.stack([np.asarray(rows[r][b]) for r in range(n_rows)])
                 for b in range(layout.n_buckets))


def _unpack_rows(buffers, layout, cast: bool = True) -> object:
    """Tuple of (R, bucket_elems) buffers -> (R, ...)-stacked leaves."""
    host = [np.asarray(b) for b in buffers]          # one transfer, not R
    n_rows = int(host[0].shape[0]) if host else 0
    trees = [bucketing.unpack(tuple(b[r] for b in host), layout, cast=cast)
             for r in range(n_rows)]
    return jax.tree.map(lambda *ls: jnp.stack([np.asarray(l) for l in ls]),
                        *trees)


def map_opt_state(opt_state, fn_tree, fn_count):
    """Apply a params-structure conversion to an optimiser state.

    Optimiser states in this repo are NamedTuples whose fields are either
    params-structured moment trees (``momentum``/``mu``/``nu``) or the
    per-replica ``count`` vector; the conversion maps each accordingly.
    """
    if not hasattr(opt_state, "_fields"):
        raise TypeError(f"unsupported optimiser state {type(opt_state)}")
    vals = {f: (fn_count(getattr(opt_state, f)) if f == "count"
                else fn_tree(getattr(opt_state, f)))
            for f in opt_state._fields}
    return type(opt_state)(**vals)


def sharded_to_replicated_tree(buffers, plan, *, cast: bool = True):
    """FSDP bucket buffers (P_pods, bucket) -> (P_dp, ...)-stacked leaves.

    Every pod's model is broadcast to all its members (members of a pod
    share weights by construction), so the result is a valid replicated
    state on the same mesh.
    """
    pod_tree = _unpack_rows(buffers, plan.shard_layout, cast=cast)
    eff = effective_rank_map(plan.topology.axis_sizes, plan.shard_axis_index)
    return jax.tree.map(lambda a: jnp.asarray(np.asarray(a)[eff]), pod_tree)


def replicated_to_sharded_tree(stacked_tree, plan, *, dtype=None):
    """(P_dp, ...)-stacked leaves -> FSDP bucket buffers (P_pods, bucket).

    Pod members are averaged in fp32 (for a checkpoint written by a
    replicated run mid-divergence this is the pod-consensus projection;
    when members are identical — e.g. right after a sync or an FSDP->
    replicated conversion — the mean is exact and the round trip is
    lossless).
    """
    eff = effective_rank_map(plan.topology.axis_sizes, plan.shard_axis_index)
    n_eff = plan.P_eff

    def pod_mean(a):
        a = np.asarray(a)
        out = []
        for e in range(n_eff):
            members = a[eff == e].astype(np.float32)
            out.append(members.mean(axis=0).astype(a.dtype))
        return jnp.asarray(np.stack(out))

    pod_tree = jax.tree.map(pod_mean, stacked_tree)
    return _pack_rows(pod_tree, plan.shard_layout, n_eff, dtype=dtype)


def fsdp_to_replicated_state(state: ReplicaState, plan) -> ReplicaState:
    """Convert a whole FSDP ReplicaState into the replicated layout."""
    eff = effective_rank_map(plan.topology.axis_sizes, plan.shard_axis_index)
    params = sharded_to_replicated_tree(state.params, plan)
    opt = map_opt_state(
        state.opt_state,
        lambda t: sharded_to_replicated_tree(t, plan, cast=False),
        lambda c: jnp.asarray(np.asarray(c)[eff]))
    return ReplicaState(params, opt, state.step, state.phase)


def replicated_to_fsdp_state(state: ReplicaState, plan) -> ReplicaState:
    """Convert a whole replicated ReplicaState into the FSDP layout."""
    eff = effective_rank_map(plan.topology.axis_sizes, plan.shard_axis_index)
    first_member = np.asarray(
        [int(np.nonzero(eff == e)[0][0]) for e in range(plan.P_eff)])
    params = replicated_to_sharded_tree(state.params, plan)
    opt = map_opt_state(
        state.opt_state,
        lambda t: replicated_to_sharded_tree(t, plan, dtype=jnp.float32),
        lambda c: jnp.asarray(np.asarray(c)[first_member]))
    return ReplicaState(params, opt, state.step, state.phase)


def merge_layered_state(state: ReplicaState, layered) -> ReplicaState:
    """Replicated state in stacked-LAYERED structure -> canonical structure.

    A streamed-fsdp checkpoint converts to the replicated layout in the
    layered tree ``{"stem", "layers", "head"}`` (the streamed plan's
    storage structure); ``layered`` (the model's
    :class:`~repro.models.common.LayeredModel`) merges each replica row
    back into the canonical stacked tree — pure restructuring, bit-exact.
    """
    merge_rows = jax.vmap(layered.merge)
    return ReplicaState(merge_rows(state.params),
                        map_opt_state(state.opt_state, merge_rows,
                                      lambda c: c),
                        state.step, state.phase)


def split_layered_state(state: ReplicaState, layered) -> ReplicaState:
    """Canonical-structure replicated state -> stacked-LAYERED structure."""
    split_rows = jax.vmap(layered.split)
    return ReplicaState(split_rows(state.params),
                        map_opt_state(state.opt_state, split_rows,
                                      lambda c: c),
                        state.step, state.phase)


def canonical_replicated_template(layered_template: ReplicaState,
                                  layered) -> ReplicaState:
    """Abstract canonical-stacked twin of a layered-stacked template.

    ``replicated_state_template`` of a *streamed* plan produces the
    layered structure (the plan's storage struct); replicated runs save
    and restore the canonical tree, so cross-policy restore derives the
    canonical template by shape-evaluating the row-wise merge.
    """
    merge_rows = lambda t: jax.eval_shape(jax.vmap(layered.merge), t)
    return ReplicaState(merge_rows(layered_template.params),
                        map_opt_state(layered_template.opt_state,
                                      merge_rows, lambda c: c),
                        layered_template.step, layered_template.phase)


def sharded_state_template(plan, opt_state_like) -> ReplicaState:
    """Abstract ReplicaState in the FSDP layout of ``plan``.

    ``opt_state_like`` supplies the optimiser state *type* (any state of
    the same optimiser, either layout); only shapes/dtypes are produced —
    used as the rebuild template for cross-policy checkpoint restore.
    """
    lay = plan.shard_layout
    n = plan.P_eff
    params = tuple(jax.ShapeDtypeStruct((n, s), d)
                   for s, d in zip(lay.bucket_sizes, lay.bucket_dtypes))
    moments = tuple(jax.ShapeDtypeStruct((n, s), np.dtype(np.float32))
                    for s in lay.bucket_sizes)
    opt = map_opt_state(
        opt_state_like, lambda _: moments,
        lambda c: jax.ShapeDtypeStruct((n,), np.dtype(c.dtype)))
    return ReplicaState(params, opt,
                        jax.ShapeDtypeStruct((), np.dtype(np.int32)),
                        jax.ShapeDtypeStruct((), np.dtype(np.int32)))


def replicated_state_template(plan, opt_state_like) -> ReplicaState:
    """Abstract ReplicaState in the replicated (P_dp, ...)-stacked layout."""
    n = plan.P
    params = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n,) + tuple(s.shape), s.dtype),
        plan.storage_struct)
    moments = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n,) + tuple(s.shape),
                                       np.dtype(np.float32)),
        plan.storage_struct)
    opt = map_opt_state(
        opt_state_like, lambda _: moments,
        lambda c: jax.ShapeDtypeStruct((n,), np.dtype(c.dtype)))
    return ReplicaState(params, opt,
                        jax.ShapeDtypeStruct((), np.dtype(np.int32)),
                        jax.ShapeDtypeStruct((), np.dtype(np.int32)))


def consolidate_state(state: ReplicaState, plan=None):
    """Average the replica axis -> the single post-training consensus model.

    Replicated states need no plan; FSDP states unpack through the plan's
    shard layout after averaging the pod axis.
    """
    from repro.checkpoint.ckpt import consolidate
    if plan is not None and plan.sharding.is_sharded and \
            isinstance(state.params, tuple):
        mean_bufs = tuple(
            jnp.mean(jnp.asarray(b, jnp.float32), axis=0).astype(b.dtype)
            for b in state.params)
        return bucketing.unpack(mean_bufs, plan.shard_layout)
    if isinstance(state.params, tuple):
        raise ValueError(
            "consolidate_state got an FSDP (shard-buffer) state but no "
            "sharded plan to unpack it through; pass the compiled plan")
    return consolidate(state.params)
