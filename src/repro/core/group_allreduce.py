"""Wait-avoiding group allreduce — TPU-native realisation.

The paper implements group allreduce as activation messages + a butterfly
(recursive-doubling) exchange inside each group, on MPI.  Under XLA the same
exchange is ``log2(S)`` stages of ``jax.lax.ppermute`` with XOR-partner
permutations, executed inside a ``jax.shard_map`` that is *manual* over the
data-parallel mesh axes and *auto* (GSPMD) over the model axis.  Each stage
combines the local shard with the partner's:

    for bit in mask_bits(P, S, t):  w = (w + ppermute(w, bit)) ;  w /= S

The XOR bit decides which mesh axis carries the exchange: low bits permute
within the ``data`` axis (intra-pod ICI), high bits within the ``pod`` axis
(inter-pod links) — the topology-awareness the paper gets from its butterfly.

Because XLA permutations are static, functions here take a *static* phase
offset; the training loop cycles through ``grouping.distinct_offsets`` and
dispatches the matching compiled step (see train/train_step.py).

Two more entry points ship alongside:

* ``global_average``        — the tau-periodic synchronous allreduce (psum).
* ``group_average_stacked`` — single-process simulator on stacked (P, ...)
  pytrees via the doubly-stochastic averaging matrix; shares the group math
  with the distributed path and is used by tests and convergence benchmarks.
"""

from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import grouping


# ---------------------------------------------------------------------------
# Distributed path (call inside shard_map; manual over dp axes)
# ---------------------------------------------------------------------------

def dp_axis_layout(mesh_axis_names: Sequence[str], mesh_shape: dict,
                   dp_axes: Sequence[str]) -> Tuple[Tuple[str, ...], Tuple[int, ...]]:
    """Minor-to-major dp axis names/sizes for global dp-rank bit mapping.

    JAX mesh axes are major-to-minor left-to-right, so e.g. mesh
    ('pod', 'data', 'model') with dp_axes ('pod', 'data') gives layout
    names=('data', 'pod'), sizes=(16, 2): global dp rank = pod*16 + data.
    """
    ordered = [a for a in mesh_axis_names if a in dp_axes]
    names = tuple(reversed(ordered))
    sizes = tuple(mesh_shape[a] for a in names)
    return names, sizes


def _xor_perm(n: int, mask: int):
    return [(i, i ^ mask) for i in range(n)]


def butterfly_exchange(x: jax.Array, bit: int, axis_names: Sequence[str],
                       axis_sizes: Sequence[int]) -> jax.Array:
    """One butterfly stage: return the XOR-partner's value for global dp bit."""
    ax, local_bit = grouping.split_bit_over_axes(bit, axis_sizes)
    perm = _xor_perm(axis_sizes[ax], 1 << local_bit)
    return jax.lax.ppermute(x, axis_names[ax], perm)


def group_average(tree, *, offset: int, P: int, S: int,
                  axis_names: Sequence[str], axis_sizes: Sequence[int],
                  average_dtype=None):
    """Group model averaging over groups of size S (paper Alg. 2 line 9+11).

    Must be called inside shard_map manual over ``axis_names``. Applies
    log2(S) ppermute+add stages and divides by S; every worker ends with the
    mean of the S models in its (dynamically selected) group.
    """
    bits = grouping.mask_bits_for_offset(P, S, offset)
    inv_s = 1.0 / S

    def avg_leaf(w):
        orig_dtype = w.dtype
        acc = w.astype(average_dtype) if average_dtype is not None else w
        for bit in bits:
            acc = acc + butterfly_exchange(acc, bit, axis_names, axis_sizes)
        acc = acc * jnp.asarray(inv_s, acc.dtype)
        return acc.astype(orig_dtype)

    return jax.tree.map(avg_leaf, tree)


def global_average(tree, axis_names: Sequence[str]):
    """tau-periodic synchronous allreduce mean over all dp replicas (line 16)."""
    names = tuple(axis_names)

    def avg_leaf(w):
        return jax.lax.pmean(w.astype(jnp.float32), names).astype(w.dtype)

    return jax.tree.map(avg_leaf, tree)


# ---------------------------------------------------------------------------
# Stacked simulator path (single process, leading replica axis)
# ---------------------------------------------------------------------------

def averaging_matrix(P: int, S: int, t: int) -> np.ndarray:
    A = np.asarray(grouping.averaging_matrix(P, S, t), dtype=np.float32)
    return A


def group_average_stacked(stacked_tree, *, P: int, S: int, t: int):
    """Simulator: W[i] <- mean over i's group, on (P, ...) stacked pytrees."""
    A = jnp.asarray(averaging_matrix(P, S, t))

    def avg_leaf(w):
        flat = w.reshape(P, -1).astype(jnp.float32)
        out = A @ flat
        return out.reshape(w.shape).astype(w.dtype)

    return jax.tree.map(avg_leaf, stacked_tree)


def global_average_stacked(stacked_tree, *, P: int):
    def avg_leaf(w):
        mean = jnp.mean(w.astype(jnp.float32), axis=0, keepdims=True)
        return jnp.broadcast_to(mean, w.shape).astype(w.dtype)

    return jax.tree.map(avg_leaf, stacked_tree)


# ---------------------------------------------------------------------------
# Analytical collective-cost model (used by benchmarks & roofline sanity)
# ---------------------------------------------------------------------------

def collective_bytes_per_device(n_bytes: int, P: int, S: int,
                                algorithm: str = "wagma") -> float:
    """Bytes sent per device per training step for an n_bytes payload.

    butterfly global  : log2(P) * N        (recursive doubling, full payload)
    ring allreduce    : 2N(P-1)/P ~= 2N    (bandwidth-optimal global)
    wagma group       : log2(S) * N        (the paper's saving)
    gossip (D-PSGD)   : 2N                 (two neighbours)
    """
    lp, ls = grouping.ilog2(P), grouping.ilog2(max(S, 1))
    if algorithm == "wagma":
        return ls * n_bytes
    if algorithm == "butterfly_global":
        return lp * n_bytes
    if algorithm == "ring_allreduce":
        return 2.0 * n_bytes * (P - 1) / P
    if algorithm == "gossip":
        return 2.0 * n_bytes
    raise ValueError(algorithm)
