"""Wait-avoiding group allreduce — legacy entry points + cost model.

The paper implements group allreduce as activation messages + a butterfly
(recursive-doubling) exchange inside each group, on MPI.  Under XLA the same
exchange is ``log2(S)`` stages of ``jax.lax.ppermute`` with XOR-partner
permutations, executed inside a ``shard_map`` (via ``repro.compat``) that is
*manual* over the data-parallel mesh axes and *auto* (GSPMD) over the model
axis.  The XOR bit decides which mesh axis carries the exchange: low bits
permute within the ``data`` axis (intra-pod ICI), high bits within the
``pod`` axis (inter-pod links) — the topology-awareness the paper gets from
its butterfly.

**Execution moved to compiled plans (DESIGN.md §9).**  As of the
``AveragingPlan`` redesign the single execution path for all averaging is
``core/plan.py``: a frozen :class:`~repro.core.plan.Topology` (mesh axes →
link classes with their own alpha/beta/gamma constants) is compiled once per
tree structure into a plan that owns the per-stage link classification,
per-link-class bucket budgets/layouts, and the wavefront schedule; averagers
call ``plan.average(tree, phase)`` / ``plan.sync(tree)``.

**Migration note.**  The deprecated kwarg shims (:func:`group_average`,
:func:`global_average`, :func:`resolve_bucket_bytes`) completed their
deprecation cycle and are now **hard errors** pointing at the plan API:

    from repro.core import plan
    topo = plan.Topology.flat(axis_names, axis_sizes)        # or .hierarchical
    p = plan.compile_plan(topo, params, plan.AveragingConfig(group_size=S))
    p.average(params, phase)                                  # in shard_map

What legitimately stays here: the minor-to-major dp-axis layout helper, the
stacked single-process simulator (shared group math, used by tests and the
convergence benchmarks), the classic single-class alpha-beta(-gamma)
collective cost model (the per-link-class model lives in ``plan``), and the
re-exported constants (``DEFAULT_ALPHA``/``DEFAULT_BETA``/``DEFAULT_GAMMA``,
``butterfly_exchange``).
"""

from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import grouping
from repro.core import overlap as pipeline
from repro.core.plan import (DEFAULT_ALPHA, DEFAULT_BETA, DEFAULT_GAMMA,
                             butterfly_exchange)


# ---------------------------------------------------------------------------
# dp-axis layout (shared by plans, averagers, and launchers)
# ---------------------------------------------------------------------------

def dp_axis_layout(mesh_axis_names: Sequence[str], mesh_shape: dict,
                   dp_axes: Sequence[str]) -> Tuple[Tuple[str, ...], Tuple[int, ...]]:
    """Minor-to-major dp axis names/sizes for global dp-rank bit mapping.

    JAX mesh axes are major-to-minor left-to-right, so e.g. mesh
    ('pod', 'data', 'model') with dp_axes ('pod', 'data') gives layout
    names=('data', 'pod'), sizes=(16, 2): global dp rank = pod*16 + data.
    """
    ordered = [a for a in mesh_axis_names if a in dp_axes]
    names = tuple(reversed(ordered))
    sizes = tuple(mesh_shape[a] for a in names)
    return names, sizes


# ---------------------------------------------------------------------------
# REMOVED kwarg shims — hard errors pointing at the plan API
# ---------------------------------------------------------------------------

_PLAN_POINTER = (
    "compile an AveragingPlan instead:\n"
    "    from repro.core import plan\n"
    "    topo = plan.Topology.flat(axis_names, axis_sizes)  # or .hierarchical\n"
    "    p = plan.compile_plan(topo, tree, plan.AveragingConfig(group_size=S))\n"
    "    p.average(tree, phase) / p.average_offset(tree, offset) / "
    "p.sync(tree)   # inside shard_map\n"
    "(every former kwarg is an AveragingConfig field or a Topology property; "
    "see README.md 'Migration note')")


def group_average(*args, **kwargs):
    """REMOVED: the ``group_average(offset=..., P=..., S=..., ...)`` kwarg
    entry point went through a deprecation cycle and is now a hard error.
    Use ``plan.compile_plan(...)`` + ``plan.average_offset(tree, offset)``.
    """
    raise RuntimeError("group_allreduce.group_average was removed; "
                       + _PLAN_POINTER)


def global_average(*args, **kwargs):
    """REMOVED: use ``plan.compile_plan(...)`` + ``plan.sync(tree)``."""
    raise RuntimeError("group_allreduce.global_average was removed; "
                       + _PLAN_POINTER)


def resolve_bucket_bytes(*args, **kwargs):
    """REMOVED: plans resolve one budget per link class at compile time
    (``plan.choose_class_bucket_bytes``)."""
    raise RuntimeError("group_allreduce.resolve_bucket_bytes was removed; "
                       + _PLAN_POINTER)


# ---------------------------------------------------------------------------
# Stacked simulator path (single process, leading replica axis)
# ---------------------------------------------------------------------------

def averaging_matrix(P: int, S: int, t: int) -> np.ndarray:
    A = np.asarray(grouping.averaging_matrix(P, S, t), dtype=np.float32)
    return A


def group_average_stacked(stacked_tree, *, P: int, S: int, t: int):
    """Simulator: W[i] <- mean over i's group, on (P, ...) stacked pytrees."""
    A = jnp.asarray(averaging_matrix(P, S, t))

    def avg_leaf(w):
        flat = w.reshape(P, -1).astype(jnp.float32)
        out = A @ flat
        return out.reshape(w.shape).astype(w.dtype)

    return jax.tree.map(avg_leaf, stacked_tree)


def global_average_stacked(stacked_tree, *, P: int):
    def avg_leaf(w):
        mean = jnp.mean(w.astype(jnp.float32), axis=0, keepdims=True)
        return jnp.broadcast_to(mean, w.shape).astype(w.dtype)

    return jax.tree.map(avg_leaf, stacked_tree)


# ---------------------------------------------------------------------------
# Analytical collective-cost model (single link class; per-class in plan.py)
# ---------------------------------------------------------------------------

def collective_bytes_per_device(n_bytes: int, P: int, S: int,
                                algorithm: str = "wagma") -> float:
    """Bytes sent per device per training step for an n_bytes payload.

    butterfly global  : log2(P) * N        (recursive doubling, full payload)
    ring allreduce    : 2N(P-1)/P ~= 2N    (bandwidth-optimal global)
    wagma group       : log2(S) * N        (the paper's saving)
    gossip (D-PSGD)   : 2N                 (two neighbours)
    """
    lp, ls = grouping.ilog2(P), grouping.ilog2(max(S, 1))
    if algorithm == "wagma":
        return ls * n_bytes
    if algorithm == "butterfly_global":
        return lp * n_bytes
    if algorithm == "ring_allreduce":
        return 2.0 * n_bytes * (P - 1) / P
    if algorithm == "gossip":
        return 2.0 * n_bytes
    raise ValueError(algorithm)


def collective_stages(P: int, S: int, algorithm: str = "wagma") -> int:
    """Serial collective rounds per step (the latency-bound term)."""
    lp, ls = grouping.ilog2(P), grouping.ilog2(max(S, 1))
    if algorithm == "wagma":
        return ls
    if algorithm == "butterfly_global":
        return lp
    if algorithm == "ring_allreduce":
        return 2 * (P - 1)
    if algorithm == "gossip":
        return 2
    raise ValueError(algorithm)


def alpha_beta_time(wire_bytes: float, stages: int, *, n_buckets: int = 1,
                    alpha: float = DEFAULT_ALPHA,
                    beta: float = DEFAULT_BETA,
                    gamma: float = 0.0,
                    overlap: bool = False) -> float:
    """The alpha-beta(-gamma) formula for ``stages`` serial collective rounds.

    Serial (``overlap=False``):
        stages * n_buckets * alpha + wire_bytes * (beta + gamma)
    — every stage launches one collective per bucket (per leaf on the
    unfused path; pass ``n_buckets=n_leaves`` to model it), each paying the
    launch latency ``alpha``; payload bytes ride the inverse bandwidth
    ``beta``; ``gamma`` adds the per-stage combine arithmetic the wire must
    wait for (0 keeps the pure-network classic formula).

    Overlapped (``overlap=True``): per stage the wavefront schedule
    (core/overlap.py) pays ``max(wire, combine)`` plus pipeline fill/drain
    instead of ``wire + combine`` — the combine of bucket k runs while
    bucket k+1's payload is on the wire (see
    ``overlap.overlapped_stage_seconds``).  With one bucket there is nothing
    to overlap and both forms coincide.
    """
    b = max(n_buckets, 1)
    if not overlap or stages <= 0:
        return stages * b * alpha + wire_bytes * (beta + gamma)
    per_stage_wire = wire_bytes * beta / stages
    per_stage_combine = wire_bytes * gamma / stages
    return stages * pipeline.overlapped_stage_seconds(
        per_stage_wire, per_stage_combine, b, alpha)


def collective_time(n_bytes: float, P: int, S: int,
                    algorithm: str = "wagma", *, n_buckets: int = 1,
                    alpha: float = DEFAULT_ALPHA,
                    beta: float = DEFAULT_BETA,
                    gamma: float = 0.0,
                    overlap: bool = False) -> float:
    """Alpha-beta wall time per step of one algorithm's collective."""
    wire = collective_bytes_per_device(n_bytes, P, S, algorithm)
    return alpha_beta_time(wire, collective_stages(P, S, algorithm),
                           n_buckets=n_buckets, alpha=alpha, beta=beta,
                           gamma=gamma, overlap=overlap)


def wagma_step_time(n_bytes: float, P: int, S: int, *, tau: int,
                    n_buckets: int = 1, alpha: float = DEFAULT_ALPHA,
                    beta: float = DEFAULT_BETA,
                    gamma: float = 0.0,
                    overlap: bool = False) -> float:
    """Tau-amortised WAGMA averaging seconds/step: (tau-1) group butterflies
    + one bandwidth-optimal ring-allreduce global sync, averaged.

    ``gamma``/``overlap`` model the combine arithmetic of the *group
    butterfly* (the path core/overlap.py restructures); the tau-periodic
    ring allreduce keeps the classic alpha-beta form — its reduction happens
    inside the collective and is already pipelined by the ring.

    Single-link-class model; the per-class hierarchical composition is
    ``plan.modeled_wagma_step_seconds``.
    """
    group = collective_time(n_bytes, P, S, "wagma", n_buckets=n_buckets,
                            alpha=alpha, beta=beta, gamma=gamma,
                            overlap=overlap)
    sync = collective_time(n_bytes, P, S, "ring_allreduce",
                           n_buckets=n_buckets, alpha=alpha, beta=beta)
    return ((tau - 1) * group + sync) / tau
