"""Wait-avoiding group allreduce — TPU-native realisation.

The paper implements group allreduce as activation messages + a butterfly
(recursive-doubling) exchange inside each group, on MPI.  Under XLA the same
exchange is ``log2(S)`` stages of ``jax.lax.ppermute`` with XOR-partner
permutations, executed inside a ``shard_map`` (via ``repro.compat``) that is
*manual* over the data-parallel mesh axes and *auto* (GSPMD) over the model
axis.  Each stage combines the local shard with the partner's:

    for bit in mask_bits(P, S, t):  w = (w + ppermute(w, bit)) ;  w /= S

The XOR bit decides which mesh axis carries the exchange: low bits permute
within the ``data`` axis (intra-pod ICI), high bits within the ``pod`` axis
(inter-pod links) — the topology-awareness the paper gets from its butterfly.

**Bucketed fused path (default).**  ``group_average(fused=True)`` packs the
pytree into a few contiguous dtype-homogeneous flat buckets
(``core/bucketing.py``) so each butterfly stage issues **one ppermute per
bucket** instead of one per leaf — collective launch count drops from
``n_leaves * log2(S)`` to ``n_buckets * log2(S)`` (the alpha term of
:func:`collective_time`) — and the combine ``(w + recv) * 1/S`` runs through
the fused Pallas kernel (``kernels/group_average.py``: fp32 accumulation,
one HBM read per operand) instead of two unfused elementwise passes.
``fused=False`` keeps the per-leaf reference path; the two are differentially
tested against each other and the stacked simulator on every phase offset.

**Overlapped bucket pipeline (default on the fused path).**  With
``overlap=True`` the buckets are no longer walked serially: the wavefront
scheduler (``core/overlap.py``, DESIGN.md §8) issues bucket k+1's ppermute
before bucket k's combine runs and lets each bucket advance to its next
butterfly stage without barriering on the others, so combine time hides
behind wire time (modeled by ``collective_time(overlap=True)``: per-stage
``max(wire, combine) + fill`` instead of ``wire + combine``).  Same-tick
combines share one multi-bucket Pallas launch.  Per-bucket stage order is
unchanged — only inter-bucket interleaving — so ``overlap=True`` stays
bit-compatible with the serial bucketed path and the per-leaf reference.
``bucket_bytes=None`` (default) picks the budget that minimises the modeled
overlapped step time (``bucketing.choose_bucket_bytes``) instead of the
fixed 32 MiB.

Because XLA permutations are static, functions here take a *static* phase
offset; the training loop cycles through ``grouping.distinct_offsets`` and
dispatches the matching compiled step (see train/train_step.py).

Two more entry points ship alongside:

* ``global_average``        — the tau-periodic synchronous allreduce (psum),
  bucketed the same way when ``fused=True``.
* ``group_average_stacked`` — single-process simulator on stacked (P, ...)
  pytrees via the doubly-stochastic averaging matrix; shares the group math
  with the distributed path and is used by tests and convergence benchmarks.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bucketing, grouping
from repro.core import overlap as pipeline


# ---------------------------------------------------------------------------
# Distributed path (call inside shard_map; manual over dp axes)
# ---------------------------------------------------------------------------

def dp_axis_layout(mesh_axis_names: Sequence[str], mesh_shape: dict,
                   dp_axes: Sequence[str]) -> Tuple[Tuple[str, ...], Tuple[int, ...]]:
    """Minor-to-major dp axis names/sizes for global dp-rank bit mapping.

    JAX mesh axes are major-to-minor left-to-right, so e.g. mesh
    ('pod', 'data', 'model') with dp_axes ('pod', 'data') gives layout
    names=('data', 'pod'), sizes=(16, 2): global dp rank = pod*16 + data.
    """
    ordered = [a for a in mesh_axis_names if a in dp_axes]
    names = tuple(reversed(ordered))
    sizes = tuple(mesh_shape[a] for a in names)
    return names, sizes


def _xor_perm(n: int, mask: int):
    return [(i, i ^ mask) for i in range(n)]


def butterfly_exchange(x: jax.Array, bit: int, axis_names: Sequence[str],
                       axis_sizes: Sequence[int]) -> jax.Array:
    """One butterfly stage: return the XOR-partner's value for global dp bit."""
    ax, local_bit = grouping.split_bit_over_axes(bit, axis_sizes)
    perm = _xor_perm(axis_sizes[ax], 1 << local_bit)
    return jax.lax.ppermute(x, axis_names[ax], perm)


def _stage_combine(acc, recv, scale: float, use_pallas: bool):
    """(acc + recv) * scale — fused Pallas kernel or plain jnp."""
    if use_pallas:
        from repro.kernels import ops
        return ops.group_average_combine(acc, recv, scale)
    return (acc + recv) * jnp.asarray(scale, acc.dtype)


def _combine_many(accs, recvs, scale: float, use_pallas: bool):
    """Batch of independent (acc, recv) combines — one wavefront tick.

    The Pallas route groups the batch by dtype and feeds each group to ONE
    multi-bucket kernel launch (grid walks buckets x row-tiles); the jnp
    route does the same per-pair arithmetic as :func:`_stage_combine`.
    """
    if not use_pallas:
        return [(a + r) * jnp.asarray(scale, a.dtype)
                for a, r in zip(accs, recvs)]
    from repro.kernels import ops
    outs = [None] * len(accs)
    by_dtype = {}
    for i, a in enumerate(accs):
        by_dtype.setdefault(jnp.dtype(a.dtype), []).append(i)
    for idxs in by_dtype.values():
        res = ops.group_average_combine_multi([accs[i] for i in idxs],
                                              [recvs[i] for i in idxs], scale)
        for i, o in zip(idxs, res):
            outs[i] = o
    return outs


def resolve_bucket_bytes(tree, bucket_bytes: Optional[int], *, P: int,
                         S: int, tau: int = 10) -> int:
    """``None`` -> the modeled-optimal budget for this tree's payload."""
    if bucket_bytes is not None:
        return bucket_bytes
    return bucketing.choose_bucket_bytes(
        bucketing.tree_payload_bytes(tree), P=P, S=S, tau=tau)


def group_average(tree, *, offset: int, P: int, S: int,
                  axis_names: Sequence[str], axis_sizes: Sequence[int],
                  average_dtype=None, fused: bool = True,
                  bucket_bytes: Optional[int] = None,
                  use_pallas: Optional[bool] = None,
                  overlap: bool = True, tau: int = 10):
    """Group model averaging over groups of size S (paper Alg. 2 line 9+11).

    Must be called inside shard_map manual over ``axis_names``. Applies
    log2(S) ppermute+add stages and divides by S; every worker ends with the
    mean of the S models in its (dynamically selected) group.

    ``fused=True`` (default) runs the bucketed flat-buffer path: one ppermute
    per bucket per stage, combine through the fused Pallas kernel (fp32
    accumulation; ``use_pallas=False`` forces the jnp combine, ``None`` means
    "pallas when fused").  ``fused=False`` is the per-leaf reference path.
    ``overlap=True`` (default) emits the fused path in the wavefront order of
    ``core/overlap.py`` — bucket k+1's ppermute ahead of bucket k's combine,
    no inter-bucket stage barrier, same-tick combines batched into one
    multi-bucket Pallas launch; ``overlap=False`` walks buckets serially.
    ``bucket_bytes=None`` picks the modeled-optimal budget
    (``bucketing.choose_bucket_bytes``; ``tau`` only feeds that model — pass
    the caller's sync period so the choice matches what analysis tools like
    ``dryrun.bucket_collective_summary`` recompute).  All variants order
    each element's
    arithmetic identically — log2(S) adds then one scale — so they agree to
    fp32-accumulation tolerance (bit-exact for fp32 accumulation dtypes).
    """
    bits = grouping.mask_bits_for_offset(P, S, offset)
    inv_s = 1.0 / S

    if not fused:
        def avg_leaf(w):
            orig_dtype = w.dtype
            acc = w.astype(average_dtype) if average_dtype is not None else w
            for bit in bits:
                acc = acc + butterfly_exchange(acc, bit, axis_names, axis_sizes)
            acc = acc * jnp.asarray(inv_s, acc.dtype)
            return acc.astype(orig_dtype)

        return jax.tree.map(avg_leaf, tree)

    pallas = True if use_pallas is None else use_pallas
    bb = resolve_bucket_bytes(tree, bucket_bytes, P=P, S=S, tau=tau)

    if not overlap:
        def mix(acc):
            for i, bit in enumerate(bits):
                recv = butterfly_exchange(acc, bit, axis_names, axis_sizes)
                scale = inv_s if i == len(bits) - 1 else 1.0
                acc = _stage_combine(acc, recv, scale, pallas)
            return acc

        return bucketing.tree_map_bucketed(mix, tree,
                                           compute_dtype=average_dtype,
                                           max_bucket_bytes=bb)

    def mix_all(bufs):
        return pipeline.overlapped_butterfly(
            bufs, bits, inv_s,
            exchange=lambda buf, bit: butterfly_exchange(
                buf, bit, axis_names, axis_sizes),
            combine_many=lambda accs, recvs, scale: _combine_many(
                accs, recvs, scale, pallas))

    return bucketing.tree_map_buckets(mix_all, tree,
                                      compute_dtype=average_dtype,
                                      max_bucket_bytes=bb)


def global_average(tree, axis_names: Sequence[str], *, fused: bool = True,
                   bucket_bytes: Optional[int] = None):
    """tau-periodic synchronous allreduce mean over all dp replicas (line 16).

    ``fused=True`` buckets the tree first: one pmean per bucket instead of
    one per leaf (same payload bytes, log2(P)x fewer collective launches).
    The reduction arithmetic lives *inside* the pmean, so there is no combine
    to pipeline here; ``bucket_bytes=None`` keeps the default budget.
    """
    names = tuple(axis_names)

    if not fused:
        def avg_leaf(w):
            return jax.lax.pmean(w.astype(jnp.float32), names).astype(w.dtype)

        return jax.tree.map(avg_leaf, tree)

    return bucketing.tree_map_bucketed(
        lambda buf: jax.lax.pmean(buf, names), tree,
        compute_dtype=jnp.float32,
        max_bucket_bytes=bucket_bytes or bucketing.DEFAULT_BUCKET_BYTES)


# ---------------------------------------------------------------------------
# Stacked simulator path (single process, leading replica axis)
# ---------------------------------------------------------------------------

def averaging_matrix(P: int, S: int, t: int) -> np.ndarray:
    A = np.asarray(grouping.averaging_matrix(P, S, t), dtype=np.float32)
    return A


def group_average_stacked(stacked_tree, *, P: int, S: int, t: int):
    """Simulator: W[i] <- mean over i's group, on (P, ...) stacked pytrees."""
    A = jnp.asarray(averaging_matrix(P, S, t))

    def avg_leaf(w):
        flat = w.reshape(P, -1).astype(jnp.float32)
        out = A @ flat
        return out.reshape(w.shape).astype(w.dtype)

    return jax.tree.map(avg_leaf, stacked_tree)


def global_average_stacked(stacked_tree, *, P: int):
    def avg_leaf(w):
        mean = jnp.mean(w.astype(jnp.float32), axis=0, keepdims=True)
        return jnp.broadcast_to(mean, w.shape).astype(w.dtype)

    return jax.tree.map(avg_leaf, stacked_tree)


# ---------------------------------------------------------------------------
# Analytical collective-cost model (used by benchmarks & roofline sanity)
# ---------------------------------------------------------------------------

def collective_bytes_per_device(n_bytes: int, P: int, S: int,
                                algorithm: str = "wagma") -> float:
    """Bytes sent per device per training step for an n_bytes payload.

    butterfly global  : log2(P) * N        (recursive doubling, full payload)
    ring allreduce    : 2N(P-1)/P ~= 2N    (bandwidth-optimal global)
    wagma group       : log2(S) * N        (the paper's saving)
    gossip (D-PSGD)   : 2N                 (two neighbours)
    """
    lp, ls = grouping.ilog2(P), grouping.ilog2(max(S, 1))
    if algorithm == "wagma":
        return ls * n_bytes
    if algorithm == "butterfly_global":
        return lp * n_bytes
    if algorithm == "ring_allreduce":
        return 2.0 * n_bytes * (P - 1) / P
    if algorithm == "gossip":
        return 2.0 * n_bytes
    raise ValueError(algorithm)


def collective_stages(P: int, S: int, algorithm: str = "wagma") -> int:
    """Serial collective rounds per step (the latency-bound term)."""
    lp, ls = grouping.ilog2(P), grouping.ilog2(max(S, 1))
    if algorithm == "wagma":
        return ls
    if algorithm == "butterfly_global":
        return lp
    if algorithm == "ring_allreduce":
        return 2 * (P - 1)
    if algorithm == "gossip":
        return 2
    raise ValueError(algorithm)


# Default network constants (Piz Daint-scale Aries; overridden by callers
# with measured values). benchmarks/cluster_sim.py reuses these.
DEFAULT_ALPHA = 20e-6          # seconds per collective launch
DEFAULT_BETA = 1.0 / 10e9      # seconds per wire byte
# Combine throughput: each butterfly stage streams the payload through the
# fused kernel — 2 reads + 1 write at P100-scale HBM (~700 GB/s), so
# seconds per *payload* byte per stage.  gamma << beta is exactly why the
# combine can hide entirely behind the wire once the schedule overlaps them.
DEFAULT_GAMMA = 3.0 / 700e9


def alpha_beta_time(wire_bytes: float, stages: int, *, n_buckets: int = 1,
                    alpha: float = DEFAULT_ALPHA,
                    beta: float = DEFAULT_BETA,
                    gamma: float = 0.0,
                    overlap: bool = False) -> float:
    """The alpha-beta(-gamma) formula for ``stages`` serial collective rounds.

    Serial (``overlap=False``):
        stages * n_buckets * alpha + wire_bytes * (beta + gamma)
    — every stage launches one collective per bucket (per leaf on the
    unfused path; pass ``n_buckets=n_leaves`` to model it), each paying the
    launch latency ``alpha``; payload bytes ride the inverse bandwidth
    ``beta``; ``gamma`` adds the per-stage combine arithmetic the wire must
    wait for (0 keeps the pure-network classic formula).

    Overlapped (``overlap=True``): per stage the wavefront schedule
    (core/overlap.py) pays ``max(wire, combine)`` plus pipeline fill/drain
    instead of ``wire + combine`` — the combine of bucket k runs while
    bucket k+1's payload is on the wire (see
    ``overlap.overlapped_stage_seconds``).  With one bucket there is nothing
    to overlap and both forms coincide.
    """
    b = max(n_buckets, 1)
    if not overlap or stages <= 0:
        return stages * b * alpha + wire_bytes * (beta + gamma)
    per_stage_wire = wire_bytes * beta / stages
    per_stage_combine = wire_bytes * gamma / stages
    return stages * pipeline.overlapped_stage_seconds(
        per_stage_wire, per_stage_combine, b, alpha)


def collective_time(n_bytes: float, P: int, S: int,
                    algorithm: str = "wagma", *, n_buckets: int = 1,
                    alpha: float = DEFAULT_ALPHA,
                    beta: float = DEFAULT_BETA,
                    gamma: float = 0.0,
                    overlap: bool = False) -> float:
    """Alpha-beta wall time per step of one algorithm's collective."""
    wire = collective_bytes_per_device(n_bytes, P, S, algorithm)
    return alpha_beta_time(wire, collective_stages(P, S, algorithm),
                           n_buckets=n_buckets, alpha=alpha, beta=beta,
                           gamma=gamma, overlap=overlap)


def wagma_step_time(n_bytes: float, P: int, S: int, *, tau: int,
                    n_buckets: int = 1, alpha: float = DEFAULT_ALPHA,
                    beta: float = DEFAULT_BETA,
                    gamma: float = 0.0,
                    overlap: bool = False) -> float:
    """Tau-amortised WAGMA averaging seconds/step: (tau-1) group butterflies
    + one bandwidth-optimal ring-allreduce global sync, averaged.

    ``gamma``/``overlap`` model the combine arithmetic of the *group
    butterfly* (the path core/overlap.py restructures); the tau-periodic
    ring allreduce keeps the classic alpha-beta form — its reduction happens
    inside the collective and is already pipelined by the ring.

    Single source of the amortisation used by ``WagmaAverager`` and
    ``launch/costmodel.averaging_comm_cost``.
    """
    group = collective_time(n_bytes, P, S, "wagma", n_buckets=n_buckets,
                            alpha=alpha, beta=beta, gamma=gamma,
                            overlap=overlap)
    sync = collective_time(n_bytes, P, S, "ring_allreduce",
                           n_buckets=n_buckets, alpha=alpha, beta=beta)
    return ((tau - 1) * group + sync) / tau
