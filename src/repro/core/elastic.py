"""Elastic topology: membership epochs, Topology diffing, state handoff.

DESIGN.md §12.  Wait-avoidance is the paper's point, but the SPMD path
used to assume a fixed healthy mesh — a preempted pod meant a full job
restart.  This module is the host-side half of surviving churn without
one:

* :func:`diff_topology` — structural diff between two dp topologies.  A
  membership change is a *resize* of one or more dp axes (axis names and
  link classes must survive the change); anything resized means the
  compiled :class:`~repro.core.plan.AveragingPlan` must be recompiled
  (the plan cache already keys on topology, so recompilation is just a
  ``compile_plan`` call on the new topology — and
  :func:`repro.core.plan.evict_topology` drops the dead entries).
* :class:`MembershipController` — epoch-stamped worker membership.  The
  butterfly needs power-of-two worlds (``grouping.ilog2`` is enforced at
  ``Topology`` construction), so the controller quantises the healthy
  worker set down to the largest power of two; surplus healthy workers
  wait as *spares*.  Leaves shrink the world immediately (a dead worker
  blocks every collective); joins — and spare promotions — are deferred
  to the next tau-sync barrier, where every surviving replica holds the
  identical post-sync consensus model, so a joiner can adopt it with
  zero staleness.  That is exactly the restart discipline Parallel
  Restarted SGD (PAPERS.md, arxiv 1807.06629) shows preserves
  convergence, and it re-enters the simulator's invariant: buffer age
  never exceeds ``staleness.max_staleness_bound(tau)``.
* :func:`handoff_state` / :func:`select_replica_rows` /
  :func:`regrow_replica_state` — checkpoint-free state movement between
  worlds, through the cross-policy :class:`~repro.core.replica.
  ReplicaState` machinery: sharded states unpack through the old plan's
  shard layout to effective (pod) rows, surviving rows are re-seated in
  new-world rank order, and sharded destinations repack through the new
  plan's layout.  No file is written; the conversion is the same
  host-side path checkpoint portability already pins bit-exact.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.replica import ReplicaState, map_opt_state, _pack_rows, \
    _unpack_rows


def largest_pow2(n: int) -> int:
    """Largest power of two <= n (0 for n <= 0)."""
    if n <= 0:
        return 0
    return 1 << (int(n).bit_length() - 1)


# ---------------------------------------------------------------------------
# Topology diffing
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TopologyDiff:
    """Structural diff between two dp topologies (old -> new).

    ``resized`` lists ``(axis_name, old_size, new_size)`` for every axis
    whose size changed.  Any resize invalidates the compiled plan: stage
    classification, per-class budgets, and the offset table all depend on
    the axis sizes.
    """
    old: object
    new: object
    resized: Tuple[Tuple[str, int, int], ...]

    @property
    def requires_recompile(self) -> bool:
        return bool(self.resized)

    def describe(self) -> str:
        if not self.resized:
            return "topology unchanged"
        parts = [f"{name}: {o} -> {n}" for name, o, n in self.resized]
        return f"resized {', '.join(parts)} (P {self.old.P} -> {self.new.P})"


def diff_topology(old, new) -> TopologyDiff:
    """Diff two topologies of the same axis/link-class structure.

    Membership changes resize dp axes; they never rename axes or change
    which link class an axis rides (the physical interconnect does not
    change when a pod leaves), so anything but a size change is an error.
    """
    if old.axis_names != new.axis_names:
        raise ValueError(f"axis names changed {old.axis_names} -> "
                         f"{new.axis_names}; membership changes only "
                         "resize axes")
    if old.axis_class != new.axis_class or \
            old.link_classes != new.link_classes:
        raise ValueError("link-class structure changed; membership changes "
                         "only resize axes")
    resized = tuple((name, o, n) for name, o, n
                    in zip(old.axis_names, old.axis_sizes, new.axis_sizes)
                    if o != n)
    return TopologyDiff(old, new, resized)


def resize_topology(topology, axis: str, new_size: int):
    """The same topology with one dp axis resized (same link classes).

    ``new_size`` must be a power of two (Topology enforces it); this is
    how a membership change turns into a topology for recompilation.
    """
    if axis not in topology.axis_names:
        raise ValueError(f"no axis {axis!r} in {topology.axis_names}")
    sizes = tuple(int(new_size) if name == axis else s
                  for name, s in zip(topology.axis_names,
                                     topology.axis_sizes))
    return dataclasses.replace(topology, axis_sizes=sizes)


# ---------------------------------------------------------------------------
# Epoch-stamped membership
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Membership:
    """One epoch's worker membership snapshot.

    ``active`` is the power-of-two collective world in rank order;
    ``spares`` are healthy workers holding no current state (demoted by a
    shrink, or joiners promoted-in-waiting); ``pending`` are announced
    joins not yet at a sync barrier.
    """
    epoch: int
    active: Tuple[int, ...]
    spares: Tuple[int, ...]
    pending: Tuple[int, ...]

    @property
    def world_size(self) -> int:
        return len(self.active)


@dataclass(frozen=True)
class MembershipEvent:
    """What one membership transition did.

    ``kind``: ``"shrink"`` (immediate, on a leave), ``"regrow"`` (at a
    tau-sync barrier), ``"defer"`` (join queued to the next barrier),
    ``"rejected-stale-epoch"`` (a detector verdict from a retired
    topology, refused — see :meth:`MembershipController.apply_verdict`)
    or ``"noop"``.  For shrinks, ``keep_rows`` are the OLD world's row
    indices that survive, in NEW world rank order — exactly the argument
    :func:`handoff_state` takes.  For regrows, ``n_joined`` counts the
    appended rows.
    """
    kind: str
    epoch: int
    world: Tuple[int, ...]
    keep_rows: Tuple[int, ...] = ()
    n_joined: int = 0


class MembershipController:
    """Epoch-stamped membership over a fixed pool of worker ids.

    The controller is pure bookkeeping — it decides *who* is in the
    world and *when* the world changes; the launch layer turns its
    events into mesh rebuilds, plan recompiles, and state handoffs.

    Rules (DESIGN.md §12):

    * the active world is always a power of two (butterfly invariant);
      surplus healthy workers are spares;
    * ``leave`` of an active worker shrinks the world immediately to
      ``largest_pow2(survivors)`` — a dead worker blocks collectives, so
      waiting is not an option; demoted-but-healthy workers become
      spares;
    * ``join`` defers to the next tau-sync barrier
      (:meth:`at_sync_barrier`), where spares + pending joiners are
      promoted up to the next power of two and adopt the post-sync
      consensus state with zero staleness;
    * every world change bumps ``epoch`` — plans, handoffs, and logs are
      stamped with it so stale recompiles are detectable.
    """

    def __init__(self, workers: Sequence[int], *, min_world: int = 2):
        workers = [int(w) for w in workers]
        if len(set(workers)) != len(workers):
            raise ValueError("duplicate worker ids")
        n = largest_pow2(len(workers))
        if n < min_world:
            raise ValueError(f"{len(workers)} workers cannot form a world "
                             f"of at least {min_world}")
        self.min_world = int(min_world)
        self.epoch = 0
        self._active: List[int] = workers[:n]
        self._spares: List[int] = workers[n:]
        self._pending: List[int] = []
        self._history: List[Membership] = [self.membership]

    @property
    def membership(self) -> Membership:
        return Membership(self.epoch, tuple(self._active),
                          tuple(self._spares), tuple(self._pending))

    @property
    def history(self) -> Tuple[Membership, ...]:
        """Every epoch's snapshot, oldest first (epoch audit trail)."""
        return tuple(self._history)

    def _bump(self) -> None:
        self.epoch += 1
        self._history.append(self.membership)

    def leave(self, worker: int) -> MembershipEvent:
        """Worker died / was preempted.  Shrinks the world if it was active."""
        worker = int(worker)
        if worker in self._pending:
            self._pending.remove(worker)
            return MembershipEvent("noop", self.epoch, tuple(self._active))
        if worker in self._spares:
            self._spares.remove(worker)
            return MembershipEvent("noop", self.epoch, tuple(self._active))
        if worker not in self._active:
            raise ValueError(f"unknown worker {worker}")
        old_active = list(self._active)
        survivors = [w for w in old_active if w != worker]
        n = largest_pow2(len(survivors))
        if n < self.min_world:
            raise RuntimeError(
                f"worker {worker} left; {len(survivors)} survivors cannot "
                f"form a world of at least {self.min_world}")
        self._active = survivors[:n]
        # demoted-but-healthy workers rejoin at the next sync barrier
        self._spares.extend(survivors[n:])
        self._bump()
        keep = tuple(old_active.index(w) for w in self._active)
        return MembershipEvent("shrink", self.epoch, tuple(self._active),
                               keep_rows=keep)

    def join(self, worker: int) -> MembershipEvent:
        """Worker announced itself; promotion waits for the sync barrier."""
        worker = int(worker)
        if worker in self._active or worker in self._spares \
                or worker in self._pending:
            return MembershipEvent("noop", self.epoch, tuple(self._active))
        self._pending.append(worker)
        return MembershipEvent("defer", self.epoch, tuple(self._active))

    def apply_verdict(self, verdict) -> MembershipEvent:
        """Detection -> membership: act on a `core.health.Verdict`.

        This is the autonomous twin of the scripted :meth:`leave`: a
        SUSPECT verdict shrinks the world (a hung partner must not block
        the butterfly), a DEAD verdict removes whatever trace of the
        worker remains (usually a noop — the suspect shrink already ran).

        A verdict stamped with a **stale epoch** is rejected outright:
        it was raised against a topology that has since been retired
        (its plan-cache entries evicted via ``plan.evict_topology``),
        and its worker/row indictment means nothing in the current
        world.  Acting on it would shrink the *current* world for a
        failure observed in a dead one.
        """
        if verdict.epoch != self.epoch:
            return MembershipEvent("rejected-stale-epoch", self.epoch,
                                   tuple(self._active))
        from repro.core import health as _health
        if verdict.state == _health.RECOVERED:
            return self.join(verdict.worker)
        if verdict.state not in (_health.SUSPECT, _health.DEAD):
            raise ValueError(f"unactionable verdict state {verdict.state!r}")
        w = int(verdict.worker)
        if w not in self._active and w not in self._spares \
                and w not in self._pending:
            return MembershipEvent("noop", self.epoch, tuple(self._active))
        return self.leave(w)

    def at_sync_barrier(self) -> MembershipEvent:
        """Called right after a tau-sync step: promote waiting workers.

        All surviving replicas hold the identical post-sync consensus
        model here, so promoted workers adopt it bit-exactly with zero
        staleness (:func:`regrow_replica_state`).  The world grows to the
        largest power of two the healthy set supports.
        """
        candidates = self._spares + self._pending
        n = largest_pow2(len(self._active) + len(candidates))
        if n <= len(self._active):
            return MembershipEvent("noop", self.epoch, tuple(self._active))
        n_joined = n - len(self._active)
        promoted = candidates[:n_joined]
        self._active = self._active + promoted
        self._spares = [w for w in self._spares if w not in promoted]
        self._pending = [w for w in self._pending if w not in promoted]
        self._bump()
        return MembershipEvent("regrow", self.epoch, tuple(self._active),
                               n_joined=n_joined)


# ---------------------------------------------------------------------------
# Checkpoint-free state handoff
# ---------------------------------------------------------------------------

def select_replica_rows(state: ReplicaState, rows: Sequence[int]
                        ) -> ReplicaState:
    """Host-side row selection on any stacked ReplicaState layout.

    Works for both layouts because every leaf — replicated ``(P_dp, ...)``
    stacked params/moments, FSDP ``(P_eff, bucket)`` shard buffers, and
    the per-replica optimiser ``count`` — carries the replica dimension
    first.  ``rows`` may repeat (that is how :func:`regrow_replica_state`
    clones the consensus row for joiners).
    """
    idx = np.asarray(list(rows), np.int64)
    sel = lambda tree: jax.tree.map(
        lambda a: jnp.asarray(np.asarray(a)[idx]), tree)
    return ReplicaState(sel(state.params),
                        map_opt_state(state.opt_state, sel, sel),
                        state.step, state.phase)


def handoff_state(state: ReplicaState, keep_rows: Sequence[int], *,
                  old_plan=None, new_plan=None) -> ReplicaState:
    """Re-seat a ReplicaState onto a resized world, checkpoint-free.

    ``keep_rows`` indexes the old world's *effective* replica rows that
    survive, in new-world rank order (a shrink event's ``keep_rows``).
    Replicated states are plain row selections; sharded states route
    through the cross-policy machinery: unpack the shard buffers through
    ``old_plan``'s layout to effective (pod) rows, select, and repack
    through ``new_plan``'s layout — the new topology generally picks
    different per-class bucket budgets, so the layouts need not match.
    Both plans must be on the same streamed-ness (a shrink never changes
    the execution engine; cross *that* seam through
    ``checkpoint.load_replica_state``).
    """
    old_sharded = old_plan is not None and old_plan.sharding.is_sharded
    new_sharded = new_plan is not None and new_plan.sharding.is_sharded
    if old_sharded != new_sharded:
        raise ValueError("handoff_state does not cross sharding policies; "
                         "both worlds must be replicated or both fsdp")
    if old_sharded and \
            old_plan.sharding.streamed != new_plan.sharding.streamed:
        raise ValueError("handoff_state does not cross streamed <-> "
                         "gather-all; restore through "
                         "checkpoint.load_replica_state instead")
    if not old_sharded:
        return select_replica_rows(state, keep_rows)

    unstack = lambda t: _unpack_rows(t, old_plan.shard_layout, cast=False)
    pod_state = ReplicaState(
        _unpack_rows(state.params, old_plan.shard_layout),
        map_opt_state(state.opt_state, unstack, lambda c: c),
        state.step, state.phase)
    pod_state = select_replica_rows(pod_state, keep_rows)

    n = new_plan.P_eff
    if len(tuple(keep_rows)) != n:
        raise ValueError(f"{len(tuple(keep_rows))} surviving rows but the "
                         f"new plan has P_eff={n}")
    restack = lambda t: _pack_rows(t, new_plan.shard_layout, n,
                                   dtype=jnp.float32)
    return ReplicaState(
        _pack_rows(pod_state.params, new_plan.shard_layout, n),
        map_opt_state(pod_state.opt_state, restack, lambda c: c),
        pod_state.step, pod_state.phase)


def regrow_replica_state(state: ReplicaState, n_total: int, *,
                         source_row: int = 0) -> ReplicaState:
    """Append joiner rows that adopt the post-sync consensus state.

    MUST be called at a tau-sync barrier: the sync collective hands every
    survivor the identical averaged model, so cloning ``source_row``
    seats the joiner on the global consensus bit-exactly — params,
    optimiser moments, and step/phase bookkeeping — with zero staleness,
    exactly the restart point ``max_staleness_bound(tau)`` assumes.
    Works on either layout (see :func:`select_replica_rows`).
    """
    leaves = jax.tree.leaves(state.params)
    n_now = int(leaves[0].shape[0]) if leaves else 0
    if n_total < n_now:
        raise ValueError(f"regrow to {n_total} < current {n_now} rows")
    rows = list(range(n_now)) + [int(source_row)] * (n_total - n_now)
    return select_replica_rows(state, rows)
