"""The paper's primary contribution: wait-avoiding group model averaging.

* grouping.py        — Algorithm 1 (dynamic butterfly grouping), pure/static
* group_allreduce.py — butterfly group allreduce via shard_map+ppermute,
                       stacked simulator, collective cost model
* wagma.py           — Algorithm 2 (WAGMA-SGD) as a composable averager
* baselines.py       — the paper's comparison set (Table I)
* staleness.py       — wait-avoidance/straggler semantics simulator
"""

from repro.core.grouping import (default_group_size, groups_for_iteration,
                                 mask_bits, n_phases, phase_offset,
                                 propagation_latency)
from repro.core.wagma import WagmaAverager, WagmaConfig
from repro.core.baselines import make_averager

__all__ = [
    "WagmaAverager", "WagmaConfig", "make_averager",
    "default_group_size", "groups_for_iteration", "mask_bits",
    "n_phases", "phase_offset", "propagation_latency",
]
