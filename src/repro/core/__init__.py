"""The paper's primary contribution: wait-avoiding group model averaging.

* grouping.py        — Algorithm 1 (dynamic butterfly grouping), pure/static
* bucketing.py       — flat-buffer bucketing: pack the params pytree into a
                       few dtype-homogeneous 1-D buckets (cached layout) so
                       every averager launches one collective per *bucket*
                       per stage instead of one per leaf (DESIGN.md §7)
* overlap.py         — software-pipelined bucket scheduler: wavefront over
                       the (bucket, stage) grid so bucket k+1's ppermute is
                       on the wire while bucket k combines (DESIGN.md §8)
* replica.py         — ReplicaState & ShardingPolicy (DESIGN.md §10): the
                       pytree the train step/averager/checkpoint/cost model
                       operate on — replicated (P_dp, ...)-stacked trees or
                       FSDP-within-pod shard buckets — plus host-side
                       cross-policy conversion and consolidation
* plan.py            — THE averaging API (DESIGN.md §9): frozen Topology
                       (mesh axes → link classes with own alpha/beta/gamma)
                       compiled once per tree structure into an
                       AveragingPlan — per-stage ICI/DCN classification,
                       per-link-class bucket budgets, wavefront schedule;
                       execution is plan.average/sync/mix inside shard_map
* group_allreduce.py — deprecated kwarg shims onto compiled plans, the
                       stacked simulator, and the single-class
                       alpha-beta(-gamma) collective cost model
* wagma.py           — Algorithm 2 (WAGMA-SGD) as a plan-holding averager
* baselines.py       — the paper's comparison set (Table I), same plans
* staleness.py       — wait-avoidance/straggler semantics simulator

Group patterns are static per compiled step: the host loop dispatches one of
``n_phases`` jitted variants by ``phase_for_step(t)`` (train/train_step.py).
"""

from repro.core.grouping import (default_group_size, groups_for_iteration,
                                 mask_bits, n_phases, phase_offset,
                                 propagation_latency)
from repro.core.replica import ReplicaState, ShardingPolicy
from repro.core.plan import (AveragingConfig, AveragingPlan, LinkClass,
                             Topology, compile_plan)
from repro.core.wagma import WagmaAverager, WagmaConfig
from repro.core.baselines import make_averager

__all__ = [
    "AveragingConfig", "AveragingPlan", "LinkClass", "ReplicaState",
    "ShardingPolicy", "Topology", "compile_plan",
    "WagmaAverager", "WagmaConfig", "make_averager",
    "default_group_size", "groups_for_iteration", "mask_bits",
    "n_phases", "phase_offset", "propagation_latency",
]
