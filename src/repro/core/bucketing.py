"""Flat-buffer bucketing for collective communication (MG-WFBP-style).

The averaging hot path used to issue one ``ppermute`` + unfused add/scale per
pytree *leaf* per butterfly stage — hundreds of sub-megabyte collectives per
step on a transformer, each paying full launch latency (the alpha term of the
alpha-beta cost model; see ``group_allreduce.collective_time``).  This module
packs a params pytree into a handful of contiguous, dtype-homogeneous 1-D
**buckets** so every stage does one collective per bucket, and the combine
arithmetic can stream through the fused Pallas kernel
(``kernels/group_average.py``) one HBM read per operand.

The pack/unpack layout is a pure function of the tree *structure*
(treedef + leaf shapes/dtypes + bucket budget) and is cached, so repeated
calls inside a compiled step trace reuse the same slicing plan:

    layout  = layout_for(tree)              # cached BucketLayout
    buckets = pack(tree, layout)            # tuple of 1-D arrays
    ...one collective per bucket...
    tree    = unpack(buckets, layout)       # exact round trip

Layout rules:

* leaves are grouped by dtype (a bucket is dtype-homogeneous so the packed
  buffer never casts), filled greedily in canonical tree order;
* a bucket closes when adding the next leaf would push it past
  ``max_bucket_bytes`` (an oversize leaf still gets its own bucket — leaves
  are never split across buckets, which keeps unpack a static slice);
* each bucket is zero-padded to a whole number of 128-element lanes so the
  Pallas combine kernel never re-pads per stage (zeros are a fixed point of
  ``(w + recv) * 1/S`` under XOR-symmetric exchanges, so the pad region
  stays zero through every butterfly stage);
* zero-size leaves occupy zero-length slices — they survive the round trip
  without ever touching a collective.

``tree_map_buckets`` is the generic driver used by every averager (WAGMA
butterfly, global psum, gossip baselines): the mixing function sees the
whole bucket list at once, which is what lets the overlapped wavefront
scheduler (``core/overlap.py``, DESIGN.md §8) interleave collectives and
combines across buckets.  ``tree_map_bucketed`` is the serial per-bucket
wrapper kept for reference paths; ``choose_bucket_bytes`` picks the budget
that minimises the modeled overlapped step time.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Default bucket budget.  32 MiB balances the alpha term (fewer, larger
# collectives) against pipelining granularity: the follow-on async overlap
# work (ROADMAP) issues bucket k+1's ppermute while combining bucket k, which
# needs at least a few buckets per model to hide anything.
DEFAULT_BUCKET_BYTES = 32 * 1024 * 1024

# TPU lane width; buckets are padded to a multiple of this so flat buffers
# tile cleanly (f32 min tile is (8, 128) — see the Pallas guide).
_LANES = 128


@dataclass(frozen=True)
class _LeafSlot:
    bucket: int            # which bucket this leaf lives in
    offset: int            # element offset of the leaf inside the bucket
    size: int              # element count (0 for empty leaves)
    shape: Tuple[int, ...]
    dtype: np.dtype


@dataclass(frozen=True)
class BucketLayout:
    """Cached pack/unpack plan for one tree structure."""
    treedef: jax.tree_util.PyTreeDef
    slots: Tuple[_LeafSlot, ...]          # one per leaf, canonical order
    bucket_sizes: Tuple[int, ...]         # padded element counts
    bucket_dtypes: Tuple[np.dtype, ...]
    # layer-aware layouts (DESIGN.md §11): the ordered group id each bucket
    # belongs to, or () for ungrouped layouts.  Groups are closed ranges —
    # every bucket holds leaves of exactly one group, and bucket indices are
    # ordered by group — so a run of buckets always covers a contiguous
    # layer span and the streamed FSDP engine can gather span k+1's buckets
    # while span k computes.
    bucket_groups: Tuple[int, ...] = ()

    @property
    def n_buckets(self) -> int:
        return len(self.bucket_sizes)

    @property
    def grouped(self) -> bool:
        return bool(self.bucket_groups)

    def group_bucket_indices(self, group: int) -> Tuple[int, ...]:
        """Bucket indices holding the given group's leaves (contiguous)."""
        return tuple(i for i, g in enumerate(self.bucket_groups)
                     if g == group)

    def group_bucket_map(self) -> Dict[int, Tuple[int, ...]]:
        """The layer <-> bucket map: ordered group id -> bucket indices."""
        out: Dict[int, Tuple[int, ...]] = {}
        for i, g in enumerate(self.bucket_groups):
            out[g] = out.get(g, ()) + (i,)
        return out

    def group_bytes(self, group: int) -> int:
        """Padded bytes of one group's buckets (its gathered footprint)."""
        return sum(self.bucket_sizes[i] * self.bucket_dtypes[i].itemsize
                   for i in self.group_bucket_indices(group))

    def describe(self) -> str:
        return " ".join(
            f"[{i}:{np.dtype(d).name}x{s}]"
            for i, (s, d) in enumerate(zip(self.bucket_sizes,
                                           self.bucket_dtypes)))

    def describe_groups(self) -> str:
        """Compact layer-map summary: ``g0->b0, g1->b1-b2, ...``."""
        if not self.grouped:
            return "ungrouped"
        parts = []
        for g, idxs in sorted(self.group_bucket_map().items()):
            rng = (f"b{idxs[0]}" if len(idxs) == 1
                   else f"b{idxs[0]}-b{idxs[-1]}")
            parts.append(f"{g}->{rng}")
        return ", ".join(parts)


def _pad_to_lanes(n: int, align: int = 1) -> int:
    unit = _LANES * max(int(align), 1)
    return -(-n // unit) * unit if n else 0


def build_layout(tree, *, max_bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                 align: int = 1,
                 groups: Optional[Tuple[int, ...]] = None) -> BucketLayout:
    """Plan buckets for ``tree`` (arrays or ShapeDtypeStructs).

    ``align`` pads every bucket to a multiple of ``align * 128`` elements
    instead of plain 128 — the sharded-replica path (core/replica.py,
    DESIGN.md §10) passes the intra-pod shard count so each bucket splits
    into ``align`` equal, lane-aligned shard slices.

    ``groups`` makes the layout **layer-aware** (DESIGN.md §11): one int
    per leaf in canonical tree order, mapping the leaf to an ordered layer
    id.  Buckets never span groups — leaves are packed group by group in
    ascending group order (canonical order within a group), and every open
    bucket closes at a group boundary — so the streamed FSDP engine can
    gather exactly one layer span's buckets at a time.  The greedy
    dtype/budget fill restarts per group, which makes the group's slice of
    the layout identical to ``build_layout`` of the group's sub-tree alone
    (pinned by tests; the plan's per-group sublayout views rely on it).
    A single layer larger than the budget still splits into several
    buckets of its own (oversize leaves keep their own bucket) — never
    into a shared one.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    metas = [(int(np.prod(l.shape, dtype=np.int64)), tuple(l.shape),
              np.dtype(l.dtype)) for l in leaves]
    if groups is not None and len(groups) != len(metas):
        raise ValueError(f"groups has {len(groups)} entries for "
                         f"{len(metas)} leaves")

    # Fill order: canonical leaf order, or (group, canonical) when grouped.
    order = list(range(len(metas)))
    if groups is not None:
        order.sort(key=lambda li: (groups[li], li))

    slot_of_leaf: Dict[int, _LeafSlot] = {}
    bucket_sizes: list = []
    bucket_dtypes: list = []
    bucket_groups: list = []
    open_bucket: Dict[np.dtype, int] = {}     # dtype -> open bucket index
    cur_group = None
    for li in order:
        size, shape, dtype = metas[li]
        if groups is not None and groups[li] != cur_group:
            cur_group = groups[li]
            open_bucket = {}                  # buckets never span groups
        bi = open_bucket.get(dtype)
        if bi is not None:
            would_be = (bucket_sizes[bi] + size) * dtype.itemsize
            if bucket_sizes[bi] > 0 and size > 0 and would_be > max_bucket_bytes:
                bi = None                      # close it, open a fresh one
        if bi is None:
            bi = len(bucket_sizes)
            bucket_sizes.append(0)
            bucket_dtypes.append(dtype)
            bucket_groups.append(cur_group)
            open_bucket[dtype] = bi
        slot_of_leaf[li] = _LeafSlot(bi, bucket_sizes[bi], size, shape, dtype)
        bucket_sizes[bi] += size

    bucket_sizes = [_pad_to_lanes(s, align) for s in bucket_sizes]
    return BucketLayout(treedef, tuple(slot_of_leaf[i] for i in range(len(metas))),
                        tuple(bucket_sizes), tuple(bucket_dtypes),
                        tuple(bucket_groups) if groups is not None else ())


_LAYOUT_CACHE: Dict[tuple, BucketLayout] = {}
_LAYOUT_STATS = {"hits": 0, "misses": 0}


def clear_layout_cache() -> None:
    """Drop all cached layouts (and the treedefs they retain).

    Layouts are keyed on tree structure, so long-lived processes that sweep
    many distinct meshes/models (parametrised tests, dry-run sweeps) would
    otherwise accumulate one entry — including a retained PyTreeDef — per
    structure forever.  Test fixtures call this between cases.
    """
    _LAYOUT_CACHE.clear()
    _LAYOUT_STATS["hits"] = _LAYOUT_STATS["misses"] = 0
    choose_bucket_bytes.cache_clear()


def layout_cache_stats() -> dict:
    """Hit/miss counters for :func:`layout_for` (cache-reuse assertions).

    The compiled-plan path (core/plan.py) traces one jitted step per phase
    offset; the layout must be derived once per (structure, budget) and hit
    thereafter — the offset is not part of the key because the layout does
    not depend on it.
    """
    return dict(_LAYOUT_STATS)


def layout_for(tree, *, max_bucket_bytes: int = DEFAULT_BUCKET_BYTES,
               align: int = 1,
               groups: Optional[Tuple[int, ...]] = None) -> BucketLayout:
    """Cached :func:`build_layout` keyed on structure, not array identity.

    The key is exactly what the layout is a function of — treedef, per-leaf
    (shape, dtype), the byte budget, the shard alignment, and the per-leaf
    layer groups.  Anything else a caller threads around (phase offset,
    averaging dtype, overlap mode) must NOT enter the key: re-tracing every
    phase variant of a step reuses one layout.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    key = (treedef, tuple((tuple(l.shape), np.dtype(l.dtype).str)
                          for l in leaves), max_bucket_bytes, align, groups)
    layout = _LAYOUT_CACHE.get(key)
    if layout is None:
        _LAYOUT_STATS["misses"] += 1
        layout = _LAYOUT_CACHE[key] = build_layout(
            tree, max_bucket_bytes=max_bucket_bytes, align=align,
            groups=groups)
    else:
        _LAYOUT_STATS["hits"] += 1
    return layout


def pack(tree, layout: BucketLayout,
         dtype=None) -> Tuple[jax.Array, ...]:
    """Concatenate the tree's leaves into the layout's flat buckets.

    ``dtype`` overrides every bucket's dtype (leaves are cast while
    packing) — used by the sharded-replica path to pack gradients or
    fp32 optimiser moments into the *storage* layout's slot positions.
    """
    leaves = jax.tree_util.tree_leaves(tree)
    parts: list = [[] for _ in range(layout.n_buckets)]
    filled: list = [0] * layout.n_buckets
    for leaf, slot in zip(leaves, layout.slots):
        if slot.size:
            flat = jnp.ravel(leaf)
            if dtype is not None:
                flat = flat.astype(dtype)
            parts[slot.bucket].append(flat)
            filled[slot.bucket] += slot.size
    out = []
    for bi, (chunks, size, bdtype) in enumerate(
            zip(parts, layout.bucket_sizes, layout.bucket_dtypes)):
        bdtype = bdtype if dtype is None else np.dtype(dtype)
        pad = size - filled[bi]
        if pad:
            chunks.append(jnp.zeros((pad,), bdtype))
        if not chunks:
            out.append(jnp.zeros((0,), bdtype))
        elif len(chunks) == 1:
            out.append(chunks[0])
        else:
            out.append(jnp.concatenate(chunks))
    return tuple(out)


def unpack(buckets: Sequence[jax.Array], layout: BucketLayout,
           cast: bool = True):
    """Exact inverse of :func:`pack` (slices are static).

    ``cast=False`` keeps each leaf in its bucket's dtype instead of the
    slot's storage dtype — the inverse of ``pack(..., dtype=...)``.
    """
    leaves = []
    for slot in layout.slots:
        buf = buckets[slot.bucket]
        if slot.size:
            flat = jax.lax.slice(buf, (slot.offset,),
                                 (slot.offset + slot.size,))
        else:
            flat = jnp.zeros((0,), slot.dtype if cast else buf.dtype)
        flat = flat.reshape(slot.shape)
        leaves.append(flat.astype(slot.dtype) if cast else flat)
    return jax.tree_util.tree_unflatten(layout.treedef, leaves)


def tree_map_buckets(fn: Callable[[list], list], tree, *,
                     compute_dtype=jnp.float32,
                     max_bucket_bytes: int = DEFAULT_BUCKET_BYTES):
    """Apply a mixing function to the whole LIST of flat buckets at once.

    ``fn`` maps the list of 1-D buffers to a list of same-shaped buffers.
    Seeing every bucket in one call is what lets the overlapped scheduler
    (core/overlap.py) interleave collectives and combines *across* buckets —
    the per-bucket driver below cannot express that.  Buffers are presented
    in ``compute_dtype`` (``None`` = storage dtype) and cast back, so bf16
    models average with fp32 accumulation while touching each leaf exactly
    once for pack and once for unpack.  Zero-size buckets are passed through
    to ``fn`` (callers skip them) and restored untouched.
    """
    layout = layout_for(tree, max_bucket_bytes=max_bucket_bytes)
    bufs = pack(tree, layout)
    origs = [b.dtype for b in bufs]
    accs = [b.astype(compute_dtype) if compute_dtype is not None and b.size
            else b for b in bufs]
    outs = fn(list(accs))
    if len(outs) != len(bufs):
        raise ValueError(f"bucket mixing fn returned {len(outs)} buffers "
                         f"for {len(bufs)} buckets")
    return unpack(tuple(o.astype(d) for o, d in zip(outs, origs)), layout)


def tree_map_bucketed(fn: Callable[[jax.Array], jax.Array], tree, *,
                      compute_dtype=jnp.float32,
                      max_bucket_bytes: int = DEFAULT_BUCKET_BYTES):
    """Apply a flat-buffer mixing function once per bucket of ``tree``.

    ``fn`` maps a 1-D buffer to a same-shaped 1-D buffer (e.g. a butterfly
    exchange-and-combine, a pmean, a gossip mix).  Per-bucket wrapper over
    :func:`tree_map_buckets` — the serial reference; the overlapped paths
    use the list-level driver directly.
    """
    return tree_map_buckets(
        lambda bufs: [fn(b) if b.size else b for b in bufs], tree,
        compute_dtype=compute_dtype, max_bucket_bytes=max_bucket_bytes)


def tree_payload_bytes(tree) -> int:
    """Total leaf bytes of a params pytree (arrays or ShapeDtypeStructs)."""
    return sum(int(np.prod(l.shape, dtype=np.int64)) * np.dtype(l.dtype).itemsize
               for l in jax.tree_util.tree_leaves(tree))


# Candidate budgets swept by :func:`choose_bucket_bytes` — 1 MiB..128 MiB in
# octaves brackets every regime the alpha-beta model distinguishes: small
# budgets buy pipelining granularity (more overlap slots), large budgets buy
# fewer per-collective launch latencies.
BUCKET_BYTES_CANDIDATES = tuple((1 << i) * 1024 * 1024 for i in range(8))


@lru_cache(maxsize=None)
def choose_bucket_bytes(payload_bytes: int, *, P: int, S: int,
                        tau: int = 10,
                        overlap: bool = True,
                        alpha: float = None, beta: float = None,
                        gamma: float = None,
                        candidates: Tuple[int, ...] = BUCKET_BYTES_CANDIDATES
                        ) -> int:
    """Bucket budget minimising the modeled (single-class) step time.

    Replaces the fixed 32 MiB default: sweeps ``candidates`` through the
    (overlapped) alpha-beta model — per-stage time
    ``launches*alpha + max(wire, combine) + fill/drain`` — and returns the
    argmin.  The tension the sweep resolves: fewer buckets amortise alpha,
    but the overlapped pipeline needs several buckets per model before the
    combine hides behind the wire at all.  Pure host-side arithmetic on
    static quantities, so the choice is free at trace time — and cached
    (the sweep reruns only for new argument tuples, not once per
    phase-offset trace).  The per-link-class variant lives in
    ``plan.choose_class_bucket_bytes``.
    """
    from repro.core import group_allreduce as ga   # circular-import guard
    alpha = ga.DEFAULT_ALPHA if alpha is None else alpha
    beta = ga.DEFAULT_BETA if beta is None else beta
    gamma = ga.DEFAULT_GAMMA if gamma is None else gamma
    payload = max(int(payload_bytes), 1)
    best, best_t = None, None
    for cand in candidates:
        n_buckets = max(1, -(-payload // cand))
        t = ga.wagma_step_time(payload, P, S, tau=tau, n_buckets=n_buckets,
                               alpha=alpha, beta=beta, gamma=gamma,
                               overlap=overlap)
        if best_t is None or t < best_t:
            best, best_t = cand, t
    return best
