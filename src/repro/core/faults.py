"""Deterministic, seeded fault injection (DESIGN.md §13).

A `FaultSchedule` is a frozen, sorted list of `FaultEvent`s — delay /
hang / crash entries keyed on (step, worker) — so the *same* schedule
replays bit-identically in tests, the CI chaos smoke and
`benchmarks/cluster_sim.py`.  Two runtimes consume it:

* `ElasticTrainer.run_under_faults` (launch/elastic.py) plays the
  schedule against a **virtual** clock: faulty workers stop
  heartbeating, the `core.health` detector turns the silence into
  verdicts, and membership reacts.  No wall time is read, so replay
  determinism is exact.
* `FaultInjector` hooks a plain `Trainer.step_once` with **real**
  effects for one designated worker identity: delays sleep wall-clock
  (the §V-B straggler experiment), crashes raise `InjectedCrash`
  mid-run (what the atomic-checkpoint tests use to die between write
  and rename).

`FaultSchedule.straggler_trace` reproduces the paper's §V-B trace —
every step, a seeded choice of `n_stragglers` workers is delayed by
320 ms — shared by the chaos tests and the cluster-sim degraded-mode
model.
"""
from __future__ import annotations

import time
import zlib
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

DELAY = "delay"
HANG = "hang"
CRASH = "crash"
_KINDS = (DELAY, HANG, CRASH)


class InjectedFault(RuntimeError):
    """Base class for faults raised by the wall-clock injector."""


class InjectedCrash(InjectedFault):
    """The scheduled crash of this worker process."""


class InjectedHang(InjectedFault):
    """A scheduled hang, surfaced as an exception once the watchdog gives
    up (a single process cannot usefully block forever)."""


@dataclass(frozen=True, order=True)
class FaultEvent:
    """One scheduled fault.

    ``until`` is the step at which a hang recovers / a crash rejoins
    (None = never); ``ms`` is the delay duration for DELAY events.
    """
    step: int
    worker: int
    kind: str
    ms: float = 0.0
    until: Optional[int] = None

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.kind == DELAY and self.ms <= 0:
            raise ValueError("delay needs ms > 0")
        if self.until is not None and self.until <= self.step:
            raise ValueError("recovery must be strictly after the fault")


def delay(worker: int, step: int, ms: float) -> FaultEvent:
    """Worker finishes its round ``ms`` late (a §V-B straggler)."""
    return FaultEvent(int(step), int(worker), DELAY, ms=float(ms))


def hang(worker: int, step: int, recover_after: Optional[int] = None
         ) -> FaultEvent:
    """Worker goes silent at ``step``; optionally wakes, state intact,
    ``recover_after`` steps later."""
    until = None if recover_after is None else int(step) + int(recover_after)
    return FaultEvent(int(step), int(worker), HANG, until=until)


def crash(worker: int, step: int, rejoin_after: Optional[int] = None
          ) -> FaultEvent:
    """Worker dies at ``step``, losing state; optionally rejoins (as a
    fresh joiner adopting consensus) ``rejoin_after`` steps later."""
    until = None if rejoin_after is None else int(step) + int(rejoin_after)
    return FaultEvent(int(step), int(worker), CRASH, until=until)


class FaultSchedule:
    """An immutable, deterministically ordered set of fault events."""

    def __init__(self, events: Iterable[FaultEvent] = ()):
        self.events: Tuple[FaultEvent, ...] = tuple(sorted(events))
        self._by_step: Dict[int, List[FaultEvent]] = {}
        for ev in self.events:
            self._by_step.setdefault(ev.step, []).append(ev)

    @classmethod
    def of(cls, *events: FaultEvent) -> "FaultSchedule":
        return cls(events)

    @classmethod
    def straggler_trace(cls, P: int, steps: int, *, ms: float = 320.0,
                        n_stragglers: int = 2, seed: int = 0
                        ) -> "FaultSchedule":
        """The paper's §V-B trace: each step, ``n_stragglers`` distinct
        seeded workers run ``ms`` late.  Same (P, steps, seed) ->
        bit-identical schedule."""
        rng = np.random.default_rng(seed)
        evs = []
        for t in range(steps):
            for w in rng.choice(P, size=min(n_stragglers, P), replace=False):
                evs.append(delay(int(w), t, ms))
        return cls(evs)

    def at(self, step: int) -> Tuple[FaultEvent, ...]:
        return tuple(self._by_step.get(step, ()))

    def delays_at(self, step: int) -> Dict[int, float]:
        """worker -> delay seconds taking effect at ``step``."""
        return {ev.worker: ev.ms / 1e3 for ev in self.at(step)
                if ev.kind == DELAY}

    @property
    def max_step(self) -> int:
        return max((ev.step for ev in self.events), default=-1)

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self):
        return iter(self.events)

    def fingerprint(self) -> str:
        """Stable content hash — equal schedules replay identically, so
        equal fingerprints promise bit-identical chaos runs."""
        text = ";".join(f"{e.step}:{e.worker}:{e.kind}:{e.ms}:{e.until}"
                        for e in self.events)
        return f"{zlib.crc32(text.encode()):08x}"

    def __repr__(self) -> str:
        return (f"FaultSchedule({len(self.events)} events, "
                f"fingerprint={self.fingerprint()})")


class FaultInjector:
    """Wall-clock runtime for one worker identity, hooked into
    ``Trainer.step_once`` (``Trainer(..., fault_injector=...)``).

    ``before_step(t)`` applies the schedule's entries for this worker:
    DELAY sleeps, CRASH raises `InjectedCrash`, HANG sleeps
    ``hang_grace_s`` then raises `InjectedHang` (the single-process
    stand-in for "the watchdog deadline expired on a hung worker").
    """

    def __init__(self, schedule: FaultSchedule, worker: int = 0, *,
                 time_scale: float = 1.0, hang_grace_s: float = 0.05,
                 sleep=time.sleep):
        self.schedule = schedule
        self.worker = int(worker)
        self.time_scale = float(time_scale)
        self.hang_grace_s = float(hang_grace_s)
        self._sleep = sleep
        self.delayed_ms = 0.0   # total injected delay, for logs

    def before_step(self, t: int) -> None:
        for ev in self.schedule.at(t):
            if ev.worker != self.worker:
                continue
            if ev.kind == DELAY:
                self.delayed_ms += ev.ms
                self._sleep(ev.ms / 1e3 * self.time_scale)
            elif ev.kind == CRASH:
                raise InjectedCrash(
                    f"worker {self.worker} crashed at step {t}")
            elif ev.kind == HANG:
                self._sleep(self.hang_grace_s * self.time_scale)
                raise InjectedHang(
                    f"worker {self.worker} hung at step {t}")
