"""Baseline data-parallel SGD variants (the paper's comparison set, Table I).

Every averager exposes the same interface as ``WagmaAverager``:

    grad_comm : bool      — True: averages gradients (pre-optimiser);
                            False: averages models (post-optimiser)
    n_phases  : int       — number of distinct compiled step variants
    phase_for_step(t)     — which variant iteration t uses
    sync_due(t)           — whether this step uses the global-sync variant
    comm(tree, phase)     — per-step collective (inside shard_map, manual dp)
    sync(tree)            — global average (inside shard_map)

As of the plan redesign (DESIGN.md §9) every baseline **builds and holds a
compiled** :class:`~repro.core.plan.AveragingPlan`: the constructor takes a
:class:`~repro.core.plan.Topology` (default: flat single link class over the
dp axes — the legacy behaviour) and each collective runs through
``plan.mix(tree, issue, combine, bits=...)`` / ``plan.sync(tree)``.  The
``bits`` are the global dp-rank XOR bits the mix touches, so the plan can
pick the bucket budget from the link class the mix actually rides (a ring on
the intra-pod axis buckets for ICI; a global psum for the DCN bottleneck).

The legacy constructor kwargs (``fused``/``bucket_bytes``/``overlap``)
survive as plan-config inputs: mixes are expressed as an ``issue`` half (the
collectives) and a ``combine`` half (the local arithmetic) so the bucketed
path can run the single-stage overlap pipeline (``overlap=True`` default,
core/overlap.py) — every bucket's collectives are issued before any bucket's
combine runs.  ``fused=False`` restores the per-leaf reference path; the
differential suite pins all granularities to agree.

Distributed semantics on a lock-step SPMD pod:

* Allreduce-SGD — synchronous global gradient pmean (standard data-parallel).
* Local SGD     — no per-step comm; global model average every H steps.
* D-PSGD        — synchronous ring gossip: W <- (W_left + W + W_right)/3.
* SGP           — one neighbour per step on a rotating hypercube edge
                  (the directed-exponential graph of the paper needs a global
                  shift permutation that crosses mesh-axis boundaries; the
                  XOR-partner variant has identical per-step traffic and the
                  same log P propagation latency — noted in DESIGN.md; the
                  *true* directed-exponential topology is exercised in the
                  convergence simulator below).
* AD-PSGD       — asynchronous pairwise averaging; on SPMD hardware realised
                  as one pairwise exchange per step on a rotating bit (its
                  asynchrony exists only in the simulator).
* Eager-SGD     — partial/solo gradient collective; traffic equals a global
                  allreduce, staleness semantics simulator-only.

For convergence studies, ``mixing_matrix(name, P, t)`` gives each variant's
P x P doubly-stochastic gossip matrix (incl. the true SGP topology).
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import numpy as np

from repro.core import bucketing, grouping
from repro.core import plan as plan_mod
from repro.core.plan import butterfly_exchange
from repro.core.replica import REPLICATED, ShardingPolicy


class _AveragerBase:
    grad_comm = False
    n_phases = 1

    def __init__(self, dp_axis_names: Sequence[str], dp_axis_sizes: Sequence[int],
                 fused: bool = True,
                 bucket_bytes: int = bucketing.DEFAULT_BUCKET_BYTES,
                 overlap: bool = True,
                 topology: Optional[plan_mod.Topology] = None,
                 sharding: ShardingPolicy = REPLICATED):
        self.axis_names = tuple(dp_axis_names)
        self.axis_sizes = tuple(int(s) for s in dp_axis_sizes)
        if topology is None:
            topology = plan_mod.Topology.flat(self.axis_names, self.axis_sizes)
        if (topology.axis_names != self.axis_names
                or topology.axis_sizes != self.axis_sizes):
            raise ValueError(
                f"topology axes {topology.axis_names}/{topology.axis_sizes} "
                f"do not match dp axes {self.axis_names}/{self.axis_sizes}")
        self.topology = topology
        self.sharding = sharding
        self.P = int(np.prod(self.axis_sizes))
        # Collectives ride the *effective* replica axes: under
        # fsdp_within_pod the shard axis carries parameter slices, not
        # divergent replicas, so every mix/ring/psum spans the remaining
        # (pod-level) axes only (DESIGN.md §10).
        if sharding.is_sharded:
            eff = topology.drop_axis(sharding.shard_axis)
        else:
            eff = topology
        self.comm_axis_names = eff.axis_names
        self.comm_axis_sizes = eff.axis_sizes
        self.P_eff = eff.P
        self.fused = fused
        self.bucket_bytes = bucket_bytes
        self.overlap = overlap
        self._cfg = plan_mod.AveragingConfig(
            average_dtype="float32", fused=fused, bucket_bytes=bucket_bytes,
            overlap=overlap)

    def phase_for_step(self, t: int) -> int:
        return t % self.n_phases

    def sync_due(self, t: int) -> bool:
        return False

    def plan_for(self, tree) -> plan_mod.AveragingPlan:
        """The compiled plan for this tree structure (cached by compile)."""
        return plan_mod.compile_plan(self.topology, tree, self._cfg,
                                     self.sharding)

    def comm(self, tree, phase: int):
        return tree

    def sync(self, tree):
        return self.plan_for(tree).sync(tree)

    def _mix_tree(self, tree, issue, combine, bits=()):
        """Run a (collective, arithmetic) mix pair through the plan."""
        return self.plan_for(tree).mix(tree, issue, combine,
                                       bits=tuple(bits))


class AllreduceAverager(_AveragerBase):
    """Standard synchronous data-parallel SGD (global gradient averaging)."""
    name = "allreduce"
    grad_comm = True

    def comm(self, tree, phase: int):
        # fp32 accumulation (also: XLA-CPU crashes on bf16 manual all-reduce);
        # bucketed: one pmean per bucket — the MG-WFBP merged-gradient layout.
        # The reduction IS the collective, so combine is the identity; the
        # global collective spans every effective dp bit -> bucket budget
        # follows the topology's bottleneck link class.  Under
        # fsdp_within_pod the tree is the grad shard buffers (already
        # pod-meaned over the shard axis), so the pmean spans pods only.
        return self._mix_tree(
            tree, lambda g: jax.lax.pmean(g, self.comm_axis_names),
            lambda g, r: r)


class LocalSGDAverager(_AveragerBase):
    """Local SGD: H local steps, then a global model average."""
    name = "local_sgd"

    def __init__(self, dp_axis_names, dp_axis_sizes, sync_period: int = 1,
                 **kw):
        super().__init__(dp_axis_names, dp_axis_sizes, **kw)
        self.sync_period = sync_period

    def sync_due(self, t: int) -> bool:
        return (t + 1) % self.sync_period == 0


class DPSGDAverager(_AveragerBase):
    """D-PSGD: synchronous ring gossip with both neighbours."""
    name = "dpsgd"

    def comm(self, tree, phase: int):
        # ring over the global dp rank space: here over the minor axis with
        # wrap; for multi-axis dp the ring lives on the minor (intra-pod) axis
        # of each pod slice plus a pod-crossing handled by the same shift on
        # the major axis every n_minor steps — approximated by a per-axis ring
        # (each device still exchanges with exactly two neighbours).
        n = self.comm_axis_sizes[0]
        fwd = [(i, (i + 1) % n) for i in range(n)]
        bwd = [(i, (i - 1) % n) for i in range(n)]

        def issue(acc):
            return (jax.lax.ppermute(acc, self.comm_axis_names[0], fwd),
                    jax.lax.ppermute(acc, self.comm_axis_names[0], bwd))

        def combine(acc, recv):
            left, right = recv
            return (acc + left + right) / 3.0

        # the ring rides the minor axis only -> bit 0's link class
        return self._mix_tree(tree, issue, combine, bits=(0,))


class SGPAverager(_AveragerBase):
    """Stochastic Gradient Push — hypercube-edge variant (one peer/step)."""
    name = "sgp"

    def __init__(self, dp_axis_names, dp_axis_sizes, neighbours: int = 1,
                 **kw):
        super().__init__(dp_axis_names, dp_axis_sizes, **kw)
        self.neighbours = neighbours
        self.n_phases = grouping.ilog2(self.P_eff)

    def comm(self, tree, phase: int):
        lp = grouping.ilog2(self.P_eff)
        bits = tuple((phase + k) % lp for k in range(self.neighbours))

        def issue(acc):
            return tuple(
                butterfly_exchange(acc, b, self.comm_axis_names,
                                   self.comm_axis_sizes)
                for b in bits)

        def combine(acc, recvs):
            total = acc
            for r in recvs:
                total = total + r
            return total / (self.neighbours + 1.0)

        return self._mix_tree(tree, issue, combine, bits=bits)


class ADPSGDAverager(_AveragerBase):
    """AD-PSGD: pairwise model averaging (async only in the simulator)."""
    name = "adpsgd"

    def __init__(self, dp_axis_names, dp_axis_sizes, **kw):
        super().__init__(dp_axis_names, dp_axis_sizes, **kw)
        self.n_phases = grouping.ilog2(self.P_eff)

    def comm(self, tree, phase: int):
        return self._mix_tree(
            tree,
            lambda acc: butterfly_exchange(acc, phase, self.comm_axis_names,
                                           self.comm_axis_sizes),
            lambda acc, other: (acc + other) / 2.0,
            bits=(phase,))


class EagerSGDAverager(AllreduceAverager):
    """Eager-SGD: partial gradient collective; SPMD traffic == allreduce."""
    name = "eager_sgd"


def make_averager(name: str, dp_axis_names, dp_axis_sizes, **kw):
    from repro.core.wagma import WagmaAverager, WagmaConfig
    name = name.lower()
    if name == "wagma":
        topology = kw.pop("topology", None)
        sharding = kw.pop("sharding", REPLICATED)
        cfg = WagmaConfig(**kw) if kw else WagmaConfig()
        return WagmaAverager(dp_axis_names, dp_axis_sizes, cfg,
                             topology=topology, sharding=sharding)
    table = {
        "allreduce": AllreduceAverager,
        "local_sgd": LocalSGDAverager,
        "dpsgd": DPSGDAverager,
        "sgp": SGPAverager,
        "adpsgd": ADPSGDAverager,
        "eager_sgd": EagerSGDAverager,
    }
    if name not in table:
        raise ValueError(f"unknown averager {name!r}; options: "
                         f"{['wagma'] + sorted(table)}")
    return table[name](dp_axis_names, dp_axis_sizes, **kw)


# ---------------------------------------------------------------------------
# Simulator-side mixing matrices (true topologies, incl. directed-exp SGP)
# ---------------------------------------------------------------------------

def mixing_matrix(name: str, P: int, t: int, *, S: int | None = None,
                  sync_period: int = 1, neighbours: int = 1,
                  rng: np.random.Generator | None = None) -> np.ndarray:
    """P x P (doubly-)stochastic gossip matrix of variant ``name`` at step t."""
    name = name.lower()
    eye = np.eye(P, dtype=np.float32)
    if name == "wagma":
        S = S or grouping.default_group_size(P)
        return np.asarray(grouping.averaging_matrix(P, S, t), np.float32)
    if name == "allreduce" or name == "eager_sgd":
        return np.full((P, P), 1.0 / P, np.float32)
    if name == "local_sgd":
        if (t + 1) % sync_period == 0:
            return np.full((P, P), 1.0 / P, np.float32)
        return eye
    if name == "dpsgd":
        A = eye / 3.0
        for i in range(P):
            A[i, (i + 1) % P] = 1 / 3.0
            A[i, (i - 1) % P] = 1 / 3.0
        return A
    if name == "sgp":
        # directed exponential graph: peer at distance 2^(t mod log2 P)
        lp = grouping.ilog2(P)
        A = eye.copy() / (neighbours + 1.0)
        for k in range(neighbours):
            d = 1 << ((t + k) % lp)
            for i in range(P):
                A[i, (i + d) % P] = 1.0 / (neighbours + 1.0)
        return A
    if name == "adpsgd":
        # one random disjoint pairing per step
        rng = rng or np.random.default_rng(t)
        perm = rng.permutation(P)
        A = eye.copy()
        for a in range(0, P - 1, 2):
            i, j = perm[a], perm[a + 1]
            A[i, i] = A[j, j] = 0.5
            A[i, j] = A[j, i] = 0.5
        return A
    raise ValueError(name)
