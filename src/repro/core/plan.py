"""Topology-aware compiled averaging plans (DESIGN.md §9).

The paper's butterfly is topology-aware by construction: low XOR bits ride
intra-pod links (ICI), high bits ride inter-pod links (DCN).  Before this
module, that structure was implicit — every entry point took ~10 threaded
kwargs (``offset/P/S/axis_names/axis_sizes/average_dtype/fused/bucket_bytes/
use_pallas/overlap/tau``) with ONE bucket budget and ONE set of alpha/beta
constants for all links.  This module makes the collective a compiled
artifact instead:

    topology = Topology.hierarchical(names, sizes, dcn_axes=("pod",))
    plan     = compile_plan(topology, params, AveragingConfig(group_size=S))
    ...inside shard_map (manual over the dp axes)...
    new      = plan.average(params, phase)      # wait-avoiding group step
    new      = plan.sync(params)                # tau-periodic global step

``compile_plan`` runs once per (topology, config, tree structure) — cached —
and precomputes everything the kwargs used to re-derive per call:

* **stage classification** — which butterfly bit of which phase offset rides
  which mesh axis, hence which :class:`LinkClass` (Layered-SGD's split of
  the averaging hierarchy along the physical interconnect);
* **per-link-class bucket budgets** — ``choose_class_bucket_bytes`` sweeps
  the per-class alpha-beta-gamma pipeline model (MG-WFBP: bucket-merge
  decisions against per-link cost constants, not a global 32 MiB default),
  so ICI stages get their own budget and DCN stages theirs;
* **per-class bucket layouts** and the wavefront schedule each stage run
  executes under (core/overlap.py).

Execution walks the offset's stages as maximal **runs** of equal link class:
the tree is cast to the accumulation dtype once, packed into the run's
class layout, butterflied in wavefront order, and repacked only at class
boundaries.  Per element the arithmetic is unchanged — ``log2(S)`` adds in
stage order, then one scale — so the plan path stays bit-identical to the
per-leaf reference and the stacked simulator under fp32 accumulation, for
any topology (pinned by tests/test_plan.py on every phase offset).

Migration note: the ``group_allreduce.group_average(...)`` kwarg shims
completed their deprecation cycle and are now hard errors; construct a
:class:`Topology` and hold the plan.

Sharded replicas (DESIGN.md §10): ``compile_plan(..., sharding=
ShardingPolicy.fsdp_within_pod(axis))`` compiles the FSDP-within-pod
realisation — the state is the plan's shard-aligned bucket buffers, the
butterfly runs pod-to-pod on each device's shard slice, and
``shard_tree``/``unshard_tree``/``grad_shards`` provide the intra-pod
gather/scatter collectives the train step composes around it.
"""

from __future__ import annotations

import math
import os
from dataclasses import dataclass, field
from functools import lru_cache
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bucketing, grouping
from repro.core import overlap as pipeline
from repro.core import streaming
from repro.core.replica import REPLICATED, ShardingPolicy


# ---------------------------------------------------------------------------
# Link classes and topologies
# ---------------------------------------------------------------------------

# Default network constants (Piz Daint-scale Aries; the single-class legacy
# model).  group_allreduce re-exports these names for its cost-model API.
DEFAULT_ALPHA = 20e-6          # seconds per collective launch
DEFAULT_BETA = 1.0 / 10e9      # seconds per wire byte
# Combine throughput: 2 reads + 1 write at P100-scale HBM (~700 GB/s) —
# seconds per *payload* byte per stage.  gamma << beta is why the combine
# can hide entirely behind the wire once the schedule overlaps them.
DEFAULT_GAMMA = 3.0 / 700e9


@dataclass(frozen=True)
class LinkClass:
    """One class of physical link with its own cost constants.

    ``alpha``  seconds per collective launch on this link class;
    ``beta``   seconds per wire byte (inverse bandwidth);
    ``gamma``  combine seconds per payload byte (HBM-side, link-independent
               in principle but kept per class so calibration can differ);
    ``bucket_bytes`` pins this class's bucket budget; ``None`` lets
    :func:`choose_class_bucket_bytes` pick the modeled argmin.
    """
    name: str
    alpha: float = DEFAULT_ALPHA
    beta: float = DEFAULT_BETA
    gamma: float = DEFAULT_GAMMA
    bucket_bytes: Optional[int] = None


# The flat single-class default reproduces the legacy (pre-plan) constants.
DEFAULT_LINK = LinkClass("link")
# Hierarchical defaults: intra-pod ICI (fast, cheap launches) vs inter-pod
# DCN (slow, expensive launches).  Replace with measured constants
# (ROADMAP: calibration) via LinkClass(...) when a real pod is available.
ICI = LinkClass("ici", alpha=1e-6, beta=1.0 / 100e9)
DCN = LinkClass("dcn", alpha=50e-6, beta=1.0 / 10e9)

# The one canonical location of the calibrated link constants.  Every loader
# (``Topology.with_measured`` with no path, ``benchmarks/calibrate_links.py``'s
# default ``--out``, the serving KV-transfer cost model) resolves through this
# constant so there is exactly one tracked file to regenerate.
DEFAULT_LINK_CONSTANTS_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))),
    "LINK_CONSTANTS.json")


@dataclass(frozen=True)
class Topology:
    """Frozen map from dp mesh axes (minor-to-major) to link classes.

    ``axis_names``/``axis_sizes`` follow ``group_allreduce.dp_axis_layout``
    order: minor-to-major, so global dp-rank bit b lives on the axis whose
    cumulative log2 size spans b (``grouping.split_bit_over_axes``).
    ``axis_class[i]`` indexes ``link_classes`` for axis i.
    """
    axis_names: Tuple[str, ...]
    axis_sizes: Tuple[int, ...]
    link_classes: Tuple[LinkClass, ...]
    axis_class: Tuple[int, ...]

    def __post_init__(self):
        if not (len(self.axis_names) == len(self.axis_sizes)
                == len(self.axis_class)):
            raise ValueError("axis_names/axis_sizes/axis_class length mismatch")
        for s in self.axis_sizes:
            grouping.ilog2(s)          # powers of two only
        for c in self.axis_class:
            if not 0 <= c < len(self.link_classes):
                raise ValueError(f"axis_class index {c} out of range")

    @classmethod
    def flat(cls, axis_names: Sequence[str], axis_sizes: Sequence[int],
             link: LinkClass = DEFAULT_LINK) -> "Topology":
        """Single link class for every axis — the legacy behaviour."""
        names = tuple(axis_names)
        return cls(names, tuple(int(s) for s in axis_sizes), (link,),
                   (0,) * len(names))

    @classmethod
    def hierarchical(cls, axis_names: Sequence[str],
                     axis_sizes: Sequence[int], *,
                     dcn_axes: Sequence[str] = ("pod",),
                     ici: LinkClass = ICI,
                     dcn: LinkClass = DCN) -> "Topology":
        """Axes named in ``dcn_axes`` ride DCN; all others ride ICI."""
        names = tuple(axis_names)
        classes = tuple(1 if a in dcn_axes else 0 for a in names)
        if 1 not in classes:
            return cls.flat(names, axis_sizes, link=ici)
        return cls(names, tuple(int(s) for s in axis_sizes), (ici, dcn),
                   classes)

    @property
    def P(self) -> int:
        p = 1
        for s in self.axis_sizes:
            p *= s
        return p

    def class_of_bit(self, bit: int) -> int:
        ax, _ = grouping.split_bit_over_axes(bit, self.axis_sizes)
        return self.axis_class[ax]

    def link_of_bit(self, bit: int) -> LinkClass:
        return self.link_classes[self.class_of_bit(bit)]

    def axis_of_bit(self, bit: int) -> str:
        ax, _ = grouping.split_bit_over_axes(bit, self.axis_sizes)
        return self.axis_names[ax]

    def bottleneck(self) -> LinkClass:
        """The slowest-wire class — what a global collective is bound by."""
        return max(self.link_classes, key=lambda l: l.beta)

    def drop_axis(self, name: str) -> "Topology":
        """This topology minus one dp axis (the FSDP shard axis).

        The remaining axes keep their minor-to-major order and their link
        classes; the result is the *effective* (pod-level) replica space a
        sharded plan butterflies over.
        """
        if name not in self.axis_names:
            raise ValueError(f"axis {name!r} not in {self.axis_names}")
        keep = [i for i, a in enumerate(self.axis_names) if a != name]
        if not keep:
            raise ValueError("cannot drop the only dp axis")
        return Topology(tuple(self.axis_names[i] for i in keep),
                        tuple(self.axis_sizes[i] for i in keep),
                        self.link_classes,
                        tuple(self.axis_class[i] for i in keep))

    def classes_in_use(self) -> Tuple[int, ...]:
        return tuple(sorted(set(self.axis_class)))

    def with_measured(self, path: Optional[str] = None) -> "Topology":
        """This topology with calibrated link constants loaded from disk.

        ``path`` defaults to :data:`DEFAULT_LINK_CONSTANTS_PATH` (the one
        tracked ``LINK_CONSTANTS.json`` at the repo root); it is a file
        written by
        ``benchmarks/calibrate_links.py`` (ROADMAP: measured alpha/beta/
        gamma constants): per mesh axis, the microbenched collective launch
        latency, inverse wire bandwidth, and combine throughput.  Each link
        class takes the *slowest* measurement among its axes (conservative
        — the class cost model prices the class's worst link).  A class's
        alpha/beta price BOTH the butterfly ppermutes and the FSDP
        all-gather/reduce-scatter path (``modeled_fsdp_step_seconds``), so
        when the file also carries ``ag_alpha``/``ag_beta`` the class takes
        the slower of the ppermute and all-gather measurements.  Classes
        with no measured axis keep their assumed defaults, and pinned
        ``bucket_bytes`` survive.
        """
        import json
        with open(path or DEFAULT_LINK_CONSTANTS_PATH) as f:
            data = json.load(f)
        axes = data.get("axes", {})
        new_classes = []
        for ci, link in enumerate(self.link_classes):
            ms = [axes[a] for a, c in zip(self.axis_names, self.axis_class)
                  if c == ci and a in axes]
            if not ms:
                new_classes.append(link)
                continue
            new_classes.append(LinkClass(
                link.name + "@measured",
                alpha=max(max(float(m["alpha"]),
                              float(m.get("ag_alpha", 0.0))) for m in ms),
                beta=max(max(float(m["beta"]),
                             float(m.get("ag_beta", 0.0))) for m in ms),
                gamma=max(float(m.get("gamma", link.gamma)) for m in ms),
                bucket_bytes=link.bucket_bytes))
        return Topology(self.axis_names, self.axis_sizes,
                        tuple(new_classes), self.axis_class)

    def describe(self) -> str:
        parts = []
        for i, link in enumerate(self.link_classes):
            axes = [f"{n}={s}" for n, s, c in
                    zip(self.axis_names, self.axis_sizes, self.axis_class)
                    if c == i]
            parts.append(f"{link.name}({', '.join(axes)}; "
                         f"a={link.alpha:.1e} b={link.beta:.1e})")
        return " | ".join(parts)


def butterfly_exchange(x: jax.Array, bit: int, axis_names: Sequence[str],
                       axis_sizes: Sequence[int]) -> jax.Array:
    """One butterfly stage: return the XOR-partner's value for global dp bit."""
    ax, local_bit = grouping.split_bit_over_axes(bit, axis_sizes)
    n = axis_sizes[ax]
    perm = [(i, i ^ (1 << local_bit)) for i in range(n)]
    return jax.lax.ppermute(x, axis_names[ax], perm)


# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AveragingConfig:
    """Everything about the averaging math that is not the topology.

    ``bucket_bytes`` is a *global override*: when set, every link class uses
    it verbatim (the legacy single-budget behaviour).  ``None`` lets each
    class pick its own modeled-optimal budget.  Exposed to legacy callers as
    ``wagma.WagmaConfig`` (same class, aliased).
    """
    group_size: Optional[int] = None      # None -> sqrt(P) rounded to pow2
    tau: int = 10                         # global sync period (paper §V-B)
    average_dtype: Optional[str] = "float32"   # accumulation dtype
    dynamic_groups: bool = True           # False -> fixed groups (ablation 2)
    fused: bool = True                    # bucketed flat-buffer path
    bucket_bytes: Optional[int] = None    # global budget override
    use_pallas: Optional[bool] = None     # None -> Pallas combine when fused
    overlap: bool = True                  # wavefront bucket pipeline (§8)


# ---------------------------------------------------------------------------
# Per-class cost model + budget choice
# ---------------------------------------------------------------------------

def class_stage_seconds(payload_bytes: float, link: LinkClass,
                        n_buckets: int, *, overlap: bool = True) -> float:
    """Modeled seconds for ONE butterfly stage on ``link`` with B buckets."""
    wire = payload_bytes * link.beta
    combine = payload_bytes * link.gamma
    if overlap:
        return pipeline.overlapped_stage_seconds(wire, combine, n_buckets,
                                                 link.alpha)
    return max(n_buckets, 1) * link.alpha + wire + combine


@lru_cache(maxsize=None)
def choose_class_bucket_bytes(
        payload_bytes: int, link: LinkClass, *, overlap: bool = True,
        candidates: Tuple[int, ...] = bucketing.BUCKET_BYTES_CANDIDATES
        ) -> int:
    """Bucket budget minimising THIS link class's modeled stage time.

    The per-class replacement for the global ``bucketing.choose_bucket_bytes``
    sweep: a cheap-launch high-bandwidth class (ICI) favours small buckets
    (pipelining granularity), an expensive-launch class (DCN) favours big
    ones (alpha amortisation) — MG-WFBP's merge criterion, per link.  The
    stage count multiplies every candidate equally, so the argmin is
    per-stage.  Cached: the sweep re-runs only for new (payload, link) pairs,
    not per phase-offset trace.
    """
    if link.bucket_bytes is not None:
        return link.bucket_bytes
    payload = max(int(payload_bytes), 1)
    best, best_t = None, None
    for cand in candidates:
        n_buckets = max(1, -(-payload // cand))
        t = class_stage_seconds(payload, link, n_buckets, overlap=overlap)
        if best_t is None or t < best_t:
            best, best_t = cand, t
    return best


def link_transfer_seconds(payload_bytes: float, link: LinkClass, *,
                          message_bytes: Optional[int] = None) -> float:
    """Modeled seconds to move ``payload_bytes`` point-to-point on ``link``.

    The serving KV-transfer path (serve/kv_transfer.py) is not a collective:
    a prefill pod streams one request's KV blocks to a decode pod, so the
    cost is the plain alpha-beta line — one launch per message plus wire
    time — with the payload packed into ``message_bytes``-sized messages.
    ``message_bytes=None`` picks this link's modeled-optimal budget via
    :func:`choose_class_bucket_bytes` (non-overlapped: a unidirectional
    send has no combine to hide behind the wire), which is exactly how the
    bucketing layer packs the blocks in practice.
    """
    payload = max(int(payload_bytes), 0)
    if payload == 0:
        return 0.0
    if message_bytes is None:
        message_bytes = choose_class_bucket_bytes(payload, link,
                                                  overlap=False)
    n_messages = max(1, -(-payload // int(message_bytes)))
    return n_messages * link.alpha + payload * link.beta


def ring_sync_seconds(payload_bytes: float, P: int, link: LinkClass,
                      n_buckets: int) -> float:
    """Classic alpha-beta ring allreduce on the bottleneck link class."""
    wire = 2.0 * payload_bytes * (P - 1) / max(P, 1)
    stages = 2 * (P - 1)
    return stages * max(n_buckets, 1) * link.alpha + wire * link.beta


def stage_class_counts(topology: Topology, S: int, offset: int
                       ) -> Dict[int, int]:
    """How many butterfly stages of this offset ride each link class."""
    counts: Dict[int, int] = {}
    for bit in grouping.mask_bits_for_offset(topology.P, S, offset):
        c = topology.class_of_bit(bit)
        counts[c] = counts.get(c, 0) + 1
    return counts


def modeled_wagma_step_seconds(payload_bytes: int, topology: Topology,
                               S: int, *, tau: int = 10,
                               overlap: bool = True,
                               bucket_bytes: Optional[int] = None) -> dict:
    """Tau-amortised hierarchical step model with per-class budgets.

    Group term: mean over the distinct phase offsets of the sum over that
    offset's stages of the stage's class cost (per-class budget, alpha,
    beta, gamma — ``class_stage_seconds``).  Sync term: ring allreduce on
    the bottleneck class.  ``bucket_bytes`` forces one global budget on
    every class (the legacy behaviour the per-class sweep is gated
    against in ``bench_group_average.py --check``).
    """
    P = topology.P
    payload = max(int(payload_bytes), 1)
    per_class = {}
    for ci in topology.classes_in_use():
        link = topology.link_classes[ci]
        budget = bucket_bytes if bucket_bytes is not None else \
            choose_class_bucket_bytes(payload, link, overlap=overlap)
        n_buckets = max(1, -(-payload // budget))
        per_class[ci] = {
            "link": link.name,
            "bucket_bytes": budget,
            "n_buckets": n_buckets,
            "stage_s": class_stage_seconds(payload, link, n_buckets,
                                           overlap=overlap),
            "alpha": link.alpha, "beta": link.beta, "gamma": link.gamma,
        }
    offsets = grouping.distinct_offsets(P, S)
    group_times = []
    for off in offsets:
        t = 0.0
        for ci, n in stage_class_counts(topology, S, off).items():
            t += n * per_class[ci]["stage_s"]
        group_times.append(t)
    group_s = float(np.mean(group_times)) if group_times else 0.0
    bn = topology.bottleneck()
    sync_budget = bucket_bytes if bucket_bytes is not None \
        else bucketing.DEFAULT_BUCKET_BYTES
    sync_s = ring_sync_seconds(payload, P, bn,
                               max(1, -(-payload // sync_budget)))
    step_s = ((tau - 1) * group_s + sync_s) / max(tau, 1)
    return {
        "payload_bytes": payload, "P": P, "S": S, "tau": tau,
        "overlap": overlap,
        "group_s": group_s, "sync_s": sync_s, "step_s": step_s,
        "per_class": {v["link"]: {k: v[k] for k in
                                  ("bucket_bytes", "n_buckets", "stage_s",
                                   "alpha", "beta", "gamma")}
                      for v in per_class.values()},
    }


def modeled_fsdp_step_seconds(payload_bytes: int, topology: Topology,
                              S: int, *, shard_axis: str, tau: int = 10,
                              overlap: bool = True,
                              bucket_bytes: Optional[int] = None) -> dict:
    """Tau-amortised step model for FSDP-within-pod sharded replicas.

    Group term: the pod-to-pod butterfly moves only each device's shard
    slice, so every stage's wire/combine payload is ``payload / pod_size``
    (launch count per stage is unchanged — one ppermute per bucket).
    Gather/scatter term: every step additionally pays the per-bucket
    parameter all-gather (fwd/bwd) and gradient reduce-scatter on the
    shard (ICI) link class — ``(k-1)/k x payload`` wire each way.  Sync
    term: bottleneck-class ring on the shard slice.
    """
    ax = topology.axis_names.index(shard_axis)
    k = topology.axis_sizes[ax]
    shard_link = topology.link_classes[topology.axis_class[ax]]
    eff = topology.drop_axis(shard_axis)
    payload = max(int(payload_bytes), 1)
    slice_payload = payload / k

    per_class = {}
    for ci in eff.classes_in_use():
        link = topology.link_classes[ci]
        budget = bucket_bytes if bucket_bytes is not None else \
            choose_class_bucket_bytes(payload, link, overlap=overlap)
        n_buckets = max(1, -(-payload // budget))
        per_class[ci] = {
            "link": link.name, "bucket_bytes": budget,
            "n_buckets": n_buckets,
            "stage_s": class_stage_seconds(slice_payload, link, n_buckets,
                                           overlap=overlap),
        }
    group_times = []
    for off in grouping.distinct_offsets(eff.P, S):
        t = 0.0
        for bit in grouping.mask_bits_for_offset(eff.P, S, off):
            t += per_class[eff.class_of_bit(bit)]["stage_s"]
        group_times.append(t)
    group_s = float(np.mean(group_times)) if group_times else 0.0

    # the implemented step gathers per shard-layout bucket, and the shard
    # layout is sized at the butterfly (bottleneck-of-effective) class's
    # budget (AveragingPlan.shard_bucket_bytes) — price the AG/RS alpha
    # term at the same launch count the compiled step actually executes
    butterfly_link = max((topology.link_classes[ci]
                          for ci in eff.classes_in_use()),
                         key=lambda l: l.beta)
    ag_budget = bucket_bytes if bucket_bytes is not None else \
        choose_class_bucket_bytes(payload, butterfly_link, overlap=overlap)
    n_ag_buckets = max(1, -(-payload // ag_budget))
    gs_wire = payload * (k - 1) / k * shard_link.beta
    gather_scatter_s = 2 * (n_ag_buckets * shard_link.alpha + gs_wire)

    bn = eff.bottleneck()
    sync_budget = bucket_bytes if bucket_bytes is not None \
        else bucketing.DEFAULT_BUCKET_BYTES
    sync_s = ring_sync_seconds(slice_payload, eff.P, bn,
                               max(1, -(-payload // sync_budget)))
    step_s = ((tau - 1) * group_s + sync_s) / max(tau, 1) + gather_scatter_s
    return {
        "payload_bytes": payload, "P": topology.P, "P_eff": eff.P,
        "pod_size": k, "S": S, "tau": tau, "overlap": overlap,
        "shard_axis": shard_axis, "shard_link": shard_link.name,
        "group_s": group_s, "sync_s": sync_s,
        "gather_scatter_s": gather_scatter_s, "step_s": step_s,
        "per_class": {v["link"]: {kk: v[kk] for kk in
                                  ("bucket_bytes", "n_buckets", "stage_s")}
                      for v in per_class.values()},
    }


def modeled_streamed_fsdp_step_seconds(
        payload_bytes: int, topology: Topology, S: int, *, shard_axis: str,
        n_spans: int, span_fwd_compute_s: float, tau: int = 10,
        overlap: bool = True, bucket_bytes: Optional[int] = None) -> dict:
    """Step model for the layer-streamed FSDP engine (DESIGN.md §11).

    The gather-all step pays ``sum(gather) + compute + sum(scatter)``
    serially and pins the full gathered tree; the streamed step pays
    ``max(compute, gather)`` per layer span plus pipeline fill/drain, and
    holds at most ~two gathered spans.  Backward re-gathers (span-level
    remat) double the gather wire — the model charges them, and the win
    survives whenever span compute covers span gather.  The averaging
    (butterfly + tau-sync) term is identical to
    :func:`modeled_fsdp_step_seconds`.
    """
    base = modeled_fsdp_step_seconds(
        payload_bytes, topology, S, shard_axis=shard_axis, tau=tau,
        overlap=overlap, bucket_bytes=bucket_bytes)
    ax = topology.axis_names.index(shard_axis)
    k = topology.axis_sizes[ax]
    shard_link = topology.link_classes[topology.axis_class[ax]]
    payload = max(int(payload_bytes), 1)
    n = max(int(n_spans), 1)
    span_payload = payload / n
    # spans bucket at the shard layout's budget (the butterfly class's)
    eff = topology.drop_axis(shard_axis)
    butterfly_link = max((topology.link_classes[ci]
                          for ci in eff.classes_in_use()),
                         key=lambda l: l.beta)
    ag_budget = bucket_bytes if bucket_bytes is not None else \
        choose_class_bucket_bytes(payload, butterfly_link, overlap=overlap)
    span_buckets = max(1, -(-int(span_payload) // ag_budget))
    span_wire = span_payload * (k - 1) / k * shard_link.beta
    ag_span = span_buckets * shard_link.alpha + span_wire   # one span gather
    rs_span = ag_span                                       # mirror scatter
    fwd_c = float(span_fwd_compute_s)
    bwd_c = 2.0 * fwd_c

    # gather-all execution: every gather lands before the first flop
    exec_gather_all = n * (ag_span + fwd_c + bwd_c + rs_span)
    # streamed: fill with the first gather, then max(compute, comm) per
    # span; the backward overlaps re-gather + scatter with the 2x compute
    exec_streamed = (ag_span + n * max(fwd_c, ag_span)
                     + n * max(bwd_c, ag_span + rs_span) + rs_span)
    averaging_s = base["step_s"] - base["gather_scatter_s"]
    step_s = averaging_s + exec_streamed
    gather_all_step_s = averaging_s + exec_gather_all
    return {
        "payload_bytes": payload, "P": topology.P, "pod_size": k,
        "S": S, "tau": tau, "n_spans": n,
        "span_payload_bytes": span_payload,
        "span_buckets": span_buckets,
        "span_gather_s": ag_span, "span_fwd_compute_s": fwd_c,
        "exec_streamed_s": exec_streamed,
        "exec_gather_all_s": exec_gather_all,
        "averaging_s": averaging_s,
        "step_s": step_s, "gather_all_step_s": gather_all_step_s,
        "streamed_win": gather_all_step_s / max(step_s, 1e-30),
        # peak transient gathered bytes: full tree vs ~2 spans in flight
        # (clamped — the engine's liveness peak can never exceed the tree,
        # and for n_spans <= 2 "two spans" IS the whole tree)
        "peak_gathered_bytes_full": float(payload),
        "peak_gathered_bytes_streamed": min(2.0 * span_payload,
                                            float(payload)),
    }


# ---------------------------------------------------------------------------
# Combine kernels (moved from group_allreduce)
# ---------------------------------------------------------------------------

def _stage_combine(acc, recv, scale: float, use_pallas: bool):
    """(acc + recv) * scale — fused Pallas kernel or plain jnp."""
    if use_pallas:
        from repro.kernels import ops
        return ops.group_average_combine(acc, recv, scale)
    return (acc + recv) * jnp.asarray(scale, acc.dtype)


def _combine_many(accs, recvs, scale: float, use_pallas: bool):
    """Batch of independent (acc, recv) combines — one wavefront tick.

    The Pallas route groups the batch by dtype and feeds each group to ONE
    multi-bucket kernel launch (grid walks buckets x row-tiles); the jnp
    route does the same per-pair arithmetic as :func:`_stage_combine`.
    """
    if not use_pallas:
        return [(a + r) * jnp.asarray(scale, a.dtype)
                for a, r in zip(accs, recvs)]
    from repro.kernels import ops
    outs = [None] * len(accs)
    by_dtype = {}
    for i, a in enumerate(accs):
        by_dtype.setdefault(jnp.dtype(a.dtype), []).append(i)
    for idxs in by_dtype.values():
        res = ops.group_average_combine_multi([accs[i] for i in idxs],
                                              [recvs[i] for i in idxs], scale)
        for i, o in zip(idxs, res):
            outs[i] = o
    return outs


# ---------------------------------------------------------------------------
# The compiled plan
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class StageRun:
    """A maximal run of consecutive butterfly stages on one link class."""
    class_index: int
    bits: Tuple[int, ...]


class AveragingPlan:
    """Compiled realisation of group + global averaging on one topology.

    Built by :func:`compile_plan`; holds the static schedule data (stage
    classification, per-class budgets/layouts, wavefront order) and exposes
    the execution entry points used inside shard_map:

        plan.average(tree, phase)     group butterfly for a phase index
        plan.sync(tree)               tau-periodic global allreduce mean
        plan.mix(tree, issue, combine, bits=...)
                                      single-round gossip/psum mixes
                                      (the baseline averagers)

    plus the stacked-simulator twins (``average_stacked``/``sync_stacked``)
    and analysis/accounting helpers (``describe``, ``expected_ppermutes``,
    ``per_class_expected``, ``modeled_step_seconds``).
    """

    def __init__(self, topology: Topology, cfg: AveragingConfig,
                 storage_struct, work_struct, payload_bytes: int,
                 sharding: ShardingPolicy = REPLICATED):
        self.topology = topology
        self.cfg = cfg
        self.sharding = sharding
        self.P = topology.P
        # Sharded plans butterfly over the *effective* (pod-level) replica
        # space: the shard axis's ranks share weights and act as ONE
        # logical WAGMA worker (DESIGN.md §10).
        if sharding.is_sharded:
            if sharding.shard_axis not in topology.axis_names:
                raise ValueError(
                    f"shard_axis {sharding.shard_axis!r} not a dp axis of "
                    f"{topology.axis_names}")
            self.shard_axis_index = topology.axis_names.index(
                sharding.shard_axis)
            self.shard_size = topology.axis_sizes[self.shard_axis_index]
            shard_link = topology.link_classes[
                topology.axis_class[self.shard_axis_index]]
            if len(topology.classes_in_use()) > 1 and \
                    shard_link.beta >= topology.bottleneck().beta:
                raise ValueError(
                    f"shard_axis {sharding.shard_axis!r} rides the "
                    f"bottleneck link class {shard_link.name!r}; FSDP "
                    "shards over an intra-pod (ICI) axis")
            self.eff_topology = topology.drop_axis(sharding.shard_axis)
        else:
            self.shard_axis_index = None
            self.shard_size = 1
            self.eff_topology = topology
        self.P_eff = self.eff_topology.P
        self.S = cfg.group_size or grouping.default_group_size(self.P_eff)
        if self.S > self.P_eff:
            raise ValueError(f"group size {self.S} exceeds replica world "
                             f"{self.P_eff}")
        self.avg_dtype = (None if cfg.average_dtype is None
                          else np.dtype(cfg.average_dtype))
        if cfg.dynamic_groups:
            self.offsets: Tuple[int, ...] = grouping.distinct_offsets(
                self.P_eff, self.S)
        else:
            self.offsets = (0,)
        self.storage_struct = storage_struct    # SDS tree, storage dtypes
        self.work_struct = work_struct          # SDS tree, accumulation dtype
        self.payload_bytes = payload_bytes      # bytes of the work tree
        self.storage_payload_bytes = bucketing.tree_payload_bytes(
            storage_struct)
        # per-class budgets, resolved once at compile time
        self.class_bucket_bytes: Dict[int, int] = {}
        for ci in topology.classes_in_use():
            link = topology.link_classes[ci]
            if cfg.bucket_bytes is not None:
                self.class_bucket_bytes[ci] = cfg.bucket_bytes
            else:
                self.class_bucket_bytes[ci] = choose_class_bucket_bytes(
                    payload_bytes, link, overlap=cfg.overlap)
        self.sync_bucket_bytes = (cfg.bucket_bytes
                                  or bucketing.DEFAULT_BUCKET_BYTES)
        self._runs: Dict[int, Tuple[StageRun, ...]] = {}
        self._shard_layout: Optional[bucketing.BucketLayout] = None
        # layer-streamed state layout (DESIGN.md §11): derive the ordered
        # leaf groups from the layered tree convention up front so a
        # non-layered tree fails at compile time, not first gather
        if sharding.is_sharded and sharding.streamed:
            self._stream_groups = streaming.layered_leaf_groups(
                storage_struct)
            self.n_stream_spans = len(storage_struct["layers"])
        else:
            self._stream_groups = None
            self.n_stream_spans = 0
        self._stream_sublayouts: Dict[int, bucketing.BucketLayout] = {}

    # -- static schedule ---------------------------------------------------
    @property
    def n_phases(self) -> int:
        return len(self.offsets)

    def runs_for_offset(self, offset: int) -> Tuple[StageRun, ...]:
        """The offset's stages as maximal runs of equal link class.

        Bits live in the *effective* replica rank space — identical to the
        full dp space for replicated plans; the pod-level space (shard
        axis dropped) for sharded plans.
        """
        cached = self._runs.get(offset)
        if cached is not None:
            return cached
        bits = grouping.mask_bits_for_offset(self.P_eff, self.S, offset)
        runs: List[StageRun] = []
        for bit in bits:
            ci = self.eff_topology.class_of_bit(bit)
            if runs and runs[-1].class_index == ci:
                runs[-1] = StageRun(ci, runs[-1].bits + (bit,))
            else:
                runs.append(StageRun(ci, (bit,)))
        self._runs[offset] = tuple(runs)
        return self._runs[offset]

    def class_layout(self, class_index: int) -> bucketing.BucketLayout:
        """The (cached) bucket layout the class's stages pack into."""
        return bucketing.layout_for(
            self.work_struct,
            max_bucket_bytes=self.class_bucket_bytes[class_index])

    # -- sharded-state layout (ShardingPolicy.fsdp_within_pod) -------------
    @property
    def shard_layout(self) -> bucketing.BucketLayout:
        """Storage-dtype bucket layout the sharded state persists in.

        Every bucket is padded to shard_size x 128 elements so each device
        owns an equal, lane-aligned contiguous slice.  One layout serves
        storage, the fwd/bwd all-gather, the grad reduce-scatter, and the
        pod-to-pod butterfly (class budgets degenerate to the butterfly
        link class's budget under sharding — the stage bits all ride the
        non-shard axes, so there is no intra-butterfly repack).
        """
        if not self.sharding.is_sharded:
            raise ValueError("shard_layout is only defined for sharded plans")
        if self._shard_layout is None:
            self._shard_layout = bucketing.layout_for(
                self.storage_struct,
                max_bucket_bytes=self.shard_bucket_bytes,
                align=self.shard_size,
                groups=self._stream_groups)
        return self._shard_layout

    @property
    def shard_bucket_bytes(self) -> int:
        """The sharded state's bucket budget: the butterfly link class's."""
        if self.cfg.bucket_bytes is not None:
            return self.cfg.bucket_bytes
        eff_classes = self.eff_topology.classes_in_use()
        link_ci = max(eff_classes,
                      key=lambda ci: self.topology.link_classes[ci].beta)
        return self.class_bucket_bytes[link_ci]

    def shard_struct(self) -> tuple:
        """ShapeDtypeStructs of one device's owned shard slices."""
        lay = self.shard_layout
        return tuple(
            jax.ShapeDtypeStruct((s // self.shard_size,), d)
            for s, d in zip(lay.bucket_sizes, lay.bucket_dtypes))

    def shard_tree(self, tree) -> tuple:
        """Full local tree -> this device's owned shard slices.

        Must run inside shard_map (manual over the dp axes): packs into the
        shard layout and takes the ``axis_index(shard_axis)``-th slice of
        every bucket.
        """
        idx = jax.lax.axis_index(self.sharding.shard_axis)
        out = []
        for buf in bucketing.pack(tree, self.shard_layout):
            n = buf.shape[0] // self.shard_size
            out.append(jax.lax.dynamic_slice(buf, (idx * n,), (n,))
                       if n else buf)
        return tuple(out)

    def unshard_tree(self, shards) -> object:
        """Owned shard slices -> the full local tree (all-gather on ICI).

        One tiled all-gather per bucket over the shard axis — the
        forward/backward parameter gather of the FSDP-within-pod step.
        """
        ax = self.sharding.shard_axis
        bufs = tuple(
            jax.lax.all_gather(b, ax, tiled=True) if b.size else
            jnp.zeros((0,), b.dtype) for b in shards)
        return bucketing.unpack(bufs, self.shard_layout)

    def grad_shards(self, grad_tree) -> tuple:
        """Full-tree gradients -> owned fp32 grad slices (pod mean).

        One tiled ``psum_scatter`` per bucket over the shard axis, scaled
        by 1/shard_size: pod members form one logical worker whose
        gradient is the mean over members, and each device keeps only the
        slice its optimiser shard needs.
        """
        ax = self.sharding.shard_axis
        inv = 1.0 / self.shard_size
        out = []
        for buf in bucketing.pack(grad_tree, self.shard_layout,
                                  dtype=jnp.float32):
            if buf.size:
                buf = jax.lax.psum_scatter(buf, ax, scatter_dimension=0,
                                           tiled=True) * inv
            out.append(buf)
        return tuple(out)

    # -- layer-streamed gather/scatter (DESIGN.md §11) ---------------------
    def _require_streamed(self):
        if self._stream_groups is None:
            raise ValueError(
                "stream_* needs a streamed plan: compile with "
                "ShardingPolicy.fsdp_within_pod(axis, streamed=True) over "
                "the layered param tree")

    def stream_bucket_indices(self, group: int) -> Tuple[int, ...]:
        """Global bucket indices holding one stream group's leaves."""
        self._require_streamed()
        return self.shard_layout.group_bucket_indices(group)

    def stream_group_template(self, group: int):
        """The group's sub-SDS-tree of the layered storage struct."""
        self._require_streamed()
        if group == streaming.STEM_GROUP:
            return self.storage_struct["stem"]
        if group == streaming.head_group(self.n_stream_spans):
            return self.storage_struct["head"]
        return self.storage_struct["layers"][group - 1]

    def stream_sublayout(self, group: int) -> bucketing.BucketLayout:
        """Pack/unpack layout of ONE group's buckets (a layout view).

        Because the grouped global layout restarts its greedy fill at every
        group boundary, laying out the group's sub-tree alone at the same
        budget/alignment reproduces exactly the global layout's slice for
        that group — asserted here once per group, then cached.
        """
        self._require_streamed()
        lay = self._stream_sublayouts.get(group)
        if lay is not None:
            return lay
        lay = bucketing.layout_for(
            self.stream_group_template(group),
            max_bucket_bytes=self.shard_bucket_bytes, align=self.shard_size)
        idxs = self.stream_bucket_indices(group)
        glob = self.shard_layout
        if (lay.n_buckets != len(idxs)
                or tuple(lay.bucket_sizes) != tuple(
                    glob.bucket_sizes[i] for i in idxs)
                or tuple(lay.bucket_dtypes) != tuple(
                    glob.bucket_dtypes[i] for i in idxs)):
            raise AssertionError(
                f"group {group} sublayout diverged from the global grouped "
                f"layout: {lay.describe()} vs global buckets {idxs}")
        self._stream_sublayouts[group] = lay
        return lay

    def stream_unshard(self, shards, group: int, *, barrier: bool = False):
        """One group's shard slices -> its full sub-tree (all-gather on ICI).

        ``barrier=True`` fences the operands through
        ``lax.optimization_barrier`` — backward *re*-gathers must not CSE
        with the forward gathers, or XLA keeps the forward buffers alive
        and the streamed memory bound silently degrades to gather-all.
        """
        self._require_streamed()
        ax = self.sharding.shard_axis
        bufs = tuple(shards[i] for i in self.stream_bucket_indices(group))
        if barrier:
            bufs = streaming._barrier(bufs)
        gathered = tuple(
            jax.lax.all_gather(b, ax, tiled=True) if b.size else
            jnp.zeros((0,), b.dtype) for b in bufs)
        return bucketing.unpack(gathered, self.stream_sublayout(group))

    def stream_grad_shards(self, grad_subtree, group: int) -> tuple:
        """One group's full-tree grads -> owned fp32 pod-mean slices.

        The exact per-group mirror of :meth:`grad_shards`: cast-to-fp32
        pack into the group's buckets, tiled ``psum_scatter`` over the
        shard axis, scale by 1/shard_size — so streamed gradients are
        bit-identical to the gather-all path's.
        """
        self._require_streamed()
        ax = self.sharding.shard_axis
        inv = 1.0 / self.shard_size
        out = []
        for buf in bucketing.pack(grad_subtree, self.stream_sublayout(group),
                                  dtype=jnp.float32):
            if buf.size:
                buf = jax.lax.psum_scatter(buf, ax, scatter_dimension=0,
                                           tiled=True) * inv
            out.append(buf)
        return tuple(out)

    def stream_group_bytes(self) -> Dict[int, int]:
        """Gathered (padded storage) bytes per stream group."""
        self._require_streamed()
        lay = self.shard_layout
        return {g: lay.group_bytes(g) for g in sorted(set(lay.bucket_groups))}

    def stream_peak_gathered_bytes(self) -> int:
        """Peak gathered bytes of the streamed schedule (liveness walk)."""
        self._require_streamed()
        return streaming.max_in_flight_gathered_bytes(
            self.stream_group_bytes(), self.n_stream_spans)

    def full_gathered_bytes(self) -> int:
        """Transient bytes of a gather-all unshard (every padded bucket)."""
        lay = self.shard_layout
        return sum(s * d.itemsize
                   for s, d in zip(lay.bucket_sizes, lay.bucket_dtypes))

    # -- execution: the paper's group butterfly ----------------------------
    def average(self, tree, phase: int):
        """Wait-avoiding group model averaging for compiled phase ``phase``.

        Replicated plans take (and return) the local params pytree; sharded
        plans take the tuple of owned shard-slice buffers and butterfly
        them pod-to-pod directly (each device exchanges only its slice).
        """
        return self.average_offset(tree, self.offsets[phase])

    def _cast_shards(self, shards):
        if self.avg_dtype is None:
            return list(shards)
        return [b.astype(self.avg_dtype) if b.size else b for b in shards]

    def _uncast_shards(self, work, shards):
        return tuple(w.astype(b.dtype) for w, b in zip(work, shards))

    def _average_sharded(self, shards, offset: int):
        """Pod-to-pod butterfly on the shard-slice buffers.

        Per element the arithmetic is exactly the replicated reference's —
        log2(S) adds in stage order, then one scale — applied to each
        device's slice, so the sharded path stays bit-identical to the
        replicated plan and the stacked simulator (tests/test_replica.py).
        """
        bits = grouping.mask_bits_for_offset(self.P_eff, self.S, offset)
        inv_s = 1.0 / self.S
        exchange = lambda buf, bit: butterfly_exchange(
            buf, bit, self.eff_topology.axis_names,
            self.eff_topology.axis_sizes)
        pallas = True if self.cfg.use_pallas is None else self.cfg.use_pallas
        work = self._cast_shards(shards)
        if self.cfg.overlap:
            work = pipeline.overlapped_butterfly(
                work, bits, inv_s, exchange=exchange,
                combine_many=lambda a, r, s: _combine_many(a, r, s, pallas))
        else:
            out = []
            for buf in work:
                if not buf.size:
                    out.append(buf)
                    continue
                for i, bit in enumerate(bits):
                    recv = exchange(buf, bit)
                    s = inv_s if i == len(bits) - 1 else 1.0
                    buf = _stage_combine(buf, recv, s, pallas)
                out.append(buf)
            work = out
        return self._uncast_shards(work, shards)

    def average_offset(self, tree, offset: int):
        """Group averaging for an explicit phase offset."""
        if self.sharding.is_sharded:
            return self._average_sharded(tree, offset)
        bits = grouping.mask_bits_for_offset(self.P_eff, self.S, offset)
        inv_s = 1.0 / self.S
        exchange = lambda buf, bit: butterfly_exchange(
            buf, bit, self.topology.axis_names, self.topology.axis_sizes)

        if not self.cfg.fused:
            def avg_leaf(w):
                orig_dtype = w.dtype
                acc = w.astype(self.avg_dtype) if self.avg_dtype is not None \
                    else w
                for bit in bits:
                    acc = acc + exchange(acc, bit)
                acc = acc * jnp.asarray(inv_s, acc.dtype)
                return acc.astype(orig_dtype)

            return jax.tree.map(avg_leaf, tree)

        pallas = True if self.cfg.use_pallas is None else self.cfg.use_pallas
        runs = self.runs_for_offset(offset)
        # Cast once up front and keep the accumulation dtype across runs, so
        # multi-class butterflies stay bit-identical to the per-leaf
        # reference (no intermediate storage-dtype round trips).
        if self.avg_dtype is not None:
            work = jax.tree.map(lambda w: w.astype(self.avg_dtype), tree)
        else:
            work = tree
        for ri, run in enumerate(runs):
            scale = inv_s if ri == len(runs) - 1 else 1.0
            budget = self.class_bucket_bytes[run.class_index]
            if self.cfg.overlap:
                def mix_all(bufs, run=run, scale=scale):
                    return pipeline.overlapped_butterfly(
                        bufs, run.bits, scale, exchange=exchange,
                        combine_many=lambda a, r, s: _combine_many(
                            a, r, s, pallas))
                work = bucketing.tree_map_buckets(
                    mix_all, work, compute_dtype=None,
                    max_bucket_bytes=budget)
            else:
                def mix(acc, run=run, scale=scale):
                    for i, bit in enumerate(run.bits):
                        recv = exchange(acc, bit)
                        s = scale if i == len(run.bits) - 1 else 1.0
                        acc = _stage_combine(acc, recv, s, pallas)
                    return acc
                work = bucketing.tree_map_bucketed(
                    mix, work, compute_dtype=None, max_bucket_bytes=budget)
        if self.avg_dtype is None:
            return work
        return jax.tree.map(lambda w, o: w.astype(o.dtype), work, tree)

    # -- execution: tau-periodic global sync -------------------------------
    def sync(self, tree):
        """Synchronous allreduce mean over all replicas (Alg. 2 line 16).

        Sharded plans pmean the shard-slice buffers over the *effective*
        (pod) axes only — shard-axis neighbours hold different slices, not
        divergent copies, so they are never averaged.
        """
        if self.sharding.is_sharded:
            names = self.eff_topology.axis_names
            return tuple(
                jax.lax.pmean(b.astype(jnp.float32), names).astype(b.dtype)
                if b.size else b for b in tree)
        names = self.topology.axis_names
        if not self.cfg.fused:
            return jax.tree.map(
                lambda w: jax.lax.pmean(w.astype(jnp.float32),
                                        names).astype(w.dtype), tree)
        return bucketing.tree_map_bucketed(
            lambda buf: jax.lax.pmean(buf, names), tree,
            compute_dtype=jnp.float32,
            max_bucket_bytes=self.sync_bucket_bytes)

    # -- execution: single-round gossip/psum mixes (baseline averagers) ----
    def mix_bucket_bytes(self, bits: Tuple[int, ...] = ()) -> int:
        """Budget for a single-round mix touching the given dp-rank bits.

        The mix's collectives ride the classes of its bits (all classes for
        a global collective, ``bits=()``); the budget follows the slowest
        wire involved — the link the mix is bound by.
        """
        if self.cfg.bucket_bytes is not None:
            return self.cfg.bucket_bytes
        if bits:
            classes = {self.eff_topology.class_of_bit(b) for b in bits}
            link = max((self.topology.link_classes[c] for c in classes),
                       key=lambda l: l.beta)
        else:
            link = self.eff_topology.bottleneck()
        return choose_class_bucket_bytes(self.payload_bytes, link,
                                         overlap=self.cfg.overlap)

    def mix(self, tree, issue: Callable, combine: Callable, *,
            bits: Tuple[int, ...] = ()):
        """Apply a flat fp32 gossip/psum mix per bucket (fused) or per leaf.

        ``issue(buf) -> recv`` is the collective half (shape-polymorphic),
        ``combine(buf, recv) -> buf`` the local arithmetic; per leaf and per
        serial bucket the halves compose back into the original mix, so all
        granularities compute identical element math.  With ``overlap=True``
        every bucket's collectives are issued before any bucket's combine
        (core/overlap.py single-stage pipeline).

        Sharded plans run the mix directly on the shard-slice buffers
        (``bits`` are effective/pod-space bits; the issue half must ride
        the non-shard axes only — the averagers guarantee that).
        """
        mixfn = lambda buf: combine(buf, issue(buf))
        if self.sharding.is_sharded:
            work = [b.astype(jnp.float32) if b.size else b for b in tree]
            if self.cfg.overlap:
                out = pipeline.overlapped_mix(work, issue, combine)
            else:
                out = [mixfn(b) if b.size else b for b in work]
            return tuple(o.astype(b.dtype) for o, b in zip(out, tree))
        if not self.cfg.fused:
            return jax.tree.map(
                lambda w: mixfn(w.astype(jnp.float32)).astype(w.dtype), tree)
        budget = self.mix_bucket_bytes(tuple(bits))
        if not self.cfg.overlap:
            return bucketing.tree_map_bucketed(
                mixfn, tree, compute_dtype=jnp.float32,
                max_bucket_bytes=budget)
        return bucketing.tree_map_buckets(
            lambda bufs: pipeline.overlapped_mix(bufs, issue, combine),
            tree, compute_dtype=jnp.float32, max_bucket_bytes=budget)

    # -- stacked-simulator twins (single process, leading replica axis) ----
    def average_stacked(self, stacked_tree, *, t: int):
        """Simulator twin over the logical replica axis (P_eff rows)."""
        from repro.core import group_allreduce as ga
        return ga.group_average_stacked(stacked_tree, P=self.P_eff,
                                        S=self.S, t=t)

    def sync_stacked(self, stacked_tree):
        from repro.core import group_allreduce as ga
        return ga.global_average_stacked(stacked_tree, P=self.P_eff)

    # -- accounting / analysis ---------------------------------------------
    def n_leaves(self) -> int:
        return len(jax.tree_util.tree_leaves(self.work_struct))

    def butterfly_summary(self, offset: int = 0) -> List[dict]:
        """One dict per stage run: link class, bits, budget, launch count.

        Sharding never changes the launch count per stage — the sharded
        butterfly runs one ppermute per shard-layout bucket, not per
        (bucket x shard) — so under FSDP every class reports the shard
        layout's bucket count.
        """
        out = []
        for run in self.runs_for_offset(offset):
            link = self.topology.link_classes[run.class_index]
            if self.sharding.is_sharded:
                units = self.shard_layout.n_buckets
                budget = self.shard_bucket_bytes
            else:
                units = (self.class_layout(run.class_index).n_buckets
                         if self.cfg.fused else self.n_leaves())
                budget = self.class_bucket_bytes[run.class_index]
            out.append({
                "link": link.name,
                "bits": run.bits,
                "axes": tuple(self.eff_topology.axis_of_bit(b)
                              for b in run.bits),
                "stages": len(run.bits),
                "bucket_bytes": budget,
                "n_buckets": units,
                "ppermutes": len(run.bits) * units,
            })
        return out

    def per_class_expected(self, offset: int = 0) -> Dict[str, dict]:
        """Expected ppermute launches per link class at one phase offset."""
        agg: Dict[str, dict] = {}
        for run in self.butterfly_summary(offset):
            ent = agg.setdefault(run["link"], {
                "stages": 0, "ppermutes": 0,
                "bucket_bytes": run["bucket_bytes"],
                "n_buckets": run["n_buckets"],
                "axes": (),
            })
            ent["stages"] += run["stages"]
            ent["ppermutes"] += run["ppermutes"]
            ent["axes"] = tuple(dict.fromkeys(ent["axes"] + run["axes"]))
        return agg

    def expected_ppermutes(self, offset: int = 0) -> int:
        return sum(r["ppermutes"] for r in self.butterfly_summary(offset))

    def modeled_step_seconds(self, *, overlap: Optional[bool] = None) -> dict:
        """Per-class alpha-beta-gamma model of this plan's step time."""
        return modeled_wagma_step_seconds(
            self.payload_bytes, self.topology, self.S, tau=self.cfg.tau,
            overlap=self.cfg.overlap if overlap is None else overlap,
            bucket_bytes=self.cfg.bucket_bytes)

    def describe(self) -> str:
        """Human-readable plan summary (stages, classes, budgets)."""
        lines = [
            f"AveragingPlan P={self.P} S={self.S} tau={self.cfg.tau} "
            f"payload={self.payload_bytes / 2**20:.2f}MiB "
            f"avg_dtype={self.avg_dtype} fused={self.cfg.fused} "
            f"overlap={self.cfg.overlap}",
            f"  topology: {self.topology.describe()}",
            f"  sharding: {self.sharding.describe()}"
            + (f" -> {self.P_eff} logical replicas of "
               f"{self.shard_size} shards" if self.sharding.is_sharded
               else ""),
        ]
        if self.sharding.is_sharded:
            lines.append(
                f"  shard layout: budget "
                f"{self.shard_bucket_bytes / 2**20:.0f}MiB -> "
                f"{self.shard_layout.n_buckets} buckets x "
                f"{self.shard_size} slices")
            if self._stream_groups is not None:
                lay = self.shard_layout
                lines.append(
                    f"  layer map ({self.n_stream_spans} spans + stem/head):"
                    f" {lay.describe_groups()}")
                lines.append(
                    f"  streamed coverage: peak gathered "
                    f"{self.stream_peak_gathered_bytes() / 2**20:.2f}MiB "
                    f"of {self.full_gathered_bytes() / 2**20:.2f}MiB "
                    f"full-tree ({streaming.expected_stream_gathers(self)} "
                    f"gathers/step fwd+bwd)")
        else:
            for ci in self.topology.classes_in_use():
                link = self.topology.link_classes[ci]
                bb = self.class_bucket_bytes[ci]
                nb = self.class_layout(ci).n_buckets if self.cfg.fused else 0
                lines.append(f"  class {link.name}: budget "
                             f"{bb / 2**20:.0f}MiB -> {nb} buckets")
        for ph, off in enumerate(self.offsets):
            runs = ", ".join(
                f"{r['link']}[bits={list(r['bits'])} x{r['n_buckets']}buk]"
                for r in self.butterfly_summary(off))
            lines.append(f"  phase {ph} (offset {off}): {runs}")
        lines.append(f"  sync: pmean budget "
                     f"{self.sync_bucket_bytes / 2**20:.0f}MiB")
        stats = bucketing.layout_cache_stats()
        lines.append(f"  layout cache: {stats['hits']} hits / "
                     f"{stats['misses']} misses")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Compilation (cached on topology x config x tree structure)
# ---------------------------------------------------------------------------

_PLAN_CACHE: Dict[tuple, AveragingPlan] = {}
# Sharded plans are additionally indexed by the *shard-buffer* structure
# they produce, so averagers handed the sharded state (a tuple of slice
# buffers) inside the train step resolve back to the plan compiled from
# the full tree at init time.
_SHARD_STRUCT_CACHE: Dict[tuple, AveragingPlan] = {}


def clear_plan_cache() -> None:
    """Drop every compile-time cache this subsystem owns.

    The single delegating entry point: compiled plans (and the treedefs
    they retain), the shard-struct index, the per-class budget sweep, AND
    ``bucketing``'s layout cache + budget sweep — a long-lived process
    that recompiles after a topology change must be able to release all
    of it with one call (previously only the autouse test fixture
    cleared the layout cache, so production churn leaked layouts).
    """
    _PLAN_CACHE.clear()
    _SHARD_STRUCT_CACHE.clear()
    choose_class_bucket_bytes.cache_clear()
    bucketing.clear_layout_cache()


def evict_topology(topology: Topology) -> int:
    """Drop cached plans compiled for one topology; returns entries removed.

    Membership changes (core/elastic.py) retire topologies for good — the
    old world size never comes back under the same object — so the
    controller evicts their plans instead of nuking every cache the way
    :func:`clear_plan_cache` does.  Cache keys lead with the topology, so
    eviction is a key-prefix filter.
    """
    removed = 0
    for cache in (_PLAN_CACHE, _SHARD_STRUCT_CACHE):
        dead = [k for k in cache if k[0] == topology]
        for k in dead:
            del cache[k]
        removed += len(dead)
    return removed


def _structure_key(tree) -> tuple:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return (treedef, tuple((tuple(l.shape), np.dtype(l.dtype).str)
                           for l in leaves))


def _config_key(cfg: AveragingConfig) -> tuple:
    avg = None if cfg.average_dtype is None \
        else np.dtype(cfg.average_dtype).name
    return (cfg.group_size, cfg.tau, avg, cfg.dynamic_groups, cfg.fused,
            cfg.bucket_bytes, cfg.use_pallas, cfg.overlap)


def compile_plan(topology: Topology, tree_shapes,
                 config: AveragingConfig = AveragingConfig(),
                 sharding: ShardingPolicy = REPLICATED) -> AveragingPlan:
    """Compile the collective once for a tree structure on a topology.

    ``tree_shapes`` may be concrete arrays, tracers, or ShapeDtypeStructs —
    only structure/shapes/dtypes are read.  Cached on (topology, config,
    sharding, structure): repeated calls from every compiled phase variant
    return the same plan object, and only the first call derives
    budgets/layouts.

    ``sharding`` selects the replica-state realisation the plan executes
    (DESIGN.md §10): ``ShardingPolicy.fsdp_within_pod(axis)`` compiles the
    sharded-state plan — ``tree_shapes`` is still the FULL local tree; the
    plan derives the shard-aligned bucket layout, and subsequent
    ``compile_plan`` calls that pass the plan's own shard-buffer tuple
    (the state the train step actually holds) resolve to the same plan.
    """
    skey = (topology, _config_key(config), sharding)
    if sharding.is_sharded:
        plan = _SHARD_STRUCT_CACHE.get(skey + (_structure_key(tree_shapes),))
        if plan is not None:
            return plan
    key = skey + (_structure_key(tree_shapes),)
    plan = _PLAN_CACHE.get(key)
    if plan is not None:
        return plan
    storage = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree_shapes)
    avg = None if config.average_dtype is None \
        else np.dtype(config.average_dtype)
    work = storage if avg is None else jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, avg), storage)
    payload = bucketing.tree_payload_bytes(work)
    plan = AveragingPlan(topology, config, storage, work, payload,
                         sharding=sharding)
    _PLAN_CACHE[key] = plan
    if sharding.is_sharded:
        # register BOTH shard-buffer structures the train step holds: the
        # storage-dtype param slices and the fp32 gradient slices
        # (grad_shards packs fp32 buffers of the same shapes), so
        # plan_for(grads) resolves here instead of silently compiling a
        # bogus plan that treats the slice tuple as a full model tree
        _SHARD_STRUCT_CACHE[
            skey + (_structure_key(plan.shard_struct()),)] = plan
        grad_struct = tuple(
            jax.ShapeDtypeStruct(s.shape, np.dtype(np.float32))
            for s in plan.shard_struct())
        _SHARD_STRUCT_CACHE.setdefault(
            skey + (_structure_key(grad_struct),), plan)
    return plan
