"""Dynamic grouping strategy (paper Algorithm 1).

The paper's pseudocode (``mask <<= shift``) is internally inconsistent with its
own worked example (P=8, S=4: iteration 1 must yield groups {0,1,4,5} and
{2,3,6,7}); the example-consistent form — which we implement and pin with
tests — is:

    stage r of iteration t exchanges over XOR-mask bit  (t*log2(S) + r) % log2(P)

for r = 0..log2(S)-1.  The union of those pairwise XOR relations partitions the
P workers into P/S non-overlapping groups of size S, and the initial bit
rotates every iteration so local updates propagate globally within
ceil(log(P)/log(S)) iterations.

Everything in this module is pure Python/NumPy on *static* quantities (the
group pattern of iteration t), because XLA collectives need static
permutations: the training loop selects one of ``n_phases(P, S)`` compiled
step variants by ``phase_offset(P, S, t)``.
"""

from __future__ import annotations

import math
from functools import lru_cache
from typing import List, Sequence, Tuple


def ilog2(x: int) -> int:
    """Exact integer log2; raises for non powers of two."""
    if x <= 0 or (x & (x - 1)) != 0:
        raise ValueError(f"{x} is not a positive power of two")
    return x.bit_length() - 1


def default_group_size(P: int) -> int:
    """The paper's S = sqrt(P), rounded down to a power of two (S>=2 for P>=4)."""
    lp = ilog2(P)
    return 1 << max(1, lp // 2) if P >= 4 else P


def phase_offset(P: int, S: int, t: int) -> int:
    """First butterfly bit used at iteration t: (t*log2 S) mod log2 P."""
    lp, ls = ilog2(P), ilog2(S)
    if ls == 0:
        return 0
    return (t * ls) % lp


def n_phases(P: int, S: int) -> int:
    """Number of distinct phase offsets (== number of compiled step variants)."""
    lp, ls = ilog2(P), ilog2(S)
    if ls == 0:
        return 1
    # offsets cycle through multiples of gcd(ls, lp) mod lp
    return lp // math.gcd(ls, lp)


def distinct_offsets(P: int, S: int) -> Tuple[int, ...]:
    """The phase offsets actually reached over the iteration sequence."""
    seen, out, t = set(), [], 0
    lp = ilog2(P)
    for t in range(lp + 1):
        o = phase_offset(P, S, t)
        if o in seen:
            break
        seen.add(o)
        out.append(o)
    return tuple(out)


def mask_bits_for_offset(P: int, S: int, offset: int) -> Tuple[int, ...]:
    """XOR-mask bit positions for the log2(S) butterfly stages, given an offset."""
    lp, ls = ilog2(P), ilog2(S)
    return tuple((offset + r) % lp for r in range(ls))


def mask_bits(P: int, S: int, t: int) -> Tuple[int, ...]:
    """XOR-mask bit positions exercised at iteration t (Algorithm 1)."""
    return mask_bits_for_offset(P, S, phase_offset(P, S, t))


@lru_cache(maxsize=None)
def groups_for_offset(P: int, S: int, offset: int) -> Tuple[Tuple[int, ...], ...]:
    """Partition of range(P) into P/S groups of size S for a phase offset.

    Union-find over the pairwise XOR equivalence relations of Algorithm 1.
    """
    bits = mask_bits_for_offset(P, S, offset)
    parent = list(range(P))

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for b in bits:
        m = 1 << b
        for p in range(P):
            q = p ^ m
            rp, rq = find(p), find(q)
            if rp != rq:
                parent[max(rp, rq)] = min(rp, rq)

    byroot = {}
    for p in range(P):
        byroot.setdefault(find(p), []).append(p)
    groups = tuple(tuple(sorted(g)) for g in sorted(byroot.values()))
    assert all(len(g) == S for g in groups), (P, S, offset, groups)
    return groups


def groups_for_iteration(P: int, S: int, t: int) -> Tuple[Tuple[int, ...], ...]:
    """The P/S groups active at training iteration t."""
    return groups_for_offset(P, S, phase_offset(P, S, t))


def averaging_matrix(P: int, S: int, t: int):
    """Doubly-stochastic P x P matrix A_t with A[i,j] = 1/S iff same group.

    Used by the stacked (single-process) simulator: W_next = A_t @ W.
    Returned as a nested list to keep this module numpy/jax-free.
    """
    A = [[0.0] * P for _ in range(P)]
    for g in groups_for_iteration(P, S, t):
        w = 1.0 / S
        for i in g:
            for j in g:
                A[i][j] = w
    return A


def propagation_latency(P: int, S: int) -> int:
    """Iterations for one worker's update to influence all P workers.

    With dynamic grouping each iteration multiplies the influenced set by S
    (fresh bits every step), so ceil(log_S P) iterations suffice — the paper's
    `log_S P` claim (e.g. P=64, S=8 -> 2).
    """
    if S <= 1:
        return math.inf if P > 1 else 0
    lp, ls = ilog2(P), ilog2(S)
    return math.ceil(lp / ls)


def split_bit_over_axes(bit: int, axis_sizes: Sequence[int]) -> Tuple[int, int]:
    """Map a global dp-rank XOR bit onto (axis_index, local_bit).

    ``axis_sizes`` is minor-to-major (e.g. [16, 2] for data=16 minor,
    pod=2 major; global rank = pod_idx*16 + data_idx).
    """
    for ax, size in enumerate(axis_sizes):
        lb = ilog2(size)
        if bit < lb:
            return ax, bit
        bit -= lb
    raise ValueError("bit exceeds total dp rank space")
