"""Functional simulator of WAGMA-SGD's wait-avoidance semantics (Alg. 2 lines 8-17).

TPU pods execute SPMD in lock-step, so the *activation/staleness* half of the
paper cannot occur on the production path (see DESIGN.md §2).  This module
simulates it faithfully on stacked (P, ...) pytrees so that the convergence
benchmarks can reproduce the paper's accuracy claims under straggler
injection (paper §V-B simulated 320 ms delays):

* every worker keeps a *send buffer* holding the last local model it completed
  (paper Fig. 3);
* when the group allreduce of iteration t triggers, on-time workers contribute
  the fresh ``W'_t`` while stragglers passively contribute their (stale)
  buffer;
* a straggler that finishes during iteration t merges late:
  ``W_{t+1} = (W_sum + W'_t) / (S+1)``  (Alg. 2 line 13);
* a worker so slow it does not finish at all keeps computing — its buffer ages
  by one iteration (bounded-staleness growth, theory Assumption 3);
* every tau iterations a global synchronous allreduce forces consistency
  (Alg. 2 line 16), resetting all staleness to zero.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import group_allreduce, grouping


class SimState(NamedTuple):
    """Stacked per-worker state. All pytree leaves have leading axis P."""
    models: object        # W_t^i        — current working model
    buffers: object       # send buffer  — last *completed* local model W'
    age: jnp.ndarray      # (P,) int32   — staleness of each buffer, iterations
    step: jnp.ndarray     # ()  int32    — global iteration t


def init_state(stacked_params) -> SimState:
    P = jax.tree.leaves(stacked_params)[0].shape[0]
    return SimState(
        models=stacked_params,
        buffers=jax.tree.map(jnp.copy, stacked_params),
        age=jnp.zeros((P,), jnp.int32),
        step=jnp.zeros((), jnp.int32),
    )


def _where_workers(mask, a, b):
    """Select per-worker between two stacked pytrees with a (P,) bool mask."""
    def sel(x, y):
        m = mask.reshape((-1,) + (1,) * (x.ndim - 1))
        return jnp.where(m, x, y)
    return jax.tree.map(sel, a, b)


def wagma_sim_step(state: SimState, local_update: Callable, *, P: int, S: int,
                   tau: int, ready: jnp.ndarray, completes: jnp.ndarray,
                   t: int) -> SimState:
    """One simulated WAGMA-SGD iteration.

    Args:
      local_update: stacked-models -> stacked proposed W' (applies the local
        SGD/optimiser step per worker on its own shard of data).
      ready:     (P,) bool — finished *before* the group collective triggered;
                 contributes fresh W' (Alg. 2 line 10-11).
      completes: (P,) bool — finishes its local step within iteration t at all.
                 ready implies completes. Late-but-completing workers merge via
                 line 13; non-completing workers keep computing (buffer ages).
      t: python int iteration (selects the dynamic group pattern).
    """
    ready = jnp.logical_and(ready, completes)
    Wprime = local_update(state.models)

    sync_now = (t + 1) % tau == 0
    if sync_now:
        # Global barrier: everyone is forced to finish and contribute (line 16).
        avg = group_allreduce.global_average_stacked(Wprime, P=P)
        return SimState(models=avg,
                        buffers=jax.tree.map(jnp.copy, Wprime),
                        age=jnp.zeros_like(state.age),
                        step=state.step + 1)

    # Contribution: fresh if ready, else the stale send buffer.
    contrib = _where_workers(ready, Wprime, state.buffers)

    # Group sums via the iteration-t averaging matrix (A @ contrib == Wsum/S).
    group_mean = group_allreduce.group_average_stacked(contrib, P=P, S=S, t=t)

    # line 11: ready worker adopts the group mean (== Wsum / S).
    # line 13: late-but-completing worker merges its late W':
    #          (Wsum + W') / (S+1) == (S * group_mean + W') / (S+1)
    def late_merge(gm, wp):
        return (S * gm.astype(jnp.float32) + wp.astype(jnp.float32)) / (S + 1.0)

    merged = jax.tree.map(lambda gm, wp: late_merge(gm, wp).astype(gm.dtype),
                          group_mean, Wprime)
    next_completing = _where_workers(ready, group_mean, merged)
    # Non-completing workers are still mid-computation: model unchanged.
    models = _where_workers(completes, next_completing, state.models)

    # Send buffer: updated with W' whenever the local step completed.
    buffers = _where_workers(completes, Wprime, state.buffers)
    age = jnp.where(ready, 0, jnp.where(completes, 1, state.age + 1))

    return SimState(models=models, buffers=buffers, age=age.astype(jnp.int32),
                    step=state.step + 1)


@dataclass
class StragglerModel:
    """Samples per-iteration readiness, mimicking paper §V-B's injected delay.

    Each iteration, ``n_stragglers`` distinct workers are drawn; a straggler is
    late to the collective, and with probability ``p_stall`` it does not even
    complete its local step within the iteration (multi-step staleness).
    """
    P: int
    n_stragglers: int = 2
    p_stall: float = 0.0
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def sample(self):
        ready = np.ones((self.P,), bool)
        completes = np.ones((self.P,), bool)
        if self.n_stragglers > 0:
            idx = self._rng.choice(self.P, size=self.n_stragglers, replace=False)
            ready[idx] = False
            stall = self._rng.random(self.n_stragglers) < self.p_stall
            completes[idx[stall]] = False
        return jnp.asarray(ready), jnp.asarray(completes)


def max_staleness_bound(tau: int) -> int:
    """Theory Assumption 3: staleness is bounded by the sync period."""
    return tau


class StalenessBoundExceeded(RuntimeError):
    """A worker's skipped contributions aged past max_staleness_bound(tau).

    Theory Assumption 3 no longer holds for this run — the degraded-mode
    driver hard-aborts rather than silently averaging arbitrarily stale
    state (DESIGN.md §13)."""


@dataclass
class SkipLedger:
    """Host-side staleness accounting for skipped contributions.

    The enforced twin of the simulator's per-worker buffer ``age``
    (`wagma_sim_step`): when the degraded-mode driver runs a round
    without a suspected partner, it charges that worker one round of
    staleness here.  The charge raises `StalenessBoundExceeded` the
    moment the age would pass `max_staleness_bound(tau)` — a hang the
    detector tolerates too long must abort, not corrupt.  Rejoining at
    a tau-sync barrier resets the age to zero (the joiner adopts the
    post-sync consensus); a confirmed-dead worker is dropped (its state
    will never be averaged in again, so it carries no staleness debt).
    """
    tau: int

    def __post_init__(self):
        self.ages: dict = {}
        self.total_skipped: dict = {}
        self.peak_age: int = 0

    def charge(self, worker: int, step: int) -> int:
        """One skipped group round for ``worker`` at ``step``."""
        age = self.ages.get(worker, 0) + 1
        self.ages[worker] = age
        self.total_skipped[worker] = self.total_skipped.get(worker, 0) + 1
        self.peak_age = max(self.peak_age, age)
        if age > max_staleness_bound(self.tau):
            raise StalenessBoundExceeded(
                f"worker {worker} skipped {age} rounds at step {step}, "
                f"exceeding max_staleness_bound(tau={self.tau})="
                f"{max_staleness_bound(self.tau)}")
        return age

    def reset(self, worker: int) -> None:
        """Worker contributed again (rejoined at a sync barrier)."""
        self.ages.pop(worker, None)

    def drop(self, worker: int) -> None:
        """Worker confirmed dead: no future contribution to age."""
        self.ages.pop(worker, None)

    def max_age(self) -> int:
        return max(self.ages.values(), default=0)

    def snapshot(self) -> dict:
        return {"ages": dict(self.ages),
                "total_skipped": dict(self.total_skipped),
                "peak_age": self.peak_age}
