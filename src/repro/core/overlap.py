"""Software-pipelined bucket scheduler — hide the combine behind the wire.

The serial bucketed averaging path (DESIGN.md §7) walks buckets one at a
time: bucket k's ``ppermute`` must land, then its combine runs, then bucket
k+1's ``ppermute`` is issued — so combine time adds directly to wire time.
This module restructures the butterfly into a **wavefront over the
(bucket, stage) grid** (DESIGN.md §8):

* within a stage, bucket k+1's exchange is *issued before* bucket k's
  combine runs (double buffering: while bucket k's arithmetic executes,
  bucket k+1's payload is already on the wire);
* across stages there is no global barrier: bucket k starts stage s+1 as
  soon as *its own* stage-s combine is done, regardless of how far the
  other buckets have progressed.

Only inter-bucket interleaving changes.  Each bucket still sees exactly the
serial per-bucket program — ``log2(S)`` exchange+add stages in order, scale
on the last — and buckets never read each other's data, so the overlapped
path is bit-compatible with the serial bucketed path and the per-leaf
reference (pinned by tests/test_overlap.py on every phase offset).

The schedule is the classic modulo schedule with initiation interval 1
across buckets and 2 along a bucket's own stage chain: cell ``(k, s)``
(bucket k, butterfly stage s) issues its exchange at tick ``k + 2s`` and
combines at tick ``k + 2s + 1``; within a tick all exchanges are emitted
before any combine.  That ordering realises both pipeline properties above
in the linear program order XLA sees, so its async collective-permute
(start/done) scheduler can overlap bucket k's combine with bucket k+1's
wire time.  Combines that fall on the same tick are mutually independent
and are handed to the caller *as a batch*, which the fused path feeds to
the multi-bucket Pallas kernel (one ``pallas_call`` whose grid walks
buckets x row-tiles) instead of one kernel launch per bucket.

The same module models the throughput claim: ``overlapped_stage_seconds``
turns the per-stage alpha-beta cost from ``launch + wire + combine`` into
``launch + max(wire, combine) + pipeline fill/drain`` (see
``group_allreduce.collective_time(overlap=True)``).
"""

from __future__ import annotations

from functools import lru_cache
from typing import Callable, Dict, List, Sequence, Tuple

EXCHANGE = "exchange"
COMBINE = "combine"

# (phase, bucket, stage): phase is EXCHANGE or COMBINE
Event = Tuple[str, int, int]


@lru_cache(maxsize=None)
def pipeline_schedule(n_buckets: int, n_stages: int) -> Tuple[Event, ...]:
    """Wavefront emission order over the (bucket, stage) grid.

    Cell (k, s) exchanges at tick ``k + 2s`` and combines at tick
    ``k + 2s + 1``; per tick, exchanges are emitted before combines.  The
    schedule therefore satisfies, in emission order:

    * per-bucket stage chain: exchange(k, s) < combine(k, s)
      < exchange(k, s+1)   (correctness — stage order unchanged);
    * overlap: exchange(k+1, s) < combine(k, s)   (bucket k+1's payload is
      on the wire before bucket k's arithmetic runs);
    * no stage barrier: exchange(k, s+1) < combine(k', s) for all
      k' >= k + 2 (bucket k advances while later buckets still combine
      the previous stage).
    """
    if n_buckets <= 0 or n_stages <= 0:
        return ()
    events: List[Event] = []
    last_tick = (n_buckets - 1) + 2 * (n_stages - 1) + 1
    for tick in range(last_tick + 1):
        for k in range(min(n_buckets - 1, tick), -1, -1):
            rem = tick - k
            if rem % 2 == 0 and rem // 2 < n_stages:
                events.append((EXCHANGE, k, rem // 2))
        for k in range(min(n_buckets - 1, tick - 1), -1, -1):
            rem = tick - 1 - k
            if rem % 2 == 0 and rem // 2 < n_stages:
                events.append((COMBINE, k, rem // 2))
    return tuple(events)


def validate_schedule(events: Sequence[Event], n_buckets: int,
                      n_stages: int) -> None:
    """Assert the three schedule invariants (used by tests; cheap, pure)."""
    pos = {(ph, k, s): i for i, (ph, k, s) in enumerate(events)}
    assert len(pos) == len(events) == 2 * n_buckets * n_stages, \
        "every cell must exchange exactly once and combine exactly once"
    for k in range(n_buckets):
        for s in range(n_stages):
            assert pos[(EXCHANGE, k, s)] < pos[(COMBINE, k, s)], (k, s)
            if s + 1 < n_stages:
                assert pos[(COMBINE, k, s)] < pos[(EXCHANGE, k, s + 1)], (k, s)
            if k + 1 < n_buckets:
                # the tentpole property: next bucket's wire before my combine
                assert pos[(EXCHANGE, k + 1, s)] < pos[(COMBINE, k, s)], (k, s)


def combine_batches(events: Sequence[Event]) -> List[List[Tuple[int, int]]]:
    """Group consecutive combine events into batches of independent cells.

    Each batch is every combine emitted between two exchange runs; cells in
    a batch touch distinct buckets, so the fused path hands a whole batch to
    one multi-bucket kernel launch instead of one launch per bucket.
    """
    batches: List[List[Tuple[int, int]]] = []
    cur: List[Tuple[int, int]] = []
    for ph, k, s in events:
        if ph == COMBINE:
            cur.append((k, s))
        elif cur:
            batches.append(cur)
            cur = []
    if cur:
        batches.append(cur)
    return batches


def overlapped_butterfly(bufs: Sequence, bits: Sequence[int], inv_s: float,
                         exchange: Callable, combine_many: Callable) -> list:
    """Run the butterfly over flat buckets in wavefront order.

    ``bufs``          flat per-bucket buffers (1-D arrays; zero-size buffers
                      pass through untouched).
    ``bits``          the log2(S) XOR mask bits, in per-bucket stage order.
    ``inv_s``         final scale, applied inside the *last* combine only —
                      exactly the serial path's arithmetic.
    ``exchange(buf, bit) -> recv``
                      one butterfly wire step (ppermute).
    ``combine_many(accs, recvs, scale) -> list``
                      combine a batch of independent (acc, recv) pairs —
                      the fused path maps this to ONE multi-bucket Pallas
                      launch; the reference path does per-pair jnp math.
    """
    state = list(bufs)
    if not bits:
        return state
    live = [i for i, b in enumerate(state) if b.size]
    n_stages = len(bits)
    inflight: Dict[int, object] = {}
    pending: List[Tuple[int, int]] = []   # current combine batch

    def flush():
        if not pending:
            return
        by_scale: Dict[float, List[int]] = {}
        for k, s in pending:
            scale = inv_s if s == n_stages - 1 else 1.0
            by_scale.setdefault(scale, []).append(k)
        for scale, ks in by_scale.items():
            outs = combine_many([state[live[k]] for k in ks],
                                [inflight.pop(k) for k in ks], scale)
            for k, out in zip(ks, outs):
                state[live[k]] = out
        pending.clear()

    for ph, k, s in pipeline_schedule(len(live), n_stages):
        if ph == EXCHANGE:
            flush()
            inflight[k] = exchange(state[live[k]], bits[s])
        else:
            pending.append((k, s))
    flush()
    return state


def overlapped_mix(bufs: Sequence, issue: Callable,
                   combine: Callable) -> list:
    """Single-stage pipeline for gossip/psum-style mixes.

    Issues every bucket's collective(s) before running any bucket's combine
    arithmetic, so the wire of bucket k+1 overlaps the combine of bucket k.
    ``issue(buf)`` returns whatever the collective(s) deliver (a buffer or a
    tuple of buffers); ``combine(buf, recv)`` is the local arithmetic.
    """
    recvs = [issue(b) if b.size else None for b in bufs]
    return [combine(b, r) if b.size else b for b, r in zip(bufs, recvs)]


# ---------------------------------------------------------------------------
# Analytic model of the schedule (used by group_allreduce / cluster_sim)
# ---------------------------------------------------------------------------

def overlapped_stage_seconds(wire_s: float, combine_s: float,
                             n_buckets: int, alpha: float) -> float:
    """Seconds for ONE butterfly stage under the wavefront schedule.

    With B equal buckets, per-bucket wire w = wire_s/B and combine
    c = combine_s/B, the stage is a two-resource pipeline: fill (first
    bucket's wire), B-1 overlapped slots at max(w, c), drain (last bucket's
    combine).  Launch latency alpha is paid per bucket regardless — issuing
    a collective is serial on the core.  Serial reference for the same
    inputs: ``n_buckets * alpha + wire_s + combine_s``.
    """
    b = max(n_buckets, 1)
    w, c = wire_s / b, combine_s / b
    return b * alpha + w + (b - 1) * max(w, c) + c
