"""Layer-streamed FSDP execution engine (DESIGN.md §11).

The gather-all FSDP step (§10) materialises the **entire** gathered param
tree before the forward starts: the intra-pod all-gather sits serially in
front of fwd/bwd — exactly the wait WAGMA-SGD exists to avoid — and the
transient gathered buffer erases most of the ÷pod-size memory win.  This
module extends the §8 wavefront idea (issue the next unit's communication
before the current unit's arithmetic) from (bucket, stage) grids to the
**joint compute/comm schedule over layer spans**:

* the shard layout is **layer-aware** (``bucketing.build_layout(groups=...)``)
  over the model's *layered* param tree ``{"stem", "layers", "head"}``
  (``models/common.LayeredModel``): every bucket belongs to exactly one
  ordered group — stem = 0, span k = k+1, head = n+1 — so one span's
  parameters are a contiguous run of whole buckets;
* **forward**: span k+1's per-bucket all-gather is issued before span k's
  compute (double buffering on the ICI wire), and span k's gathered
  buffers die as soon as its compute is done — peak gathered memory is
  ~2 layer spans (+ stem/head), not the full tree;
* **backward**: spans are *re-gathered* in reverse order (span-level
  rematerialisation — the remat recompute and the FSDP backward gather are
  the same walk), each span's pod-mean fp32 gradient is reduce-scattered
  to its owner slices the moment its VJP completes (while span k-1's VJP
  runs), and the re-gathered buffers die with the span.

The engine composes per-span ``jax.vjp`` calls manually instead of
differentiating through the collectives, for two reasons: (a) the gradient
reduce-scatter must accumulate in fp32 regardless of the storage dtype
(``plan.stream_grad_shards`` packs the span's leaf cotangents to fp32
before the ``psum_scatter``, exactly like the gather-all path's
``grad_shards``), and (b) backward re-gathers must not be CSE'd with the
forward gathers (XLA would otherwise keep the forward buffer alive and
silently restore gather-all memory) — re-gather operands pass through
``lax.optimization_barrier``.  Because the per-span primal/VJP ops are the
same ops ``jax.value_and_grad(model.loss)`` runs on the gathered tree, the
streamed step is **bit-identical** to the gather-all step (pinned by
tests/test_streaming.py on every phase offset).

``stream_schedule`` is the declarative event order the engine realises;
``validate_stream_schedule`` pins its invariants (gather-before-compute,
span-k+1-prefetch, at most two span gathers in flight) and
``max_in_flight_gathered_bytes`` walks the schedule's liveness to bound
peak gathered memory — the dry-run smoke cross-checks the compiled HLO
against both.
"""

from __future__ import annotations

from functools import lru_cache
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

# Ordered stream groups of a layered tree: stem, spans 1..n, head.
STEM_GROUP = 0


def span_group(k: int) -> int:
    return k + 1


def head_group(n_spans: int) -> int:
    return n_spans + 1


def is_layered_tree(tree) -> bool:
    """Structural check for the ``{"stem", "layers", "head"}`` convention."""
    return (isinstance(tree, dict) and set(tree) == {"stem", "layers", "head"}
            and isinstance(tree["layers"], (tuple, list)))


def layered_leaf_groups(tree) -> Tuple[int, ...]:
    """Per-leaf ordered layer ids of a layered tree (canonical leaf order).

    This is the ``groups`` input of :func:`bucketing.build_layout`: stem
    leaves map to 0, span-k leaves to k+1, head leaves to n_spans+1.
    """
    if not is_layered_tree(tree):
        raise ValueError(
            "streamed sharding needs the layered param tree "
            '{"stem", "layers", "head"} (models/common.LayeredModel.split); '
            f"got a {type(tree).__name__} with "
            f"{sorted(tree) if isinstance(tree, dict) else '?'}")
    n_spans = len(tree["layers"])
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, _leaf in flat:
        top = getattr(path[0], "key", None)
        if top == "stem":
            out.append(STEM_GROUP)
        elif top == "head":
            out.append(head_group(n_spans))
        else:
            out.append(span_group(int(path[1].idx)))
    return tuple(out)


# ---------------------------------------------------------------------------
# The joint compute/comm schedule
# ---------------------------------------------------------------------------

GATHER = "gather"        # issue a group's per-bucket all-gathers
COMPUTE = "compute"      # forward-apply a group (stem or a span)
GRAD = "grad"            # run a group's VJP (head's includes the loss)
SCATTER = "scatter"      # reduce-scatter a group's pod-mean fp32 grads

Event = Tuple[str, int]


@lru_cache(maxsize=None)
def stream_schedule(n_spans: int) -> Tuple[Event, ...]:
    """Event order of one streamed fwd+bwd over groups 0..n_spans+1.

    Forward: gather(g+1) is emitted before compute(g) for every span, so
    the next span's wire time hides behind the current span's arithmetic;
    the head's gather hides behind the last span.  Backward: the head VJP
    (which produces the loss) runs first with span n's re-gather already
    in flight, then spans re-gather/VJP/scatter in reverse with span k-1's
    re-gather emitted before span k's VJP.  The stem is gathered once and
    stays live to the end (tied unembeddings read it in the head).
    """
    n = int(n_spans)
    head = head_group(n)
    ev: List[Event] = [(GATHER, STEM_GROUP), (COMPUTE, STEM_GROUP)]
    if n:
        ev.append((GATHER, span_group(0)))
    for k in range(n):
        # prefetch the next group's buckets before this span computes
        ev.append((GATHER, span_group(k + 1) if k + 1 < n else head))
        ev.append((COMPUTE, span_group(k)))
    if not n:
        ev.append((GATHER, head))
    # backward: span n's re-gather overlaps the head VJP
    if n:
        ev.append((GATHER, span_group(n - 1)))
    ev += [(GRAD, head), (SCATTER, head)]
    for k in range(n - 1, -1, -1):
        if k:
            ev.append((GATHER, span_group(k - 1)))     # prefetch re-gather
        ev += [(GRAD, span_group(k)), (SCATTER, span_group(k))]
    ev += [(GRAD, STEM_GROUP), (SCATTER, STEM_GROUP)]
    return tuple(ev)


def _liveness(events: Sequence[Event], n_spans: int):
    """Yield (event, live_groups_after) walking the schedule's liveness.

    A group's gathered buffers are live from its (re)gather until its
    consuming compute/VJP is done; the stem stays live until its own VJP
    (the head may read it for tied unembeddings).
    """
    live: set = set()
    for ph, g in events:
        if ph == GATHER:
            live.add(g)
        elif ph == COMPUTE and g != STEM_GROUP:
            live.discard(g)                    # fwd span dies after compute
        elif ph == GRAD:
            live.discard(g)                    # bwd group dies after its VJP
        yield (ph, g), frozenset(live)
    assert not live, live


def validate_stream_schedule(events: Sequence[Event], n_spans: int) -> None:
    """Assert the streamed-schedule invariants (pure, used by tests/CI)."""
    head = head_group(n_spans)
    pos: Dict[Event, List[int]] = {}
    for i, e in enumerate(events):
        pos.setdefault(e, []).append(i)
    # every span gathers twice (fwd + bwd re-gather), stem/head once
    for k in range(n_spans):
        assert len(pos[(GATHER, span_group(k))]) == 2, k
    assert len(pos[(GATHER, STEM_GROUP)]) == len(pos[(GATHER, head)]) == 1
    # gather precedes the consuming compute / VJP; scatter follows the VJP
    for k in range(n_spans):
        g = span_group(k)
        assert pos[(GATHER, g)][0] < pos[(COMPUTE, g)][0]
        assert pos[(GATHER, g)][1] < pos[(GRAD, g)][0]
        assert pos[(GRAD, g)][0] < pos[(SCATTER, g)][0]
    # the tentpole property: span k+1's wire is in flight before span k's
    # compute (fwd), span k-1's before span k's VJP (bwd)
    for k in range(n_spans - 1):
        assert pos[(GATHER, span_group(k + 1))][0] < \
            pos[(COMPUTE, span_group(k))][0], k
        assert pos[(GATHER, span_group(k))][1] < \
            pos[(GRAD, span_group(k + 1))][0], k
    # at most two *span* gathers in flight at any point (stem/head ride
    # along; the dry-run memory bound counts them separately)
    for _, live in _liveness(events, n_spans):
        spans_live = [g for g in live if 0 < g <= n_spans]
        assert len(spans_live) <= 2, (spans_live, n_spans)


def max_in_flight_gathered_bytes(group_bytes: Dict[int, int],
                                 n_spans: int) -> int:
    """Peak gathered bytes of the schedule (liveness walk, exact)."""
    peak = 0
    for _, live in _liveness(stream_schedule(n_spans), n_spans):
        peak = max(peak, sum(group_bytes.get(g, 0) for g in live))
    return peak


def expected_stream_gathers(plan) -> int:
    """All-gather launches of ONE streamed fwd+bwd (the HLO cross-check).

    Every group's buckets gather once in the forward; spans re-gather in
    the backward (stem and head stay live / are still live at their VJPs).
    Zero-size buckets never launch a collective.
    """
    lay = plan.shard_layout
    n_real = sum(1 for s in lay.bucket_sizes if s)
    n_span_real = sum(
        1 for s, g in zip(lay.bucket_sizes, lay.bucket_groups)
        if s and 0 < g <= plan.n_stream_spans)
    return n_real + n_span_real


def _barrier(x):
    """CSE fence for backward re-gathers (identity on old jax)."""
    opt = getattr(jax.lax, "optimization_barrier", None)
    return opt(x) if opt is not None else x


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

def streamed_loss_and_grad_shards(plan, layered, shards, batch, *,
                                  remat: bool = True):
    """One streamed fwd+bwd inside shard_map (manual over the dp axes).

    ``plan``     a streamed-policy :class:`~repro.core.plan.AveragingPlan`
                 compiled over the layered param tree;
    ``layered``  the model's :class:`~repro.models.common.LayeredModel`;
    ``shards``   this device's owned shard-slice buffers (full tuple);
    ``batch``    the local batch;
    ``remat``    must equal the gather-all reference's remat flag — remat
                 changes the fused gradient reductions XLA emits (not the
                 math), and bit-exactness vs the gather-all step is the
                 contract.

    Returns ``(loss, metrics, grad_shards)`` where ``grad_shards`` is the
    fp32 pod-mean gradient slice tuple in global bucket order — the same
    object ``plan.grad_shards(jax.grad(model.loss))`` produces on the
    gather-all path, computed without ever materialising the full gathered
    tree: the engine walks :func:`stream_schedule`, composing per-span
    ``jax.vjp`` calls across the saved span-boundary activations.
    """
    n = layered.n_spans
    head = head_group(n)
    if plan.n_stream_spans != n:
        raise ValueError(f"plan has {plan.n_stream_spans} spans, "
                         f"model decomposes into {n}")

    gathered: Dict[int, object] = {}
    regathered: set = set()
    boundary: Dict[int, object] = {}      # span group -> its input carry
    pending: Dict[int, object] = {}       # group -> grads awaiting scatter
    grad_list = [None] * plan.shard_layout.n_buckets
    stem_tree = carry = aux = None
    d_carry = d_stem_head = loss = metrics = None

    for ph, g in stream_schedule(n):
        if ph == GATHER:
            gathered[g] = plan.stream_unshard(shards, g,
                                              barrier=g in regathered)
            regathered.add(g)
        elif ph == COMPUTE:
            if g == STEM_GROUP:
                stem_tree = gathered[STEM_GROUP]   # live until its own VJP
                carry, aux = layered.stem(stem_tree, batch)
            else:
                boundary[g] = carry
                # forward primal only — no residuals are kept (the backward
                # re-gathers and re-runs the span inside its VJP), so the
                # remat flag is irrelevant here
                carry = layered.span(g - 1, gathered.pop(g), carry, aux,
                                     remat=False)
        elif ph == GRAD:
            if g == head:
                loss, vjp_fn, metrics = jax.vjp(
                    lambda h, s, c: layered.head_loss(h, s, c, aux, batch),
                    gathered.pop(head), stem_tree, carry, has_aux=True)
                d_head, d_stem_head, d_carry = vjp_fn(
                    jnp.ones((), loss.dtype))
                pending[head] = d_head
            elif g == STEM_GROUP:
                _, vjp_fn = jax.vjp(
                    lambda s: layered.stem(s, batch)[0], stem_tree)
                (d_stem,) = vjp_fn(d_carry)
                # tied unembeddings contribute through the head too; for
                # untied models the head cotangent is zeros and the add is
                # a bitwise no-op
                pending[STEM_GROUP] = jax.tree.map(
                    jnp.add, d_stem, d_stem_head)
            else:
                _, vjp_fn = jax.vjp(
                    lambda p, c: layered.span(g - 1, p, c, aux, remat=remat),
                    gathered.pop(g), boundary.pop(g))
                pending[g], d_carry = vjp_fn(d_carry)
        else:  # SCATTER: fp32 pod-mean reduce-scatter, bucket order
            for bi, buf in zip(plan.stream_bucket_indices(g),
                               plan.stream_grad_shards(pending.pop(g), g)):
                grad_list[bi] = buf

    assert all(b is not None for b in grad_list)
    return loss, metrics, tuple(grad_list)
