"""Heartbeat failure detection for the elastic training driver.

The elastic layer (DESIGN.md §12) knows how to shrink and regrow the
power-of-two world, but until now only *scripted* departures drove it.
This module closes the loop: each worker is expected to heartbeat once
per averaging round, and a deadline-based detector turns silence into
membership verdicts (DESIGN.md §13):

    ALIVE --silent past suspect timeout--> SUSPECT --still silent past
    confirm timeout--> DEAD

A SUSPECT verdict downgrades the round to the survivors' quantised
world (the driver feeds it to ``MembershipController.apply_verdict``);
a DEAD verdict makes the departure permanent (the worker's staleness
ledger entry is dropped, a later rejoin is treated as a fresh join).
A heartbeat from a SUSPECT/DEAD worker yields a RECOVERED verdict and
counts as a *flap*: the worker's suspect timeout backs off
multiplicatively so a flapping worker stops churning the membership.

Verdicts are **epoch-stamped**: every verdict carries the membership
epoch it was raised under, and ``MembershipController.apply_verdict``
rejects verdicts from a dead epoch — by the time a stale verdict
lands, the topology it indicts has been evicted from the plan cache
and its row assignment means nothing in the current world.

The detector is driven entirely by an explicit clock (`now` floats in
seconds), never ``time.time()``: under ``run_under_faults`` the clock
is virtual (step * step_time_s), which is what makes a replayed
`FaultSchedule` bit-identical.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

ALIVE = "alive"
SUSPECT = "suspect"
DEAD = "dead"
RECOVERED = "recovered"


@dataclass(frozen=True)
class DetectorConfig:
    """Timeouts (seconds of detector clock) and the flap backoff."""
    suspect_timeout_s: float = 0.25   # silence before ALIVE -> SUSPECT
    confirm_timeout_s: float = 0.30   # further silence before SUSPECT -> DEAD
    backoff: float = 2.0              # suspect timeout multiplier per flap
    max_backoff: float = 8.0          # cap on the accumulated multiplier

    def __post_init__(self):
        if self.suspect_timeout_s <= 0 or self.confirm_timeout_s <= 0:
            raise ValueError("timeouts must be positive")
        if self.backoff < 1.0:
            raise ValueError("backoff must be >= 1")


@dataclass(frozen=True)
class Verdict:
    """A detector state transition, stamped with the membership epoch."""
    worker: int
    state: str        # SUSPECT | DEAD | RECOVERED
    epoch: int        # membership epoch the verdict was raised under
    at: float         # detector clock when the transition fired
    silent_s: float   # observed silence at that moment


@dataclass
class HeartbeatRecord:
    last_beat: float
    state: str = ALIVE
    suspected_at: Optional[float] = None
    flaps: int = 0    # SUSPECT/DEAD -> RECOVERED cycles; drives the backoff


class FailureDetector:
    """Deadline-based failure detector over an explicit clock.

    ``heartbeat(worker, now)`` records liveness (and reports recovery);
    ``poll(deadline)`` is called once per averaging round with the
    round's collective deadline and returns every state transition the
    silence implies at that instant.
    """

    def __init__(self, workers: Sequence[int],
                 config: Optional[DetectorConfig] = None, *,
                 epoch: int = 0, now: float = 0.0):
        self.config = config or DetectorConfig()
        self.epoch = int(epoch)
        self.records: Dict[int, HeartbeatRecord] = {
            int(w): HeartbeatRecord(last_beat=float(now)) for w in workers}

    # -- bookkeeping ------------------------------------------------------
    def set_epoch(self, epoch: int) -> None:
        """Re-stamp after a membership transition; later verdicts carry it."""
        self.epoch = int(epoch)

    def state(self, worker: int) -> str:
        return self.records[worker].state

    def suspect_timeout(self, worker: int) -> float:
        """Per-worker suspect deadline: base timeout x capped flap backoff."""
        rec = self.records[worker]
        mult = min(self.config.backoff ** rec.flaps, self.config.max_backoff)
        return self.config.suspect_timeout_s * mult

    # -- events -----------------------------------------------------------
    def heartbeat(self, worker: int, now: float) -> Optional[Verdict]:
        """Record a beat; returns a RECOVERED verdict if the worker was out.

        Recovery from SUSPECT (or DEAD, i.e. a rejoin announce) counts as
        a flap and raises this worker's future suspect timeout.
        """
        rec = self.records.get(worker)
        if rec is None:  # unseen worker announcing itself
            rec = self.records[worker] = HeartbeatRecord(last_beat=float(now))
            return None
        silent = float(now) - rec.last_beat
        rec.last_beat = max(rec.last_beat, float(now))
        if rec.state == ALIVE:
            return None
        rec.state = ALIVE
        rec.suspected_at = None
        rec.flaps += 1
        return Verdict(worker, RECOVERED, self.epoch, float(now), silent)

    def poll(self, deadline: float) -> List[Verdict]:
        """Evaluate every worker's silence at the round's deadline."""
        out: List[Verdict] = []
        for w in sorted(self.records):
            rec = self.records[w]
            if rec.state == DEAD:
                continue
            silent = float(deadline) - rec.last_beat
            if rec.state == ALIVE and silent > self.suspect_timeout(w):
                rec.state = SUSPECT
                rec.suspected_at = float(deadline)
                out.append(Verdict(w, SUSPECT, self.epoch, float(deadline),
                                   silent))
            elif (rec.state == SUSPECT
                  and float(deadline) - rec.suspected_at
                  > self.config.confirm_timeout_s):
                rec.state = DEAD
                out.append(Verdict(w, DEAD, self.epoch, float(deadline),
                                   silent))
        return out
