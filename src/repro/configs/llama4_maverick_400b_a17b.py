"""llama4-maverick-400b-a17b [moe] — 128e top-1 + shared expert, MoE every
other layer [hf:meta-llama/Llama-4-Scout-17B-16E family card]."""
from repro.configs.base import ModelConfig

SOURCE = "hf:meta-llama/Llama-4-Scout-17B-16E (Llama 4 family)"


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b", family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=8192, vocab=202048,
        n_experts=128, top_k=1, moe_every=2, shared_expert=True,
        tie_embeddings=False, rope_theta=5e5, source=SOURCE,
    )


def smoke_config() -> ModelConfig:
    return config().variant(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                            d_ff=256, vocab=512, n_experts=4, moe_chunks=2)
