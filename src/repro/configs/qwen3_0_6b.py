"""qwen3-0.6b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B]."""
from repro.configs.base import ModelConfig

SOURCE = "hf:Qwen/Qwen3-8B (Qwen3 family card)"


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-0.6b", family="dense",
        n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8,
        d_ff=3072, vocab=151936, qk_norm=True, rope_theta=1e6,
        source=SOURCE,
    )


def smoke_config() -> ModelConfig:
    return config().variant(n_layers=2, d_model=256, n_heads=4,
                            n_kv_heads=2, d_ff=512, vocab=512)
