"""recurrentgemma-2b [hybrid] — RG-LRU + local attention 1:2
[arXiv:2402.19427 (Griffin)]."""
from repro.configs.base import ModelConfig

SOURCE = "arXiv:2402.19427 (Griffin/RecurrentGemma)"


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid",
        n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
        d_ff=7680, vocab=256000, act="gelu", emb_scale=True,
        lru_width=2560, conv_width=4, source=SOURCE,
    )


def smoke_config() -> ModelConfig:
    return config().variant(n_layers=3, d_model=120, n_heads=2, n_kv_heads=1,
                            d_ff=256, vocab=512, lru_width=120)
