"""starcoder2-7b [dense] — GQA, RoPE [arXiv:2402.19173]."""
from repro.configs.base import ModelConfig

SOURCE = "arXiv:2402.19173 (StarCoder2)"


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b", family="dense",
        n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4,
        d_ff=18432, vocab=49152,
        gated_mlp=False, act="gelu", norm="ln", rope_theta=1e5,
        tie_embeddings=False, source=SOURCE,
    )


def smoke_config() -> ModelConfig:
    return config().variant(n_layers=2, d_model=144, n_heads=4,
                            n_kv_heads=2, d_ff=512, vocab=512)
