"""whisper-medium [audio] — enc-dec, conv frontend stubbed [arXiv:2212.04356].

24 encoder + 24 decoder layers (whisper-medium); the mel/conv frontend is a
stub: input_specs feeds (B, 1500, d_model) frame embeddings.
"""
from repro.configs.base import ModelConfig

SOURCE = "arXiv:2212.04356 (Whisper)"


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium", family="audio",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=4096, vocab=51865,
        encoder_layers=24, encoder_frames=1500,
        gated_mlp=False, act="gelu", norm="ln", tie_embeddings=True,
        source=SOURCE,
    )


def smoke_config() -> ModelConfig:
    return config().variant(n_layers=2, encoder_layers=2, d_model=128,
                            n_heads=4, n_kv_heads=4, d_ff=256, vocab=512,
                            encoder_frames=16)
