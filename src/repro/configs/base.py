"""Config dataclasses for the model zoo and the distributed run."""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default d_model // n_heads

    # attention
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None    # window of "local" attention layers
    local_per_global: int = 0               # gemma3: 5 local then 1 global
    causal: bool = True

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1                      # llama4: MoE every other layer
    first_dense: int = 0                    # kimi: leading dense layers
    shared_expert: bool = False
    capacity_factor: float = 1.25
    moe_chunks: int = 8                     # token-chunked dispatch (memory)
    moe_impl: str = "shardmap"              # shardmap | slotmap | onehot_scatter
    router_aux_coef: float = 0.01

    # SSM / hybrid
    block_pattern: Tuple[str, ...] = ()     # e.g. ("rglru","rglru","attn")
    conv_width: int = 4                     # RG-LRU temporal conv
    lru_width: Optional[int] = None

    # enc-dec / modality frontends (STUBS per assignment)
    encoder_layers: int = 0
    encoder_frames: int = 0                 # whisper: 1500 frame embeddings
    n_patches: int = 0                      # internvl2: 256 patch embeddings

    # misc
    act: str = "silu"
    gated_mlp: bool = True                  # SwiGLU vs plain MLP
    norm: str = "rms"                       # rms | ln
    emb_scale: bool = False                 # gemma: scale emb by sqrt(d)
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    attn_block_q: int = 512                 # blocked-attention tile sizes
    attn_block_k: int = 1024
    mlstm_chunk: int = 256
    source: str = ""                        # paper/model-card citation

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Embedding-table vocab padded to /256 so it shards evenly over the
        model axis (whisper 51865, internvl2 92553 are not %16)."""
        return ((self.vocab + 255) // 256) * 256

    def variant(self, **kw) -> "ModelConfig":
        return replace(self, **kw)

    def with_sliding_window(self, window: int = 8192) -> "ModelConfig":
        """Explicit `swa` variant for long_500k on full-attention archs.

        sliding_window set with local_per_global == 0 means *all* attention
        layers are windowed (uniform-local); local_per_global = k > 0 means
        the gemma3-style k-local-then-1-global pattern.
        """
        return replace(self, sliding_window=window,
                       name=self.name + "+swa")


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class RunConfig:
    arch: str = "tinyllama-1.1b"
    shape: str = "train_4k"
    averager: str = "wagma"                 # wagma | allreduce | local_sgd | ...
    group_size: Optional[int] = None        # None -> sqrt(P)
    tau: int = 10
    multi_pod: bool = False
    optimizer: str = "sgd"                  # paper's optimiser
    learning_rate: float = 0.1
    momentum: float = 0.9
    weight_decay: float = 0.0
    steps: int = 100
    seed: int = 0
    microbatch: Optional[int] = None        # grad-accumulation chunks
    remat: bool = True
    fsdp: int = 1                           # hierarchical WAGMA: FSDP factor
