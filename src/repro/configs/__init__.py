"""Architecture configs: the 10 assigned archs + the paper's own Transformer.

Each ``<id>.py`` exposes ``config()`` (exact assigned dimensions) and
``smoke_config()`` (reduced: <=2 blocks, d_model<=512, <=4 experts) for CPU
smoke tests. ``get_config(name)`` resolves by arch id.
"""

from repro.configs.base import ModelConfig, RunConfig, SHAPES, InputShape

_ARCHS = (
    "xlstm_350m", "qwen3_0_6b", "whisper_medium", "starcoder2_7b",
    "internvl2_2b", "gemma3_12b", "llama4_maverick_400b_a17b",
    "kimi_k2_1t_a32b", "tinyllama_1_1b", "recurrentgemma_2b",
    "transformer_wmt",
)

_ALIASES = {
    "xlstm-350m": "xlstm_350m",
    "qwen3-0.6b": "qwen3_0_6b",
    "whisper-medium": "whisper_medium",
    "starcoder2-7b": "starcoder2_7b",
    "internvl2-2b": "internvl2_2b",
    "gemma3-12b": "gemma3_12b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "transformer-wmt": "transformer_wmt",
}


def arch_names():
    return list(_ALIASES)[:-1]  # the 10 assigned ids (dashed form)


def _module(name: str):
    import importlib
    key = _ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    if key not in _ARCHS:
        raise ValueError(f"unknown arch {name!r}; known: {sorted(_ALIASES)}")
    return importlib.import_module(f"repro.configs.{key}")


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    mod = _module(name)
    return mod.smoke_config() if smoke else mod.config()


__all__ = ["ModelConfig", "RunConfig", "SHAPES", "InputShape",
           "get_config", "arch_names"]
