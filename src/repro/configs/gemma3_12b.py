"""gemma3-12b [dense] — 5:1 local:global attention, 128k ctx
[hf:google/gemma-3-1b-pt family card]."""
from repro.configs.base import ModelConfig

SOURCE = "hf:google/gemma-3-1b-pt (Gemma 3 family)"


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b", family="dense",
        n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8,
        d_ff=15360, vocab=262144,
        sliding_window=1024, local_per_global=5, qk_norm=True,
        emb_scale=True, act="gelu", rope_theta=1e6, source=SOURCE,
    )


def smoke_config() -> ModelConfig:
    return config().variant(n_layers=6, d_model=128, n_heads=4, n_kv_heads=2,
                            d_ff=256, vocab=512, sliding_window=32,
                            local_per_global=2)
