"""xlstm-350m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517]."""
from repro.configs.base import ModelConfig

SOURCE = "arXiv:2405.04517 (xLSTM)"


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m", family="ssm",
        n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab=50304, source=SOURCE,
    )


def smoke_config() -> ModelConfig:
    return config().variant(n_layers=2, d_model=128, n_heads=2,
                            n_kv_heads=2, vocab=512)
