"""transformer_wmt — the paper's own model: standard Transformer (Vaswani),
61,362,176 trainable params, used for the WMT17 convergence experiments
(paper §V-C). Encoder consumes source tokens (no modality stub)."""
from repro.configs.base import ModelConfig

SOURCE = "paper §V-C / arXiv:1706.03762 (Transformer base)"


def config() -> ModelConfig:
    return ModelConfig(
        name="transformer-wmt", family="audio",   # encdec path, token encoder
        n_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
        d_ff=2048, vocab=32768,
        encoder_layers=6, encoder_frames=0,       # 0 -> token encoder (src)
        gated_mlp=False, act="relu", norm="ln", source=SOURCE,
    )


def smoke_config() -> ModelConfig:
    return config().variant(n_layers=2, encoder_layers=2, d_model=128,
                            n_heads=4, n_kv_heads=4, d_ff=256, vocab=512)
