"""kimi-k2-1t-a32b [moe] — trillion-param MoE, 384e top-8 + shared expert,
first layer dense (paper-table giant) [arXiv:2501.kimi2]."""
from repro.configs.base import ModelConfig

SOURCE = "arXiv:2501.kimi2 (Kimi K2)"


def config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b", family="moe",
        n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
        d_ff=2048, vocab=163840,
        n_experts=384, top_k=8, moe_every=1, first_dense=1,
        shared_expert=True, tie_embeddings=False, rope_theta=5e6,
        source=SOURCE,
    )


def smoke_config() -> ModelConfig:
    return config().variant(n_layers=3, first_dense=1, d_model=128,
                            n_heads=4, n_kv_heads=2, d_ff=64, vocab=512,
                            n_experts=4, top_k=2, moe_chunks=2)
