"""internvl2-2b [vlm] — InternViT (stub) + InternLM2 LM [arXiv:2404.16821]."""
from repro.configs.base import ModelConfig

SOURCE = "arXiv:2404.16821 (InternVL 1.5/2 report)"


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b", family="vlm",
        n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
        d_ff=8192, vocab=92553, n_patches=256, rope_theta=1e6,
        tie_embeddings=False, source=SOURCE,
    )


def smoke_config() -> ModelConfig:
    return config().variant(n_layers=2, d_model=128, n_heads=4,
                            n_kv_heads=2, d_ff=256, vocab=512, n_patches=8)
