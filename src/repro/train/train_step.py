"""Distributed WAGMA-SGD train step.

Topology: ``shard_map`` (via ``repro.compat``) *manual* over the
data-parallel mesh axes (``pod``, ``data``) — local gradients, local
optimiser step, then the averager's collective (group butterfly / global
psum / gossip) — and *auto* (GSPMD) over the ``model`` axis for
tensor/expert parallelism inside each replica.

The averager's collective runs through a **compiled AveragingPlan**
(core/plan.py, DESIGN.md §9): the averager's frozen ``Topology`` (mesh axes
→ link classes with their own alpha/beta/gamma constants) is compiled once
per tree structure into a plan that owns the per-stage link classification
(which butterfly bits ride ICI vs DCN), per-link-class bucket layouts and
modeled-optimal budgets, and the wavefront schedule; inside the manual
region the step simply calls ``plan.average(tree, phase)`` /
``plan.sync(tree)``.  The execution realisation is unchanged from §7/§8:
dtype-homogeneous flat buckets (one ppermute per bucket per stage), fused
Pallas combine with fp32 accumulation, overlapped wavefront emission order
(bucket k+1's ppermute before bucket k's combine, same-tick combines in one
multi-bucket Pallas launch) — but every stage run now packs at *its link
class's* budget, and hierarchical (pod-aware) topologies repack only at
class boundaries.  Per-leaf (``fused=False``) and serial-bucketed
(``overlap=False``) behaviour remain available as plan configs and are
differentially tested to match bit-for-bit.

Because model averaging needs **divergent per-replica weights**, params and
optimiser state carry a leading dp-replica axis of size P_dp, sharded over
(pod, data): global arrays are (P_dp, ...) and each replica sees its own
slice (squeezed inside the manual region). Per-device memory equals classic
replicated data parallelism. See DESIGN.md §2 for the FSDP tension and the
hierarchical-WAGMA mitigation.

**Compiled-phase-variant dispatch.** XLA collectives need static
permutations, so the group pattern of iteration t is static per compiled
variant: the host loop (launch/train.py ``Trainer._step_fn``) calls
``averager.phase_for_step(t)`` / ``sync_due(t)`` and dispatches to one of
``averager.n_phases + 1`` cached jitted step functions (+1 = the tau-sync
step).  Every variant shares the same bucket layout cache.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.core.group_allreduce import dp_axis_layout
from repro.models import common as cm


def dp_axes_of(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _dp_spec(mesh):
    dp = dp_axes_of(mesh)
    return dp if len(dp) > 1 else dp[0]


def stacked_init(model, mesh, key, abstract: bool = False):
    """Per-replica-divergent params: leading dp axis of size P_dp.

    abstract=True returns ShapeDtypeStructs with shardings (for dry-run).
    """
    dp = dp_axes_of(mesh)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    shapes = jax.eval_shape(model.init, key)
    model_specs = cm.tree_specs(shapes)
    dp_spec = _dp_spec(mesh)

    def full_spec(spec):
        return P(dp_spec, *spec)

    specs = jax.tree.map(full_spec, model_specs,
                         is_leaf=lambda x: isinstance(x, P))
    if abstract:
        tree = jax.tree.map(
            lambda s, sp: jax.ShapeDtypeStruct(
                (n_dp,) + s.shape, s.dtype,
                sharding=NamedSharding(mesh, sp)),
            shapes, specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        return tree, specs

    params0 = model.init(key)

    def rep(a, sp):
        out = jnp.broadcast_to(a[None], (n_dp,) + a.shape)
        return jax.device_put(out, NamedSharding(mesh, sp))

    return jax.tree.map(rep, params0, specs), specs


def build_train_step(model, optimizer, averager, mesh, *, phase: int,
                     sync: bool, microbatch: Optional[int] = None,
                     remat: bool = True):
    """Returns jitted step(stacked_params, stacked_opt, batch) ->
    (params, opt, metrics)."""
    dp = dp_axes_of(mesh)
    dp_spec = _dp_spec(mesh)

    def replica_fn(params, opt_state, batch):
        def loss_fn(p, mb):
            loss, metrics = model.loss(p, mb, remat=remat)
            return loss, metrics

        if microbatch and microbatch > 1:
            b_local = jax.tree.leaves(batch)[0].shape[0]
            if b_local % microbatch or b_local < microbatch:
                raise ValueError(
                    f"microbatch={microbatch} must divide the per-replica "
                    f"batch {b_local}")

            def split(a):
                return a.reshape((microbatch, a.shape[0] // microbatch)
                                 + a.shape[1:])
            mbs = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (loss, metrics), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + loss), metrics

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), metrics_all = jax.lax.scan(
                acc_body, (g0, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / microbatch, grads)
            metrics = jax.tree.map(lambda m: m.mean(), metrics_all)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)

        if averager.grad_comm:
            grads = (averager.sync(grads) if sync
                     else averager.comm(grads, phase))
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        if not averager.grad_comm:
            new_params = (averager.sync(new_params) if sync
                          else averager.comm(new_params, phase))
        metrics = {k: jax.lax.pmean(v.astype(jnp.float32), dp)
                   for k, v in metrics.items()}
        return new_params, new_opt, metrics

    squeeze = lambda t: jax.tree.map(lambda a: a[0], t)
    expand = lambda t: jax.tree.map(lambda a: a[None], t)

    def step(stacked_params, stacked_opt, batch):
        p, o, m = replica_fn(squeeze(stacked_params), squeeze(stacked_opt),
                             batch)
        return expand(p), expand(o), m

    lead = P(dp_spec)
    sm = compat.shard_map(
        step, mesh=mesh,
        in_specs=(lead, lead, lead),
        out_specs=(lead, lead, P()),
        axis_names=set(dp), check_vma=False,
    )
    return jax.jit(sm, donate_argnums=(0, 1))


def train_shardings(mesh, param_specs, opt_state_shapes, params_shapes):
    """NamedSharding trees for (params, opt_state) given the param specs.

    Momentum/mu/nu leaves have the same (stacked) shapes as params and take
    the matching param spec; scalar counts take P(dp).
    """
    dp_spec = _dp_spec(mesh)
    spec_by_shape = {}
    for sp, sh in zip(
            jax.tree.leaves(param_specs, is_leaf=lambda x: isinstance(x, P)),
            jax.tree.leaves(params_shapes,
                            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))):
        spec_by_shape.setdefault(tuple(sh.shape), sp)

    def opt_spec(leaf):
        sp = spec_by_shape.get(tuple(leaf.shape))
        if sp is None:
            sp = P(*([dp_spec] + [None] * (len(leaf.shape) - 1))) \
                if len(leaf.shape) >= 1 else P()
        return sp

    opt_specs = jax.tree.map(opt_spec, opt_state_shapes,
                             is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    to_ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                   is_leaf=lambda x: isinstance(x, P))
    return to_ns(param_specs), to_ns(opt_specs)


def batch_shardings(mesh, batch_shapes):
    """Batch arrays shard axis 0 (global batch) over the dp axes."""
    dp_spec = _dp_spec(mesh)

    def spec(leaf):
        return NamedSharding(mesh, P(dp_spec, *([None] * (len(leaf.shape) - 1))))

    return jax.tree.map(spec, batch_shapes,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
