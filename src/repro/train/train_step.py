"""Distributed WAGMA-SGD train step.

Topology: ``shard_map`` (via ``repro.compat``) *manual* over the
data-parallel mesh axes (``pod``, ``data``) — local gradients, local
optimiser step, then the averager's collective (group butterfly / global
psum / gossip) — and *auto* (GSPMD) over the ``model`` axis for
tensor/expert parallelism inside each replica.

The averager's collective runs through a **compiled AveragingPlan**
(core/plan.py, DESIGN.md §9): the averager's frozen ``Topology`` (mesh axes
→ link classes with their own alpha/beta/gamma constants) is compiled once
per tree structure into a plan that owns the per-stage link classification
(which butterfly bits ride ICI vs DCN), per-link-class bucket layouts and
modeled-optimal budgets, and the wavefront schedule; inside the manual
region the step simply calls ``plan.average(tree, phase)`` /
``plan.sync(tree)``.  The execution realisation is unchanged from §7/§8:
dtype-homogeneous flat buckets (one ppermute per bucket per stage), fused
Pallas combine with fp32 accumulation, overlapped wavefront emission order
(bucket k+1's ppermute before bucket k's combine, same-tick combines in one
multi-bucket Pallas launch) — but every stage run now packs at *its link
class's* budget, and hierarchical (pod-aware) topologies repack only at
class boundaries.  Per-leaf (``fused=False``) and serial-bucketed
(``overlap=False``) behaviour remain available as plan configs and are
differentially tested to match bit-for-bit.

**Replica state (DESIGN.md §10).**  The step operates on a
:class:`~repro.core.replica.ReplicaState` — params + optimiser state +
averager step/phase bookkeeping — whose layout the averager's
:class:`~repro.core.replica.ShardingPolicy` dictates:

* ``replicated`` — model averaging needs divergent per-replica weights, so
  params and optimiser state carry a leading dp-replica axis of size P_dp,
  sharded over (pod, data): global arrays are (P_dp, ...) and each replica
  sees its own slice (squeezed inside the manual region).  Per-device
  memory equals classic replicated data parallelism (the §2 tension).
* ``fsdp_within_pod(shard_axis)`` — replicas inside a pod share weights and
  shard them over the intra-pod (ICI) axis: the state holds
  (P_pods, bucket) flat shard buckets, the step all-gathers params per
  bucket on ICI for fwd/bwd (inside the microbatch body, so the gathered
  tree is a per-microbatch transient and the fp32 grad accumulator is
  shard-sized), reduce-scatters the pod-mean gradient back, updates only
  the owned shard, and the averager butterflies pod-to-pod on the slices
  directly.  Per-device param+opt memory ÷ pod size.
* ``fsdp_within_pod(shard_axis, streamed=True)`` — same sharding, but the
  buckets are laid out layer-aware over the model's layered tree and the
  step runs the **layer-streamed engine** (core/streaming.py, DESIGN.md
  §11): span k+1's gather is in flight while span k computes, the
  backward re-gathers spans and reduce-scatters each span's grads as its
  VJP completes — peak gathered memory ~2 layer spans, bit-identical to
  the gather-all step.

**Compiled-phase-variant dispatch.** XLA collectives need static
permutations, so the group pattern of iteration t is static per compiled
variant: the host loop (launch/train.py ``Trainer._step_fn``) calls
``averager.phase_for_step(t)`` / ``sync_due(t)`` and dispatches to one of
``averager.n_phases + 1`` cached jitted step functions (+1 = the tau-sync
step).  Every variant shares the same bucket layout cache.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.core.group_allreduce import dp_axis_layout
from repro.core.replica import ReplicaState, map_opt_state
from repro.models import common as cm


def dp_axes_of(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _dp_spec(mesh):
    dp = dp_axes_of(mesh)
    return dp if len(dp) > 1 else dp[0]


def stacked_init(model, mesh, key, abstract: bool = False):
    """Per-replica-divergent params: leading dp axis of size P_dp.

    abstract=True returns ShapeDtypeStructs with shardings (for dry-run).
    """
    dp = dp_axes_of(mesh)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    shapes = jax.eval_shape(model.init, key)
    model_specs = cm.tree_specs(shapes)
    dp_spec = _dp_spec(mesh)

    def full_spec(spec):
        return P(dp_spec, *spec)

    specs = jax.tree.map(full_spec, model_specs,
                         is_leaf=lambda x: isinstance(x, P))
    if abstract:
        tree = jax.tree.map(
            lambda s, sp: jax.ShapeDtypeStruct(
                (n_dp,) + s.shape, s.dtype,
                sharding=NamedSharding(mesh, sp)),
            shapes, specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        return tree, specs

    params0 = model.init(key)

    def rep(a, sp):
        out = jnp.broadcast_to(a[None], (n_dp,) + a.shape)
        return jax.device_put(out, NamedSharding(mesh, sp))

    return jax.tree.map(rep, params0, specs), specs


@functools.lru_cache(maxsize=32)
def _model_shapes(model):
    """Abstract full param tree (key-independent shapes).

    Cached per model object: every step-variant build and spec derivation
    re-asks for the same shapes, and eval_shape re-traces ``model.init``
    each time otherwise.
    """
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


@functools.lru_cache(maxsize=32)
def _layered_shapes(model):
    """Abstract *layered* param tree ``{"stem", "layers", "head"}``.

    The tree the streamed-policy plan compiles over (its layer-aware shard
    layout needs per-leaf layer ids — DESIGN.md §11).
    """
    if model.layered is None:
        raise ValueError(
            f"--sharding fsdp --streamed needs a per-layer apply "
            f"decomposition, but the {model.cfg.family!r} family does not "
            "expose one (models/registry.ModelAPI.layered)")
    return jax.eval_shape(model.layered.split, _model_shapes(model))


def _plan_of(model, averager):
    """The averager's compiled plan for this model's state tree.

    Streamed FSDP plans compile over the layered tree (layer-aware shard
    layout); everything else over the canonical full tree.
    """
    if averager.sharding.is_sharded and averager.sharding.streamed:
        return averager.plan_for(_layered_shapes(model))
    return averager.plan_for(_model_shapes(model))


def _eff_dim0_spec(mesh, averager):
    """Dim-0 spec for (P_eff, ...) stacked FSDP state arrays.

    Mesh-order (major-to-minor) effective dp axes, so the C-order index of
    dim 0 equals the minor-to-major effective replica rank — the same
    convention the replicated (P_dp, ...) stacking and the stacked
    simulator use.
    """
    shard_axis = averager.sharding.shard_axis
    eff = tuple(a for a in dp_axes_of(mesh) if a != shard_axis)
    return eff if len(eff) != 1 else eff[0]


def replica_state_specs(model, optimizer, averager, mesh):
    """PartitionSpec pytree for a :class:`ReplicaState` (shard_map in/out).

    Replicated: every params/opt leaf shards dim 0 (the replica axis) over
    all dp axes.  FSDP: the (P_pods, bucket) buffers shard dim 0 over the
    effective (pod) axes and dim 1 over the shard axis; the per-replica
    optimiser ``count`` shards dim 0 only.
    """
    dp_spec = _dp_spec(mesh)
    if not averager.sharding.is_sharded:
        lead = P(dp_spec)
        return ReplicaState(lead, lead, P(), P())
    eff0 = _eff_dim0_spec(mesh, averager)
    buf = P(eff0, averager.sharding.shard_axis)
    plan = _plan_of(model, averager)
    opt_shapes = jax.eval_shape(optimizer.init, plan.shard_struct())
    opt_specs = map_opt_state(opt_shapes, lambda _: buf, lambda _: P(eff0))
    return ReplicaState(buf, opt_specs, P(), P())


def _scalar_sds(mesh):
    return jax.ShapeDtypeStruct((), jnp.int32,
                                sharding=NamedSharding(mesh, P()))


def init_replica_state(model, optimizer, averager, mesh, key,
                       abstract: bool = False) -> ReplicaState:
    """Build the global :class:`ReplicaState` the train step operates on.

    Replicated policy: (P_dp, ...)-stacked divergent params (``stacked_init``)
    + vmapped optimiser state.  FSDP policy: the compiled plan's
    shard-aligned bucket buffers, stacked (P_pods, bucket) and sharded over
    (effective axes, shard axis).  ``abstract=True`` returns
    ShapeDtypeStructs with shardings (dry-run compilation).
    """
    from repro.core import bucketing

    is_sds = lambda x: isinstance(x, jax.ShapeDtypeStruct)

    if not averager.sharding.is_sharded:
        if abstract:
            params, pspecs = stacked_init(model, mesh, key, abstract=True)
            opt_shapes = jax.eval_shape(
                lambda p: jax.vmap(optimizer.init)(p), params)
            _, opt_sh = train_shardings(mesh, pspecs, opt_shapes, params)
            opt_sds = jax.tree.map(
                lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                                   sharding=sh),
                opt_shapes, opt_sh, is_leaf=is_sds)
            return ReplicaState(params, opt_sds, _scalar_sds(mesh),
                                _scalar_sds(mesh))
        params, _ = stacked_init(model, mesh, key)
        opt_state = jax.jit(lambda p: jax.vmap(optimizer.init)(p))(params)
        return ReplicaState.create(params, opt_state)

    plan = _plan_of(model, averager)
    specs = replica_state_specs(model, optimizer, averager, mesh)
    n_eff = plan.P_eff
    lay = plan.shard_layout
    buf_sharding = NamedSharding(mesh, specs.params)
    if abstract:
        bufs = tuple(
            jax.ShapeDtypeStruct((n_eff, size), dt, sharding=buf_sharding)
            for size, dt in zip(lay.bucket_sizes, lay.bucket_dtypes))
    else:
        init_tree = model.init(key)
        if averager.sharding.streamed:
            init_tree = model.layered.split(init_tree)
        packed = bucketing.pack(init_tree, lay)
        bufs = tuple(
            jax.device_put(jnp.broadcast_to(b[None], (n_eff,) + b.shape),
                           buf_sharding)
            for b in packed)
    opt_shapes = jax.eval_shape(lambda p: jax.vmap(optimizer.init)(p), bufs)
    if abstract:
        count_sharding = NamedSharding(mesh,
                                       P(_eff_dim0_spec(mesh, averager)))
        opt = map_opt_state(
            opt_shapes,
            lambda sub: jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                               sharding=buf_sharding),
                sub, is_leaf=is_sds),
            lambda c: jax.ShapeDtypeStruct(c.shape, c.dtype,
                                           sharding=count_sharding))
        return ReplicaState(bufs, opt, _scalar_sds(mesh), _scalar_sds(mesh))
    opt = jax.jit(lambda p: jax.vmap(optimizer.init)(p))(bufs)
    return ReplicaState.create(bufs, opt)


def tree_all_finite(tree):
    """Traced scalar bool: every leaf of ``tree`` is NaN/Inf-free."""
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return jnp.asarray(True)
    return functools.reduce(jnp.logical_and,
                            (jnp.isfinite(l).all() for l in leaves))


def guarded_update(optimizer, grads, opt_state, params, *, finite=None):
    """Optimiser update with the non-finite gradient guard (DESIGN.md §13).

    When ``grads`` contain a NaN/Inf, the whole update is skipped —
    params and optimiser state pass through **bit-exact** — so a
    diverging or corrupted replica contributes its last good weights to
    the group average instead of poisoning it.  When grads are finite
    the result is bit-exact ``optimizer.update`` (``where(True, new,
    old)``), so differential tests see no change.  Pass ``finite`` to
    override the local check (the fsdp step pmin-reduces it over the
    shard axis first, so every shard of a pod agrees).  Returns
    ``(new_params, new_opt_state, skipped)``.
    """
    if finite is None:
        finite = tree_all_finite(grads)
    new_params, new_opt = optimizer.update(grads, opt_state, params)
    keep = lambda new, old: jnp.where(finite, new, old)
    new_params = jax.tree.map(keep, new_params, params)
    new_opt = jax.tree.map(keep, new_opt, opt_state)
    return new_params, new_opt, jnp.logical_not(finite)


def build_train_step(model, optimizer, averager, mesh, *, phase: int,
                     sync: bool, microbatch: Optional[int] = None,
                     remat: bool = True):
    """Returns jitted step(state: ReplicaState, batch) -> (state, metrics)."""
    from repro.core import streaming

    dp = dp_axes_of(mesh)
    dp_spec = _dp_spec(mesh)
    sharded = averager.sharding.is_sharded
    streamed = sharded and averager.sharding.streamed
    plan = _plan_of(model, averager) if sharded else None
    layered = model.layered if streamed else None

    def _accumulate_microbatches(one, batch, g0):
        """Scan ``one(mb) -> (grads, metrics, loss)`` over microbatches.

        Shared by all three grad paths: fp32 accumulation into ``g0``
        (zeros shaped like the grads — a full-tree pytree for replicated,
        the shard-slice tuple for fsdp), mean loss metrics.  ``one`` runs
        entirely inside the scan body, so any gather it performs is a
        body-local transient, never pinned across the scan.
        """
        b_local = jax.tree.leaves(batch)[0].shape[0]
        if b_local % microbatch or b_local < microbatch:
            raise ValueError(
                f"microbatch={microbatch} must divide the per-replica "
                f"batch {b_local}")
        mbs = jax.tree.map(
            lambda a: a.reshape((microbatch, a.shape[0] // microbatch)
                                + a.shape[1:]), batch)

        def acc_body(carry, mb):
            g_acc, l_acc = carry
            g, metrics, loss = one(mb)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g)
            return (g_acc, l_acc + loss), metrics

        (grads, _), metrics_all = jax.lax.scan(
            acc_body, (g0, jnp.zeros((), jnp.float32)), mbs)
        grads = jax.tree.map(lambda g: g / microbatch, grads)
        metrics = jax.tree.map(lambda m: m.mean(), metrics_all)
        return grads, metrics

    def _shard_g0():
        return tuple(jnp.zeros(s.shape, jnp.float32)
                     for s in plan.shard_struct())

    def grads_and_metrics(params, batch):
        def loss_fn(p, mb):
            loss, metrics = model.loss(p, mb, remat=remat)
            return loss, metrics

        def one(mb):
            (loss, metrics), g = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            return g, metrics, loss

        if microbatch and microbatch > 1:
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            return _accumulate_microbatches(one, batch, g0)
        (_, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return grads, metrics

    def sharded_grads_and_metrics(shards, batch):
        """Gather-all FSDP grads -> fp32 pod-mean shard slices.

        The gather and the reduce-scatter both live INSIDE the microbatch
        body: the gathered tree is a body-local transient (freed after each
        microbatch's bwd, never pinned across the scan) and the fp32
        accumulator is shard-sized, not full-tree-sized.
        """
        def loss_fn(p, mb):
            return model.loss(p, mb, remat=remat)

        def one(mb):
            full = plan.unshard_tree(shards)
            (loss, metrics), g = jax.value_and_grad(
                loss_fn, has_aux=True)(full, mb)
            return plan.grad_shards(g), metrics, loss

        if microbatch and microbatch > 1:
            return _accumulate_microbatches(one, batch, _shard_g0())
        grads, metrics, _ = one(batch)
        return grads, metrics

    def streamed_grads_and_metrics(shards, batch):
        """Layer-streamed FSDP grads (core/streaming.py, DESIGN.md §11).

        Gather span k+1 while span k computes; backward re-gathers spans
        (span-level remat) and reduce-scatters each span's pod-mean fp32
        gradient the moment its VJP completes.  Bit-identical to
        ``sharded_grads_and_metrics`` — same per-span primal/VJP ops, same
        fp32 pack -> psum_scatter -> 1/pod scaling.
        """
        def one(mb):
            loss, metrics, gs = streaming.streamed_loss_and_grad_shards(
                plan, layered, shards, mb, remat=remat)
            return gs, metrics, loss

        if microbatch and microbatch > 1:
            return _accumulate_microbatches(one, batch, _shard_g0())
        grads, metrics, _ = one(batch)
        return grads, metrics

    def replica_fn(params, opt_state, batch):
        if streamed:
            grads, metrics = streamed_grads_and_metrics(params, batch)
        elif sharded:
            grads, metrics = sharded_grads_and_metrics(params, batch)
        else:
            grads, metrics = grads_and_metrics(params, batch)

        if averager.grad_comm:
            grads = (averager.sync(grads) if sync
                     else averager.comm(grads, phase))
        # non-finite guard on the (pod-mean, for fsdp; group/global-mean,
        # for grad_comm averagers) gradients: a poisoned replica skips its
        # update and keeps averaging in its last good weights
        finite = tree_all_finite(grads)
        if sharded:
            # psum-scattered pod-mean shards can carry the NaN on one
            # slice only; every shard of the pod must agree to skip
            finite = jax.lax.pmin(finite.astype(jnp.int32),
                                  averager.sharding.shard_axis) > 0
        new_params, new_opt, skipped = guarded_update(
            optimizer, grads, opt_state, params, finite=finite)
        if not averager.grad_comm:
            new_params = (averager.sync(new_params) if sync
                          else averager.comm(new_params, phase))
        metrics = dict(metrics)
        metrics["skipped_nonfinite"] = skipped
        metrics = {k: jax.lax.pmean(v.astype(jnp.float32), dp)
                   for k, v in metrics.items()}
        return new_params, new_opt, metrics

    squeeze = lambda t: jax.tree.map(lambda a: a[0], t)
    expand = lambda t: jax.tree.map(lambda a: a[None], t)

    def step(state, batch):
        p, o, m = replica_fn(squeeze(state.params), squeeze(state.opt_state),
                             batch)
        new_state = ReplicaState(
            expand(p), expand(o), state.step + 1,
            jnp.asarray(-1 if sync else phase, jnp.int32))
        return new_state, m

    state_specs = replica_state_specs(model, optimizer, averager, mesh)
    sm = compat.shard_map(
        step, mesh=mesh,
        in_specs=(state_specs, P(dp_spec)),
        out_specs=(state_specs, P()),
        axis_names=set(dp), check_vma=False,
    )
    return jax.jit(sm, donate_argnums=(0,))


def train_shardings(mesh, param_specs, opt_state_shapes, params_shapes):
    """NamedSharding trees for (params, opt_state) given the param specs.

    Momentum/mu/nu leaves have the same (stacked) shapes as params and take
    the matching param spec; scalar counts take P(dp).
    """
    dp_spec = _dp_spec(mesh)
    spec_by_shape = {}
    for sp, sh in zip(
            jax.tree.leaves(param_specs, is_leaf=lambda x: isinstance(x, P)),
            jax.tree.leaves(params_shapes,
                            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))):
        spec_by_shape.setdefault(tuple(sh.shape), sp)

    def opt_spec(leaf):
        sp = spec_by_shape.get(tuple(leaf.shape))
        if sp is None:
            sp = P(*([dp_spec] + [None] * (len(leaf.shape) - 1))) \
                if len(leaf.shape) >= 1 else P()
        return sp

    opt_specs = jax.tree.map(opt_spec, opt_state_shapes,
                             is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    to_ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                   is_leaf=lambda x: isinstance(x, P))
    return to_ns(param_specs), to_ns(opt_specs)


def batch_shardings(mesh, batch_shapes):
    """Batch arrays shard axis 0 (global batch) over the dp axes."""
    dp_spec = _dp_spec(mesh)

    def spec(leaf):
        return NamedSharding(mesh, P(dp_spec, *([None] * (len(leaf.shape) - 1))))

    return jax.tree.map(spec, batch_shapes,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
