from repro.train.train_step import (build_train_step, stacked_init,
                                    train_shardings, dp_axes_of)

__all__ = ["build_train_step", "stacked_init", "train_shardings",
           "dp_axes_of"]
