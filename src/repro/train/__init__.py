from repro.train.train_step import (batch_shardings, build_train_step,
                                    dp_axes_of, guarded_update,
                                    init_replica_state,
                                    replica_state_specs, stacked_init,
                                    train_shardings, tree_all_finite)

__all__ = ["batch_shardings", "build_train_step", "dp_axes_of",
           "guarded_update", "init_replica_state", "replica_state_specs",
           "stacked_init", "train_shardings", "tree_all_finite"]
