"""SGD with (Nesterov) momentum — the paper's optimiser for all three tasks.

Momentum buffers are kept in float32 regardless of the parameter dtype
(mixed-precision-safe); weight decay is decoupled (applied to weights, not
folded into the momentum), matching common large-batch recipes.
"""

from __future__ import annotations

from typing import Callable, NamedTuple, Union

import jax
import jax.numpy as jnp


class SGDState(NamedTuple):
    momentum: object   # pytree like params, float32
    count: jnp.ndarray


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def sgd(learning_rate: Union[float, Callable], momentum: float = 0.9,
        nesterov: bool = False, weight_decay: float = 0.0) -> Optimizer:
    lr_fn = learning_rate if callable(learning_rate) else (lambda _: learning_rate)

    def init(params):
        mom = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return SGDState(momentum=mom, count=jnp.zeros((), jnp.int32))

    def update(grads, state: SGDState, params):
        lr = jnp.asarray(lr_fn(state.count), jnp.float32)

        def step(p, g, m):
            g32 = g.astype(jnp.float32)
            if weight_decay:
                g32 = g32 + weight_decay * p.astype(jnp.float32)
            m_new = momentum * m + g32
            upd = (g32 + momentum * m_new) if nesterov else m_new
            return (p.astype(jnp.float32) - lr * upd).astype(p.dtype), m_new

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.momentum)
        new = [step(p, g, m) for p, g, m in zip(flat_p, flat_g, flat_m)]
        new_p = treedef.unflatten([a for a, _ in new])
        new_m = treedef.unflatten([b for _, b in new])
        return new_p, SGDState(momentum=new_m, count=state.count + 1)

    return Optimizer(init=init, update=update)
