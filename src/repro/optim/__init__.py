"""Pure-JAX first-order optimisers (no external deps).

Optax-like interface:  opt.init(params) -> state;
opt.update(grads, state, params) -> (new_params, new_state).
The update *applies* the step (returns new params) because WAGMA averages the
updated weights W' = W + U(G) (paper Alg. 2 line 6-7).
"""

from repro.optim.sgd import sgd
from repro.optim.adamw import adamw
from repro.optim.schedule import constant, cosine_warmup

__all__ = ["sgd", "adamw", "constant", "cosine_warmup"]
