"""AdamW with float32 moments (used by the Transformer/LLM configs)."""

from __future__ import annotations

from typing import Callable, NamedTuple, Union

import jax
import jax.numpy as jnp

from repro.optim.sgd import Optimizer


class AdamWState(NamedTuple):
    mu: object
    nu: object
    count: jnp.ndarray


def adamw(learning_rate: Union[float, Callable], b1: float = 0.9,
          b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    lr_fn = learning_rate if callable(learning_rate) else (lambda _: learning_rate)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(mu=jax.tree.map(zeros, params),
                          nu=jax.tree.map(zeros, params),
                          count=jnp.zeros((), jnp.int32))

    def update(grads, state: AdamWState, params):
        count = state.count + 1
        lr = jnp.asarray(lr_fn(count), jnp.float32)
        c1 = 1.0 - b1 ** count.astype(jnp.float32)
        c2 = 1.0 - b2 ** count.astype(jnp.float32)

        def step(p, g, mu, nu):
            g32 = g.astype(jnp.float32)
            mu_new = b1 * mu + (1 - b1) * g32
            nu_new = b2 * nu + (1 - b2) * jnp.square(g32)
            upd = (mu_new / c1) / (jnp.sqrt(nu_new / c2) + eps)
            p32 = p.astype(jnp.float32)
            if weight_decay:
                upd = upd + weight_decay * p32
            return (p32 - lr * upd).astype(p.dtype), mu_new, nu_new

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_mu = treedef.flatten_up_to(state.mu)
        flat_nu = treedef.flatten_up_to(state.nu)
        new = [step(*a) for a in zip(flat_p, flat_g, flat_mu, flat_nu)]
        unf = lambda i: treedef.unflatten([n[i] for n in new])
        return unf(0), AdamWState(mu=unf(1), nu=unf(2), count=count)

    return Optimizer(init=init, update=update)
