"""Learning-rate schedules as step -> lr callables (jittable)."""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_warmup(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.asarray(step, jnp.float32)
        warm = peak_lr * (s + 1.0) / max(warmup_steps, 1)
        prog = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(s < warmup_steps, warm, cos)
    return fn
