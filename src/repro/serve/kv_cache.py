"""Paged KV cache: fixed-size block pool with per-request block tables.

DESIGN.md §14.  The dense decode path (serve/decode.py) keeps one
contiguous ``(n_sb, B, max_len, KH, hd)`` cache per batch — every request
reserves ``max_len`` positions up front and every request in the batch
shares one scalar position.  Production serving needs neither: requests
arrive with ragged prompt lengths, grow one token at a time, and finish at
different steps.  This module provides the vLLM-style resolution:

* a host-side :class:`BlockPool` allocator hands out fixed-size **blocks**
  (``block_size`` token positions each) and tracks a per-request **block
  table** — physical block ids covering exactly the request's tokens;
* the device-side pool is the model's own cache tree with the batch/seq
  dims replaced by ``(n_blocks, block_size)``:
  ``{"global": {"k","v"}}`` leaves of shape
  ``(n_sb, n_blocks, block_size, KH, hd)``;
* :func:`build_paged_decode` runs one decode step for a whole **ragged**
  batch: per request, gather the block table into a contiguous view
  ``(n_sb, 1, S_view, KH, hd)`` and run the model's *own* ``decode_step``
  on it (vmapped over requests, each at its own position), then scatter
  the newly written K/V back to ``(table[pos // bs], pos % bs)``.
  Because the per-request math IS ``model.decode_step`` on a cache view
  whose valid prefix is bit-identical to the dense cache, outputs are
  bit-exact against per-request uncontended decode (pinned in
  tests/test_serve_paged.py);
* :func:`build_paged_prefill` fills a request's blocks through the
  model's own ``prefill`` (B=1) and reshapes the returned cache into
  block rows.

Block 0 is the **null block**: never allocated, owned by nobody.  Padding
rows of a bucket-padded decode batch point their whole table at it, so
their (discarded) gathers and scatters never touch a real request's
blocks.  Stale contents of reused or null blocks are unobservable:
``decode_attention`` masks every position >= the request's length to an
exact softmax zero, and each position is written before it first becomes
valid.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import common as cm

NULL_BLOCK = 0


class OutOfBlocks(RuntimeError):
    """The pool cannot cover a request's tokens; caller must preempt."""


@dataclass
class BlockPool:
    """Host-side block allocator with per-request block tables.

    Invariants (pinned by the hypothesis property test):
    * a block is owned by at most one request (the null block by none);
    * ``free`` / ``evict`` return every owned block to the free list;
    * a request's table always holds exactly
      ``ceil(covered_tokens / block_size)`` blocks.
    """
    n_blocks: int
    block_size: int
    evictions: int = 0
    _free: List[int] = field(default_factory=list)
    _tables: Dict[object, List[int]] = field(default_factory=dict)
    _tokens: Dict[object, int] = field(default_factory=dict)

    def __post_init__(self):
        if self.n_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is the null block)")
        if self.block_size < 1:
            raise ValueError("block_size must be positive")
        # LIFO free list; block 0 (null) is never handed out.
        self._free = list(range(self.n_blocks - 1, 0, -1))

    @property
    def n_free(self) -> int:
        return len(self._free)

    def blocks_for(self, n_tokens: int) -> int:
        return -(-max(int(n_tokens), 0) // self.block_size)

    def tokens_covered(self, rid) -> int:
        return self._tokens.get(rid, 0)

    def table(self, rid) -> List[int]:
        return list(self._tables.get(rid, ()))

    def padded_table(self, rid, max_blocks: int) -> np.ndarray:
        """The request's table padded with the null block to a fixed width."""
        tbl = self._tables.get(rid, [])
        if len(tbl) > max_blocks:
            raise ValueError(f"request {rid!r} holds {len(tbl)} blocks "
                             f"> max_blocks={max_blocks}")
        out = np.full((max_blocks,), NULL_BLOCK, np.int32)
        out[:len(tbl)] = tbl
        return out

    def can_allocate(self, rid, n_tokens: int) -> bool:
        need = self.blocks_for(n_tokens) - len(self._tables.get(rid, ()))
        return need <= self.n_free

    def allocate(self, rid, n_tokens: int) -> List[int]:
        """Grow ``rid``'s table to cover ``n_tokens``; returns the table.

        Atomic: raises :class:`OutOfBlocks` without taking anything when
        the free list cannot cover the growth.  Never shrinks.
        """
        tbl = self._tables.setdefault(rid, [])
        need = self.blocks_for(n_tokens) - len(tbl)
        if need > self.n_free:
            if not tbl:
                del self._tables[rid]
            raise OutOfBlocks(
                f"request {rid!r} needs {need} more blocks for {n_tokens} "
                f"tokens; {self.n_free} free of {self.n_blocks - 1}")
        for _ in range(max(need, 0)):
            tbl.append(self._free.pop())
        self._tokens[rid] = max(self._tokens.get(rid, 0), int(n_tokens))
        return list(tbl)

    def free(self, rid) -> int:
        """Release every block of ``rid``; returns how many were freed."""
        tbl = self._tables.pop(rid, [])
        self._tokens.pop(rid, None)
        self._free.extend(reversed(tbl))
        return len(tbl)

    def evict(self, rid) -> int:
        """Preemption: same as :meth:`free`, counted separately."""
        n = self.free(rid)
        if n:
            self.evictions += 1
        return n

    def owned_blocks(self) -> List[int]:
        return [b for tbl in self._tables.values() for b in tbl]

    def check_invariants(self) -> None:
        owned = self.owned_blocks()
        assert NULL_BLOCK not in owned, "null block was allocated"
        assert len(owned) == len(set(owned)), "a block is double-owned"
        assert not set(owned) & set(self._free), "owned block on free list"
        assert len(owned) + self.n_free == self.n_blocks - 1, \
            "blocks leaked or duplicated"
        for rid, tbl in self._tables.items():
            assert len(tbl) == self.blocks_for(self._tokens[rid]), \
                f"table of {rid!r} does not cover its tokens exactly"


# ---------------------------------------------------------------------------
# Device pool + paged model steps
# ---------------------------------------------------------------------------

def init_paged_pool(model, n_blocks: int, block_size: int):
    """The model's cache tree with ``(B, max_len) -> (n_blocks, block_size)``.

    Only full-attention ("global") caches page; sliding-window ring caches
    keep a window per *request*, not per position, so they do not decompose
    into shareable blocks — serving them paged needs a per-request ring
    pool and is out of scope (fails loudly).
    """
    shapes = jax.eval_shape(lambda: model.init_caches(1, block_size))
    extra = set(shapes) - {"global"}
    if extra:
        raise NotImplementedError(
            f"paged serving supports full-attention (global) caches only; "
            f"{model.cfg.name} has cache groups {sorted(shapes)}")

    def mk(s):
        # (n_sb, 1, block_size, KH, hd) -> (n_sb, n_blocks, block_size, KH, hd)
        return jnp.zeros((s.shape[0], n_blocks) + s.shape[2:], s.dtype)

    return jax.tree.map(mk, shapes)


def _gather_view(pool_leaf, table):
    """(n_sb, n_blocks, bs, ...), table (max_blocks,) ->
    (n_sb, 1, max_blocks*bs, ...) — a dense single-request cache view."""
    g = jnp.take(pool_leaf, table, axis=1)
    return g.reshape((g.shape[0], 1, g.shape[1] * g.shape[2]) + g.shape[3:])


def build_paged_decode(model, *, block_size: int):
    """jit'd ragged-batch decode:
    ``step(params, pool, tables, tokens, positions) -> (pool, next_tokens)``.

    ``tables`` (N, max_blocks) int32, ``tokens``/``positions`` (N,) int32 —
    every request at its *own* position.  One compile per (N, max_blocks)
    shape; the scheduler pads N to a bucket so recompiles happen only on
    bucket boundaries.  Greedy next-token selection matches
    ``build_serve_step`` (vocab-padding columns masked before argmax).
    """
    vocab = model.cfg.vocab

    def step(params, pool, tables, tokens, positions):
        def one(table, tok, pos):
            views = jax.tree.map(lambda p: _gather_view(p, table), pool)
            logits, new = model.decode_step(params, views, tok[None, None],
                                            pos)

            def written(leaf):                      # (n_sb, 1, S_view, ...)
                leaf = leaf[:, 0]
                return jax.lax.dynamic_slice_in_dim(leaf, pos, 1,
                                                    axis=1)[:, 0]

            lg = logits[0, -1]
            lg = jnp.where(jnp.arange(lg.shape[-1]) < vocab, lg, cm.NEG_INF)
            nxt = jnp.argmax(lg).astype(tok.dtype)
            return nxt, jax.tree.map(written, new)

        nxt, kv = jax.vmap(one)(tables, tokens, positions)
        blk = jnp.take_along_axis(
            tables, (positions // block_size)[:, None], axis=1)[:, 0]
        slot = positions % block_size

        def scatter(pool_leaf, new):                 # new (N, n_sb, ...)
            return pool_leaf.at[:, blk, slot].set(jnp.moveaxis(new, 0, 1))

        return jax.tree.map(scatter, pool, kv), nxt

    return jax.jit(step, donate_argnums=(1,))


def build_paged_prefill(model, *, block_size: int):
    """jit'd single-request prefill into the pool:
    ``fn(params, pool, tokens, table) -> (pool, first_token)``.

    ``tokens`` (1, L) int32 at the natural prompt length (prefill K/V and
    last-token logits must be bit-identical to the uncontended reference,
    so the prompt is never padded — one compile per distinct prompt
    length; chunked prefill is future work).  ``table`` (max_blocks,)
    int32 — the request's padded table; ``max_blocks * block_size`` is the
    view length every later decode gathers, so prefill pads its cache to
    exactly that.
    """
    vocab = model.cfg.vocab

    def prefill(params, pool, tokens, table):
        s_view = table.shape[0] * block_size
        logits, caches = model.prefill(params, {"tokens": tokens}, s_view)

        def scatter(pool_leaf, c):                  # c (n_sb, 1, S_view, ...)
            c = c[:, 0].reshape((c.shape[0], table.shape[0], block_size)
                                + c.shape[3:])
            return pool_leaf.at[:, table].set(c)

        lg = logits[0, -1]
        lg = jnp.where(jnp.arange(lg.shape[-1]) < vocab, lg, cm.NEG_INF)
        first = jnp.argmax(lg).astype(tokens.dtype)
        return jax.tree.map(scatter, pool, caches), first

    return jax.jit(prefill, donate_argnums=(1,))


def extract_blocks(pool, table: np.ndarray):
    """Host copies of the blocks in ``table``: leaves (n_sb, len(table),
    block_size, ...).  The KV-transfer layer ships these."""
    tbl = jnp.asarray(np.asarray(table, np.int32))
    return jax.tree.map(lambda p: np.asarray(jnp.take(p, tbl, axis=1)), pool)


def insert_blocks(pool, table: np.ndarray, blocks):
    """Write shipped block rows into this pool at ``table`` (eager)."""
    tbl = jnp.asarray(np.asarray(table, np.int32))
    return jax.tree.map(
        lambda p, b: p.at[:, tbl].set(jnp.asarray(b, p.dtype)), pool, blocks)
