"""Disaggregated prefill/decode: link-costed KV block transfer.

DESIGN.md §14.  Prefill and decode want different hardware: prefill is a
compute-bound batch job, decode a latency-bound memory-bound loop, and
colocating them puts every multi-second 32k-token prefill on the decode
batch's critical path.  The production fix (vLLM/DistServe-style) runs
them on separate pods and streams each request's KV blocks from the
prefill pod to the decode pod.

This module reuses what training already built:

* the **connector interface** (:class:`KVConnector`, ``insert``/``select``
  over an abstracted :class:`Transport`) mirrors vLLM's
  ``kv_connector/base.py`` — the prefill worker inserts a request's
  blocks, the decode worker selects them, and neither knows the wire;
* the **bucketing layer** packs the ragged per-request block tree into
  dtype-homogeneous flat messages at the *link's* modeled-optimal budget
  (``plan.choose_class_bucket_bytes`` — DCN wants few large messages,
  ICI tolerates many small ones);
* the **Topology/LinkClass constants** (calibrated
  ``LINK_CONSTANTS.json`` via ``Topology.with_measured``) cost every
  transfer through ``plan.link_transfer_seconds`` so placement is a
  modeled decision, not a vibe — ``benchmarks/serve_sim.py`` consumes the
  same numbers.

Transfers are bit-exact: ``pack``/``unpack`` round-trips the block tree
verbatim, so a disaggregated serve produces bit-identical tokens to the
colocated scheduler (pinned in tests/test_serve_transfer.py).
"""

from __future__ import annotations

import abc
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bucketing
from repro.core import plan as plan_mod
from repro.models import common as cm
from repro.serve import kv_cache
from repro.serve.scheduler import Request, ServeScheduler


def kv_payload_bytes(cfg, n_tokens: int) -> int:
    """Bytes of K+V a dense-family request carries for ``n_tokens``."""
    itemsize = jnp.dtype(cfg.dtype).itemsize
    return int(2 * cfg.n_layers * cfg.n_kv_heads * cfg.hd * itemsize
               * max(int(n_tokens), 0))


# ---------------------------------------------------------------------------
# Transport + connector
# ---------------------------------------------------------------------------

class Transport(abc.ABC):
    """One-way message pipe between a prefill and a decode worker."""

    @abc.abstractmethod
    def send(self, rid, messages: Tuple[np.ndarray, ...]
             ) -> Tuple[np.ndarray, ...]:
        """Ship flat messages; returns what the receiver observes."""


class InProcessTransport(Transport):
    """Both workers in one process: the wire is a host-side copy."""

    def __init__(self):
        self.messages_sent = 0
        self.bytes_sent = 0

    def send(self, rid, messages):
        out = tuple(np.array(m, copy=True) for m in messages)
        self.messages_sent += len(out)
        self.bytes_sent += sum(m.nbytes for m in out)
        return out


@dataclass
class TransferStats:
    requests: int = 0
    blocks: int = 0
    payload_bytes: int = 0
    messages: int = 0
    modeled_seconds: float = 0.0


class KVConnector(abc.ABC):
    """vLLM-style KV exchange point between prefill and decode workers."""

    @abc.abstractmethod
    def insert(self, rid, kv_blocks, meta: dict) -> None:
        """Publish one finished request's KV blocks (+ metadata)."""

    @abc.abstractmethod
    def select(self, rid) -> Optional[Tuple[object, dict]]:
        """Take a request's blocks; None when not (yet) inserted."""


class LinkCostedConnector(KVConnector):
    """Connector that packs blocks into link-budget-sized messages.

    ``link`` prices the transfer (default: the DCN class — prefill and
    decode pods live across the data-center network); pass a class from
    ``Topology.with_measured(...)`` for calibrated constants.
    ``message_bytes`` overrides the modeled-optimal per-message budget.
    """

    def __init__(self, link: plan_mod.LinkClass = plan_mod.DCN,
                 transport: Optional[Transport] = None,
                 message_bytes: Optional[int] = None):
        self.link = link
        self.transport = transport or InProcessTransport()
        self.message_bytes = message_bytes
        self.stats = TransferStats()
        self._store: Dict[object, Tuple[tuple, bucketing.BucketLayout,
                                        dict]] = {}

    def budget_for(self, payload_bytes: int) -> int:
        if self.message_bytes is not None:
            return int(self.message_bytes)
        return plan_mod.choose_class_bucket_bytes(
            max(int(payload_bytes), 1), self.link, overlap=False)

    def insert(self, rid, kv_blocks, meta: dict) -> None:
        if rid in self._store:
            raise KeyError(f"request {rid!r} already inserted")
        payload = bucketing.tree_payload_bytes(kv_blocks)
        budget = self.budget_for(payload)
        # the bucketing layer flattens the block tree; the wire then chunks
        # each flat buffer at the link's message budget (layout_for never
        # splits a single leaf, and one KV leaf can dwarf the budget)
        layout = bucketing.layout_for(kv_blocks, max_bucket_bytes=budget)
        bufs = [np.asarray(m) for m in bucketing.pack(kv_blocks, layout)]
        messages, splits = [], []
        for buf in bufs:
            per = max(1, budget // buf.dtype.itemsize)
            chunks = [buf[i:i + per] for i in range(0, buf.size, per)] \
                or [buf]
            splits.append(len(chunks))
            messages.extend(chunks)
        messages = self.transport.send(rid, tuple(messages))
        self._store[rid] = (messages, tuple(splits), layout, dict(meta))
        self.stats.requests += 1
        self.stats.blocks += int(meta.get("n_blocks", 0))
        self.stats.payload_bytes += int(payload)
        self.stats.messages += len(messages)
        self.stats.modeled_seconds += plan_mod.link_transfer_seconds(
            payload, self.link, message_bytes=budget)

    def select(self, rid):
        entry = self._store.pop(rid, None)
        if entry is None:
            return None
        messages, splits, layout, meta = entry
        bufs, i = [], 0
        for n in splits:
            bufs.append(np.concatenate(messages[i:i + n])
                        if n > 1 else messages[i])
            i += n
        return bucketing.unpack(bufs, layout), meta


# ---------------------------------------------------------------------------
# Disaggregated serving
# ---------------------------------------------------------------------------

def build_prefill_export(model, *, block_size: int, max_blocks: int):
    """jit'd prefill-worker step: ``fn(params, tokens (1, L)) ->
    (block rows (n_sb, max_blocks, bs, ...), first_token)``.

    Identical math to ``build_paged_prefill`` (same ``max_len`` padding,
    same masked greedy argmax) minus the pool scatter — the blocks leave
    through the connector instead.
    """
    vocab = model.cfg.vocab

    def fn(params, tokens):
        s_view = max_blocks * block_size
        logits, caches = model.prefill(params, {"tokens": tokens}, s_view)

        def blocked(c):                              # (n_sb, 1, S_view, ...)
            return c[:, 0].reshape((c.shape[0], max_blocks, block_size)
                                   + c.shape[3:])

        lg = logits[0, -1]
        lg = jnp.where(jnp.arange(lg.shape[-1]) < vocab, lg, cm.NEG_INF)
        first = jnp.argmax(lg).astype(tokens.dtype)
        return jax.tree.map(blocked, caches), first

    return jax.jit(fn)


class DisaggregatedScheduler(ServeScheduler):
    """The continuous-batching scheduler with prefill on another worker.

    The decode side is unchanged (same pool, same bucket-padded decode
    batches); only ``_do_prefill`` differs — the prompt's K/V is computed
    with ``prefill_params`` (the prefill pod's weight copy), shipped
    through the connector as packed messages, and unpacked into this
    pool's blocks.  Outputs are bit-identical to the colocated scheduler.
    """

    def __init__(self, model, params, *, prefill_params=None,
                 connector: Optional[KVConnector] = None,
                 link: plan_mod.LinkClass = plan_mod.DCN, **kw):
        super().__init__(model, params, **kw)
        self.prefill_params = params if prefill_params is None \
            else prefill_params
        self.connector = connector if connector is not None \
            else LinkCostedConnector(link=link)
        self._export = build_prefill_export(
            model, block_size=self.block_size,
            max_blocks=self.max_blocks_per_req)

    def _do_prefill(self, req: Request, table: np.ndarray) -> int:
        # --- prefill worker ---
        blocks_tree, first = self._export(self.prefill_params,
                                          jnp.asarray(req.prompt[None]))
        n_ship = len(self.blocks.table(req.rid))     # covers prompt_len + 1
        shipped = jax.tree.map(lambda b: np.asarray(b[:, :n_ship]),
                               blocks_tree)
        self.connector.insert(req.rid, shipped,
                              {"first": int(first), "n_blocks": n_ship,
                               "prompt_len": req.prompt_len})
        # --- decode worker ---
        got = self.connector.select(req.rid)
        assert got is not None, f"connector lost request {req.rid!r}"
        kv_blocks, meta = got
        self.pool = kv_cache.insert_blocks(self.pool, table[:n_ship],
                                           kv_blocks)
        return int(meta["first"])
