"""Continuous-batching scheduler over the paged KV cache (DESIGN.md §14).

Request lifecycle::

    WAITING --admit(prefill)--> RUNNING --max tokens / EOS--> FINISHED
       ^                          |
       +------under pressure------+   (preemption frees the victim's blocks)

Each :meth:`ServeScheduler.step` admits as many waiting requests as the
block pool can hold (prefill runs at admission, one request at a time, and
writes the prompt's K/V straight into the pool), then runs ONE decode
iteration for every running request — a single vmapped
``build_paged_decode`` call in which each request sits at its own
position.  Requests join and leave the batch between iterations without
draining anyone else: that is continuous batching.

**Bucket-padded batch shapes.**  The decode batch is padded up to the next
entry of ``batch_buckets`` (powers of two by default) with rows pointing
at the null block, so ``serve_step`` recompiles only when the running set
crosses a bucket boundary — never per request count.
``decode_shapes_compiled`` records every distinct padded shape for the
tests/CI to assert exactly that.

**Preemption (recompute).**  When a decode step needs a block and the pool
is exhausted, the most-recently admitted running request is evicted: its
blocks return to the pool and it re-queues at the *front* of the waiting
line with its generated tokens dropped.  On re-admission it recomputes
from the prompt; greedy decode is deterministic, so the regenerated tokens
— and therefore the request's final output — are bit-identical to an
uncontended run (vLLM's recompute policy).
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.serve import kv_cache
from repro.serve.kv_cache import BlockPool, OutOfBlocks

WAITING, RUNNING, FINISHED = "waiting", "running", "finished"


@dataclass
class Request:
    rid: object
    prompt: np.ndarray                  # (L,) int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    state: str = WAITING
    out: List[int] = field(default_factory=list)
    preemptions: int = 0

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.size)

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new_tokens or (
            self.eos_id is not None and bool(self.out)
            and self.out[-1] == self.eos_id)


def _bucket(n: int, buckets: Sequence[int]) -> int:
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(f"batch {n} exceeds the largest bucket {buckets[-1]}")


class ServeScheduler:
    """Continuous batching + paged KV over one model replica.

    ``n_blocks`` / ``block_size`` size the pool (block 0 is reserved);
    ``max_blocks_per_req`` bounds any request's context at
    ``max_blocks_per_req * block_size`` tokens and fixes the decode view
    length (= the dense reference's ``max_len``).
    """

    def __init__(self, model, params, *, n_blocks: int, block_size: int,
                 max_blocks_per_req: int, max_batch: int = 8,
                 batch_buckets: Optional[Sequence[int]] = None):
        self.model, self.params = model, params
        self.block_size = int(block_size)
        self.max_blocks_per_req = int(max_blocks_per_req)
        self.max_batch = int(max_batch)
        if batch_buckets is None:
            batch_buckets = []
            b = 1
            while b < self.max_batch:
                batch_buckets.append(b)
                b *= 2
            batch_buckets.append(self.max_batch)
        self.batch_buckets = tuple(sorted(set(int(b) for b in batch_buckets)))
        self.blocks = BlockPool(n_blocks, block_size)
        self.pool = kv_cache.init_paged_pool(model, n_blocks, block_size)
        self._decode = kv_cache.build_paged_decode(model,
                                                   block_size=block_size)
        self._prefill = kv_cache.build_paged_prefill(model,
                                                     block_size=block_size)
        self.waiting: deque = deque()
        self.running: List[Request] = []
        self.finished: Dict[object, Request] = {}
        self.decode_shapes_compiled: set = set()
        self.n_decode_steps = 0
        self.n_prefills = 0

    # -- admission -----------------------------------------------------

    def submit(self, req: Request) -> None:
        max_ctx = self.max_blocks_per_req * self.block_size
        if req.prompt_len + req.max_new_tokens > max_ctx:
            raise ValueError(
                f"request {req.rid!r} needs {req.prompt_len + req.max_new_tokens}"
                f" positions > max context {max_ctx}")
        req.state = WAITING
        self.waiting.append(req)

    def _do_prefill(self, req: Request, table: np.ndarray) -> int:
        """Prefill ``req`` into the pool; returns the first generated token.

        Overridden by the disaggregated scheduler (serve/kv_transfer.py):
        there the prefill runs on a different worker and the K/V blocks
        arrive through the connector.
        """
        tokens = jnp.asarray(req.prompt[None])
        self.pool, first = self._prefill(self.params, self.pool, tokens,
                                         jnp.asarray(table))
        return int(first)

    def _admit(self) -> None:
        while self.waiting and len(self.running) < self.max_batch:
            req = self.waiting[0]
            # prompt + 1 so the first decode write has a slot
            if not self.blocks.can_allocate(req.rid, req.prompt_len + 1):
                break
            self.waiting.popleft()
            self.blocks.allocate(req.rid, req.prompt_len + 1)
            table = self.blocks.padded_table(req.rid, self.max_blocks_per_req)
            first = self._do_prefill(req, table)
            self.n_prefills += 1
            req.out = [first]
            req.state = RUNNING
            self.running.append(req)
            self._retire(req)

    # -- preemption ----------------------------------------------------

    def _preempt(self, victim: Request) -> None:
        self.blocks.evict(victim.rid)
        victim.out = []
        victim.preemptions += 1
        victim.state = WAITING
        self.running.remove(victim)
        self.waiting.appendleft(victim)

    def _ensure_blocks(self, req: Request) -> bool:
        """Cover this step's K/V write; False if ``req`` itself got evicted."""
        need = req.prompt_len + len(req.out)
        while True:
            try:
                self.blocks.allocate(req.rid, need)
                return True
            except OutOfBlocks:
                if len(self.running) == 1:
                    raise OutOfBlocks(
                        f"request {req.rid!r} alone exceeds the pool "
                        f"({self.blocks.n_blocks - 1} blocks of "
                        f"{self.block_size})")
                victim = self.running[-1]
                self._preempt(victim)
                if victim is req:
                    return False

    # -- the serve loop ------------------------------------------------

    def _retire(self, req: Request) -> None:
        if req.state == RUNNING and req.done:
            self.blocks.free(req.rid)
            self.running.remove(req)
            req.state = FINISHED
            self.finished[req.rid] = req

    def step(self) -> bool:
        """Admit + one decode iteration; False when nothing is in flight."""
        self._admit()
        if not self.running:
            if self.waiting:
                # nothing running and the head of the queue cannot be
                # admitted: the pool cannot serve this request at all
                req = self.waiting[0]
                self.blocks.allocate(req.rid, req.prompt_len + 1)
            return False
        batch = [r for r in list(self.running)
                 if r.state == RUNNING and self._ensure_blocks(r)]
        # later _ensure_blocks calls can only preempt *later* admissions
        # (victims pop from the running tail), but keep the guard honest:
        batch = [r for r in batch if r.state == RUNNING]
        if not batch:
            return True
        n_pad = _bucket(len(batch), self.batch_buckets)
        tables = np.zeros((n_pad, self.max_blocks_per_req), np.int32)
        tokens = np.zeros((n_pad,), np.int32)
        positions = np.zeros((n_pad,), np.int32)
        for i, req in enumerate(batch):
            tables[i] = self.blocks.padded_table(req.rid,
                                                 self.max_blocks_per_req)
            tokens[i] = req.out[-1]
            positions[i] = req.prompt_len + len(req.out) - 1
        self.decode_shapes_compiled.add((n_pad, self.max_blocks_per_req))
        self.pool, nxt = self._decode(self.params, self.pool,
                                      jnp.asarray(tables),
                                      jnp.asarray(tokens),
                                      jnp.asarray(positions))
        nxt = np.asarray(nxt)
        for i, req in enumerate(batch):
            req.out.append(int(nxt[i]))
            self._retire(req)
        self.n_decode_steps += 1
        return True

    def run(self) -> Dict[object, List[int]]:
        """Serve until every submitted request finishes."""
        while self.waiting or self.running:
            self.step()
        return {rid: list(r.out) for rid, r in self.finished.items()}
