"""Train-to-serve weight handoff (DESIGN.md §14).

A training run ends (or snapshots) as a :class:`~repro.core.replica.
ReplicaState` in whatever layout its :class:`~repro.core.replica.
ShardingPolicy` dictates — (P_dp, ...)-stacked replicated leaves, FSDP
flat shard buckets, or the streamed (layer-grouped) bucket layout.  The
serving engine wants exactly one thing: the single consensus params tree
in the model's canonical structure, ready for ``model.prefill`` /
``model.decode_step``.

``serving_weights_from_state`` is that bridge, built entirely from the
existing consolidation paths: ``consolidate_state`` averages the replica
axis (replicated) or the pod axis + unpacks through the plan's shard
layout (fsdp), and streamed states additionally merge the layered
``{"stem", "layers", "head"}`` structure back to canonical via the
model's ``ModelAPI.layered``.  Because every policy consolidates to the
same consensus, serving weights are bit-identical no matter which layout
the training run used (pinned in tests/test_serve_handoff.py).

``serving_weights_from_checkpoint`` goes through the checkpoint
round-trip instead (``load_replica_state`` already routes cross-policy /
streamed restores), so a serving fleet can pick weights off disk without
knowing how the trainer sharded them.
"""

from __future__ import annotations

from typing import Optional

from repro.core import replica as replica_mod


def _merge_if_layered(tree, plan, model):
    streamed = (plan is not None and plan.sharding.is_sharded
                and plan.sharding.streamed)
    if not streamed:
        return tree
    if model is None or model.layered is None:
        raise ValueError(
            "a streamed-fsdp state consolidates into the layered tree; "
            "pass model= (with ModelAPI.layered) to merge it back to the "
            "canonical structure")
    return model.layered.merge(tree)


def serving_weights_from_state(state: replica_mod.ReplicaState, *,
                               plan=None, model=None):
    """Consolidate a ReplicaState (any policy) into serving params.

    ``plan`` is the compiled AveragingPlan the state was trained under —
    required for FSDP states (it owns the shard layout); ``model`` is the
    serving ``ModelAPI`` — required for streamed states (its ``layered``
    merges the layered tree).
    """
    tree = replica_mod.consolidate_state(state, plan)
    return _merge_if_layered(tree, plan, model)


def serving_weights_from_checkpoint(path: str, template, *, plan=None,
                                    model=None,
                                    layered: Optional[object] = None):
    """Load a replica-state checkpoint (any policy) as serving params.

    ``template`` is the *restoring* layout's abstract ReplicaState (same
    argument as ``load_replica_state``); the checkpoint's own policy is
    read from its manifest, and cross-policy restores route through the
    existing conversion paths.  Returns the canonical consensus params.
    """
    from repro.checkpoint import ckpt
    sharding = ckpt.checkpoint_sharding(path)
    layered = layered or (model.layered if model is not None else None)
    state = ckpt.load_replica_state(path, template, sharding=sharding,
                                    plan=plan, layered=layered)
    tree = replica_mod.consolidate_state(
        state, plan if sharding.is_sharded else None)
    if sharding.is_sharded and sharding.streamed:
        tree = _merge_if_layered(tree, plan, model)
    return tree
