from repro.serve.decode import build_serve_step, build_prefill, cache_shardings

__all__ = ["build_serve_step", "build_prefill", "cache_shardings"]
