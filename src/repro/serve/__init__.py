from repro.serve.decode import build_serve_step, build_prefill, cache_shardings
from repro.serve.kv_cache import (BlockPool, OutOfBlocks, init_paged_pool,
                                  build_paged_decode, build_paged_prefill)
from repro.serve.scheduler import Request, ServeScheduler
from repro.serve.kv_transfer import (KVConnector, LinkCostedConnector,
                                     InProcessTransport,
                                     DisaggregatedScheduler)
from repro.serve.handoff import (serving_weights_from_state,
                                 serving_weights_from_checkpoint)

__all__ = [
    "build_serve_step", "build_prefill", "cache_shardings",
    "BlockPool", "OutOfBlocks", "init_paged_pool",
    "build_paged_decode", "build_paged_prefill",
    "Request", "ServeScheduler",
    "KVConnector", "LinkCostedConnector", "InProcessTransport",
    "DisaggregatedScheduler",
    "serving_weights_from_state", "serving_weights_from_checkpoint",
]
