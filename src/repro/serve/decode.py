"""Serving: prefill + single-token decode steps (pure pjit/GSPMD — WAGMA is a
training-time technique; serving uses the consolidated/replicated weights).

Decode shapes lower ``serve_step``: ONE new token against a ``seq_len`` KV
cache. Sharding strategy:

* batch >= n_dp     -> cache batch dim sharded over (pod, data)
* batch == 1 (long_500k) -> KV *sequence* dim sharded over (pod, data):
  flash-decoding-style distributed attention; GSPMD partitions the softmax
  max/sum reductions over the sharded key axis.
* q/kv heads + head_dim placed on the ``model`` axis via the weight specs;
  recurrent (SSM/RG-LRU) states shard their channel dim over ``model``.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import common as cm


def _dp(mesh):
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    return dp if len(dp) > 1 else dp[0]


def cache_shardings(mesh, cache_shapes, batch: int, model_axis="model"):
    """Sharding tree for cache pytrees (family-agnostic heuristics).

    KV caches are rank>=5 ``(..., B, S, KH, hd)``; recurrent states are
    rank 3-5 with B in position 1. We shard B over dp when divisible, else
    the largest seq-like dim; KH goes on the model axis when divisible,
    else hd.

    Raises ``ValueError`` when the dp extent divides *neither* the batch
    nor any other dim of a leaf — silently replicating a cache across a
    multi-device dp mesh is an OOM-in-production bug, not a fallback.
    """
    dp = _dp(mesh)
    n_dp = 1
    for a in (dp if isinstance(dp, tuple) else (dp,)):
        n_dp *= mesh.shape[a]
    n_model = mesh.shape.get(model_axis, 1)

    def spec(leaf):
        shape = leaf.shape
        entries = [None] * len(shape)
        # Locate the batch dim.  Several dims can equal `batch` (a ring
        # window, seq, or head count sized exactly B), so collect every
        # candidate and tiebreak on the canonical position: caches in this
        # repo put B at dim 1 (after the layer-stack dim) for every rank>=3
        # leaf, and at dim 0 only for rank<=2 recurrent vectors.
        cands = [i for i, s in enumerate(shape)
                 if (s == batch and i >= 1)
                 or (i == 0 and len(shape) <= 2 and s == batch)]
        b_idx = 1 if len(cands) > 1 and 1 in cands else \
            (cands[0] if cands else None)
        if b_idx is not None and batch % n_dp == 0 and batch >= n_dp:
            entries[b_idx] = dp
        else:
            # shard the largest remaining dim over dp (seq for KV caches)
            cand = max(range(len(shape)), key=lambda i: shape[i])
            if shape[cand] % n_dp == 0 and (b_idx is None or cand != b_idx):
                entries[cand] = dp
            elif n_dp > 1:
                raise ValueError(
                    f"cache_shardings: no dim of cache leaf {shape} "
                    f"(batch={batch}) divides the dp extent {n_dp}; "
                    "refusing to silently replicate — resize the batch/"
                    "cache or serve on a smaller dp mesh")
        # model axis: last dim (hd / channel) if divisible and not tiny
        for i in range(len(shape) - 1, -1, -1):
            if entries[i] is None and shape[i] % n_model == 0 \
                    and shape[i] >= n_model and i != b_idx:
                entries[i] = model_axis
                break
        return NamedSharding(mesh, P(*entries))

    return jax.tree.map(spec, cache_shapes,
                        is_leaf=lambda x: hasattr(x, "shape"))


def serve_param_shardings(mesh, params_shapes):
    specs = cm.tree_specs(params_shapes)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def build_serve_step(model, mesh, *, greedy: bool = True):
    """jit'd serve_step(params, caches, token (B,1), pos) ->
    (next_token (B,1), logits, caches)."""

    vocab = model.cfg.vocab

    def serve_step(params, caches, token, pos):
        logits, caches = model.decode_step(params, caches, token, pos)
        # mask vocab-padding columns (table padded to /256 for sharding)
        mask = jnp.arange(logits.shape[-1]) < vocab
        logits = jnp.where(mask, logits, cm.NEG_INF)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(token.dtype)[:, None]
        return nxt, logits, caches

    return jax.jit(serve_step, donate_argnums=(1,))


def build_prefill(model, mesh, max_len: int, remat: bool = True):
    if model.prefill is None:
        return None

    def prefill_step(params, batch):
        return model.prefill(params, batch, max_len, remat)

    return jax.jit(prefill_step)
