"""repro — WAGMA-SGD (Wait-Avoiding Group Model Averaging) on TPU pods in JAX.

Reproduction of Li et al., "Breaking (Global) Barriers in Parallel Stochastic
Optimization with Wait-Avoiding Group Averaging", IEEE TPDS 2020.
"""

__version__ = "0.1.0"
