from repro.checkpoint.ckpt import save_checkpoint, load_checkpoint, consolidate

__all__ = ["save_checkpoint", "load_checkpoint", "consolidate"]
