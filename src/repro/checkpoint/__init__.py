from repro.checkpoint.ckpt import (checkpoint_sharding, consolidate,
                                   load_checkpoint, load_replica_state,
                                   save_checkpoint, save_replica_state)

__all__ = ["checkpoint_sharding", "consolidate", "load_checkpoint",
           "load_replica_state", "save_checkpoint", "save_replica_state"]
