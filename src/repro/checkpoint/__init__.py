from repro.checkpoint.ckpt import (ChecksumError, checkpoint_sharding,
                                   consolidate, load_checkpoint,
                                   load_replica_state, save_checkpoint,
                                   save_replica_state)

__all__ = ["ChecksumError", "checkpoint_sharding", "consolidate",
           "load_checkpoint", "load_replica_state", "save_checkpoint",
           "save_replica_state"]
