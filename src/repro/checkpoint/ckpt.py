"""Checkpointing: pytrees -> .npz with flattened key paths + a JSON manifest.

WAGMA keeps *divergent* per-replica weights (leading dp axis). ``consolidate``
averages the replica axis to emit a single serving/export model — the paper's
"global consensus achieved post-training by choosing the model average" (Q4).
"""

from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> dict:
    flat = {}

    def visit(path, leaf):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":      # npz has no bf16: widen to f32
            arr = arr.astype(np.float32)
        flat[key] = arr

    jax.tree_util.tree_map_with_path(visit, tree)
    return flat


def save_checkpoint(path: str, params, opt_state=None, step: int = 0,
                    metadata: Optional[dict] = None):
    os.makedirs(path, exist_ok=True)
    flat = _flatten(params)
    np.savez(os.path.join(path, "params.npz"), **flat)
    if opt_state is not None:
        np.savez(os.path.join(path, "opt_state.npz"), **_flatten(opt_state))
    manifest = {
        "step": int(step),
        "keys": sorted(flat),
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "metadata": metadata or {},
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)


def load_checkpoint(path: str, params_template, opt_template=None):
    """Restore into the structure of the given templates."""
    data = np.load(os.path.join(path, "params.npz"))

    def rebuild(template, npz):
        flat_keys = []

        def visit(p, leaf):
            key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                           for k in p)
            flat_keys.append((key, leaf))

        jax.tree_util.tree_map_with_path(visit, template)
        leaves = []
        for key, leaf in flat_keys:
            arr = npz[key]
            assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape, leaf.shape)
            leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
        return jax.tree.unflatten(jax.tree.structure(template), leaves)

    params = rebuild(params_template, data)
    with open(os.path.join(path, "manifest.json")) as f:
        step = json.load(f)["step"]
    if opt_template is not None:
        opt = rebuild(opt_template, np.load(os.path.join(path, "opt_state.npz")))
        return params, opt, step
    return params, step


def consolidate(stacked_params):
    """Average the leading dp-replica axis -> single consensus model."""
    return jax.tree.map(
        lambda a: jnp.mean(a.astype(jnp.float32), axis=0).astype(a.dtype),
        stacked_params)
