"""Checkpointing: pytrees -> .npz with flattened key paths + a JSON manifest.

WAGMA keeps *divergent* per-replica weights (leading dp axis). ``consolidate``
averages the replica axis to emit a single serving/export model — the paper's
"global consensus achieved post-training by choosing the model average" (Q4).

:func:`save_replica_state` / :func:`load_replica_state` round-trip the whole
:class:`~repro.core.replica.ReplicaState` (params + optimiser state + the
averager step/phase bookkeeping) in either layout the
:class:`~repro.core.replica.ShardingPolicy` dictates: replicated
(P_dp, ...)-stacked leaves or FSDP-within-pod (P_pods, bucket) shard
buffers (DESIGN.md §10).  The manifest records the policy, and ``load``
converts across policies through the compiled plan when the restoring run
uses the other one — save from a sharded run, restore into a replicated
run and vice versa, with ``consolidate`` agreeing either way
(tests/test_replica.py pins the equality).

Writes are **atomic** (DESIGN.md §13): every file lands on a temp path,
is flushed + fsynced, then rename-committed; the manifest — carrying a
crc32 checksum per stored leaf — is written last, so a crash at any
point mid-save (exactly what ``core.faults.InjectedCrash`` induces)
leaves either the previous complete checkpoint or a torn write that
:func:`load_checkpoint` rejects loudly on checksum/manifest mismatch —
never a half-written state that loads silently.
"""

from __future__ import annotations

import json
import os
import zlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

# rename-commit seam; the crash-mid-save tests monkeypatch this to die
# between the data files and the manifest
_replace = os.replace


class ChecksumError(RuntimeError):
    """A stored leaf's bytes do not match the manifest's checksum."""


def _checksum(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


def _atomic_savez(path: str, flat: dict) -> None:
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
        f.flush()
        os.fsync(f.fileno())
    _replace(tmp, path)


def _atomic_write_text(path: str, text: str) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(text)
        f.flush()
        os.fsync(f.fileno())
    _replace(tmp, path)


def _fsync_dir(path: str) -> None:
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _flatten(tree) -> dict:
    flat = {}

    def visit(path, leaf):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":      # npz has no bf16: widen to f32
            arr = arr.astype(np.float32)
        flat[key] = arr

    jax.tree_util.tree_map_with_path(visit, tree)
    return flat


def save_checkpoint(path: str, params, opt_state=None, step: int = 0,
                    metadata: Optional[dict] = None):
    """Atomic save: data files first, checksummed manifest last.

    The manifest rename is the commit point — a reader either sees the
    previous complete (manifest, data) pair or the new one, and a torn
    combination (new data + old manifest, or a crash before any rename)
    fails the checksum verification in :func:`load_checkpoint` instead
    of loading silently.
    """
    os.makedirs(path, exist_ok=True)
    flat = _flatten(params)
    _atomic_savez(os.path.join(path, "params.npz"), flat)
    manifest = {
        "step": int(step),
        "keys": sorted(flat),
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "checksums": {k: _checksum(v) for k, v in flat.items()},
        "metadata": metadata or {},
    }
    if opt_state is not None:
        opt_flat = _flatten(opt_state)
        _atomic_savez(os.path.join(path, "opt_state.npz"), opt_flat)
        manifest["opt_checksums"] = {k: _checksum(v)
                                     for k, v in opt_flat.items()}
    _atomic_write_text(os.path.join(path, "manifest.json"),
                       json.dumps(manifest, indent=2))
    _fsync_dir(path)


def load_checkpoint(path: str, params_template, opt_template=None):
    """Restore into the structure of the given templates.

    Every leaf read is verified against the manifest's crc32 before use
    (checkpoints predating the checksums load unverified); a mismatch —
    a torn write, bit rot, or data files newer than the manifest —
    raises :class:`ChecksumError` instead of returning corrupt state.
    """
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "params.npz"))

    def rebuild(template, npz, checksums):
        flat_keys = []

        def visit(p, leaf):
            key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                           for k in p)
            flat_keys.append((key, leaf))

        jax.tree_util.tree_map_with_path(visit, template)
        leaves = []
        for key, leaf in flat_keys:
            arr = npz[key]
            assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape, leaf.shape)
            if checksums is not None and key in checksums:
                got = _checksum(arr)
                if got != checksums[key]:
                    raise ChecksumError(
                        f"checkpoint {path!r} leaf {key!r}: stored bytes "
                        f"hash {got}, manifest says {checksums[key]} — "
                        "torn or corrupted write")
            leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
        return jax.tree.unflatten(jax.tree.structure(template), leaves)

    params = rebuild(params_template, data, manifest.get("checksums"))
    step = manifest["step"]
    if opt_template is not None:
        opt = rebuild(opt_template, np.load(os.path.join(path, "opt_state.npz")),
                      manifest.get("opt_checksums"))
        return params, opt, step
    return params, step


def consolidate(stacked_params):
    """Average the leading dp-replica axis -> single consensus model."""
    return jax.tree.map(
        lambda a: jnp.mean(a.astype(jnp.float32), axis=0).astype(a.dtype),
        stacked_params)


# ---------------------------------------------------------------------------
# ReplicaState round trip (DESIGN.md §10)
# ---------------------------------------------------------------------------

def save_replica_state(path: str, state, sharding=None,
                       metadata: Optional[dict] = None):
    """Persist a whole ReplicaState (params, opt, step/phase, policy)."""
    from repro.core.replica import REPLICATED
    sharding = sharding or REPLICATED
    meta = dict(metadata or {})
    meta.update({
        "replica_state": True,
        "phase": int(np.asarray(state.phase)),
        "sharding": sharding.kind,
        "shard_axis": sharding.shard_axis,
        "streamed": sharding.streamed,
    })
    save_checkpoint(path, state.params, opt_state=state.opt_state,
                    step=int(np.asarray(state.step)), metadata=meta)


def checkpoint_sharding(path: str):
    """The ShardingPolicy a replica-state checkpoint was written under."""
    from repro.core.replica import ShardingPolicy
    with open(os.path.join(path, "manifest.json")) as f:
        meta = json.load(f)["metadata"]
    return ShardingPolicy(meta.get("sharding", "replicated"),
                          meta.get("shard_axis"),
                          meta.get("streamed", False))


def load_replica_state(path: str, template, *, sharding=None, plan=None,
                       layered=None):
    """Restore a ReplicaState into ``template``'s layout.

    ``sharding`` is the *restoring run's* policy (default replicated);
    when it differs from the policy the checkpoint was written under, the
    state is rebuilt in the source layout (derived from ``plan`` — the
    compiled AveragingPlan of the model, required for any cross-policy
    restore) and converted host-side: pod models broadcast to members
    (sharded -> replicated) or pod-averaged and packed (replicated ->
    sharded).

    When the streamed layout is on either side of the conversion,
    ``layered`` (the model's ``ModelAPI.layered``) is additionally
    required: streamed plans store the layered tree ``{"stem", "layers",
    "head"}`` while replicated checkpoints hold the canonical tree, so
    the restore merges/splits each replica row across structures (pure
    restructuring, bit-exact).
    """
    from repro.core import replica as replica_mod
    sharding = sharding or replica_mod.REPLICATED
    src = checkpoint_sharding(path)
    if src.kind == sharding.kind and src.streamed != sharding.streamed:
        # both fsdp but different bucket layouts (layer-streamed vs
        # gather-all): one plan cannot describe both, and the npz keys are
        # flat bucket indices, so a direct template load would silently
        # mix layouts — route through the canonical-replicated conversion
        # path instead (load in the source layout, convert to replicated,
        # convert back under this run's plan; bit-exact, DESIGN.md §11)
        return _load_across_stream_layouts(path, template, src, sharding,
                                           plan, layered)
    needs_layered = (src.kind != sharding.kind
                     and (src.streamed or sharding.streamed))
    if needs_layered and layered is None:
        raise ValueError(
            f"converting between {src.describe()} and {sharding.describe()}"
            " crosses the layered <-> canonical tree structures; pass "
            "layered= (the model's ModelAPI.layered)")
    if src.kind == sharding.kind:
        src_template = template
    elif plan is None:
        raise ValueError(
            f"checkpoint at {path} was written under {src.describe()} but "
            f"the run uses {sharding.describe()}; pass the compiled plan "
            "to convert")
    elif src.is_sharded:
        src_template = replica_mod.sharded_state_template(
            plan, template.opt_state)
    else:
        # replicated checkpoints hold the canonical tree; a streamed
        # plan's replicated template is layered, so canonicalise it
        src_template = replica_mod.replicated_state_template(
            plan, template.opt_state)
        if sharding.streamed:
            src_template = replica_mod.canonical_replicated_template(
                src_template, layered)

    params, opt, step = load_checkpoint(path, src_template.params,
                                        src_template.opt_state)
    with open(os.path.join(path, "manifest.json")) as f:
        phase = json.load(f)["metadata"].get("phase", -1)
    state = replica_mod.ReplicaState.create(params, opt, step=step,
                                            phase=phase)
    if src.kind == sharding.kind:
        return state
    if src.is_sharded:
        state = replica_mod.fsdp_to_replicated_state(state, plan)
        if src.streamed:
            state = replica_mod.merge_layered_state(state, layered)
        return state
    if sharding.streamed:
        state = replica_mod.split_layered_state(state, layered)
    return replica_mod.replicated_to_fsdp_state(state, plan)


def _load_across_stream_layouts(path, template, src, sharding, plan,
                                layered):
    """streamed <-> gather-all fsdp restore via the canonical replicated path.

    ``plan`` is the RESTORING run's plan.  The source layout's plan is
    compiled here on the same topology/config with the flipped streamed
    bit; the state loads in the source layout, converts host-side to the
    replicated layout, crosses the layered <-> canonical tree structures
    when the two plans were compiled over different trees (``layered``
    required for that — the real-model case, where gather-all plans hold
    the canonical tree and streamed plans the layered one; pass
    ``layered=None`` when both plans share one tree structure), and
    converts back under the destination plan.  Pure restructuring +
    pod-mean of identical broadcast members — bit-exact.
    """
    from repro.core import replica as replica_mod
    from repro.core.plan import compile_plan

    if plan is None:
        raise ValueError(
            f"checkpoint at {path} was written under {src.describe()} but "
            f"the run uses {sharding.describe()}; pass the compiled plan "
            "to convert across the bucket layouts")
    src_policy = replica_mod.ShardingPolicy.fsdp_within_pod(
        src.shard_axis or sharding.shard_axis, streamed=src.streamed)
    if layered is None:
        # both plans over one tree structure (e.g. gather-all compiled
        # directly over a layered tree); compile_plan validates it fits
        src_tree = plan.storage_struct
    elif src.streamed:
        # destination gather-all holds the canonical tree; the source
        # stored the layered tree
        src_tree = jax.eval_shape(layered.split, plan.storage_struct)
    else:
        # destination streamed holds the layered tree; the source stored
        # the canonical tree
        src_tree = jax.eval_shape(layered.merge, plan.storage_struct)
    src_plan = compile_plan(plan.topology, src_tree, plan.cfg, src_policy)
    src_template = replica_mod.sharded_state_template(src_plan,
                                                      template.opt_state)
    params, opt, step = load_checkpoint(path, src_template.params,
                                        src_template.opt_state)
    with open(os.path.join(path, "manifest.json")) as f:
        phase = json.load(f)["metadata"].get("phase", -1)
    state = replica_mod.ReplicaState.create(params, opt, step=step,
                                            phase=phase)
    state = replica_mod.fsdp_to_replicated_state(state, src_plan)
    if layered is not None:
        state = replica_mod.merge_layered_state(state, layered) \
            if src.streamed else \
            replica_mod.split_layered_state(state, layered)
    return replica_mod.replicated_to_fsdp_state(state, plan)
