"""Synthetic data pipeline.

Two generators:

* ``SyntheticTask`` — a *learnable* LM task: tokens follow a fixed random
  first-order teacher (permutation-mixture transition table), so
  cross-entropy meaningfully decreases during the convergence benchmarks and
  example drivers. Deterministic per (seed, step, worker).
* length-imbalance sampling (paper §V-C Fig. 6): per-batch sentence lengths
  drawn from a log-normal fitted to the paper's WMT distribution, returned as
  padded (tokens, mask) — used by the straggler simulator and benchmarks to
  reproduce the unbalanced-workload setting.

Everything is numpy-host-side; device placement happens in the launcher via
``jax.device_put`` with the batch sharding.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np


@dataclass
class SyntheticTask:
    vocab: int
    seq_len: int
    seed: int = 0
    order_mix: float = 0.75     # teacher determinism (learnability)

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = self.vocab
        # teacher: tok_{t+1} = perm[tok_t] with prob order_mix, else uniform
        self.perm = rng.permutation(v)

    def batch(self, step: int, worker: int, batch_size: int,
              seq_len: Optional[int] = None) -> dict:
        s = seq_len or self.seq_len
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + worker)
        toks = np.empty((batch_size, s + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, batch_size)
        noise = rng.random((batch_size, s)) > self.order_mix
        rand = rng.integers(0, self.vocab, (batch_size, s))
        for t in range(s):
            nxt = self.perm[toks[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], rand[:, t], nxt)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def imbalanced_batch(self, step: int, worker: int, batch_size: int,
                         median_len: Optional[int] = None) -> dict:
        """Padded batch with log-normal lengths (paper Fig. 6 style)."""
        s = self.seq_len
        med = median_len or max(s // 4, 8)
        rng = np.random.default_rng(
            (self.seed * 2_000_003 + step) * 65_537 + worker)
        lens = np.clip(rng.lognormal(np.log(med), 0.6, batch_size), 4, s
                       ).astype(np.int32)
        base = self.batch(step, worker, batch_size)
        mask = (np.arange(s)[None, :] < lens[:, None]).astype(np.float32)
        return {**base, "mask": mask, "lengths": lens}

    def work_per_batch(self, batch: dict) -> float:
        """Relative compute cost (token count) — the imbalance signal."""
        if "lengths" in batch:
            return float(batch["lengths"].sum())
        return float(batch["tokens"].size)


def make_batch_fn(cfg, shape, seed: int = 0, imbalanced: bool = False):
    """Returns batch_fn(step, worker, per_worker_batch) for a model config,
    adding the modality-stub inputs required by the family."""
    task = SyntheticTask(vocab=cfg.vocab, seq_len=shape.seq_len, seed=seed)
    rng = np.random.default_rng(seed + 77)

    def fn(step: int, worker: int, bsz: int) -> dict:
        if cfg.family == "vlm":
            s_text = shape.seq_len - cfg.n_patches
            b = task.batch(step, worker, bsz, seq_len=s_text)
            b["patches"] = rng.standard_normal(
                (bsz, cfg.n_patches, cfg.d_model)).astype(np.float32) * 0.02
            return b
        if cfg.family == "audio":
            b = (task.imbalanced_batch(step, worker, bsz) if imbalanced
                 else task.batch(step, worker, bsz))
            if cfg.encoder_frames:
                b["frames"] = rng.standard_normal(
                    (bsz, cfg.encoder_frames, cfg.d_model)
                ).astype(np.float32) * 0.02
            else:
                b["src"] = np.random.default_rng(seed + step).integers(
                    0, cfg.vocab, (bsz, 64), dtype=np.int32)
            return b
        return (task.imbalanced_batch(step, worker, bsz) if imbalanced
                else task.batch(step, worker, bsz))

    return fn
