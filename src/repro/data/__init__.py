from repro.data.synthetic import SyntheticTask, make_batch_fn

__all__ = ["SyntheticTask", "make_batch_fn"]
