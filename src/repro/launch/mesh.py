"""Production mesh definitions (TPU v5e pods; 256 chips/pod).

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 4, model: int = 2):
    """Small mesh over forced host devices (tests / examples)."""
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_over(devices, shape, axes):
    """Mesh over an explicit device subset (elastic worlds, DESIGN.md §12).

    ``jax.make_mesh`` always takes every visible device; an elastic
    shrink needs a mesh over just the surviving workers' devices, and a
    regrow one over survivors + joiners in membership rank order.
    """
    import numpy as np
    devices = list(devices)
    n = 1
    for s in shape:
        n *= int(s)
    if n != len(devices):
        raise ValueError(f"mesh shape {tuple(shape)} needs {n} devices, "
                         f"got {len(devices)}")
    return jax.sharding.Mesh(np.asarray(devices).reshape(shape), tuple(axes))


# TPU v5e hardware constants for the roofline terms
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (per-device collective bw)
HBM_PER_CHIP = 16 * 2**30    # 16 GiB
