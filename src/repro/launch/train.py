"""Production training driver.

Builds the mesh, model, optimiser, and averager; maintains the cache of
compiled step variants (one per butterfly phase offset + the tau-sync step);
streams synthetic data; logs metrics; checkpoints.

Usage (CPU demo on forced host devices is in examples/; on a real pod run):

    python -m repro.launch.train --arch tinyllama-1.1b --averager wagma \
        --steps 500 --data-axis 16 --model-axis 16 [--multi-pod]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.configs.base import InputShape
from repro.core.baselines import make_averager
from repro.core.group_allreduce import dp_axis_layout
from repro.core.replica import REPLICATED, ShardingPolicy, consolidate_state
from repro.data import make_batch_fn
from repro.models.registry import build_model
from repro.optim import sgd, adamw, cosine_warmup
from repro.train import build_train_step, init_replica_state, dp_axes_of
from repro.checkpoint import save_replica_state
from repro import compat


def resolve_sharding(sharding, dp_names, streamed: bool = False
                     ) -> ShardingPolicy:
    """CLI/ctor spelling -> ShardingPolicy.

    ``None``/``"replicated"`` -> replicated; ``"fsdp"`` shards over the
    minor (intra-pod) dp axis; ``streamed=True`` (or the ``"fsdp_streamed"``
    spelling) selects the layer-streamed state layout (DESIGN.md §11); a
    ready ShardingPolicy passes through.
    """
    if isinstance(sharding, ShardingPolicy):
        if streamed and not sharding.streamed:
            import dataclasses
            return dataclasses.replace(sharding, streamed=True)
        return sharding
    if sharding == "fsdp_streamed":
        sharding, streamed = "fsdp", True
    if sharding is None or sharding == "replicated":
        if streamed:
            raise ValueError("--streamed requires --sharding fsdp")
        return REPLICATED
    if sharding == "fsdp":
        return ShardingPolicy.fsdp_within_pod(dp_names[0], streamed=streamed)
    raise ValueError(f"unknown sharding {sharding!r}; options: "
                     f"replicated | fsdp | fsdp_streamed | "
                     f"ShardingPolicy(...)")


class Trainer:
    def __init__(self, cfg, mesh, *, averager="wagma", group_size=None,
                 tau=10, optimizer="sgd", learning_rate=0.1, momentum=0.9,
                 seq_len=512, global_batch=None, seed=0, microbatch=None,
                 imbalanced=False, topology=None, sharding=None,
                 streamed=False, init_state=None, fault_injector=None):
        self.cfg = cfg
        self.mesh = mesh
        self.model = build_model(cfg)
        dp = dp_axes_of(mesh)
        self.n_dp = int(np.prod([mesh.shape[a] for a in dp]))
        names, sizes = dp_axis_layout(mesh.axis_names, dict(mesh.shape), dp)
        self.sharding = resolve_sharding(sharding, names, streamed=streamed)
        kw = {}
        if averager == "wagma":
            kw = {"group_size": group_size, "tau": tau}
        elif averager == "local_sgd":
            kw = {"sync_period": tau}
        if topology is not None:
            # pod-aware (or custom) Topology: the averager compiles one
            # AveragingPlan per tree structure on it — per-link-class bucket
            # budgets, stage classification, wavefront schedule (DESIGN §9)
            kw["topology"] = topology
        kw["sharding"] = self.sharding
        self.averager = make_averager(averager, names, sizes, **kw)
        if optimizer == "sgd":
            self.opt = sgd(learning_rate, momentum=momentum)
        else:
            self.opt = adamw(learning_rate)
        self.shape = InputShape("custom", seq_len,
                                global_batch or 8 * self.n_dp, "train")
        self.batch_fn = make_batch_fn(cfg, self.shape, seed=seed,
                                      imbalanced=imbalanced)
        self.microbatch = microbatch
        self._steps = {}
        dp_spec = dp if len(dp) > 1 else dp[0]
        self._dp_spec = dp_spec
        with compat.set_mesh(mesh):
            if init_state is not None:
                # elastic handoff / warm start: seat a host-side
                # ReplicaState (already in this policy's layout, with the
                # right replica-row count for this mesh) instead of
                # initialising fresh weights
                self.state = self._put_state(init_state)
            else:
                self.state = init_replica_state(self.model, self.opt,
                                                self.averager, mesh,
                                                jax.random.PRNGKey(seed))
        self._batch_sharding = lambda v: NamedSharding(
            mesh, P(dp_spec, *([None] * (v.ndim - 1))))
        # core.faults.FaultInjector (or None): wall-clock fault runtime
        # for this process's worker identity, consulted before each step
        self.fault_injector = fault_injector
        # replica-steps whose optimiser update was skipped by the
        # non-finite gradient guard (train/train_step.py), accumulated
        # from the per-step `skipped_nonfinite` metric fraction
        self.skipped_nonfinite = 0.0
        self.last_metrics = {}

    def _put_state(self, state):
        """device_put a host ReplicaState with this run's shardings."""
        from repro.core.replica import ReplicaState, map_opt_state
        from repro.train import replica_state_specs
        specs = replica_state_specs(self.model, self.opt, self.averager,
                                    self.mesh)
        scalar = NamedSharding(self.mesh, P())
        put = lambda spec: (lambda t: jax.tree.map(
            lambda a: jax.device_put(jnp.asarray(a),
                                     NamedSharding(self.mesh, spec)), t))
        # the per-replica count vector shards over dim 0 only
        opt = map_opt_state(state.opt_state, put(specs.params),
                            put(P(specs.params[0])))
        return ReplicaState(put(specs.params)(state.params), opt,
                            jax.device_put(jnp.asarray(state.step), scalar),
                            jax.device_put(jnp.asarray(state.phase), scalar))

    @property
    def params(self):
        return self.state.params

    def plan(self):
        """The compiled AveragingPlan the train step executes."""
        from repro.train.train_step import _plan_of
        return _plan_of(self.model, self.averager)

    def _step_fn(self, t: int):
        sync = self.averager.sync_due(t)
        phase = self.averager.phase_for_step(t)
        key = ("sync",) if sync else ("group", phase)
        if key not in self._steps:
            self._steps[key] = build_train_step(
                self.model, self.opt, self.averager, self.mesh,
                phase=phase, sync=sync, microbatch=self.microbatch)
        return self._steps[key]

    def _put_batch(self, t: int):
        per = self.shape.global_batch
        nb = self.batch_fn(t, 0, per)
        return {k: jax.device_put(jnp.asarray(v), self._batch_sharding(
            jnp.asarray(v))) for k, v in nb.items()}

    def step_once(self, t: int) -> float:
        """Run global step ``t`` (data, variant dispatch, update); returns loss.

        ``t`` is the *global* step index — the butterfly phase and the
        tau-sync schedule key off it, so an elastic driver that rebuilds
        the Trainer mid-run keeps passing its own monotonic counter.
        Callers outside :meth:`run` wrap in ``compat.set_mesh(self.mesh)``.
        """
        if self.fault_injector is not None:
            self.fault_injector.before_step(t)
        batch = self._put_batch(t)
        step = self._step_fn(t)
        self.state, metrics = step(self.state, batch)
        self.last_metrics = {k: float(v) for k, v in metrics.items()}
        self.skipped_nonfinite += \
            self.last_metrics.get("skipped_nonfinite", 0.0) * self.n_dp
        return float(metrics["loss"])

    def run(self, steps: int, log_every: int = 10, ckpt_dir=None,
            ckpt_every=0):
        history = []
        with compat.set_mesh(self.mesh):
            t0 = time.time()
            for t in range(steps):
                loss = self.step_once(t)
                history.append(loss)
                if log_every and (t % log_every == 0 or t == steps - 1):
                    dt = time.time() - t0
                    tput = self.shape.global_batch * self.shape.seq_len \
                        * (t + 1) / max(dt, 1e-9)
                    skip = (f" skipped_nonfinite {self.skipped_nonfinite:.0f}"
                            if self.skipped_nonfinite else "")
                    print(f"step {t:5d} loss {loss:.4f} "
                          f"({tput:,.0f} tok/s wall){skip}", flush=True)
                if ckpt_dir and ckpt_every and (t + 1) % ckpt_every == 0:
                    save_replica_state(
                        ckpt_dir, jax.device_get(self.state),
                        sharding=self.sharding,
                        metadata={"arch": self.cfg.name})
        return history

    def consolidated(self):
        plan = self.plan() if self.sharding.is_sharded else None
        return consolidate_state(jax.device_get(self.state), plan)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--averager", default="wagma")
    ap.add_argument("--group-size", type=int, default=None)
    ap.add_argument("--tau", type=int, default=10)
    ap.add_argument("--optimizer", default="sgd")
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=512)
    ap.add_argument("--global-batch", type=int, default=None)
    ap.add_argument("--data-axis", type=int, default=None)
    ap.add_argument("--model-axis", type=int, default=None)
    ap.add_argument("--pod-axis", type=int, default=None,
                    help="with --data-axis: build a (pod, data, model) "
                         "mesh — required for --sharding fsdp (the pod "
                         "axis carries the pod-to-pod averaging)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--pod-dcn", action="store_true",
                    help="hierarchical topology: the pod axis rides DCN "
                         "constants/budget, data rides ICI (DESIGN.md §9)")
    ap.add_argument("--sharding", default="replicated",
                    choices=["replicated", "fsdp"],
                    help="fsdp: shard params/opt over the intra-pod dp "
                         "axis; replicas inside a pod act as one logical "
                         "WAGMA worker (DESIGN.md §10)")
    ap.add_argument("--streamed", action="store_true",
                    help="with --sharding fsdp: layer-streamed execution — "
                         "gather layer span k+1 while span k computes, "
                         "backward re-gathers + early reduce-scatters "
                         "(DESIGN.md §11; needs a model with a per-layer "
                         "apply decomposition)")
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--imbalanced", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    if args.data_axis and args.pod_axis:
        mesh = jax.make_mesh(
            (args.pod_axis, args.data_axis, args.model_axis or 1),
            ("pod", "data", "model"))
    elif args.data_axis:
        mesh = jax.make_mesh((args.data_axis, args.model_axis or 1),
                             ("data", "model"))
    else:
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=args.multi_pod)

    cfg = get_config(args.arch, smoke=args.smoke)
    topology = None
    if args.pod_dcn:
        from repro.core.plan import Topology
        names, sizes = dp_axis_layout(mesh.axis_names, dict(mesh.shape),
                                      dp_axes_of(mesh))
        topology = Topology.hierarchical(names, sizes, dcn_axes=("pod",))
    tr = Trainer(cfg, mesh, averager=args.averager,
                 group_size=args.group_size, tau=args.tau,
                 optimizer=args.optimizer, learning_rate=args.lr,
                 seq_len=args.seq_len, global_batch=args.global_batch,
                 microbatch=args.microbatch, imbalanced=args.imbalanced,
                 topology=topology, sharding=args.sharding,
                 streamed=args.streamed)
    hist = tr.run(args.steps, ckpt_dir=args.ckpt_dir,
                  ckpt_every=50 if args.ckpt_dir else 0)
    print(f"final loss {hist[-1]:.4f} (start {hist[0]:.4f})")


if __name__ == "__main__":
    main()
