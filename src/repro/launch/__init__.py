# Launchers: production mesh, dry-run (lower+compile on 512 virtual devices),
# roofline analysis, and the real training / serving drivers.
