"""Elastic membership driver: survive pod churn without a restart.

DESIGN.md §12.  Composes the host-side membership machinery
(core/elastic.py) with the production Trainer:

* a **leave** (preemption, dead host) shrinks the dp mesh immediately —
  the survivors' replica rows are re-seated host-side
  (checkpoint-free), a mesh over just the surviving devices is built,
  and the averaging plan recompiles for the new topology (the plan
  cache keys on topology; the dead topology's entries are evicted);
* a **join** waits for the next tau-sync barrier: right after the sync
  collective every survivor holds the identical consensus model, so the
  joiner clones it bit-exactly with zero staleness (Parallel Restarted
  SGD's restart discipline — the same barrier that bounds simulator
  buffer age by ``max_staleness_bound(tau)``);
* every world change is **epoch-stamped** and logged with the topology
  diff and the number of evicted plan-cache entries.

The power-of-two butterfly invariant is kept by quantising the healthy
set (surplus workers wait as spares and rejoin at the barrier too).

:func:`kill_rejoin_demo` scripts the whole protocol on the forced-host
CPU mesh — it is both the CI smoke (``python -m repro.launch.elastic``)
and the body of the kill/rejoin subprocess test, so the gate and the
test exercise one code path.

**Chaos mode** (DESIGN.md §13): :meth:`ElasticTrainer.run_under_faults`
drives the same machinery *autonomously* — no scripted leaves.  A
seeded `core.faults.FaultSchedule` silences workers on a virtual clock,
the `core.health.FailureDetector` turns silence past the per-round
collective deadline into suspect/confirm verdicts, a suspect downgrades
the round to the survivors' quantised world through
``MembershipController.apply_verdict`` (same handoff + plan eviction as
a scripted leave), every skipped contribution is charged to a
`core.staleness.SkipLedger` (hard abort past ``max_staleness_bound``),
and recovered workers rejoin bit-identically at the tau-sync barrier.
Time is virtual (``step * step_time_s``), so the same schedule replays
bit-identically — :func:`chaos_demo` is the CI smoke
(``python -m repro.launch.elastic --chaos``) and the chaos-matrix test
body.

Scope: the elastic driver runs the replicated policy (every worker is
one dp replica).  Sharded (FSDP-within-pod) worlds hand off through the
same :func:`~repro.core.elastic.handoff_state` conversion machinery at
pod granularity — pinned host-side in tests/test_elastic.py — but wiring
pod-granular membership into the driver is future work.
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib

import jax
import numpy as np

from repro import compat
from repro.core import faults as faults_mod
from repro.core import health as health_mod
from repro.core import plan as plan_mod
from repro.core.elastic import (MembershipController, diff_topology,
                                largest_pow2, select_replica_rows)
from repro.core.faults import FaultSchedule
from repro.core.health import DetectorConfig, FailureDetector
from repro.core.staleness import SkipLedger
from repro.launch.mesh import mesh_over
from repro.launch.train import Trainer


def _rows_identical(params) -> bool:
    """True iff every stacked leaf's replica rows are bitwise identical."""
    for leaf in jax.tree.leaves(params):
        a = np.asarray(leaf)
        if a.shape[0] > 1 and not (a == a[:1]).all():
            return False
    return True


class ElasticTrainer:
    """Drive WAGMA training across membership changes, restart-free.

    ``devices`` is the physical pool; controller worker ``w`` maps to
    ``devices[w]``.  The active world always forms a ``(n_dp, 1)``
    ``("data", "model")`` mesh over its devices.  ``group_size`` is
    clamped to the current world (a shrink below S would otherwise make
    the butterfly impossible).
    """

    def __init__(self, cfg, devices=None, *, tau: int = 4, group_size=None,
                 min_world: int = 2, seed: int = 0, **trainer_kw):
        if trainer_kw.get("sharding") not in (None, "replicated"):
            raise NotImplementedError(
                "ElasticTrainer drives the replicated policy; sharded "
                "worlds convert through core.elastic.handoff_state at pod "
                "granularity (see module docstring)")
        if trainer_kw.pop("averager", "wagma") != "wagma":
            raise NotImplementedError("elastic membership needs the "
                                      "tau-sync barrier (wagma averager)")
        self.cfg = cfg
        self.devices = list(devices if devices is not None
                            else jax.devices())
        self.tau = int(tau)
        self.group_size = group_size
        self.seed = seed
        self.trainer_kw = trainer_kw
        self.controller = MembershipController(range(len(self.devices)),
                                               min_world=min_world)
        self.epoch_log: list = []
        self.trainer: Trainer = None
        self._build(None)

    # -- world (re)construction ------------------------------------------

    def _S(self, world_size: int):
        if self.group_size is None:
            return None
        return max(2, min(int(self.group_size), world_size))

    def _build(self, init_state) -> None:
        world = self.controller.membership.active
        mesh = mesh_over([self.devices[w] for w in world],
                         (len(world), 1), ("data", "model"))
        self.trainer = Trainer(self.cfg, mesh, averager="wagma",
                               group_size=self._S(len(world)), tau=self.tau,
                               seed=self.seed, init_state=init_state,
                               **self.trainer_kw)

    def _transition(self, ev, rows) -> None:
        """Re-seat state on the new world and recompile the plan."""
        old_topo = self.trainer.averager.topology
        host = jax.device_get(self.trainer.state)
        consensus = _rows_identical(host.params)
        if ev.kind == "regrow" and not consensus:
            raise AssertionError(
                "regrow outside the tau-sync barrier: survivor rows are "
                "not the post-sync consensus")
        self._build(select_replica_rows(host, rows))
        diff = diff_topology(old_topo, self.trainer.averager.topology)
        evicted = plan_mod.evict_topology(old_topo)
        self.epoch_log.append({
            "epoch": ev.epoch, "kind": ev.kind, "world": list(ev.world),
            "topology_diff": diff.describe(), "plans_evicted": evicted,
            "consensus_at_transition": consensus,
        })

    # -- membership events -----------------------------------------------

    def leave(self, worker: int):
        """Worker died; shrink the world now (it blocks every collective)."""
        ev = self.controller.leave(worker)
        if ev.kind == "shrink":
            self._transition(ev, rows=list(ev.keep_rows))
        return ev

    def join(self, worker: int):
        """Announce a (re)joining worker; promoted at the next tau-sync."""
        return self.controller.join(worker)

    def _maybe_regrow(self):
        """The tau-sync barrier: promote spares/joiners onto the consensus."""
        ev = self.controller.at_sync_barrier()
        if ev.kind == "regrow":
            n_old = len(ev.world) - ev.n_joined
            # joiners clone row 0 — the post-sync consensus replica
            self._transition(ev, rows=list(range(n_old)) + [0] * ev.n_joined)
        return ev

    # -- driving ---------------------------------------------------------

    @property
    def world_size(self) -> int:
        return self.controller.membership.world_size

    def run(self, steps: int, events=None, log_every: int = 0):
        """Train ``steps`` global steps, applying scheduled churn.

        ``events`` maps global step t -> iterable of ``("leave", w)`` /
        ``("join", w)`` applied *before* step t runs.  Returns one record
        per step: ``{"t", "loss", "world", "epoch"}``.
        """
        events = events or {}
        records = []
        for t in range(steps):
            for kind, w in events.get(t, ()):
                if kind == "leave":
                    self.leave(w)
                elif kind == "join":
                    self.join(w)
                else:
                    raise ValueError(f"unknown event {kind!r}")
            sync = self.trainer.averager.sync_due(t)
            with compat.set_mesh(self.trainer.mesh):
                loss = self.trainer.step_once(t)
            records.append({"t": t, "loss": loss,
                            "world": self.world_size,
                            "epoch": self.controller.epoch})
            if log_every and (t % log_every == 0 or t == steps - 1):
                print(f"step {t:4d} loss {loss:.4f} world "
                      f"{self.world_size} epoch {self.controller.epoch}"
                      + (" [sync]" if sync else ""), flush=True)
            if sync:
                self._maybe_regrow()
        return records

    # -- chaos mode (DESIGN.md §13) --------------------------------------

    def state_digest(self) -> str:
        """SHA-256 over every replica-state leaf's bytes — two runs with
        bit-identical state produce equal digests."""
        host = jax.device_get(self.trainer.state)
        h = hashlib.sha256()
        for leaf in jax.tree.leaves(host):
            h.update(np.ascontiguousarray(np.asarray(leaf)).tobytes())
        return h.hexdigest()

    def run_under_faults(self, steps: int, schedule: FaultSchedule, *,
                         detector: DetectorConfig = None,
                         step_time_s: float = 0.1,
                         collective_deadline_s: float = 0.05,
                         log_every: int = 0) -> dict:
        """Train under a fault schedule with detector-driven membership.

        Unlike :meth:`run`, nothing here is scripted: the schedule only
        controls *when workers fall silent* on the virtual clock
        (``now = t * step_time_s``).  Each round, live workers heartbeat,
        the detector is polled at the round's collective deadline
        (``now + collective_deadline_s``), and its verdicts drive the
        membership — suspect -> immediate shrink to the survivors'
        quantised world, recovery -> join promoted at the tau-sync
        barrier, confirm -> permanent death.  Every round a shrunk-away
        worker misses is charged to the `SkipLedger`, which raises
        `StalenessBoundExceeded` past ``max_staleness_bound(tau)``.

        Because no wall time is ever read, replaying the same schedule
        is bit-identical.  Returns ``{"records", "events", "staleness",
        "schedule_fingerprint", "state_digest"}``; the structured event
        log (kinds: hang/crash/delay onset, wake, recover, suspect,
        confirm-dead, shrink, regrow, stale-verdict-rejected) also stays
        on ``self.event_log``.
        """
        det = FailureDetector(range(len(self.devices)), detector,
                              epoch=self.controller.epoch)
        ledger = SkipLedger(tau=self.tau)
        self.event_log: list = []
        down = {}           # worker -> FaultEvent currently silencing it
        busy_until = {}     # worker -> virtual time its delayed round ends
        pending_beats = []  # (deliver_time, worker) — delayed heartbeats
        out_since = {}      # worker -> step it was shrunk away at
        records = []

        def log(kind, worker, t, now, **extra):
            e = {"kind": kind, "worker": worker, "step": t,
                 "wall": round(now, 6), "epoch": self.controller.epoch}
            e.update(extra)
            self.event_log.append(e)

        def on_beat(verdict, t, now):
            # a recovered worker announces a (re)join; the barrier promotes
            if verdict is None or verdict.state != health_mod.RECOVERED:
                return
            log("recover", verdict.worker, t, now,
                silent_s=round(verdict.silent_s, 6))
            if verdict.worker not in self.controller.membership.active:
                self.join(verdict.worker)

        for t in range(steps):
            now = t * step_time_s
            # 1. faults scheduled at t take effect before the round
            for fev in schedule.at(t):
                if fev.kind == faults_mod.DELAY:
                    done = now + fev.ms / 1e3
                    busy_until[fev.worker] = max(
                        busy_until.get(fev.worker, 0.0), done)
                    pending_beats.append((done, fev.worker))
                    log("delay", fev.worker, t, now, ms=fev.ms)
                else:  # hang / crash: silence until `until` (maybe forever)
                    down[fev.worker] = fev
                    log(fev.kind, fev.worker, t, now, until=fev.until)
            # 2. hangs/crashes whose recovery step arrived wake up
            for w, fev in list(down.items()):
                if fev.until is not None and t >= fev.until:
                    del down[w]
                    log("wake", w, t, now)
            # 3. heartbeats: matured delayed beats, then on-time beats
            for bt, w in sorted(pending_beats):
                if bt <= now and w not in down:
                    on_beat(det.heartbeat(w, bt), t, now)
            pending_beats = [(bt, w) for bt, w in pending_beats
                             if bt > now and w not in down]
            for w in range(len(self.devices)):
                if w in down or busy_until.get(w, 0.0) > now:
                    continue
                on_beat(det.heartbeat(w, now), t, now)
            # 4. the round's collective deadline turns silence into verdicts
            for v in det.poll(now + collective_deadline_s):
                if v.epoch != self.controller.epoch:
                    # a verdict raised earlier in this same poll batch,
                    # just before a shrink bumped the epoch: the detector
                    # state is still current, so re-stamp rather than
                    # reject (the stale-epoch guard is for verdicts held
                    # across topologies, not batch-mates)
                    v = dataclasses.replace(v, epoch=self.controller.epoch)
                if v.state == health_mod.SUSPECT:
                    log("suspect", v.worker, t, now,
                        silent_s=round(v.silent_s, 6),
                        timeout_s=round(det.suspect_timeout(v.worker), 6))
                elif v.state == health_mod.DEAD:
                    log("confirm-dead", v.worker, t, now,
                        silent_s=round(v.silent_s, 6))
                ev = self.controller.apply_verdict(v)
                if ev.kind == "shrink":
                    self._transition(ev, rows=list(ev.keep_rows))
                    det.set_epoch(self.controller.epoch)
                    out_since[v.worker] = t
                    log("shrink", v.worker, t, now, world=list(ev.world))
                elif ev.kind == "rejected-stale-epoch":
                    log("stale-verdict-rejected", v.worker, t, now,
                        verdict_epoch=v.epoch)
                if v.state == health_mod.DEAD:
                    # permanent: no future contribution to age
                    ledger.drop(v.worker)
                    out_since.pop(v.worker, None)
            # 5. staleness: every shrunk-away survivor misses this round
            for w in sorted(out_since):
                ledger.charge(w, t)
            # 6. run the round on the (possibly downgraded) world
            sync = self.trainer.averager.sync_due(t)
            with compat.set_mesh(self.trainer.mesh):
                loss = self.trainer.step_once(t)
            records.append({"t": t, "loss": loss, "world": self.world_size,
                            "epoch": self.controller.epoch,
                            "max_skip_age": ledger.max_age()})
            if log_every and (t % log_every == 0 or t == steps - 1):
                print(f"step {t:4d} loss {loss:.4f} world "
                      f"{self.world_size} epoch {self.controller.epoch} "
                      f"skip-age {ledger.max_age()}"
                      + (" [sync]" if sync else ""), flush=True)
            # 7. tau-sync barrier: promote recovered workers onto consensus
            if sync:
                prev = set(self.controller.membership.active)
                ev = self._maybe_regrow()
                if ev.kind == "regrow":
                    det.set_epoch(self.controller.epoch)
                    for w in ev.world:
                        if w not in prev:
                            ledger.reset(w)
                            out_since.pop(w, None)
                            log("regrow", w, t, now, world=list(ev.world))
        return {"records": records, "events": list(self.event_log),
                "staleness": ledger.snapshot(),
                "schedule_fingerprint": schedule.fingerprint(),
                "state_digest": self.state_digest()}


def kill_rejoin_demo(*, arch: str = "qwen3-0.6b", steps: int = 8,
                     tau: int = 4, group_size: int = 2, world: int = 4,
                     leave_step: int = 2, leave_worker: int = 2,
                     learning_rate: float = 0.05, seed: int = 0,
                     log_every: int = 1) -> dict:
    """Scripted kill/rejoin scenario on the CPU mesh; asserts the protocol.

    Timeline (defaults, tau=4): steps 0..1 on the full world; at t=2
    worker ``leave_worker`` is killed and immediately announces its
    rejoin -> the world shrinks to ``largest_pow2(world-1)`` (one healthy
    survivor is demoted to spare) and training continues; the t=3
    tau-sync is the rejoin barrier -> the spare and the returned worker
    adopt the post-sync consensus and the world regrows; the final step
    (``steps-1``, a tau-sync) pins the acceptance criterion: every
    replica row — the rejoiner's included — is **bit-identical** to the
    survivors'.

    Raises AssertionError on any protocol violation; returns the report
    dict otherwise.  Needs >= ``world`` visible devices
    (``XLA_FLAGS=--xla_force_host_platform_device_count=N``).
    """
    from repro.configs import get_config

    assert steps % tau == 0, "the last step must be a tau-sync"
    assert leave_step < steps and leave_step % tau != tau - 1
    devices = jax.devices()
    if len(devices) < world:
        raise RuntimeError(
            f"need {world} devices, have {len(devices)}; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={world}")

    cfg = get_config(arch, smoke=True)
    et = ElasticTrainer(cfg, devices[:world], tau=tau,
                        group_size=group_size, seed=seed,
                        learning_rate=learning_rate)
    events = {leave_step: [("leave", leave_worker),
                           ("join", leave_worker)]}
    records = et.run(steps, events=events, log_every=log_every)

    losses = [r["loss"] for r in records]
    assert len(records) == steps and np.isfinite(losses).all(), \
        "training did not continue across the membership changes"
    shrunk = max(2, largest_pow2(world - 1))
    mid = [r["world"] for r in records
           if leave_step <= r["t"] < ((leave_step // tau) + 1) * tau]
    assert mid and all(w == shrunk for w in mid), \
        f"expected the shrunken world {shrunk} between leave and barrier, " \
        f"got {mid}"
    m = et.controller.membership
    assert m.world_size == world and not m.spares and not m.pending, \
        f"world did not regrow: {m}"
    assert m.epoch == 2, f"expected epochs shrink+regrow, got {m.epoch}"
    kinds = [e["kind"] for e in et.epoch_log]
    assert kinds == ["shrink", "regrow"], kinds
    assert all(e["plans_evicted"] >= 1 for e in et.epoch_log), \
        "dropped topologies left plan-cache entries behind"
    assert et.epoch_log[1]["consensus_at_transition"], \
        "rejoin barrier was not a consensus point"

    # THE acceptance criterion: at the first post-rejoin tau-sync (the
    # final step), the rejoined worker's replica row is bit-identical to
    # every survivor's
    host = jax.device_get(et.trainer.state)
    bit_identical = _rows_identical(host.params)
    assert bit_identical, \
        "post-rejoin tau-sync left replica rows divergent"

    return {"arch": cfg.name, "steps": steps, "tau": tau, "world": world,
            "leave_step": leave_step, "leave_worker": leave_worker,
            "history": records, "epoch_log": et.epoch_log,
            "rejoin_bit_identical": bool(bit_identical),
            "final_loss": losses[-1]}


def chaos_demo(*, arch: str = "qwen3-0.6b", steps: int = 12, tau: int = 4,
               group_size: int = 2, world: int = 8,
               learning_rate: float = 0.05, seed: int = 0,
               log_every: int = 1) -> dict:
    """CI chaos smoke: one hang + one crash/rejoin on the 8-dev host mesh.

    Nothing is scripted — the fixed `FaultSchedule` (a hang at t=2 that
    wakes 3 steps later, a crash at t=8 that rejoins 3 steps later) only
    silences workers; the failure detector does the rest.  Expected
    timeline with the default timeouts (suspect 0.25 s, confirm 0.30 s,
    0.1 s virtual rounds): the hung worker is suspected ~2.5 silent
    rounds in -> world 8 -> 4 without a restart; its recovery heartbeat
    announces a rejoin promoted at the t=7 tau-sync (8 again, skipped
    rounds charged up to exactly ``max_staleness_bound(tau)``); the
    crashed worker repeats the cycle through the t=11 barrier.  Asserts
    survivor convergence, detector-driven epochs, staleness accounting,
    and the bit-identical rejoin; raises AssertionError otherwise.
    """
    from repro.configs import get_config

    devices = jax.devices()
    if len(devices) < world:
        raise RuntimeError(
            f"need {world} devices, have {len(devices)}; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={world}")
    schedule = FaultSchedule.of(
        faults_mod.hang(1, 2, recover_after=3),
        faults_mod.crash(3, 8, rejoin_after=3),
    )
    cfg = get_config(arch, smoke=True)
    et = ElasticTrainer(cfg, devices[:world], tau=tau,
                        group_size=group_size, seed=seed,
                        learning_rate=learning_rate)
    rep = et.run_under_faults(steps, schedule, log_every=log_every)

    losses = [r["loss"] for r in rep["records"]]
    assert len(losses) == steps and np.isfinite(losses).all(), \
        "survivor world did not keep training through the faults"
    kinds = [e["kind"] for e in rep["events"]]
    for needed in ("hang", "crash", "suspect", "shrink", "recover",
                   "wake", "regrow"):
        assert needed in kinds, f"missing {needed!r} events: {kinds}"
    m = et.controller.membership
    assert m.world_size == world and not m.spares and not m.pending, \
        f"world did not regrow after the faults: {m}"
    assert [e["kind"] for e in et.epoch_log] == \
        ["shrink", "regrow", "shrink", "regrow"], et.epoch_log
    stale = rep["staleness"]
    assert stale["total_skipped"] and not stale["ages"], \
        f"skipped contributions not visible / not settled: {stale}"
    assert 1 <= stale["peak_age"] <= tau, stale
    host = jax.device_get(et.trainer.state)
    assert _rows_identical(host.params), \
        "rejoiners not bit-identical to survivors at the tau-sync"
    rep.update(arch=cfg.name, steps=steps, tau=tau, world=world,
               final_loss=losses[-1], epoch_log=et.epoch_log)
    return rep


def main() -> int:
    ap = argparse.ArgumentParser(
        description="elastic kill/rejoin smoke on the forced-host CPU mesh")
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--tau", type=int, default=4)
    ap.add_argument("--group-size", type=int, default=2)
    ap.add_argument("--world", type=int, default=4)
    ap.add_argument("--leave-step", type=int, default=2)
    ap.add_argument("--leave-worker", type=int, default=2)
    ap.add_argument("--chaos", action="store_true",
                    help="run the detector-driven chaos smoke instead of "
                         "the scripted kill/rejoin scenario")
    args = ap.parse_args()
    if args.chaos:
        try:
            rep = chaos_demo(arch=args.arch, tau=args.tau,
                             group_size=args.group_size)
        except (AssertionError, RuntimeError) as e:
            print(f"CHAOS-DEMO FAIL {e}")
            return 1
        for e in rep["events"]:
            print(f"  t={e['step']:3d} wall={e['wall']:.2f}s epoch "
                  f"{e['epoch']} {e['kind']:22s} worker {e['worker']}")
        skipped = sum(rep["staleness"]["total_skipped"].values())
        print(f"CHAOS-DEMO PASS schedule {rep['schedule_fingerprint']}: "
              f"hang + crash/rejoin detected (no scripts), world "
              f"{rep['world']} -> {min(r['world'] for r in rep['records'])}"
              f" -> {rep['world']}, {skipped} skipped contributions "
              f"(peak staleness {rep['staleness']['peak_age']} <= tau="
              f"{rep['tau']}), rejoiners bit-identical, final loss "
              f"{rep['final_loss']:.4f}")
        return 0
    try:
        rep = kill_rejoin_demo(arch=args.arch, steps=args.steps,
                               tau=args.tau, group_size=args.group_size,
                               world=args.world, leave_step=args.leave_step,
                               leave_worker=args.leave_worker)
    except (AssertionError, RuntimeError) as e:
        print(f"ELASTIC-DEMO FAIL {e}")
        return 1
    for e in rep["epoch_log"]:
        print(f"epoch {e['epoch']} {e['kind']:6s} world {e['world']} "
              f"({e['topology_diff']}; {e['plans_evicted']} plans evicted)")
    print(f"ELASTIC-DEMO PASS world {rep['world']} -> "
          f"{min(r['world'] for r in rep['history'])} -> {rep['world']}, "
          f"rejoiner bit-identical at the post-rejoin tau-sync, final "
          f"loss {rep['final_loss']:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
