"""Elastic membership driver: survive pod churn without a restart.

DESIGN.md §12.  Composes the host-side membership machinery
(core/elastic.py) with the production Trainer:

* a **leave** (preemption, dead host) shrinks the dp mesh immediately —
  the survivors' replica rows are re-seated host-side
  (checkpoint-free), a mesh over just the surviving devices is built,
  and the averaging plan recompiles for the new topology (the plan
  cache keys on topology; the dead topology's entries are evicted);
* a **join** waits for the next tau-sync barrier: right after the sync
  collective every survivor holds the identical consensus model, so the
  joiner clones it bit-exactly with zero staleness (Parallel Restarted
  SGD's restart discipline — the same barrier that bounds simulator
  buffer age by ``max_staleness_bound(tau)``);
* every world change is **epoch-stamped** and logged with the topology
  diff and the number of evicted plan-cache entries.

The power-of-two butterfly invariant is kept by quantising the healthy
set (surplus workers wait as spares and rejoin at the barrier too).

:func:`kill_rejoin_demo` scripts the whole protocol on the forced-host
CPU mesh — it is both the CI smoke (``python -m repro.launch.elastic``)
and the body of the kill/rejoin subprocess test, so the gate and the
test exercise one code path.

Scope: the elastic driver runs the replicated policy (every worker is
one dp replica).  Sharded (FSDP-within-pod) worlds hand off through the
same :func:`~repro.core.elastic.handoff_state` conversion machinery at
pod granularity — pinned host-side in tests/test_elastic.py — but wiring
pod-granular membership into the driver is future work.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import compat
from repro.core import plan as plan_mod
from repro.core.elastic import (MembershipController, diff_topology,
                                largest_pow2, select_replica_rows)
from repro.launch.mesh import mesh_over
from repro.launch.train import Trainer


def _rows_identical(params) -> bool:
    """True iff every stacked leaf's replica rows are bitwise identical."""
    for leaf in jax.tree.leaves(params):
        a = np.asarray(leaf)
        if a.shape[0] > 1 and not (a == a[:1]).all():
            return False
    return True


class ElasticTrainer:
    """Drive WAGMA training across membership changes, restart-free.

    ``devices`` is the physical pool; controller worker ``w`` maps to
    ``devices[w]``.  The active world always forms a ``(n_dp, 1)``
    ``("data", "model")`` mesh over its devices.  ``group_size`` is
    clamped to the current world (a shrink below S would otherwise make
    the butterfly impossible).
    """

    def __init__(self, cfg, devices=None, *, tau: int = 4, group_size=None,
                 min_world: int = 2, seed: int = 0, **trainer_kw):
        if trainer_kw.get("sharding") not in (None, "replicated"):
            raise NotImplementedError(
                "ElasticTrainer drives the replicated policy; sharded "
                "worlds convert through core.elastic.handoff_state at pod "
                "granularity (see module docstring)")
        if trainer_kw.pop("averager", "wagma") != "wagma":
            raise NotImplementedError("elastic membership needs the "
                                      "tau-sync barrier (wagma averager)")
        self.cfg = cfg
        self.devices = list(devices if devices is not None
                            else jax.devices())
        self.tau = int(tau)
        self.group_size = group_size
        self.seed = seed
        self.trainer_kw = trainer_kw
        self.controller = MembershipController(range(len(self.devices)),
                                               min_world=min_world)
        self.epoch_log: list = []
        self.trainer: Trainer = None
        self._build(None)

    # -- world (re)construction ------------------------------------------

    def _S(self, world_size: int):
        if self.group_size is None:
            return None
        return max(2, min(int(self.group_size), world_size))

    def _build(self, init_state) -> None:
        world = self.controller.membership.active
        mesh = mesh_over([self.devices[w] for w in world],
                         (len(world), 1), ("data", "model"))
        self.trainer = Trainer(self.cfg, mesh, averager="wagma",
                               group_size=self._S(len(world)), tau=self.tau,
                               seed=self.seed, init_state=init_state,
                               **self.trainer_kw)

    def _transition(self, ev, rows) -> None:
        """Re-seat state on the new world and recompile the plan."""
        old_topo = self.trainer.averager.topology
        host = jax.device_get(self.trainer.state)
        consensus = _rows_identical(host.params)
        if ev.kind == "regrow" and not consensus:
            raise AssertionError(
                "regrow outside the tau-sync barrier: survivor rows are "
                "not the post-sync consensus")
        self._build(select_replica_rows(host, rows))
        diff = diff_topology(old_topo, self.trainer.averager.topology)
        evicted = plan_mod.evict_topology(old_topo)
        self.epoch_log.append({
            "epoch": ev.epoch, "kind": ev.kind, "world": list(ev.world),
            "topology_diff": diff.describe(), "plans_evicted": evicted,
            "consensus_at_transition": consensus,
        })

    # -- membership events -----------------------------------------------

    def leave(self, worker: int):
        """Worker died; shrink the world now (it blocks every collective)."""
        ev = self.controller.leave(worker)
        if ev.kind == "shrink":
            self._transition(ev, rows=list(ev.keep_rows))
        return ev

    def join(self, worker: int):
        """Announce a (re)joining worker; promoted at the next tau-sync."""
        return self.controller.join(worker)

    def _maybe_regrow(self):
        """The tau-sync barrier: promote spares/joiners onto the consensus."""
        ev = self.controller.at_sync_barrier()
        if ev.kind == "regrow":
            n_old = len(ev.world) - ev.n_joined
            # joiners clone row 0 — the post-sync consensus replica
            self._transition(ev, rows=list(range(n_old)) + [0] * ev.n_joined)
        return ev

    # -- driving ---------------------------------------------------------

    @property
    def world_size(self) -> int:
        return self.controller.membership.world_size

    def run(self, steps: int, events=None, log_every: int = 0):
        """Train ``steps`` global steps, applying scheduled churn.

        ``events`` maps global step t -> iterable of ``("leave", w)`` /
        ``("join", w)`` applied *before* step t runs.  Returns one record
        per step: ``{"t", "loss", "world", "epoch"}``.
        """
        events = events or {}
        records = []
        for t in range(steps):
            for kind, w in events.get(t, ()):
                if kind == "leave":
                    self.leave(w)
                elif kind == "join":
                    self.join(w)
                else:
                    raise ValueError(f"unknown event {kind!r}")
            sync = self.trainer.averager.sync_due(t)
            with compat.set_mesh(self.trainer.mesh):
                loss = self.trainer.step_once(t)
            records.append({"t": t, "loss": loss,
                            "world": self.world_size,
                            "epoch": self.controller.epoch})
            if log_every and (t % log_every == 0 or t == steps - 1):
                print(f"step {t:4d} loss {loss:.4f} world "
                      f"{self.world_size} epoch {self.controller.epoch}"
                      + (" [sync]" if sync else ""), flush=True)
            if sync:
                self._maybe_regrow()
        return records


def kill_rejoin_demo(*, arch: str = "qwen3-0.6b", steps: int = 8,
                     tau: int = 4, group_size: int = 2, world: int = 4,
                     leave_step: int = 2, leave_worker: int = 2,
                     learning_rate: float = 0.05, seed: int = 0,
                     log_every: int = 1) -> dict:
    """Scripted kill/rejoin scenario on the CPU mesh; asserts the protocol.

    Timeline (defaults, tau=4): steps 0..1 on the full world; at t=2
    worker ``leave_worker`` is killed and immediately announces its
    rejoin -> the world shrinks to ``largest_pow2(world-1)`` (one healthy
    survivor is demoted to spare) and training continues; the t=3
    tau-sync is the rejoin barrier -> the spare and the returned worker
    adopt the post-sync consensus and the world regrows; the final step
    (``steps-1``, a tau-sync) pins the acceptance criterion: every
    replica row — the rejoiner's included — is **bit-identical** to the
    survivors'.

    Raises AssertionError on any protocol violation; returns the report
    dict otherwise.  Needs >= ``world`` visible devices
    (``XLA_FLAGS=--xla_force_host_platform_device_count=N``).
    """
    from repro.configs import get_config

    assert steps % tau == 0, "the last step must be a tau-sync"
    assert leave_step < steps and leave_step % tau != tau - 1
    devices = jax.devices()
    if len(devices) < world:
        raise RuntimeError(
            f"need {world} devices, have {len(devices)}; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={world}")

    cfg = get_config(arch, smoke=True)
    et = ElasticTrainer(cfg, devices[:world], tau=tau,
                        group_size=group_size, seed=seed,
                        learning_rate=learning_rate)
    events = {leave_step: [("leave", leave_worker),
                           ("join", leave_worker)]}
    records = et.run(steps, events=events, log_every=log_every)

    losses = [r["loss"] for r in records]
    assert len(records) == steps and np.isfinite(losses).all(), \
        "training did not continue across the membership changes"
    shrunk = max(2, largest_pow2(world - 1))
    mid = [r["world"] for r in records
           if leave_step <= r["t"] < ((leave_step // tau) + 1) * tau]
    assert mid and all(w == shrunk for w in mid), \
        f"expected the shrunken world {shrunk} between leave and barrier, " \
        f"got {mid}"
    m = et.controller.membership
    assert m.world_size == world and not m.spares and not m.pending, \
        f"world did not regrow: {m}"
    assert m.epoch == 2, f"expected epochs shrink+regrow, got {m.epoch}"
    kinds = [e["kind"] for e in et.epoch_log]
    assert kinds == ["shrink", "regrow"], kinds
    assert all(e["plans_evicted"] >= 1 for e in et.epoch_log), \
        "dropped topologies left plan-cache entries behind"
    assert et.epoch_log[1]["consensus_at_transition"], \
        "rejoin barrier was not a consensus point"

    # THE acceptance criterion: at the first post-rejoin tau-sync (the
    # final step), the rejoined worker's replica row is bit-identical to
    # every survivor's
    host = jax.device_get(et.trainer.state)
    bit_identical = _rows_identical(host.params)
    assert bit_identical, \
        "post-rejoin tau-sync left replica rows divergent"

    return {"arch": cfg.name, "steps": steps, "tau": tau, "world": world,
            "leave_step": leave_step, "leave_worker": leave_worker,
            "history": records, "epoch_log": et.epoch_log,
            "rejoin_bit_identical": bool(bit_identical),
            "final_loss": losses[-1]}


def main() -> int:
    ap = argparse.ArgumentParser(
        description="elastic kill/rejoin smoke on the forced-host CPU mesh")
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--tau", type=int, default=4)
    ap.add_argument("--group-size", type=int, default=2)
    ap.add_argument("--world", type=int, default=4)
    ap.add_argument("--leave-step", type=int, default=2)
    ap.add_argument("--leave-worker", type=int, default=2)
    args = ap.parse_args()
    try:
        rep = kill_rejoin_demo(arch=args.arch, steps=args.steps,
                               tau=args.tau, group_size=args.group_size,
                               world=args.world, leave_step=args.leave_step,
                               leave_worker=args.leave_worker)
    except (AssertionError, RuntimeError) as e:
        print(f"ELASTIC-DEMO FAIL {e}")
        return 1
    for e in rep["epoch_log"]:
        print(f"epoch {e['epoch']} {e['kind']:6s} world {e['world']} "
              f"({e['topology_diff']}; {e['plans_evicted']} plans evicted)")
    print(f"ELASTIC-DEMO PASS world {rep['world']} -> "
          f"{min(r['world'] for r in rep['history'])} -> {rep['world']}, "
          f"rejoiner bit-identical at the post-rejoin tau-sync, final "
          f"loss {rep['final_loss']:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
