"""Analytic FLOP / HBM-byte model per (arch x input-shape x mesh).

``cost_analysis()`` counts scan bodies once (DESIGN.md §6b), so the roofline
compute/memory terms come from this model; tests validate the per-layer FLOP
formulas against ``cost_analysis`` on small *unrolled* model variants, and the
collective term comes from the loop-aware HLO parser (hlo_analysis.py).

Conventions:
* FLOPs are per *device* per step: per-replica flops / n_model.
* A matmul (m,k)@(k,n) costs 2mkn.
* Training = fwd + bwd (2x fwd) + remat re-forward ~= 4x fwd.
* MoE expert compute is counted at *capacity* (cf-inflated — what the HLO
  actually does), with the useful-FLOP ratio exposing the padding waste.
* HBM bytes are a structured estimate: parameter traffic (fwd read + bwd read
  + grad write + optimiser update + averaging r/w) + activation traffic
  (major per-layer tensors, x2 for bwd) + KV-cache traffic for decode.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig, InputShape
from repro.core import bucketing, group_allreduce
from repro.core import plan as plan_mod


@dataclass
class CostReport:
    flops_per_device: float
    hbm_bytes_per_device: float
    model_flops: float            # 6*N_active*D (the "useful" reference)
    params_total: int
    params_active: int
    breakdown: dict


def param_count(cfg: ModelConfig) -> tuple[int, int]:
    """(total, active-per-token) parameter counts from the config."""
    d, ff, hd = cfg.d_model, cfg.d_ff, cfg.hd
    H, KH, V, L = cfg.n_heads, cfg.n_kv_heads, cfg.vocab, cfg.n_layers
    attn = d * H * hd + 2 * d * KH * hd + H * hd * d
    mlp = d * ff * (3 if cfg.gated_mlp else 2)
    emb = V * d * (1 if cfg.tie_embeddings else 2)

    if cfg.family == "moe":
        n_moe = (L - cfg.first_dense) // cfg.moe_every
        n_dense = L - n_moe
        expert = 3 * d * ff
        shared = 3 * d * ff if cfg.shared_expert else 0
        router = d * cfg.n_experts
        total = (L * attn + n_dense * mlp
                 + n_moe * (cfg.n_experts * expert + shared + router) + emb)
        active = (L * attn + n_dense * mlp
                  + n_moe * (cfg.top_k * expert + shared + router) + emb)
        return total, active

    if cfg.family == "ssm":            # xlstm: alternating mLSTM/sLSTM
        di = 2 * d
        mlstm = d * 2 * di + 3 * di * di + 2 * di * cfg.n_heads + di * d
        dh = d // cfg.n_heads
        slstm = d * 4 * d + cfg.n_heads * dh * 4 * dh + d * d
        total = (L // 2) * (mlstm + slstm) + emb
        return total, total

    if cfg.family == "hybrid":         # recurrentgemma
        w = cfg.lru_width or d
        rec = 2 * d * w + 2 * w * w + cfg.conv_width * w + w * d + mlp
        n_attn = L // 3
        n_rec = L - n_attn
        total = n_rec * rec + n_attn * (attn + mlp) + emb
        return total, total

    if cfg.family == "audio":          # enc-dec
        cross = d * H * hd + 2 * d * KH * hd + H * hd * d
        enc = cfg.encoder_layers * (attn + mlp)
        dec = L * (attn + cross + mlp)
        src_emb = V * d if cfg.encoder_frames == 0 else 0
        pos = (cfg.encoder_frames or 4096) * d
        total = enc + dec + emb + src_emb + pos
        return total, total

    total = L * (attn + mlp) + emb     # dense / vlm
    return total, total


def _attn_ctx(cfg, S, causal_avg=True):
    """Average attended context length per token during a forward."""
    full = S / 2 if causal_avg else S
    if cfg.local_per_global > 0:
        k = cfg.local_per_global
        w = min(cfg.sliding_window, S)
        loc = min(w, S / 2)
        return (k * loc + full) / (k + 1)
    if cfg.sliding_window is not None:
        return min(cfg.sliding_window, S / 2)
    return full


def fwd_flops_per_token(cfg: ModelConfig, S: int) -> dict:
    """Forward FLOPs per token, split by component."""
    d, ff, hd = cfg.d_model, cfg.d_ff, cfg.hd
    H, KH, V, L = cfg.n_heads, cfg.n_kv_heads, cfg.vocab, cfg.n_layers
    proj = 2 * (d * H * hd + 2 * d * KH * hd + H * hd * d)
    ctx = _attn_ctx(cfg, S)
    score = 2 * 2 * ctx * H * hd
    mlp = 2 * d * ff * (3 if cfg.gated_mlp else 2)
    unemb = 2 * d * V
    out = {"unembed": unemb}

    if cfg.family == "moe":
        n_moe = (L - cfg.first_dense) // cfg.moe_every
        n_dense = L - n_moe
        cap_mult = cfg.capacity_factor
        expert = 2 * 3 * d * ff * cfg.top_k * cap_mult
        shared = 2 * 3 * d * ff if cfg.shared_expert else 0
        router = 2 * d * cfg.n_experts
        out.update(attn=L * (proj + score), dense_mlp=n_dense * mlp,
                   moe=n_moe * (expert + shared + router))
        return out

    if cfg.family == "ssm":
        di = 2 * d
        dh = di // cfg.n_heads
        m_proj = 2 * (d * 2 * di + 3 * di * di + di * d)
        m_state = 8 * dh * dh * cfg.n_heads     # C update + Cq per token
        dhs = d // cfg.n_heads
        s_proj = 2 * (4 * d * d + cfg.n_heads * dhs * 4 * dhs + d * d)
        s_state = 12 * d
        out.update(mlstm=(L // 2) * (m_proj + m_state),
                   slstm=(L // 2) * (s_proj + s_state))
        return out

    if cfg.family == "hybrid":
        w = cfg.lru_width or d
        rec = 2 * (2 * d * w + 2 * w * w + w * d) + 2 * cfg.conv_width * w + 10 * w
        n_attn = L // 3
        n_rec = L - n_attn
        win_ctx = min(2048, S / 2)
        attn_l = proj + 2 * 2 * win_ctx * H * hd
        out.update(recurrent=n_rec * (rec + mlp), attn=n_attn * (attn_l + mlp))
        return out

    if cfg.family == "audio":
        # decoder per-token; encoder amortised over decoder tokens
        F = cfg.encoder_frames or 64
        cross = proj / 2 + 2 * 2 * F * H * hd
        enc_per_dec_tok = cfg.encoder_layers * (proj + 2 * 2 * (F / 2) * H * hd
                                                + mlp) * (F / max(S, 1))
        out.update(dec=L * (proj + score + cross + mlp), enc=enc_per_dec_tok)
        return out

    out.update(attn=L * (proj + score), mlp=L * mlp)
    return out


def train_cost(cfg: ModelConfig, shape: InputShape, *, n_dp: int,
               n_model: int, remat: bool = True, averaging_stages: int = 2,
               optimizer: str = "sgd") -> CostReport:
    B, S = shape.global_batch, shape.seq_len
    tokens_local = B * S / n_dp
    comp = fwd_flops_per_token(cfg, S)
    fwd = sum(comp.values()) * tokens_local
    mult = 4.0 if remat else 3.0
    flops_replica = fwd * mult
    flops_device = flops_replica / n_model

    total, active = param_count(cfg)
    p_local = total / n_model                 # per-device params (bf16)
    opt_bytes = 8 if optimizer == "sgd" else 16   # fp32 m (or m+v) r/w
    # fwd read + bwd read + grad write + opt + param write + averaging r/w
    param_traffic = p_local * (2 + 2 + 2 + opt_bytes + 2
                               + 4 * averaging_stages)
    d = cfg.d_model
    L = max(cfg.n_layers, 1)
    act_traffic = tokens_local / n_model * d * L * 2 * 8 * (2 if remat else 1.5)
    hbm = param_traffic + act_traffic

    model_flops = 6.0 * active * (B * S) / (n_dp * n_model)
    return CostReport(flops_device, hbm, model_flops, total, active,
                      {"fwd_components_per_token": comp,
                       "param_traffic": param_traffic,
                       "act_traffic": act_traffic})


def prefill_cost(cfg, shape, *, n_dp: int, n_model: int) -> CostReport:
    B, S = shape.global_batch, shape.seq_len
    tokens_local = B * S / n_dp
    comp = fwd_flops_per_token(cfg, S)
    fwd = sum(comp.values()) * tokens_local
    flops_device = fwd / n_model
    total, active = param_count(cfg)
    p_local = total / n_model
    d = cfg.d_model
    act = tokens_local / n_model * d * cfg.n_layers * 2 * 6
    kv_write = tokens_local / n_model * cfg.n_layers * 2 * cfg.n_kv_heads * cfg.hd * 2
    hbm = p_local * 2 + act + kv_write
    model_flops = 2.0 * active * B * S / (n_dp * n_model)
    return CostReport(flops_device, hbm, model_flops, total, active,
                      {"fwd_components_per_token": comp, "kv_write": kv_write})


def decode_cost(cfg, shape, *, n_dp: int, n_model: int) -> CostReport:
    """One-token serve_step against a seq_len cache."""
    B, S = shape.global_batch, shape.seq_len
    tok_local = max(B / n_dp, 1) if B >= n_dp else B
    comp = fwd_flops_per_token(cfg, S)
    # decode attends the full cache, not S/2
    comp = dict(comp)
    for key in ("attn", "dec"):
        if key in comp:
            comp[key] = comp[key] * 2          # causal-avg -> full ctx
    fwd = sum(comp.values()) * tok_local
    flops_device = fwd / n_model

    total, active = param_count(cfg)
    # our capacity-dispatch MoE reads ALL expert weights each step (finding!)
    weight_read = total / n_model * 2
    # KV-cache read traffic (the decode bottleneck)
    if cfg.family == "ssm":
        di = 2 * cfg.d_model
        dh = di // cfg.n_heads
        state = (cfg.n_layers // 2) * (cfg.n_heads * dh * dh + 3 * cfg.d_model) * 4
        cache_read = B * state * 2 / (n_dp * n_model)
    elif cfg.family == "hybrid":
        w_lru = cfg.lru_width or cfg.d_model
        n_attn = cfg.n_layers // 3
        cache_read = (B * (cfg.n_layers - n_attn) * w_lru * 4 * 2
                      + B * n_attn * min(2048, S) * 2 * cfg.n_kv_heads
                      * cfg.hd * 2) / (n_dp * n_model)
    else:
        ctx = min(cfg.sliding_window, S) if cfg.sliding_window \
            and cfg.local_per_global == 0 else S
        if cfg.local_per_global > 0:
            k = cfg.local_per_global
            ctx = (k * min(cfg.sliding_window, S) + S) / (k + 1)
        layers = cfg.n_layers + (cfg.encoder_layers if cfg.family == "audio" else 0)
        cache_read = (B * layers * ctx * 2 * cfg.n_kv_heads * cfg.hd * 2
                      / (n_dp * n_model))
    hbm = weight_read + cache_read
    model_flops = 2.0 * active * B / (n_dp * n_model)
    return CostReport(flops_device, hbm, model_flops, total, active,
                      {"weight_read": weight_read, "cache_read": cache_read})


@dataclass
class CommReport:
    """Alpha-beta averaging-communication cost (see group_allreduce)."""
    payload_bytes: float          # per-device averaged payload per step
    n_leaves: int
    n_buckets: int
    t_per_leaf: float             # seconds/step, one collective per leaf
    t_bucketed: float             # seconds/step, one collective per bucket
    speedup: float
    # overlapped bucket pipeline (DESIGN.md §8): combine hidden behind wire
    t_serial_gamma: float = 0.0   # serial-bucketed incl. combine (gamma term)
    t_overlapped: float = 0.0     # seconds/step at the chosen budget
    t_overlapped_same_budget: float = 0.0   # overlapped at the serial budget
    overlap_speedup: float = 1.0  # t_serial_gamma / t_overlapped
    chosen_bucket_bytes: int = 0  # argmin of the overlapped model
    n_buckets_overlapped: int = 0  # launch count/stage at the chosen budget
    # hierarchical topology (DESIGN.md §9): per-link-class alpha-beta terms
    per_class: dict = None        # link name -> budget/buckets/stage seconds
    t_hierarchical: float = 0.0   # per-class budgets, per-class constants
    t_hierarchical_flat_budget: float = 0.0  # same topology, one 32MiB budget
    hierarchical_budget_win: float = 1.0     # flat_budget / per-class
    # FSDP-within-pod sharded replicas (DESIGN.md §10): per-device memory
    # and the per-step gather/scatter overhead the sharding buys it with
    mem_replicated: float = 0.0       # persistent param+opt bytes/device
    mem_fsdp_within_pod: float = 0.0  # same, sharded over the pod
    mem_ratio: float = 1.0            # replicated / fsdp (>= pod size)
    fsdp_pod_size: int = 1
    t_fsdp: float = 0.0               # modeled sharded step seconds
    gather_scatter_s: float = 0.0     # per-step AG+RS overhead on ICI
    # transient gathered-buffer footprint (DESIGN.md §11): the gather-all
    # step pins the whole gathered tree through fwd/bwd; the layer-streamed
    # engine holds ~2 layer spans
    peak_gathered_bytes: float = 0.0          # gather-all full-tree transient
    peak_gathered_bytes_streamed: float = 0.0  # streamed ~2-span bound
    t_fsdp_streamed: float = 0.0      # streamed step incl. compute overlap
    t_fsdp_gather_all: float = 0.0    # same model, serial gather-then-compute
    streamed_win: float = 1.0         # gather_all / streamed step ratio


def replica_memory_bytes(payload_bytes: float, *, pod_size: int = 1,
                         opt_bytes_ratio: float = 2.0) -> dict:
    """Persistent per-device param + optimiser-state bytes per policy.

    ``opt_bytes_ratio`` is optimiser bytes per param byte (fp32 momentum
    over bf16 params = 2.0; AdamW mu+nu = 4.0).  FSDP-within-pod divides
    the whole persistent footprint by the pod size; the transient
    all-gather buffer (one bucket's full payload during fwd/bwd) is
    reported separately — it bounds how low the bucket budget must stay.
    """
    mem_rep = float(payload_bytes) * (1.0 + opt_bytes_ratio)
    mem_fsdp = mem_rep / max(pod_size, 1)
    return {
        "mem_replicated": mem_rep,
        "mem_fsdp_within_pod": mem_fsdp,
        "mem_ratio": mem_rep / max(mem_fsdp, 1e-30),
    }


def averaging_comm_cost(cfg: ModelConfig, *, P: int, S: int, tau: int = 10,
                        n_model: int = 1, n_leaves: int, n_buckets: int = None,
                        dtype_bytes: int = 2,
                        payload_bytes: float = None,
                        bucket_bytes: int = bucketing.DEFAULT_BUCKET_BYTES,
                        alpha: float = group_allreduce.DEFAULT_ALPHA,
                        beta: float = group_allreduce.DEFAULT_BETA,
                        gamma: float = group_allreduce.DEFAULT_GAMMA,
                        topology=None, fsdp_shard_axis: str = None,
                        fsdp_S: int = None,
                        fsdp_streamed_spans: int = None,
                        span_fwd_compute_s: float = 0.0,
                        opt_bytes_ratio: float = 2.0) -> CommReport:
    """Per-step averaging wall time: per-leaf vs bucketed vs overlapped.

    The beta (bandwidth) term is identical — bucketing moves the same bytes —
    so the bucketing win is the alpha term: ``log2(S) * n_launches * alpha``,
    tau-amortised by ``group_allreduce.wagma_step_time`` (the same formula
    ``WagmaAverager.comm_time_per_step`` reports).  The overlapped fields
    add the ``gamma`` combine term and compare serial (``wire + combine``
    per stage) against the wavefront pipeline (``max(wire, combine) +
    fill``) at the budget ``bucketing.choose_bucket_bytes`` picks.

    ``topology`` (a :class:`repro.core.plan.Topology`) adds the
    hierarchical fields: per-link-class stage terms with each class's own
    alpha/beta/gamma and modeled-optimal budget
    (``plan.modeled_wagma_step_seconds``), compared against forcing one
    global 32 MiB budget on the same topology.

    ``fsdp_shard_axis`` (with ``topology``) additionally fills the
    FSDP-within-pod fields (DESIGN.md §10): persistent per-device
    param+opt memory under both policies (``replica_memory_bytes``), the
    modeled sharded step time (butterfly on 1/pod_size of the payload,
    plus the per-step all-gather/reduce-scatter overhead on the shard
    link class — ``plan.modeled_fsdp_step_seconds``), with ``fsdp_S``
    the pod-level group size (default: sqrt of the pod count), and the
    gather-all transient ``peak_gathered_bytes`` (the whole gathered tree
    is live through fwd/bwd).

    ``fsdp_streamed_spans`` (with ``span_fwd_compute_s``, the forward
    compute seconds of one layer span) adds the layer-streamed engine's
    fields (DESIGN.md §11, ``plan.modeled_streamed_fsdp_step_seconds``):
    per-span ``max(compute, gather)`` step time vs the serial
    gather-then-compute reference, and the ~2-span streamed peak.

    ``payload_bytes`` overrides the ``param_count``-estimated payload with
    an exact figure (e.g. from ``jax.eval_shape`` on the real model), so
    benchmarks and the cost model share one implementation of the
    comparison.
    """
    if payload_bytes is None:
        total, _ = param_count(cfg)
        payload = total / n_model * dtype_bytes
    else:
        payload = float(payload_bytes)
    if n_buckets is None:
        n_buckets = max(1, -(-int(payload) // bucket_bytes))

    def per_step(n_launch: int, *, gamma_: float = 0.0,
                 overlap: bool = False) -> float:
        return group_allreduce.wagma_step_time(
            payload, P, S, tau=tau, n_buckets=n_launch, alpha=alpha,
            beta=beta, gamma=gamma_, overlap=overlap)

    t_leaf, t_bucket = per_step(n_leaves), per_step(n_buckets)
    chosen = bucketing.choose_bucket_bytes(int(payload), P=P, S=S, tau=tau,
                                           alpha=alpha, beta=beta, gamma=gamma)
    n_chosen = max(1, -(-int(payload) // chosen))
    t_serial_g = per_step(n_buckets, gamma_=gamma)
    t_overlap = per_step(n_chosen, gamma_=gamma, overlap=True)
    rep = CommReport(payload, n_leaves, n_buckets, t_leaf, t_bucket,
                     t_leaf / t_bucket,
                     t_serial_gamma=t_serial_g,
                     t_overlapped=t_overlap,
                     t_overlapped_same_budget=per_step(
                         n_buckets, gamma_=gamma, overlap=True),
                     overlap_speedup=t_serial_g / t_overlap,
                     chosen_bucket_bytes=chosen,
                     n_buckets_overlapped=n_chosen)
    if topology is not None:
        hier = plan_mod.modeled_wagma_step_seconds(
            int(payload), topology, S, tau=tau, overlap=True)
        flat_budget = plan_mod.modeled_wagma_step_seconds(
            int(payload), topology, S, tau=tau, overlap=True,
            bucket_bytes=bucket_bytes)
        rep.per_class = hier["per_class"]
        rep.t_hierarchical = hier["step_s"]
        rep.t_hierarchical_flat_budget = flat_budget["step_s"]
        rep.hierarchical_budget_win = (flat_budget["step_s"]
                                       / max(hier["step_s"], 1e-30))
        if fsdp_shard_axis is not None:
            from repro.core import grouping
            ax = topology.axis_names.index(fsdp_shard_axis)
            pod = topology.axis_sizes[ax]
            eff_P = topology.P // pod
            S_eff = fsdp_S or grouping.default_group_size(eff_P)
            fsdp = plan_mod.modeled_fsdp_step_seconds(
                int(payload), topology, S_eff, shard_axis=fsdp_shard_axis,
                tau=tau, overlap=True)
            mem = replica_memory_bytes(payload, pod_size=pod,
                                       opt_bytes_ratio=opt_bytes_ratio)
            rep.mem_replicated = mem["mem_replicated"]
            rep.mem_fsdp_within_pod = mem["mem_fsdp_within_pod"]
            rep.mem_ratio = mem["mem_ratio"]
            rep.fsdp_pod_size = pod
            rep.t_fsdp = fsdp["step_s"]
            rep.gather_scatter_s = fsdp["gather_scatter_s"]
            rep.peak_gathered_bytes = float(payload)
            if fsdp_streamed_spans:
                streamed = plan_mod.modeled_streamed_fsdp_step_seconds(
                    int(payload), topology, S_eff,
                    shard_axis=fsdp_shard_axis,
                    n_spans=fsdp_streamed_spans,
                    span_fwd_compute_s=span_fwd_compute_s, tau=tau,
                    overlap=True)
                rep.t_fsdp_streamed = streamed["step_s"]
                rep.t_fsdp_gather_all = streamed["gather_all_step_s"]
                rep.streamed_win = streamed["streamed_win"]
                rep.peak_gathered_bytes_streamed = \
                    streamed["peak_gathered_bytes_streamed"]
    return rep


def cost_for(cfg, shape, kind: str, *, n_dp: int, n_model: int, **kw):
    if kind == "train":
        return train_cost(cfg, shape, n_dp=n_dp, n_model=n_model, **kw)
    if kind == "prefill":
        return prefill_cost(cfg, shape, n_dp=n_dp, n_model=n_model)
    return decode_cost(cfg, shape, n_dp=n_dp, n_model=n_model)
