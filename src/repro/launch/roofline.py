"""Roofline analysis: read experiments/dryrun/*.json, emit the §Roofline table.

Per (arch x shape x mesh):
    compute term    = analytic FLOPs / (chip peak 197 TFLOP/s bf16)
    memory term     = analytic HBM bytes / (819 GB/s)
    collective term = loop-aware HLO wire bytes (TPU-adjusted) / (50 GB/s)
plus the dominant term, MODEL_FLOPS/HLO_FLOPs utilisation ratio, and a
one-line "what would move the dominant term" note.

All terms are per-device per-step seconds on the TPU v5e target.
"""

from __future__ import annotations

import argparse
import glob
import json
import os

from repro.launch.mesh import PEAK_FLOPS, HBM_BW, ICI_BW, HBM_PER_CHIP

ADVICE = {
    ("compute",): "raise arithmetic intensity: larger per-device batch or "
                  "lower-precision matmuls; already compute-bound is the goal",
    ("memory",): "cut HBM traffic: fp32->bf16 averaging buffers, microbatch "
                 "activations, fuse averaging axpy (kernels/group_average)",
    ("collective",): "cut wire bytes: arch-tuned logical mesh (less TP for "
                     "small models), sequence-parallel resharding, bf16 "
                     "averaging payload, one-shot MoE all-to-all",
}


def analyse(rec: dict) -> dict:
    a = rec["analytic"]
    colls = rec["collectives"]
    compute = a["flops_per_device"] / PEAK_FLOPS
    memory = a["hbm_bytes_per_device"] / HBM_BW
    wire = colls.get("total_wire_bytes_tpu_adjusted",
                     colls["total_wire_bytes"])
    collective = wire / ICI_BW
    terms = {"compute": compute, "memory": memory, "collective": collective}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    useful = a["model_flops_per_device"] / max(a["flops_per_device"], 1.0)
    mem_dev = rec["memory"]["per_device_total"]
    return {
        "tag": rec["tag"],
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "dominant": dom,
        "step_lower_bound_s": bound,
        "roofline_fraction": compute / bound if bound else 0.0,
        "useful_flop_ratio": useful,
        "hbm_per_device_GiB": mem_dev / 2**30,
        "fits_hbm": mem_dev <= HBM_PER_CHIP,
        "advice": ADVICE[(dom,)],
    }


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:7.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:6.1f}ms"
    return f"{x*1e6:6.0f}us"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--json-out", default="experiments/roofline.json")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()

    rows = []
    for path in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        if path.endswith("summary.json"):
            continue
        rec = json.load(open(path))
        if rec.get("status") != "ok":
            if rec.get("status") == "skipped":
                rows.append({"tag": rec["tag"], "skipped": rec["reason"]})
            continue
        rows.append(analyse(rec))

    with open(args.json_out, "w") as f:
        json.dump(rows, f, indent=2)

    hdr = (f"{'pair (arch__shape__mesh)':58s} {'compute':>9s} {'memory':>9s} "
           f"{'collect':>9s} {'dominant':>10s} {'cmp/roof':>8s} "
           f"{'useful':>7s} {'HBM GiB':>8s} fits")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        if "skipped" in r:
            print(f"{r['tag']:58s} SKIP ({r['skipped']})")
            continue
        print(f"{r['tag']:58s} {fmt_s(r['compute_s']):>9s} "
              f"{fmt_s(r['memory_s']):>9s} {fmt_s(r['collective_s']):>9s} "
              f"{r['dominant']:>10s} {r['roofline_fraction']:8.2%} "
              f"{r['useful_flop_ratio']:7.2f} {r['hbm_per_device_GiB']:8.2f} "
              f"{'y' if r['fits_hbm'] else 'N'}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
