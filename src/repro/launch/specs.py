"""ShapeDtypeStruct input stand-ins for every (arch x shape) pair.

``input_specs`` returns weak-type-correct, shardable abstract values — no
device allocation — for train batches, prefill batches, and decode states.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES
from repro.models.registry import build_model
from repro.serve.decode import cache_shardings, serve_param_shardings


def _dp_spec(mesh):
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    return dp if len(dp) > 1 else dp[0]


def _sds(shape, dtype, mesh=None, spec=None):
    sh = NamedSharding(mesh, spec) if mesh is not None and spec is not None else None
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sh)


def batch_specs(cfg, shape, mesh) -> dict:
    """Abstract train/prefill batch for a model config + input shape."""
    B, S = shape.global_batch, shape.seq_len
    dp = _dp_spec(mesh)
    b2 = lambda s: _sds(s, jnp.int32, mesh, P(dp, None))
    out = {}
    if cfg.family == "vlm":
        s_text = S - cfg.n_patches
        out["tokens"] = b2((B, s_text))
        out["labels"] = b2((B, s_text))
        out["patches"] = _sds((B, cfg.n_patches, cfg.d_model), jnp.float32,
                              mesh, P(dp, None, None))
    elif cfg.family == "audio":
        out["tokens"] = b2((B, S))
        out["labels"] = b2((B, S))
        if cfg.encoder_frames:
            out["frames"] = _sds((B, cfg.encoder_frames, cfg.d_model),
                                 jnp.float32, mesh, P(dp, None, None))
        else:
            out["src"] = b2((B, 64))
    else:
        out["tokens"] = b2((B, S))
        out["labels"] = b2((B, S))
    return out


def decode_specs(cfg, shape, mesh):
    """(params, caches, token, pos) abstract values for serve_step."""
    B, S = shape.global_batch, shape.seq_len
    model = build_model(cfg)
    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pshard = serve_param_shardings(mesh, params_shapes)
    params = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        params_shapes, pshard)
    cache_shapes = jax.eval_shape(lambda: model.init_caches(B, S))
    cshard = cache_shardings(mesh, cache_shapes, B)
    caches = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        cache_shapes, cshard)
    dp = _dp_spec(mesh)
    tok_spec = P(dp, None) if B % _dp_size(mesh) == 0 and B >= _dp_size(mesh) \
        else P(None, None)
    token = _sds((B, 1), jnp.int32, mesh, tok_spec)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return params, caches, token, pos


def _dp_size(mesh):
    n = 1
    for a in mesh.axis_names:
        if a in ("pod", "data"):
            n *= mesh.shape[a]
    return n


def serve_params_specs(cfg, mesh):
    model = build_model(cfg)
    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pshard = serve_param_shardings(mesh, params_shapes)
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        params_shapes, pshard)
