"""Parse compiled (post-SPMD) HLO text for collective traffic.

``cost_analysis()`` counts while-loop bodies once (see DESIGN.md §6b), so we
walk the computation call graph, multiply collectives inside loop bodies by
the loop trip count (recovered from jax's canonical scan condition
``compare(iv, constant(N)), direction=LT``), and convert tensor sizes to
per-device *wire bytes* with the standard algorithm factors:

    all-reduce          2 * N * (g-1)/g     (ring / reduce-scatter+all-gather)
    all-gather          N_out * (g-1)/g
    reduce-scatter      N_in  * (g-1)/g
    all-to-all          N * (g-1)/g
    collective-permute  N                   (one send per device)
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\(")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _tensor_bytes(shape_str: str) -> int:
    """Bytes of an HLO shape string (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:                       # iota v2 form [ngroups,gsize]
        return int(m.group(2))
    return default


@dataclass
class Collective:
    kind: str
    tensor_bytes: int
    group: int
    count: int = 1              # after trip-count multiplication

    @property
    def wire_bytes(self) -> float:
        g = max(self.group, 1)
        n = self.tensor_bytes
        if self.kind == "all-reduce":
            w = 2 * n * (g - 1) / g
        elif self.kind in ("all-gather", "reduce-scatter", "all-to-all"):
            w = n * (g - 1) / g
        else:                    # collective-permute
            w = n
        return w * self.count


@dataclass
class Computation:
    name: str
    collectives: List[Collective] = field(default_factory=list)
    calls: List[Tuple[str, str, str]] = field(default_factory=list)  # (kind, callee, cond)
    constants: List[int] = field(default_factory=list)


def _split_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        # computation header (non-indented): `%name (...) -> ... {` / `ENTRY ...`
        if not line.startswith(" ") and "->" in line and line.rstrip().endswith("{"):
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)", stripped)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                continue
        if cur is None:
            continue
        cm_ = _COLL_RE.search(stripped)
        if cm_ and "=" in stripped:
            kind = cm_.group(1)
            rhs = stripped.split("=", 1)[1].strip()
            # output shape precedes the op name; for all-gather this is the
            # (larger) gathered tensor, matching the wire-cost formula input
            nbytes = _tensor_bytes(rhs.split(kind)[0])
            cur.collectives.append(
                Collective(kind, nbytes, _group_size(stripped, 0)))
        if " while(" in stripped:
            mb = re.search(r"body=%?([\w.\-]+)", stripped)
            mc = re.search(r"condition=%?([\w.\-]+)", stripped)
            if mb:
                cur.calls.append(("while", mb.group(1),
                                  mc.group(1) if mc else ""))
        if " fusion(" in stripped:
            mm = re.search(r"calls=%?([\w.\-]+)", stripped)
            if mm:
                cur.calls.append(("call", mm.group(1), ""))
        if " call(" in stripped:
            mm = re.search(r"to_apply=%?([\w.\-]+)", stripped)
            if mm:
                cur.calls.append(("call", mm.group(1), ""))
        if " conditional(" in stripped:
            seg = stripped.split("branch_computations=", 1)
            if len(seg) == 2:
                blob = seg[1].split("}")[0]
                for mm in re.finditer(r"%?([\w.\-]+)", blob):
                    cur.calls.append(("call", mm.group(1), ""))
            else:
                for attr in ("true_computation", "false_computation"):
                    mm = re.search(attr + r"=%?([\w.\-]+)", stripped)
                    if mm:
                        cur.calls.append(("call", mm.group(1), ""))
        for mm in re.finditer(r"constant\((\d+)\)", stripped):
            cur.constants.append(int(mm.group(1)))
    return comps


def _trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    cond = comps.get(cond_name)
    if cond is None or not cond.constants:
        return 1
    return max(cond.constants)


def collective_summary(hlo_text: str, default_group: int = 1,
                       halve_kinds=("all-reduce",)) -> dict:
    """Total per-device wire bytes by collective kind, loop-aware."""
    comps = _split_computations(hlo_text)
    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w.\-]+)", line)
            if m:
                entry = m.group(1)
    if entry is None or entry not in comps:
        entry = next(iter(comps)) if comps else None
    totals: Dict[str, float] = {}
    counts: Dict[str, int] = {}
    flags = {"unknown_trip": False}

    def walk(name: str, mult: int, seen):
        comp = comps.get(name)
        if comp is None or name in seen:
            return
        seen = seen | {name}
        for c in comp.collectives:
            g = c.group if c.group else default_group
            wb = Collective(c.kind, c.tensor_bytes, g).wire_bytes * mult
            totals[c.kind] = totals.get(c.kind, 0.0) + wb
            counts[c.kind] = counts.get(c.kind, 0) + mult
        for kind, callee, cond in comp.calls:
            if kind == "while":
                trips = _trip_count(comps, cond) if cond else 1
                if trips == 1:
                    flags["unknown_trip"] = True
                walk(callee, mult * trips, seen)
            else:
                walk(callee, mult, seen)

    if entry:
        walk(entry, 1, frozenset())
    total = sum(totals.values())
    # XLA-CPU widens bf16 collectives to f32 (all-reduce via the
    # AllReducePromotion pass; collective-permute via generic f32 widening —
    # both probed on jax 0.8.2). On TPU they stay bf16, so the TPU-adjusted
    # estimate halves the bytes of ``halve_kinds`` (the kinds whose payload
    # is bf16 in the source program; callers set this from the model /
    # averaging dtype).
    adjusted = total - sum(totals.get(k, 0.0) / 2 for k in halve_kinds)
    return {
        "wire_bytes_by_kind": totals,
        "counts_by_kind": counts,
        "total_wire_bytes": total,
        "total_wire_bytes_tpu_adjusted": adjusted,
        "halved_kinds": list(halve_kinds),
        "unknown_trip_counts": flags["unknown_trip"],
    }


_PAIRS_RE = re.compile(
    r"collective-permute(?:-start)?(?:\.\d+)?\(.*?"
    r"source_target_pairs=\{((?:\{\d+,\s*\d+\},?)*)\}")


def _coords_fn(axis_sizes: Sequence[int]):
    """device id -> mesh coordinates (C-order over axis_sizes, maj-to-min).

    Shared by every HLO-side mesh classifier below — the device-id
    convention must stay identical between the permute, axis-count, and
    per-op-detail parsers or their cross-checks disagree.
    """
    sizes = [int(s) for s in axis_sizes]
    strides = [1] * len(sizes)
    for i in range(len(sizes) - 2, -1, -1):
        strides[i] = strides[i + 1] * sizes[i + 1]

    def coords(dev: int) -> Tuple[int, ...]:
        return tuple((dev // strides[i]) % sizes[i] for i in range(len(sizes)))

    return coords, len(sizes)


def _replica_groups_axes(groups_blob: str, coords) -> set:
    """Mesh-axis indices an op's explicit ``replica_groups`` span."""
    axes: set = set()
    for grp in re.findall(r"\{([\d,\s]+)\}", "{" + groups_blob + "}"):
        members = [int(x) for x in grp.replace(" ", "").split(",") if x]
        if len(members) < 2:
            continue
        base = coords(members[0])
        for dev in members[1:]:
            c = coords(dev)
            axes.update(i for i in range(len(base)) if c[i] != base[i])
    return axes


def permute_axis_counts(hlo_text: str, axis_names: Sequence[str],
                        axis_sizes: Sequence[int]) -> Dict[str, int]:
    """Classify each compiled collective-permute by the mesh axis it moves.

    Parses every ``collective-permute``'s ``source_target_pairs`` and maps
    the first moving pair's device-id delta onto mesh coordinates (device id
    = C-order flattened index over ``axis_sizes``, major-to-minor — the
    ``jax.make_mesh`` default).  The axis whose coordinate differs is the
    axis the permute rides; a permute whose pairs disagree (or that moves
    several axes at once) lands under ``"mixed"``.  The per-link-class HLO
    cross-check in ``dryrun.bucket_collective_summary`` feeds these counts
    through ``Topology.axis_class`` so ICI and DCN launches are verified
    separately, not just in aggregate.
    """
    names = list(axis_names)
    coords, n_axes = _coords_fn(axis_sizes)

    counts: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _PAIRS_RE.search(line.strip().rstrip(","))
        if not m:
            continue
        pairs = re.findall(r"\{(\d+),\s*(\d+)\}", "{" + m.group(1) + "}")
        axes = set()
        for src, tgt in pairs:
            s, t = int(src), int(tgt)
            if s == t:
                continue
            cs, ct = coords(s), coords(t)
            moved = [i for i in range(n_axes) if cs[i] != ct[i]]
            axes.update(moved if len(moved) == 1 else [-1])
        if not axes:
            continue
        key = names[axes.pop()] if len(axes) == 1 and -1 not in axes \
            else "mixed"
        counts[key] = counts.get(key, 0) + 1
    return counts


_GROUPED_RE = re.compile(
    r"(all-gather|reduce-scatter|all-reduce|all-to-all)"
    r"(?:-start)?(?:\.\d+)?\(.*?"
    r"replica_groups=\{((?:\{[\d,\s]+\},?)*)\}")


def collective_axis_counts(hlo_text: str, axis_names: Sequence[str],
                           axis_sizes: Sequence[int],
                           kinds: Sequence[str] = ("all-gather",
                                                   "reduce-scatter")
                           ) -> Dict[str, Dict[str, int]]:
    """Classify grouped collectives by the mesh axis their groups span.

    The replica-group analogue of :func:`permute_axis_counts`: parses each
    matching op's explicit ``replica_groups`` and maps every group's member
    device ids to mesh coordinates (C-order over ``axis_sizes``,
    major-to-minor).  A group whose members differ along exactly one axis
    rides that axis; groups spanning several axes (or ops whose groups
    disagree) land under ``"mixed"``.  Returns ``{kind: {axis: count}}``.

    The FSDP-within-pod CI smoke (DESIGN.md §10) uses this to assert the
    sharded train step's parameter all-gathers and gradient
    reduce-scatters ride the intra-pod (shard) axis ONLY — any all-gather
    classified onto a DCN axis is a leak of the sharding invariant.
    """
    names = list(axis_names)
    coords, _ = _coords_fn(axis_sizes)

    counts: Dict[str, Dict[str, int]] = {}
    for line in hlo_text.splitlines():
        m = _GROUPED_RE.search(line.strip().rstrip(","))
        if not m or m.group(1) not in kinds:
            continue
        kind = m.group(1)
        axes = _replica_groups_axes(m.group(2), coords)
        if not axes:
            continue
        key = names[axes.pop()] if len(axes) == 1 else "mixed"
        ent = counts.setdefault(kind, {})
        ent[key] = ent.get(key, 0) + 1
    return counts


def grouped_collective_details(hlo_text: str, axis_names: Sequence[str],
                               axis_sizes: Sequence[int],
                               kinds: Sequence[str] = ("all-gather",
                                                       "reduce-scatter")
                               ) -> List[dict]:
    """Per-op records ``{kind, axis, tensor_bytes}`` for grouped collectives.

    The per-op companion to :func:`collective_axis_counts`: besides
    classifying each op's replica groups onto a mesh axis, it records the
    op's **output tensor bytes** (for an all-gather that is the gathered
    buffer — the quantity the streamed-FSDP in-flight bound constrains).
    The ``--sharding fsdp --streamed`` dry-run smoke asserts no single
    all-gather exceeds the largest layer-span bucket: a gather-all
    regression would reappear as one big full-bucket gather.
    """
    names = list(axis_names)
    coords, _ = _coords_fn(axis_sizes)

    out: List[dict] = []
    for line in hlo_text.splitlines():
        stripped = line.strip().rstrip(",")
        m = _GROUPED_RE.search(stripped)
        if not m or m.group(1) not in kinds or "=" not in stripped:
            continue
        kind = m.group(1)
        rhs = stripped.split("=", 1)[1].strip()
        nbytes = _tensor_bytes(rhs.split(kind)[0])
        axes = _replica_groups_axes(m.group(2), coords)
        if not axes:
            continue
        axis = names[axes.pop()] if len(axes) == 1 else "mixed"
        out.append({"kind": kind, "axis": axis, "tensor_bytes": nbytes})
    return out


def count_ppermutes(jaxpr) -> int:
    """Count ``ppermute`` equations in a (possibly nested) jaxpr.

    Pre-lowering companion to the HLO parser above: the differential tests
    and benchmarks use it to pin the bucketed averaging path's collective
    *launch* count (n_buckets * log2(S)) straight from the trace, before
    XLA has a chance to fuse or reorder anything.
    """
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "ppermute":
            n += 1
        for v in eqn.params.values():
            inner = getattr(v, "jaxpr", None)
            if inner is not None and hasattr(inner, "eqns"):
                n += count_ppermutes(inner)
            elif hasattr(v, "eqns"):
                n += count_ppermutes(v)
    return n
