"""Multi-pod dry-run: .lower().compile() every (arch x input-shape x mesh).

The forced host-device count is set by ``main()`` (CLI entry) BEFORE the
first jax backend init — jax locks the device count there, not at import
— via ``os.environ.setdefault`` so a caller (e.g. the CI fsdp smoke) can
force a smaller count.  Importing this module has NO side effects: tools
that import it for :func:`resolve_config` / :func:`lower_pair` keep their
own device view (tests/test_launch_import.py pins this).

For each pair this lowers the appropriate step:
    train_4k              -> WAGMA train_step (group-averaging variant)
    prefill_32k           -> prefill (forward + KV capture)
    decode_32k, long_500k -> serve_step (1 token vs seq_len cache)

and records memory_analysis / cost_analysis / loop-aware collective bytes —
plus, for train steps, the compiled-plan launch cross-check (expected
ppermutes per link class from the AveragingPlan vs collective-permutes
found in the compiled HLO, classified per mesh axis) and the plan's
human-readable summary (stages, link class, bucket count, budget per
class) — to experiments/dryrun/<arch>__<shape>__<mesh>[__variant].json.
``--hierarchical`` compiles the pod-aware 2-link-class topology.
``--sharding fsdp`` compiles the FSDP-within-pod ReplicaState step
(DESIGN.md §10) and FAILS if any parameter all-gather / gradient
reduce-scatter leaks off the intra-pod shard axis onto a DCN axis
(``hlo_analysis.collective_axis_counts``); ``--smoke`` + ``--mesh-shape``
shrink the sweep to the CI-sized 8-device smoke (scripts/ci.sh).

long_500k rules (DESIGN.md §5): native for xlstm/recurrentgemma/gemma3;
explicit `swa` sliding-window variant for the pure full-attention archs;
skipped for whisper (enc-dec 448-position decoder semantics).
"""

import argparse
import json
import os
import time
import traceback

import jax


def _force_host_device_count(n: int = 512) -> None:
    """Pin the forced host-device count for the dry-run sweep.

    Must run before the first jax backend init (the first ``jax.devices``
    /first compilation — importing jax does not init).  ``setdefault`` so
    an explicit caller-supplied XLA_FLAGS (the CI smokes) wins.  Called
    from ``main()`` only: merely importing this module must never pin the
    device count of the embedding process.
    """
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={n}")

from repro.configs import SHAPES, arch_names, get_config
from repro.launch import mesh as mesh_lib
from repro.launch import specs as specs_lib
from repro.launch.hlo_analysis import collective_summary
from repro.launch.costmodel import cost_for, param_count
from repro.models.registry import build_model
from repro import compat

LONG_NATIVE = {"xlstm-350m", "recurrentgemma-2b", "gemma3-12b"}
LONG_SKIP = {"whisper-medium"}
SWA_WINDOW = 8192


def bucket_collective_summary(averager, local_params, colls: dict,
                              mesh=None, hlo_text: str = None) -> dict:
    """Compiled-plan launch accounting, cross-checked against HLO per class.

    Asks the averager's :class:`~repro.core.plan.AveragingPlan` for the
    expected ``ppermute`` launch count of one averaging step — per link
    class (one collective per bucket per butterfly/gossip round on that
    class's own budget; the overlapped scheduler reorders launches but
    never adds any) — and compares it with the ``collective-permute`` count
    the loop-aware HLO parser found in the compiled step.  With ``mesh``
    and ``hlo_text`` given, each compiled permute is additionally
    classified by the mesh axis it moves (``hlo_analysis.
    permute_axis_counts``) so the cross-check runs per link class, not
    just in aggregate.  ``match`` is exact on dp-only meshes; with a model
    axis GSPMD may add its own permutes, so ``extra_in_hlo`` reports the
    difference instead of failing.

    Also emits ``plan_summary`` — the plan's human-readable compilation
    record (stages, link class, bucket count, budget per class).
    """
    from repro.core import bucketing, grouping

    n_leaves = len(jax.tree_util.tree_leaves(local_params))
    name = getattr(averager, "name", "?")
    plan = averager.plan_for(local_params)
    fused = plan.cfg.fused

    if name == "wagma":
        offset = plan.offsets[0]            # dryrun compiles phase 0
        per_class = plan.per_class_expected(offset)
        expected = plan.expected_ppermutes(offset)
        mix_budget = None
    else:
        # (bit, permutes-on-that-bit) per phase-0 mix round: D-PSGD sends to
        # both ring neighbours on the minor axis; SGP one permute per
        # rotating neighbour bit; AD-PSGD one pairwise exchange on bit 0
        bit_rounds = {"dpsgd": ((0, 2),), "adpsgd": ((0, 1),),
                      "sgp": tuple((b, 1) for b in range(
                          getattr(averager, "neighbours", 1)))
                      }.get(name, ())
        bits = tuple(b for b, _ in bit_rounds)
        mix_budget = plan.mix_bucket_bytes(bits)
        layout = bucketing.layout_for(local_params,
                                      max_bucket_bytes=mix_budget)
        units = layout.n_buckets if fused else n_leaves
        per_class = {}
        for bit, rounds in bit_rounds:
            link = plan.topology.link_classes[plan.topology.class_of_bit(bit)]
            ent = per_class.setdefault(link.name, {
                "stages": 0, "ppermutes": 0, "bucket_bytes": mix_budget,
                "n_buckets": units, "axes": ()})
            ent["stages"] += rounds
            ent["ppermutes"] += rounds * units
            ent["axes"] = tuple(dict.fromkeys(
                ent["axes"] + (plan.topology.axis_of_bit(bit),)))
        expected = sum(e["ppermutes"] for e in per_class.values())

    hlo_pp = int(colls.get("counts_by_kind", {}).get("collective-permute", 0))
    out = {
        "averager": name,
        "topology": plan.topology.describe(),
        "n_leaves": n_leaves,
        "class_bucket_bytes": {
            plan.topology.link_classes[ci].name: bb
            for ci, bb in plan.class_bucket_bytes.items()},
        "per_class_expected": per_class,
        "expected_ppermutes": expected,
        "hlo_ppermutes": hlo_pp,
        "match": hlo_pp == expected,
        "extra_in_hlo": hlo_pp - expected,
        "plan_summary": plan.describe(),
        # legacy aggregate field kept for existing consumers
        "n_buckets": max((v["n_buckets"] for v in per_class.values()),
                         default=0),
    }
    if mesh is not None and hlo_text is not None:
        from repro.launch.hlo_analysis import permute_axis_counts
        axis_counts = permute_axis_counts(
            hlo_text, tuple(mesh.axis_names),
            tuple(mesh.shape[a] for a in mesh.axis_names))
        by_class = {}
        known = set()
        for ci in plan.topology.classes_in_use():
            cls_name = plan.topology.link_classes[ci].name
            axes = [a for a, c in zip(plan.topology.axis_names,
                                      plan.topology.axis_class) if c == ci]
            by_class[cls_name] = sum(axis_counts.get(a, 0) for a in axes)
            known.update(axes)
        out["hlo_ppermutes_by_axis"] = axis_counts
        out["hlo_ppermutes_by_class"] = by_class
        out["hlo_ppermutes_other_axes"] = sum(
            n for a, n in axis_counts.items() if a not in known)
        out["per_class_match"] = {
            cls: by_class.get(cls, 0) == ent["ppermutes"]
            for cls, ent in per_class.items()}
    return out


def resolve_config(arch: str, shape_name: str, smoke: bool = False):
    """Returns (cfg, variant_tag) or (None, reason) for documented skips.

    ``smoke`` picks the reduced config BEFORE the long_500k variant logic
    so the sliding-window (swa) patch still applies to the smoke config.
    """
    cfg = get_config(arch, smoke=smoke)
    if shape_name != "long_500k":
        return cfg, ""
    if arch in LONG_SKIP:
        return None, "skip: enc-dec decoder has no 500k-context analogue"
    if arch in LONG_NATIVE:
        return cfg, ""
    return cfg.with_sliding_window(SWA_WINDOW), "swa"


def lower_pair(arch: str, shape_name: str, mesh, *, averager: str = "wagma",
               group_size=None, donate: bool = True,
               average_dtype: str = "float32", microbatch=None,
               cfg_overrides: dict = None, hierarchical: bool = False,
               sharding: str = "replicated", streamed: bool = False,
               smoke: bool = False):
    """Build + lower + compile one (arch, shape) on the given mesh.

    Tuning knobs for the §Perf hillclimb: ``mesh`` may be any logical
    reshaping of the production chips (e.g. (256,1) for a TP-free small
    model), ``average_dtype`` sets the butterfly payload precision,
    ``microbatch`` enables gradient accumulation, ``cfg_overrides`` patches
    the ModelConfig (e.g. attention block sizes, moe_chunks).
    """
    cfg, variant = resolve_config(arch, shape_name, smoke=smoke)
    if cfg is None:
        return {"status": "skipped", "reason": variant}
    if cfg_overrides:
        cfg = cfg.variant(**cfg_overrides)
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    av = None
    t0 = time.time()

    with compat.set_mesh(mesh):
        if shape.kind == "train":
            from repro.core.baselines import make_averager
            from repro.core.group_allreduce import dp_axis_layout
            from repro.launch.train import resolve_sharding
            from repro.optim import sgd
            from repro.train import build_train_step, init_replica_state

            names, sizes = dp_axis_layout(
                mesh.axis_names, dict(mesh.shape),
                tuple(a for a in mesh.axis_names if a in ("pod", "data")))
            policy = resolve_sharding(sharding, names, streamed=streamed)
            kw = {"sharding": policy}
            if averager == "wagma":
                kw["average_dtype"] = average_dtype
                if group_size:
                    kw["group_size"] = group_size
            if hierarchical:
                from repro.core.plan import Topology
                kw["topology"] = Topology.hierarchical(names, sizes)
            av = make_averager(averager, names, sizes, **kw)
            opt = sgd(0.1, momentum=0.9)
            state_sds = init_replica_state(model, opt, av, mesh,
                                           jax.random.PRNGKey(0),
                                           abstract=True)
            params_sds = state_sds.params
            batch = specs_lib.batch_specs(cfg, shape, mesh)
            step = build_train_step(model, opt, av, mesh, phase=0, sync=False,
                                    microbatch=microbatch)
            lowered = step.lower(state_sds, batch)
        elif shape.kind == "prefill":
            params_sds = specs_lib.serve_params_specs(cfg, mesh)
            batch = specs_lib.batch_specs(cfg, shape, mesh)

            def prefill_fn(params, b):
                return model.prefill(params, b, shape.seq_len)

            lowered = jax.jit(prefill_fn).lower(params_sds, batch)
        else:  # decode
            params_sds, caches_sds, token, pos = specs_lib.decode_specs(
                cfg, shape, mesh)

            def serve_step(params, caches, tok, pos):
                import jax.numpy as jnp
                logits, caches = model.decode_step(params, caches, tok, pos)
                nxt = jnp.argmax(logits[:, -1, :], -1).astype(tok.dtype)[:, None]
                return nxt, caches

            lowered = jax.jit(serve_step,
                              donate_argnums=(1,) if donate else ()
                              ).lower(params_sds, caches_sds, token, pos)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compat.cost_analysis(compiled)
    hlo = compiled.as_text()
    halve = ["all-reduce"]
    if average_dtype == "bfloat16":
        halve.append("collective-permute")   # butterfly payload is bf16
    colls = collective_summary(hlo, halve_kinds=tuple(halve))
    bucket_colls = None
    if av is not None:
        if av.sharding.is_sharded and av.sharding.streamed:
            # streamed plans compile over the layered tree (layer-aware
            # shard layout, DESIGN.md §11)
            from repro.train.train_step import _layered_shapes
            local_params = _layered_shapes(model)
        elif av.sharding.is_sharded:
            # the sharded plan was compiled from the full model tree at
            # state-init time; hand the summary the same structure
            local_params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        else:
            local_params = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype),
                params_sds,
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        bucket_colls = bucket_collective_summary(av, local_params, colls,
                                                 mesh=mesh, hlo_text=hlo)
        if av.sharding.is_sharded:
            # FSDP invariant: parameter all-gathers / gradient
            # reduce-scatters ride the intra-pod shard axis ONLY —
            # classify every grouped collective by mesh axis and flag
            # any landing on another dp axis (a DCN leak)
            from repro.launch.hlo_analysis import collective_axis_counts
            ag = collective_axis_counts(
                hlo, tuple(mesh.axis_names),
                tuple(mesh.shape[a] for a in mesh.axis_names))
            dp_axes = {a for a in mesh.axis_names if a in ("pod", "data")}
            shard_ax = av.sharding.shard_axis
            # a "mixed" classification (replica groups spanning several
            # mesh axes — e.g. a full-dp pod x data gather) is exactly the
            # kind of leak this gate exists to catch, so it counts too
            leaks = {
                kind: {a: n for a, n in ent.items()
                       if a == "mixed" or (a in dp_axes and a != shard_ax)}
                for kind, ent in ag.items()}
            leaks = {k: v for k, v in leaks.items() if v}
            # the gate must not pass vacuously: if the parser classified
            # ZERO gathers onto the shard axis (e.g. an XLA version
            # switches to iota-form replica_groups the regex cannot read),
            # the invariant is untested and the smoke must fail loudly
            on_shard = (ag.get("all-gather", {}).get(shard_ax, 0)
                        + ag.get("reduce-scatter", {}).get(shard_ax, 0))
            if on_shard == 0:
                leaks["unparsed"] = {
                    "reason": "no all-gather/reduce-scatter classified "
                              "onto the shard axis — parser saw nothing"}
            bucket_colls["gather_scatter_by_axis"] = ag
            bucket_colls["fsdp_gather_leaks"] = leaks
            bucket_colls["fsdp_gathers_intra_pod_only"] = not leaks
        if av.sharding.is_sharded and av.sharding.streamed:
            # streamed invariants (DESIGN.md §11), cross-checked in HLO:
            # (a) no single all-gather exceeds one layer-span bucket (a
            #     gather-all regression reappears as a full-tree-sized
            #     gather), (b) the all-gather count on the shard axis
            #     equals the schedule's fwd+bwd expectation (a CSE'd
            #     backward re-gather silently pins forward buffers and
            #     shows up as a shortfall), (c) the schedule's own peak
            #     stays under the two-span bound vs the full tree
            from repro.core import streaming
            from repro.launch.hlo_analysis import grouped_collective_details
            plan = av.plan_for(local_params)
            lay = plan.shard_layout
            # XLA-CPU widens bf16 collectives to f32 (see
            # hlo_analysis.collective_summary), so the per-op bound uses
            # the widened itemsize; on TPU the payload stays narrow
            max_bucket = max(
                (s * max(d.itemsize, 4) for s, d in zip(lay.bucket_sizes,
                                                        lay.bucket_dtypes)),
                default=0)
            details = grouped_collective_details(
                hlo, tuple(mesh.axis_names),
                tuple(mesh.shape[a] for a in mesh.axis_names))
            shard_ax = av.sharding.shard_axis
            ags = [d for d in details
                   if d["kind"] == "all-gather" and d["axis"] == shard_ax]
            expected_ags = streaming.expected_stream_gathers(plan)
            oversize = [d for d in ags if d["tensor_bytes"] > max_bucket]
            stream_report = {
                "expected_gathers": expected_ags,
                "hlo_gathers_on_shard_axis": len(ags),
                "gathers_match": len(ags) == expected_ags,
                "max_gather_bytes": max(
                    (d["tensor_bytes"] for d in ags), default=0),
                "max_span_bucket_bytes": max_bucket,
                "oversize_gathers": len(oversize),
                "peak_gathered_bytes": plan.stream_peak_gathered_bytes(),
                "full_gathered_bytes": plan.full_gathered_bytes(),
                "layer_bucket_map": lay.describe_groups(),
            }
            stream_report["ok"] = (stream_report["gathers_match"]
                                   and not oversize
                                   and stream_report["peak_gathered_bytes"]
                                   < stream_report["full_gathered_bytes"])
            bucket_colls["streamed"] = stream_report
        print("  " + bucket_colls["plan_summary"].replace("\n", "\n  "),
              flush=True)
    n_dp = 1
    for a in mesh.axis_names:
        if a in ("pod", "data"):
            n_dp *= mesh.shape[a]
    n_model = mesh.shape.get("model", 1)
    cm = cost_for(cfg, shape, shape.kind, n_dp=n_dp, n_model=n_model)
    total_p, active_p = param_count(cfg)

    return {
        "status": "ok",
        "arch": arch, "shape": shape_name, "variant": variant,
        "averager": averager if shape.kind == "train" else None,
        "mesh": dict(mesh.shape),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "per_device_total": (ma.argument_size_in_bytes
                                 + ma.temp_size_in_bytes
                                 + ma.output_size_in_bytes
                                 - ma.alias_size_in_bytes),
        },
        "xla_cost_analysis": {
            "flops": ca.get("flops"),
            "bytes_accessed": ca.get("bytes accessed"),
            "note": "scan bodies counted once by XLA; see analytic model",
        },
        "collectives": colls,
        "bucket_collectives": bucket_colls,
        "analytic": {
            "flops_per_device": cm.flops_per_device,
            "hbm_bytes_per_device": cm.hbm_bytes_per_device,
            "model_flops_per_device": cm.model_flops,
            "params_total": total_p,
            "params_active": active_p,
        },
        "hlo_bytes": len(hlo),
    }


def main():
    _force_host_device_count()          # before any jax device/compile use
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--averager", default="wagma")
    ap.add_argument("--group-size", type=int, default=None)
    ap.add_argument("--hierarchical", action="store_true",
                    help="pod-aware topology: pod axis rides DCN, data "
                         "rides ICI, per-class bucket budgets")
    ap.add_argument("--sharding", default="replicated",
                    choices=["replicated", "fsdp"],
                    help="fsdp: FSDP-within-pod sharded replicas "
                         "(DESIGN.md §10); the run fails if any parameter "
                         "all-gather leaks off the intra-pod shard axis")
    ap.add_argument("--streamed", action="store_true",
                    help="with --sharding fsdp: layer-streamed execution "
                         "engine (DESIGN.md §11) — the run fails if any "
                         "gather leaves the intra-pod axis, any single "
                         "all-gather exceeds one layer-span bucket, or the "
                         "shard-axis gather count mismatches the streamed "
                         "schedule (CSE'd backward re-gathers)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced smoke configs (CI-sized compile)")
    ap.add_argument("--mesh-shape", default=None,
                    help="comma ints overriding the production mesh: "
                         "'pod,data,model' (3 values) or 'data,model' (2); "
                         "product must equal the forced host-device count")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    pairs = []
    archs = arch_names() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if (args.all or args.both_meshes) \
        else [args.multi_pod]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                pairs.append((arch, shape, mp))

    results = []
    for arch, shape, mp in pairs:
        if args.mesh_shape:
            dims = tuple(int(x) for x in args.mesh_shape.split(","))
            axes = ("pod", "data", "model") if len(dims) == 3 \
                else ("data", "model")
            mesh = jax.make_mesh(dims, axes)
            mesh_tag = "x".join(str(d) for d in dims)
        else:
            mesh = mesh_lib.make_production_mesh(multi_pod=mp)
            mesh_tag = "2x16x16" if mp else "16x16"
        tag = f"{arch}__{shape}__{mesh_tag}"
        if args.averager != "wagma":
            tag += f"__{args.averager}"
        if args.hierarchical:
            tag += "__hier"
        if args.sharding != "replicated":
            tag += f"__{args.sharding}"
        if args.streamed:
            tag += "__streamed"
        print(f"=== {tag} ===", flush=True)
        try:
            res = lower_pair(arch, shape, mesh, averager=args.averager,
                             group_size=args.group_size,
                             hierarchical=args.hierarchical,
                             sharding=args.sharding, streamed=args.streamed,
                             smoke=args.smoke)
            if res.get("bucket_collectives") and \
                    res["bucket_collectives"].get(
                        "fsdp_gathers_intra_pod_only") is False:
                res["status"] = "error"
                res["error"] = ("fsdp all-gather leak: " + str(
                    res["bucket_collectives"]["fsdp_gather_leaks"]))
            stream_rep = (res.get("bucket_collectives") or {}).get("streamed")
            if stream_rep and not stream_rep["ok"]:
                res["status"] = "error"
                res["error"] = ("streamed invariant violated: "
                                + str(stream_rep))
        except Exception as e:
            res = {"status": "error", "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
            print("  ERROR:", res["error"], flush=True)
        else:
            if res["status"] == "ok":
                mem = res["memory"]["per_device_total"] / 2**30
                cw = res["collectives"]["total_wire_bytes"] / 2**20
                print(f"  ok: compile={res['compile_s']}s "
                      f"mem/dev={mem:.2f}GiB coll={cw:.1f}MiB "
                      f"flops/dev={res['analytic']['flops_per_device']:.3e}",
                      flush=True)
            else:
                print(f"  {res['status']}: "
                      f"{res.get('reason', res.get('error', ''))}",
                      flush=True)
        res["tag"] = tag
        results.append(res)
        with open(os.path.join(args.out, tag + ".json"), "w") as f:
            json.dump(res, f, indent=2, default=str)

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\nDRY-RUN SUMMARY: ok={n_ok} skipped={n_skip} error={n_err} "
          f"of {len(results)}")
    with open(os.path.join(args.out, "summary.json"), "w") as f:
        json.dump([{k: r.get(k) for k in
                    ("tag", "status", "compile_s", "memory", "collectives",
                     "bucket_collectives", "analytic", "error")}
                   for r in results], f, indent=2, default=str)
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
