"""RG-LRU linear recurrence kernel: h_t = a_t * h_{t-1} + x_t.

TPU adaptation (DESIGN.md): the recurrence is elementwise over the channel
dim, so we tile channels onto the 128-lane VPU axis and batch onto sublanes;
time is walked *sequentially inside the block* while the grid parallelises
(batch-tile, channel-tile). Per grid step the kernel streams a
(block_b, block_t, block_w) brick of a/x through VMEM with the carry h held
in a VMEM scratch across the time-block axis of the grid.

Grid: (nb, nw, nt) with time innermost (sequential) — carry persists in
scratch between time blocks of the same (batch, channel) tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, x_ref, h0_ref, o_ref, carry_ref, *, block_t: int,
                  nt: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        carry_ref[...] = h0_ref[:, 0, :].astype(jnp.float32)

    h = carry_ref[...]
    a = a_ref[...].astype(jnp.float32)                 # (bb, block_t, bw)
    x = x_ref[...].astype(jnp.float32)

    def step(t, hs):
        h, out = hs
        h = a[:, t, :] * h + x[:, t, :]
        out = jax.lax.dynamic_update_index_in_dim(out, h, t, 1)
        return h, out

    out0 = jnp.zeros_like(x)
    h, out = jax.lax.fori_loop(0, block_t, step, (h, out0))
    o_ref[...] = out.astype(o_ref.dtype)
    carry_ref[...] = h


def rglru_scan(a, x, h0=None, *, block_b: int = 8, block_t: int = 128,
               block_w: int = 128, interpret: bool = False):
    """a, x (B,S,W); h0 (B,W) or None -> h (B,S,W) (dtype of x)."""
    b, s, w = a.shape
    if h0 is None:
        h0 = jnp.zeros((b, w), jnp.float32)
    block_b = min(block_b, b)
    block_t = min(block_t, s)
    block_w = min(block_w, w)
    pb, pt, pw = (-b) % block_b, (-s) % block_t, (-w) % block_w
    if pb or pt or pw:
        a = jnp.pad(a, ((0, pb), (0, pt), (0, pw)))
        # pad x with zeros and a with zeros: h stays constant in padding
        x = jnp.pad(x, ((0, pb), (0, pt), (0, pw)))
        h0 = jnp.pad(h0, ((0, pb), (0, pw)))
    nb = a.shape[0] // block_b
    nw = a.shape[2] // block_w
    nt = a.shape[1] // block_t

    out = pl.pallas_call(
        functools.partial(_rglru_kernel, block_t=block_t, nt=nt),
        grid=(nb, nw, nt),
        in_specs=[
            pl.BlockSpec((block_b, block_t, block_w),
                         lambda bi, wi, ti: (bi, ti, wi)),
            pl.BlockSpec((block_b, block_t, block_w),
                         lambda bi, wi, ti: (bi, ti, wi)),
            pl.BlockSpec((block_b, 1, block_w),
                         lambda bi, wi, ti: (bi, 0, wi)),
        ],
        out_specs=pl.BlockSpec((block_b, block_t, block_w),
                               lambda bi, wi, ti: (bi, ti, wi)),
        out_shape=jax.ShapeDtypeStruct(a.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((block_b, block_w), jnp.float32)],
        interpret=interpret,
    )(a, x, h0.reshape(h0.shape[0], 1, h0.shape[1]))
    return out[:b, :s, :w]
