"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These are *naive* references (materialise the full score matrix, sequential
scans) — slow but obviously correct, for the kernel test sweeps.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True, window=None):
    """q (B,Sq,H,hd), k/v (B,Sk,KH,hd) -> (B,Sq,H,hd). GQA by head repeat."""
    b, sq, h, hd = q.shape
    sk, kh = k.shape[1], k.shape[2]
    rep = h // kh
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def group_average_ref(w, recv, inv_s: float):
    """Butterfly combine step: (w + recv) * inv_s in fp32, back to w.dtype."""
    return ((w.astype(jnp.float32) + recv.astype(jnp.float32)) * inv_s
            ).astype(w.dtype)


def rglru_scan_ref(a, x, h0=None):
    """Sequential linear recurrence h_t = a_t*h_{t-1} + x_t; a,x (B,S,W)."""
    b, s, w = a.shape
    h = jnp.zeros((b, w), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, inputs):
        at, xt = inputs
        h = at.astype(jnp.float32) * h + xt.astype(jnp.float32)
        return h, h

    _, hs = jax.lax.scan(step, h, (jnp.moveaxis(a, 1, 0), jnp.moveaxis(x, 1, 0)))
    return jnp.moveaxis(hs, 0, 1).astype(x.dtype)


def mlstm_chunk_ref(q, k, v, i_pre, f_pre):
    """Sequential mLSTM (matches models/xlstm.py mlstm_step).

    q,k,v (B,S,H,dh); i_pre,f_pre (B,S,H). Returns h (B,S,H,dh) fp32.
    """
    from repro.models.xlstm import mlstm_step
    b, s, h, dh = q.shape
    state = (jnp.zeros((b, h, dh, dh), jnp.float32),
             jnp.zeros((b, h, dh), jnp.float32),
             jnp.full((b, h), -1e30, jnp.float32))
    xs = jax.tree.map(lambda a: jnp.moveaxis(a, 1, 0), (q, k, v, i_pre, f_pre))
    _, hs = jax.lax.scan(mlstm_step, state, xs)
    return jnp.moveaxis(hs, 0, 1)
