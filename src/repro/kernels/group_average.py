"""Fused butterfly-combine kernel: out = (w + recv) * inv_s.

The arithmetic half of a WAGMA butterfly stage (paper Alg. 2 line 11): after
``ppermute`` delivers the partner's weights, each device combines its shard
with the received shard. Done naively this is two HBM-bound elementwise passes
(add, then scale) plus dtype converts; the fused kernel streams both operands
through VMEM tiles once, accumulating in fp32 and writing the model dtype —
one read of each operand + one write, the HBM floor.

1-D tiling: weights arrive flattened; the grid walks (n // block) tiles of
``block`` elements (8*128*128 default = 128 KiB bf16 tiles, well inside the
~16 MiB VMEM budget with double-buffering).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _combine_kernel(w_ref, r_ref, o_ref, *, inv_s: float):
    w = w_ref[...].astype(jnp.float32)
    r = r_ref[...].astype(jnp.float32)
    o_ref[...] = ((w + r) * inv_s).astype(o_ref.dtype)


def group_average_combine(w, recv, inv_s: float, *, block: int = 8 * 128 * 128,
                          interpret: bool = False):
    """Flat fused (w + recv) * inv_s; w/recv any shape, same dtype."""
    shape, dtype = w.shape, w.dtype
    flat_w = w.reshape(-1)
    flat_r = recv.reshape(-1)
    n = flat_w.size
    block = min(block, n)
    pad = (-n) % block
    if pad:
        flat_w = jnp.pad(flat_w, (0, pad))
        flat_r = jnp.pad(flat_r, (0, pad))
    grid = (flat_w.size // block,)
    out = pl.pallas_call(
        functools.partial(_combine_kernel, inv_s=inv_s),
        grid=grid,
        in_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                  pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((flat_w.size,), dtype),
        interpret=interpret,
    )(flat_w, flat_r)
    return out[:n].reshape(shape)
