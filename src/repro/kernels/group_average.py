"""Fused butterfly-combine kernel: out = (w + recv) * inv_s.

The arithmetic half of a WAGMA butterfly stage (paper Alg. 2 line 11): after
``ppermute`` delivers the partner's weights, each device combines its shard
with the received shard. Done naively this is two HBM-bound elementwise passes
(add, then scale) plus dtype converts; the fused kernel streams both operands
through VMEM tiles once, accumulating in fp32 and writing the model dtype —
one read of each operand + one write, the HBM floor.

Tiling: weights arrive flattened (the bucketed averaging path —
``core/bucketing.py`` — hands us lane-padded flat buckets); the buffer is
viewed as (rows, 128) lanes and the grid walks ``block_rows``-row tiles
(1024 x 128 default = 512 KiB f32 per operand tile, comfortably inside the
~16 MiB VMEM budget with double buffering; f32 min tile is (8, 128)).
Sub-lane sizes and non-divisible row counts are zero-padded once here — the
bucketed caller never triggers that path because its buckets are pre-padded.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LANES = 128


def _combine_kernel(w_ref, r_ref, o_ref, *, inv_s: float):
    w = w_ref[...].astype(jnp.float32)
    r = r_ref[...].astype(jnp.float32)
    o_ref[...] = ((w + r) * inv_s).astype(o_ref.dtype)


def group_average_combine(w, recv, inv_s: float, *, block_rows: int = 1024,
                          interpret: bool = False):
    """Fused (w + recv) * inv_s; w/recv any shape, same dtype."""
    shape, dtype = w.shape, w.dtype
    n = w.size
    if n == 0:
        return w
    flat_w = w.reshape(-1)
    flat_r = recv.reshape(-1)
    rows = -(-n // _LANES)
    block_rows = min(block_rows, rows)
    rows_padded = -(-rows // block_rows) * block_rows
    pad = rows_padded * _LANES - n
    if pad:
        flat_w = jnp.pad(flat_w, (0, pad))
        flat_r = jnp.pad(flat_r, (0, pad))
    tw = flat_w.reshape(rows_padded, _LANES)
    tr = flat_r.reshape(rows_padded, _LANES)
    grid = (rows_padded // block_rows,)
    out = pl.pallas_call(
        functools.partial(_combine_kernel, inv_s=inv_s),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, _LANES), lambda i: (i, 0)),
                  pl.BlockSpec((block_rows, _LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, _LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows_padded, _LANES), dtype),
        interpret=interpret,
    )(tw, tr)
    return out.reshape(-1)[:n].reshape(shape)
