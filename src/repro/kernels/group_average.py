"""Fused butterfly-combine kernel: out = (w + recv) * inv_s.

The arithmetic half of a WAGMA butterfly stage (paper Alg. 2 line 11): after
``ppermute`` delivers the partner's weights, each device combines its shard
with the received shard. Done naively this is two HBM-bound elementwise passes
(add, then scale) plus dtype converts; the fused kernel streams both operands
through VMEM tiles once, accumulating in fp32 and writing the model dtype —
one read of each operand + one write, the HBM floor.

Tiling: weights arrive flattened (the bucketed averaging path —
``core/bucketing.py`` — hands us lane-padded flat buckets); the buffer is
viewed as (rows, 128) lanes and the grid walks ``block_rows``-row tiles
(1024 x 128 default = 512 KiB f32 per operand tile, comfortably inside the
~16 MiB VMEM budget with double buffering; f32 min tile is (8, 128)).
Sub-lane sizes and non-divisible row counts are zero-padded once here — the
bucketed caller never triggers that path because its buckets are pre-padded.

``group_average_combine_multi`` is the overlapped-scheduler variant: a batch
of independent bucket pairs (one wavefront tick of core/overlap.py) shares a
single ``pallas_call`` whose grid walks buckets x row-tiles, so the next
bucket's DMA overlaps the current bucket's compute instead of paying one
kernel launch per bucket per stage.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LANES = 128


def _combine_kernel(w_ref, r_ref, o_ref, *, inv_s: float):
    w = w_ref[...].astype(jnp.float32)
    r = r_ref[...].astype(jnp.float32)
    o_ref[...] = ((w + r) * inv_s).astype(o_ref.dtype)


def _tiled_combine(flat_w, flat_r, inv_s: float, n: int, block_rows: int,
                   interpret: bool):
    """One pallas_call over the (rows, 128) view of a flat fp pair."""
    dtype = flat_w.dtype
    rows = -(-n // _LANES)
    block_rows = min(block_rows, rows)
    rows_padded = -(-rows // block_rows) * block_rows
    pad = rows_padded * _LANES - n
    if pad:
        flat_w = jnp.pad(flat_w, (0, pad))
        flat_r = jnp.pad(flat_r, (0, pad))
    tw = flat_w.reshape(rows_padded, _LANES)
    tr = flat_r.reshape(rows_padded, _LANES)
    grid = (rows_padded // block_rows,)
    out = pl.pallas_call(
        functools.partial(_combine_kernel, inv_s=inv_s),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, _LANES), lambda i: (i, 0)),
                  pl.BlockSpec((block_rows, _LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, _LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows_padded, _LANES), dtype),
        interpret=interpret,
    )(tw, tr)
    return out.reshape(-1)


def group_average_combine(w, recv, inv_s: float, *, block_rows: int = 1024,
                          interpret: bool = False):
    """Fused (w + recv) * inv_s; w/recv any shape, same dtype."""
    shape = w.shape
    n = w.size
    if n == 0:
        return w
    out = _tiled_combine(w.reshape(-1), recv.reshape(-1), inv_s, n,
                         block_rows, interpret)
    return out[:n].reshape(shape)


def group_average_combine_multi(ws, rs, inv_s: float, *,
                                block_rows: int = 1024,
                                interpret: bool = False):
    """Combine a LIST of same-dtype flat bucket pairs in ONE pallas_call.

    The overlapped bucket scheduler (core/overlap.py) lands several mutually
    independent combines on the same wavefront tick; launching the
    single-pair kernel once per bucket would pay one kernel dispatch each.
    Instead the buckets' (rows, 128) tiles are laid out back to back in one
    grid — emit_pipeline-style, the grid walks buckets x row-tiles, so while
    tile t of bucket k computes, Pallas's automatic double buffering is
    already DMA-ing tile t+1 (possibly the first tile of bucket k+1) into
    VMEM: one launch, DMA of the next bucket overlapped with compute of the
    current.

    Buckets may be ragged (any sizes, incl. lane-unaligned); each is padded
    to whole 128-lane rows so tiles never straddle two buckets' elements.
    All pairs share one static ``inv_s`` — the scheduler batches per scale —
    and one dtype (callers group by dtype; buckets are dtype-homogeneous).
    """
    if len(ws) != len(rs) or not ws:
        raise ValueError("need matching, non-empty bucket lists")
    dtype = ws[0].dtype
    if any(w.dtype != dtype or r.dtype != dtype for w, r in zip(ws, rs)):
        raise ValueError("multi-bucket combine needs one dtype per launch")
    if len(ws) == 1:
        return [group_average_combine(ws[0], rs[0], inv_s,
                                      block_rows=block_rows,
                                      interpret=interpret)]
    sizes = [w.size for w in ws]
    row_sizes = [-(-n // _LANES) * _LANES for n in sizes]

    def cat(bufs):
        parts = []
        for buf, n, rn in zip(bufs, sizes, row_sizes):
            flat = buf.reshape(-1)
            parts.append(jnp.pad(flat, (0, rn - n)) if rn != n else flat)
        return jnp.concatenate(parts) if len(parts) > 1 else parts[0]

    total = sum(row_sizes)
    flat = _tiled_combine(cat(ws), cat(rs), inv_s, total, block_rows,
                          interpret)
    outs, off = [], 0
    for w, n, rn in zip(ws, sizes, row_sizes):
        outs.append(jax.lax.slice(flat, (off,), (off + n,)).reshape(w.shape))
        off += rn
    return outs
