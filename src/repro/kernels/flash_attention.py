"""Flash attention for TPU in Pallas: blockwise online-softmax with explicit
VMEM BlockSpec tiling (MXU-aligned 128-multiples), causal + sliding-window +
GQA (grouped KV heads via index_map, no materialised head repeat).

Grid: (batch*heads, num_q_blocks, num_k_blocks) — the K dimension is the
innermost (sequential on TPU) axis so the fp32 accumulators (acc, m, l) live
in VMEM scratch across K steps.

The hardware TARGET is TPU (Mosaic); on CPU the kernel is validated with
``interpret=True`` against ref.flash_attention_ref (tests/test_kernels.py).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 scale: float, causal: bool, window, block_q: int,
                 block_k: int, nk: int, sq: int, sk: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                   # (block_q, hd)
    k = k_ref[0].astype(jnp.float32)                   # (block_k, hd)
    v = v_ref[0].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = k_pos < sk
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kj == nk - 1)
    def _done():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q (B,Sq,H,hd), k/v (B,Sk,KH,hd) -> (B,Sq,H,hd)."""
    b, sq, h, hd = q.shape
    sk, kh = k.shape[1], k.shape[2]
    assert h % kh == 0
    rep = h // kh
    scale = 1.0 / math.sqrt(hd)

    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    pq = (-sq) % block_q
    pk = (-sk) % block_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq = q.shape[1] // block_q
    nk = k.shape[1] // block_k

    # layout (B*H, S, hd): flatten batch x heads into the parallel grid axis
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, q.shape[1], hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kh, k.shape[1], hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kh, v.shape[1], hd)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, nk=nk, sq=sq, sk=sk)

    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, qi, kj: (bh, qi, 0)),
            # GQA: head bh reads KV head bh//rep — no repeat materialised
            pl.BlockSpec((1, block_k, hd),
                         lambda bh, qi, kj, rep=rep: (bh // rep, kj, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda bh, qi, kj, rep=rep: (bh // rep, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda bh, qi, kj: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, q.shape[1], hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),    # acc
            pltpu.VMEM((block_q,), jnp.float32),       # m
            pltpu.VMEM((block_q,), jnp.float32),       # l
        ],
        interpret=interpret,
    )(qf, kf, vf)

    out = out.reshape(b, h, q.shape[1], hd).transpose(0, 2, 1, 3)
    return out[:, :sq]
