"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU so the same call sites work in CPU
tests; on a TPU backend the Mosaic kernels lower natively.
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.group_average import group_average_combine as _combine
from repro.kernels.group_average import (group_average_combine_multi
                                         as _combine_multi)
from repro.kernels.rglru_scan import rglru_scan as _rglru


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, block_q=128,
                    block_k=128, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _flash(q, k, v, causal=causal, window=window, block_q=block_q,
                  block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("inv_s", "interpret"))
def group_average_combine(w, recv, inv_s, *, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _combine(w, recv, float(inv_s), interpret=interpret)


@functools.partial(jax.jit, static_argnames=("inv_s", "interpret"))
def group_average_combine_multi(ws, rs, inv_s, *, interpret=None):
    """One launch for a batch of independent bucket combines (overlap path)."""
    interpret = _default_interpret() if interpret is None else interpret
    return _combine_multi(list(ws), list(rs), float(inv_s),
                          interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def rglru_scan(a, x, h0=None, *, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _rglru(a, x, h0, interpret=interpret)
