#!/usr/bin/env bash
# Tier-1 verify: the whole suite, one command, locally and in CI.
#
#   scripts/ci.sh            # full tier-1 run (fails fast, quiet)
#   scripts/ci.sh -k fused   # extra pytest args pass through
#
# The main pytest process stays on the real single-device CPU view — the
# distributed/differential tests (tests/test_distributed.py,
# tests/test_group_average_fused.py) each spawn subprocesses with
# XLA_FLAGS=--xla_force_host_platform_device_count=8, so the 8-device
# host-platform CPU mesh is exercised without ever forcing the flag
# globally (it must not leak into unrelated compilation caches).
set -euo pipefail
cd "$(dirname "$0")/.."

# Belt and braces: never inherit a stray device-forcing flag or GPU pick-up.
unset XLA_FLAGS
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

# Guard: compiled bytecode must never be tracked (it once was; .gitignore
# covers new files, this catches anything force-added or historical).
if git ls-files | grep -qE '(^|/)__pycache__/|\.py[co]$'; then
  echo "ci.sh: tracked __pycache__/*.pyc files found:" >&2
  git ls-files | grep -E '(^|/)__pycache__/|\.py[co]$' >&2
  exit 1
fi

# Dev-only deps (hypothesis): install on demand so the 7 property tests run
# in tier-1 instead of skipping.  Best-effort — offline/air-gapped runners
# fall back to the hypothesis_compat skip shim and the suite stays green.
if ! python -c "import hypothesis" >/dev/null 2>&1; then
  if ! python -m pip install --quiet -r requirements-dev.txt >/dev/null 2>&1; then
    echo "ci.sh: requirements-dev.txt install failed (offline?);" \
         "property tests will skip" >&2
  fi
fi

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"

# Modeled-perf gate: overlapped < serial for transformer_wmt AND the
# hierarchical (2-link-class pod x data) per-class bucket budgets beat the
# single global budget (distinct per-class choices).  Writes the tracked
# BENCH_group_average.json; model-only, a few seconds.
python benchmarks/bench_group_average.py --check

# FSDP-within-pod smoke (DESIGN.md §10): compile the sharded train step on
# an 8-device (pod=2, data=4, model=1) host mesh with the hierarchical
# topology and cross-check the plan — the run exits non-zero if the plan's
# per-class ppermute expectation mismatches the compiled HLO or any
# parameter all-gather / gradient reduce-scatter leaks off the intra-pod
# shard axis onto a DCN (pod) axis.
XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k \
  --smoke --sharding fsdp --hierarchical --mesh-shape 2,4,1 \
  --out experiments/dryrun-ci

# Layer-streamed FSDP smoke (DESIGN.md §11): compile the streamed train
# step and cross-check the schedule against the HLO — the run exits
# non-zero if any gather leaves the intra-pod axis, any single all-gather
# exceeds one layer-span bucket (a gather-all regression), or the
# shard-axis gather count mismatches the streamed fwd+bwd expectation
# (a CSE'd backward re-gather that would silently pin forward buffers).
XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k \
  --smoke --sharding fsdp --streamed --hierarchical --mesh-shape 2,4,1 \
  --out experiments/dryrun-ci

# Elastic kill/rejoin smoke (DESIGN.md §12): scripted preemption on the
# 8-device host mesh — a worker leaves mid-training, the dp mesh shrinks
# and the averaging plan recompiles in place (no restart), the worker
# rejoins at the tau-sync barrier, and the run exits non-zero unless the
# rejoiner's replica row is bit-identical to the survivors' at the first
# post-rejoin tau-sync (and the dead topology's plan-cache entries were
# evicted).  Same code path as tests/test_elastic.py's subprocess test.
XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
  python -m repro.launch.elastic

# Chaos smoke (DESIGN.md §13): detector-driven fault tolerance on the
# 8-device host mesh — a fixed FaultSchedule (one hang that wakes, one
# crash that rejoins) silences workers on the virtual clock; NOTHING is
# scripted.  The heartbeat failure detector must suspect each silent
# worker past the collective deadline, shrink the world in place, charge
# the skipped contributions to the staleness ledger (never past
# max_staleness_bound(tau)), and re-admit recovered workers bit-identical
# at the tau-sync barrier — the run exits non-zero on any violation.
XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
  python -m repro.launch.elastic --chaos

# Elastic churn gate (DESIGN.md §12): discrete-event preemption trace,
# elastic recovery (in-place recompile + host-side handoff) vs the
# checkpoint-restart baseline — exits non-zero if the elastic overhead
# fraction is unbounded (>=10% of wall clock) or restart wins on goodput.
PYTHONPATH=src python benchmarks/cluster_sim.py --churn

# Link-constant calibration scaffold smoke (ROADMAP: measured
# alpha/beta/gamma): microbench ppermute/all-gather per mesh axis on the
# 8-device CPU mesh and round-trip the JSON through
# Topology.with_measured.  Tiny payloads — a few seconds.  The scratch
# output name is deliberately NOT LINK_CONSTANTS.json: the one canonical
# copy lives at the repo root (plan.DEFAULT_LINK_CONSTANTS_PATH) and is
# regenerated manually with full payloads.
python benchmarks/calibrate_links.py --smoke \
  --out experiments/LINK_CONSTANTS.smoke.json

# Serving gate (DESIGN.md §14): request-level simulator over the analytic
# cost model — continuous-batching decode loop with inline prefill stalls
# (colocated) vs split prefill/decode pods with DCN KV transfer
# (disaggregated).  Writes the tracked BENCH_serving.json; exits non-zero
# unless disaggregation wins p99 inter-token latency AND holds goodput at
# the modeled operating point.  Model-only, a few seconds.
python benchmarks/serve_sim.py --check
