"""Calibrate per-mesh-axis link constants (ROADMAP: measured alpha/beta/gamma).

The overlapped cost model (DESIGN.md §8) and the per-link-class
``LinkClass`` defaults (``plan.ICI``/``plan.DCN``) run on assumed
constants.  This scaffold microbenches the real backend:

* **alpha** — per-collective launch latency: wall time of a lane-sized
  ``ppermute`` ring shift on each mesh axis (latency-dominated);
* **beta**  — inverse wire bandwidth: the marginal time per byte between a
  small and a large ``ppermute`` payload on the same axis;
* **ag_alpha/ag_beta** — all-gather latency/bandwidth per axis (the FSDP
  gather path): ``with_measured`` takes the slower of the ppermute and
  all-gather rates per class, so a backend whose gathers are slower than
  its ring permutes prices the streamed-engine gather model honestly;
* **gamma** — combine throughput: the fused ``(acc + recv) * scale``
  kernel's seconds per payload byte on this backend's memory system.

Results land in ``LINK_CONSTANTS.json`` (``--out``):

    {"backend": ..., "mesh": {...}, "axes": {axis: {alpha, beta, gamma,
     ag_alpha, ag_beta, ...}}}

which ``plan.Topology.with_measured(path)`` loads back into a topology's
link classes (each class takes the slowest measurement among its axes).
On the forced-host-device CPU mesh the numbers measure XLA's CPU
emulation, not real wire — useful as a smoke of the scaffold (scripts/ci.sh
runs ``--smoke``) and as the recording template for a real TPU/GPU pod,
where this script is the calibration the ROADMAP item asks for.

Usage:
    python benchmarks/calibrate_links.py [--mesh-shape 2,4] [--iters 20]
        [--big-mb 4] [--out LINK_CONSTANTS.json] [--smoke]
"""

import argparse
import json
import os
import sys
import time

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.plan import DEFAULT_LINK_CONSTANTS_PATH

# One canonical tracked location (repo root) shared with
# Topology.with_measured's default — there is no second copy to drift.
OUT_JSON = DEFAULT_LINK_CONSTANTS_PATH
SMALL_ELEMS = 128                      # one lane: latency-dominated
_WARMUP = 3


def _time(fn, x, iters: int) -> float:
    out = jax.block_until_ready(fn(x))          # compile
    for _ in range(_WARMUP):
        out = jax.block_until_ready(fn(x))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(x))
    del out
    return (time.perf_counter() - t0) / iters


def _ring(axis: str, n: int):
    perm = [(i, (i + 1) % n) for i in range(n)]
    return lambda buf: jax.lax.ppermute(buf, axis, perm)


def bench_axis(mesh, axis: str, *, big_elems: int, iters: int) -> dict:
    """Microbench one mesh axis: ppermute + all-gather latency/bandwidth."""
    n = mesh.shape[axis]

    def collective_fn(body):
        return jax.jit(compat.shard_map(
            body, mesh=mesh, in_specs=P(axis), out_specs=P(axis),
            axis_names=set(mesh.axis_names)))

    def stacked(elems):
        return jnp.zeros((n, elems), jnp.float32)

    ring = _ring(axis, n)
    t_pp_small = _time(collective_fn(ring), stacked(SMALL_ELEMS), iters)
    t_pp_big = _time(collective_fn(ring), stacked(big_elems), iters)
    big_bytes = big_elems * 4
    small_bytes = SMALL_ELEMS * 4
    beta = max(t_pp_big - t_pp_small, 1e-12) / max(big_bytes - small_bytes, 1)

    def ag_body(b):
        # consume every gathered row (sum) so XLA cannot elide the gather,
        # and keep the output per-device-sized so the timing excludes any
        # host-side materialisation
        return jax.lax.all_gather(b, axis, tiled=True).sum(
            axis=0, keepdims=True)

    ag_fn = collective_fn(ag_body)
    t_ag_small = _time(ag_fn, stacked(SMALL_ELEMS), iters)
    t_ag_big = _time(ag_fn, stacked(big_elems), iters)
    # all-gather moves (n-1)/n of the gathered buffer per device
    ag_wire = big_bytes * n * (n - 1) / n
    ag_beta = max(t_ag_big - t_ag_small, 1e-12) / max(ag_wire, 1)

    return {
        "alpha": t_pp_small,
        "beta": beta,
        "ppermute_small_s": t_pp_small,
        "ppermute_big_s": t_pp_big,
        "ag_alpha": t_ag_small,
        "ag_beta": ag_beta,
        "axis_size": n,
        "payload_big_bytes": big_bytes,
    }


def bench_gamma(*, big_elems: int, iters: int) -> float:
    """Combine throughput: fused (acc + recv) * scale seconds per byte."""
    from repro.core.plan import _stage_combine
    acc = jnp.zeros((big_elems,), jnp.float32)
    f = jax.jit(lambda a: _stage_combine(a, a, 0.5, False))
    t = _time(f, acc, iters)
    return t / (big_elems * 4)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh-shape", default="2,4",
                    help="comma ints: 'pod,data' (2) or 'pod,data,model' "
                         "(3); product must divide the device count")
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--big-mb", type=float, default=4.0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny payload + few iters (CI scaffold smoke)")
    ap.add_argument("--out", default=OUT_JSON)
    args = ap.parse_args()
    if args.smoke:
        args.iters = min(args.iters, 5)
        args.big_mb = min(args.big_mb, 1.0)

    dims = tuple(int(x) for x in args.mesh_shape.split(","))
    axes = ("pod", "data", "model")[:len(dims)] if len(dims) != 2 \
        else ("pod", "data")
    mesh = jax.make_mesh(dims, axes)
    big_elems = int(args.big_mb * 2**20 / 4)

    report = {
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "mesh": {a: int(mesh.shape[a]) for a in mesh.axis_names},
        "iters": args.iters,
        "note": ("microbenched collective constants; on a forced-host CPU "
                 "mesh these measure XLA's emulation, not real links — "
                 "re-run on a TPU/GPU pod for production constants"),
        "axes": {},
    }
    gamma = bench_gamma(big_elems=big_elems, iters=args.iters)
    with compat.set_mesh(mesh):
        for axis in mesh.axis_names:
            if mesh.shape[axis] < 2 or axis == "model":
                continue
            print(f"benching axis {axis!r} (size {mesh.shape[axis]})...",
                  flush=True)
            ent = bench_axis(mesh, axis, big_elems=big_elems,
                             iters=args.iters)
            ent["gamma"] = gamma
            report["axes"][axis] = ent
            print(f"  alpha {ent['alpha']:.3e}s  beta {ent['beta']:.3e}s/B "
                  f"ag_beta {ent['ag_beta']:.3e}s/B gamma {gamma:.3e}s/B",
                  flush=True)

    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out}")

    # round-trip through the Topology loader as a self-check
    from repro.core.plan import Topology
    names = tuple(a for a in mesh.axis_names if a in report["axes"])
    if names:
        topo = Topology.hierarchical(
            names, tuple(mesh.shape[a] for a in names),
            dcn_axes=("pod",)).with_measured(args.out)
        print("with_measured ->", topo.describe())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
