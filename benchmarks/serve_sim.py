"""Request-level serving simulator: latency percentiles vs load + disagg gate.

Answers the question the serving engine exists for: *what request rate can
this cluster sustain at what tail latency?* — and whether prefill/decode
**disaggregation** (serve/kv_transfer.py) beats colocation at the modeled
operating point.

Model (DESIGN.md §14):

* Per-phase latencies come from the analytic cost model
  (``prefill_cost`` / ``decode_cost``, launch/costmodel.py) pushed
  through the chip roofline (``PEAK_FLOPS`` / ``HBM_BW``,
  launch/mesh.py).
* KV transfer (disaggregated only) is costed by
  ``plan.link_transfer_seconds`` on the DCN link class at the link's
  modeled-optimal message budget — the same arithmetic the
  ``LinkCostedConnector`` executes (``--measured`` swaps in the
  calibrated constants from the tracked ``LINK_CONSTANTS.json``).
* Arrivals are Poisson; prompt/output lengths are seeded lognormals.
  The sweep is expressed as *load fractions* of the cluster's modeled
  capacity so the same flags exercise any arch at comparable pressure.
* A **colocated** pod interleaves prefill into its continuous-batching
  decode loop: each admission stalls every running request's next token
  for the full prefill — the head-of-line blocking disaggregation
  removes.  A **disaggregated** cluster splits the same pod count into
  FCFS prefill pods and pure-decode pods; each request's KV blocks ride
  DCN between them, which delays its *second* token (the first comes
  back from the prefill itself).
* The decode batch is capped by pod HBM: weights + per-token KV bytes
  (``kv_transfer.kv_payload_bytes``) must fit — the simulator derives
  the block-pool capacity instead of assuming one.

Reported per placement and load: TTFT p50/p95/p99, per-output-token
latency — both per-request mean (TPOT) and per-gap inter-token latency
(ITL) percentiles — and goodput (finished requests/s meeting the
TTFT+TPOT SLO).  ``disagg_win`` = colocated p99 ITL / disaggregated p99
ITL at the operating point: colocation stalls *every* running stream
once per admission, while the disagg transfer taxes each stream exactly
once, so under load the tail gap is where the placement decision shows.

Results land in ``BENCH_serving.json`` at the repo root.  ``--check``
(CHECK-SERVE, wired into scripts/ci.sh) exits non-zero unless
disaggregation wins p99 ITL *and* holds goodput at the operating point.
"""

import argparse
import json
import os
import sys
from collections import deque
from dataclasses import dataclass, field
from typing import List, Optional

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))

import numpy as np

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.core import plan as plan_mod
from repro.launch import costmodel
from repro.launch.mesh import PEAK_FLOPS, HBM_BW, HBM_PER_CHIP
from repro.serve.kv_transfer import kv_payload_bytes

OUT_JSON = os.path.join(_ROOT, "BENCH_serving.json")


def _roofline(report) -> float:
    return max(report.flops_per_device / PEAK_FLOPS,
               report.hbm_bytes_per_device / HBM_BW)


class Latency:
    """Memoised per-phase roofline latencies for one (arch, pod) point."""

    def __init__(self, cfg, n_model: int):
        self.cfg, self.n_model = cfg, n_model
        self._pf, self._dec = {}, {}

    def prefill(self, prompt_len: int) -> float:
        key = max(64, int(prompt_len))
        if key not in self._pf:
            shape = InputShape("pf", key, 1, "prefill")
            self._pf[key] = _roofline(costmodel.prefill_cost(
                self.cfg, shape, n_dp=1, n_model=self.n_model))
        return self._pf[key]

    def decode(self, batch: int, ctx: int) -> float:
        # quantise ctx so the memo table stays small
        ctx = max(256, 1 << int(np.ceil(np.log2(max(ctx, 1)))))
        key = (int(batch), ctx)
        if key not in self._dec:
            shape = InputShape("dec", ctx, key[0], "decode")
            self._dec[key] = _roofline(costmodel.decode_cost(
                self.cfg, shape, n_dp=1, n_model=self.n_model))
        return self._dec[key]


@dataclass
class SimRequest:
    rid: int
    t_arrive: float
    prompt_len: int
    n_new: int
    t_ready: float = 0.0            # KV available at the decode pod
    t_first: Optional[float] = None
    t_done: Optional[float] = None
    t_last: Optional[float] = None  # previous token's emission time
    tokens: int = 0                 # decode tokens produced so far
    itl: List[float] = field(default_factory=list)

    @property
    def ttft(self) -> float:
        return self.t_first - self.t_arrive

    @property
    def tpot(self) -> float:
        return (self.t_done - self.t_first) / max(self.n_new - 1, 1)


def sample_workload(rng, n: int, *, max_prompt: int,
                    max_new: int) -> List[SimRequest]:
    t = np.cumsum(rng.exponential(1.0, size=n))   # unit rate; scaled later
    pl = np.clip(rng.lognormal(np.log(max_prompt / 4), 0.7, n), 16,
                 max_prompt).astype(int)
    nn = np.clip(rng.lognormal(np.log(max_new / 2), 0.6, n), 4,
                 max_new).astype(int)
    return [SimRequest(i, float(t[i]), int(pl[i]), int(nn[i]))
            for i in range(n)]


def run_decode_pod(jobs: List[SimRequest], lat: Latency, *,
                   inline_prefill: bool, max_batch: int) -> None:
    """Continuous-batching loop of one pod (mutates the jobs in place).

    ``inline_prefill``: prefill runs on this pod between decode
    iterations and stalls the running batch (colocated).  Otherwise jobs
    arrive with KV ready at ``t_ready`` and ``t_first``/``t_last``
    already set by the prefill pod (disaggregated decode pod).
    """
    waiting = deque(sorted(jobs, key=lambda r: r.t_ready))
    running: List[SimRequest] = []
    now = 0.0
    while waiting or running:
        if not running and waiting and waiting[0].t_ready > now:
            now = waiting[0].t_ready
        while waiting and len(running) < max_batch \
                and waiting[0].t_ready <= now:
            req = waiting.popleft()
            if inline_prefill:
                now += lat.prefill(req.prompt_len)   # stalls the whole pod
                req.t_first = now                    # first token at prefill
                req.t_last = now
            if req.n_new <= 1:
                req.t_done = req.t_first
                continue
            running.append(req)
        if not running:
            continue
        ctx = int(np.mean([r.prompt_len + r.tokens for r in running]))
        now += lat.decode(len(running), ctx)
        for req in list(running):
            req.tokens += 1
            req.itl.append(now - req.t_last)
            req.t_last = now
            if req.tokens >= req.n_new - 1:
                req.t_done = now
                running.remove(req)


def run_prefill_pods(reqs: List[SimRequest], lat: Latency, *,
                     n_pods: int, transfer) -> None:
    """FCFS prefill across ``n_pods``; sets t_first and decode t_ready."""
    free_at = [0.0] * n_pods
    for req in sorted(reqs, key=lambda r: r.t_arrive):
        pod = int(np.argmin(free_at))
        start = max(free_at[pod], req.t_arrive)
        done = start + lat.prefill(req.prompt_len)
        free_at[pod] = done
        req.t_first = done                           # first token from prefill
        req.t_last = done
        req.t_ready = done + transfer(req.prompt_len)


def simulate(reqs: List[SimRequest], lat: Latency, *, pods: int,
             prefill_pods: int, max_batch: int, transfer,
             disaggregated: bool) -> List[SimRequest]:
    reqs = [SimRequest(r.rid, r.t_arrive, r.prompt_len, r.n_new)
            for r in reqs]
    if disaggregated:
        decode_pods = pods - prefill_pods
        assert decode_pods >= 1
        run_prefill_pods(reqs, lat, n_pods=prefill_pods, transfer=transfer)
    else:
        decode_pods = pods
        for r in reqs:
            r.t_ready = r.t_arrive                   # prefill runs in-loop
    shards = [[] for _ in range(decode_pods)]
    for r in reqs:
        shards[r.rid % decode_pods].append(r)
    for shard in shards:
        run_decode_pod(shard, lat, inline_prefill=not disaggregated,
                       max_batch=max_batch)
    return reqs


def percentiles(xs) -> dict:
    xs = np.asarray(sorted(xs))
    return {p: float(np.percentile(xs, q))
            for p, q in (("p50", 50), ("p95", 95), ("p99", 99))}


def summarise(reqs: List[SimRequest], *, slo_ttft: float,
              slo_tpot: float) -> dict:
    span = max(r.t_done for r in reqs) - min(r.t_arrive for r in reqs)
    good = [r for r in reqs if r.ttft <= slo_ttft and r.tpot <= slo_tpot]
    gaps = [g for r in reqs for g in r.itl]
    return {
        "ttft_s": percentiles([r.ttft for r in reqs]),
        "tpot_s": percentiles([r.tpot for r in reqs]),
        "itl_s": percentiles(gaps) if gaps else {},
        "goodput_rps": len(good) / max(span, 1e-9),
        "slo_attainment": len(good) / len(reqs),
        "finish_span_s": float(span),
    }


def modeled_capacity_rps(lat: Latency, reqs, *, pods: int,
                         max_batch: int) -> float:
    """Rough cluster capacity: per-request pod occupancy at full batch."""
    mean_prompt = float(np.mean([r.prompt_len for r in reqs]))
    mean_new = float(np.mean([r.n_new for r in reqs]))
    ctx = int(mean_prompt + mean_new / 2)
    occupancy = (lat.prefill(int(mean_prompt))
                 + mean_new * lat.decode(max_batch, ctx) / max_batch)
    return pods / occupancy


def main():
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--pods", type=int, default=8,
                    help="total serving pods (disagg splits them)")
    ap.add_argument("--prefill-pods", type=int, default=1)
    ap.add_argument("--devices-per-pod", type=int, default=4,
                    help="model-parallel degree inside a pod")
    ap.add_argument("--requests", type=int, default=512)
    ap.add_argument("--load", type=float, nargs="*",
                    default=[0.3, 0.5, 0.7, 0.85],
                    help="arrival rates as fractions of modeled capacity")
    ap.add_argument("--qps", type=float, nargs="*", default=None,
                    help="absolute arrival rates (overrides --load)")
    ap.add_argument("--max-prompt", type=int, default=4096)
    ap.add_argument("--max-new", type=int, default=512)
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--slo-ttft", type=float, default=2.0)
    ap.add_argument("--slo-tpot", type=float, default=0.05)
    ap.add_argument("--measured", action="store_true",
                    help="price KV transfer with the calibrated "
                         "LINK_CONSTANTS.json instead of the nominal DCN "
                         "class (host-smoke calibrations are wildly "
                         "pessimistic, so the CI gate runs nominal)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=OUT_JSON)
    ap.add_argument("--check", action="store_true",
                    help="CHECK-SERVE gate: disagg wins p99 ITL and holds "
                         "goodput at the operating point (mid-sweep load)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    n_model = args.devices_per_pod
    lat = Latency(cfg, n_model)

    # KV transfer rides DCN.  ``--measured`` swaps in the calibrated
    # constants from the tracked LINK_CONSTANTS.json; the default (and the
    # CI gate) prices the nominal class so the result is deterministic
    # whatever the last calibration measured.
    link = plan_mod.DCN
    measured = bool(args.measured
                    and os.path.exists(plan_mod.DEFAULT_LINK_CONSTANTS_PATH))
    if measured:
        topo = plan_mod.Topology.hierarchical(
            ("data", "pod"), (2, 2)).with_measured()
        link = topo.link_classes[1]

    def transfer(prompt_len: int) -> float:
        return plan_mod.link_transfer_seconds(
            kv_payload_bytes(cfg, prompt_len), link)

    # derive the pod's KV token capacity from HBM (the block-pool budget)
    total, _ = costmodel.param_count(cfg)
    weight_bytes = total * 2 / n_model
    kv_tok = kv_payload_bytes(cfg, 1) / n_model
    kv_budget = 0.9 * HBM_PER_CHIP - weight_bytes
    cap_tokens = int(kv_budget / kv_tok)
    max_batch = min(args.max_batch,
                    max(1, cap_tokens // (args.max_prompt + args.max_new)))

    rng = np.random.default_rng(args.seed)
    base = sample_workload(rng, args.requests, max_prompt=args.max_prompt,
                           max_new=args.max_new)
    cap_rps = modeled_capacity_rps(lat, base, pods=args.pods,
                                   max_batch=max_batch)
    if args.qps:
        points = [(q, q / cap_rps) for q in args.qps]
    else:
        points = [(f * cap_rps, f) for f in args.load]
    print(f"[serve_sim] {cfg.name}: modeled capacity {cap_rps:.1f} rps "
          f"({args.pods} pods x {n_model} chips, max_batch {max_batch}, "
          f"KV capacity {cap_tokens} tokens/pod)")

    sweep = []
    for qps, loadf in points:
        reqs = [SimRequest(r.rid, r.t_arrive / qps, r.prompt_len, r.n_new)
                for r in base]
        colo = simulate(reqs, lat, pods=args.pods, prefill_pods=0,
                        max_batch=max_batch, transfer=transfer,
                        disaggregated=False)
        disagg = simulate(reqs, lat, pods=args.pods,
                          prefill_pods=args.prefill_pods,
                          max_batch=max_batch, transfer=transfer,
                          disaggregated=True)
        kw = dict(slo_ttft=args.slo_ttft, slo_tpot=args.slo_tpot)
        c, d = summarise(colo, **kw), summarise(disagg, **kw)
        win = c["itl_s"]["p99"] / max(d["itl_s"]["p99"], 1e-12)
        sweep.append({"qps": qps, "load": loadf, "colocated": c,
                      "disaggregated": d, "disagg_win_p99_itl": win})
        print(f"[serve_sim] load={loadf:.2f} ({qps:.1f} rps) | colo p99 itl "
              f"{c['itl_s']['p99']*1e3:.2f} ms ttft "
              f"{c['ttft_s']['p99']*1e3:.0f} ms goodput "
              f"{c['goodput_rps']:.1f} rps | disagg p99 itl "
              f"{d['itl_s']['p99']*1e3:.2f} ms ttft "
              f"{d['ttft_s']['p99']*1e3:.0f} ms goodput "
              f"{d['goodput_rps']:.1f} rps | win {win:.2f}x")

    op = sweep[len(sweep) // 2]
    report = {
        "arch": cfg.name,
        "pods": args.pods,
        "prefill_pods": args.prefill_pods,
        "devices_per_pod": n_model,
        "max_batch": max_batch,
        "kv_token_capacity_per_pod": cap_tokens,
        "modeled_capacity_rps": cap_rps,
        "dcn_link": {"name": link.name, "alpha": link.alpha,
                     "beta": link.beta, "measured": measured},
        "transfer_example_s": {str(n): transfer(n) for n in (1024, 4096)},
        "slo": {"ttft_s": args.slo_ttft, "tpot_s": args.slo_tpot},
        "requests": args.requests,
        "seed": args.seed,
        "sweep": sweep,
        "operating_point": {
            "qps": op["qps"],
            "load": op["load"],
            "disagg_win_p99_itl": op["disagg_win_p99_itl"],
            "goodput_colocated_rps": op["colocated"]["goodput_rps"],
            "goodput_disaggregated_rps":
                op["disaggregated"]["goodput_rps"],
        },
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"[serve_sim] wrote {args.out}")

    if args.check:
        opp = report["operating_point"]
        ok_itl = opp["disagg_win_p99_itl"] > 1.0
        ok_goodput = (opp["goodput_disaggregated_rps"]
                      >= 0.95 * opp["goodput_colocated_rps"])
        print("CHECK-SERVE", "PASS" if (ok_itl and ok_goodput) else "FAIL",
              f"(load={opp['load']:.2f}: disagg p99-ITL win "
              f"{opp['disagg_win_p99_itl']:.2f}x, goodput "
              f"{opp['goodput_disaggregated_rps']:.2f} vs "
              f"{opp['goodput_colocated_rps']:.2f} rps colocated)")
        if not (ok_itl and ok_goodput):
            sys.exit(1)


if __name__ == "__main__":
    main()
