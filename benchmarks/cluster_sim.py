"""Discrete-event cluster simulator — paper Fig. 4 / 7 / 10 analogue.

No multi-GPU cluster exists in this container, so throughput is reproduced
the way the paper's own roofline reasoning predicts it: per-iteration worker
compute times are sampled from the measured/imbalanced distributions (fixed
imagenet + 320 ms injected stragglers, Fig. 6-style log-normal for WMT,
heavy-tailed Fig. 9-style for RL), and each algorithm's synchronisation rule
decides who waits for whom:

    allreduce / local-sync : everyone waits for the slowest worker
    D-PSGD                 : wait for your 2 ring neighbours (sync clock)
    SGP                    : wait for your 1-2 graph peers
    AD-PSGD                : pairwise, no barrier (async)
    eager                  : global collective but stragglers contribute
                             stale grads — barrier over the fastest half
    WAGMA                  : wait for your *group* (size S), with the
                             wait-avoiding rule: a straggler does not block
                             the group (its stale buffer is used), so the
                             group advances at the group-median pace;
                             tau-periodic global barrier

Communication cost per step is added from the alpha-beta collective model
(core/group_allreduce.collective_bytes_per_device + per-launch latency) at
the paper's network bandwidth scale: every serial stage launches
``n_buckets`` collectives (one per flat bucket on the fused path, one per
pytree leaf on the unfused path), each paying LATENCY; payload bytes ride
LINK_BW.  ``bucketing_win`` sweeps the launch count to show why the
bucketed averager matters at scale.  Output: steps/hour vs P per algorithm.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.group_allreduce import (alpha_beta_time,
                                        collective_bytes_per_device,
                                        DEFAULT_ALPHA, DEFAULT_BETA,
                                        DEFAULT_GAMMA)
from repro.core import bucketing, grouping
from repro.core import plan as plan_mod
from repro.core.elastic import largest_pow2
from repro.core.faults import FaultSchedule
from repro.core.staleness import max_staleness_bound

LINK_BW = 1.0 / DEFAULT_BETA   # bytes/s per node (Piz Daint-scale Aries)
LATENCY = DEFAULT_ALPHA        # per collective launch
COMBINE_SPB = DEFAULT_GAMMA    # combine seconds/payload byte per stage


def compute_time_samples(rng, P, steps, workload: str):
    if workload == "imagenet":      # fixed-size + 2 injected 320ms stragglers
        base = rng.normal(0.30, 0.01, (steps, P))
        for t in range(steps):
            idx = rng.choice(P, 2, replace=False)
            base[t, idx] += 0.32
        return np.clip(base, 0.05, None)
    if workload == "wmt":           # paper Fig. 6: bucketed lengths, lognormal
        return np.clip(rng.lognormal(np.log(0.45), 0.35, (steps, P)), 0.1, 6.0)
    if workload == "rl":            # paper Fig. 9: 1.7s..43.5s, median ~2
        return np.clip(rng.lognormal(np.log(2.0), 0.8, (steps, P)), 1.7, 43.5)
    raise ValueError(workload)


def comm_time(n_bytes: float, P: int, S: int, algo: str, *,
              n_buckets: int = 1, gamma: float = 0.0,
              overlap: bool = False) -> float:
    """Alpha-beta collective time: stages x n_buckets x alpha + bytes x beta.

    ``n_buckets`` is the launch count per serial stage: 1-few for the
    bucketed fused averager, the pytree leaf count (hundreds) for the
    per-leaf path.  ``gamma`` adds the per-stage combine arithmetic and
    ``overlap=True`` runs it through the wavefront pipeline model
    (``max(wire, combine) + fill`` per stage, DESIGN.md §8).
    """
    wire = collective_bytes_per_device(n_bytes, P, max(S, 2), {
        "wagma": "wagma", "allreduce": "ring_allreduce",
        "local_sgd": "ring_allreduce", "dpsgd": "gossip", "sgp": "gossip",
        "adpsgd": "gossip", "eager": "ring_allreduce",
    }[algo])
    # true per-topology stage counts (sgp/adpsgd exchange with ONE peer per
    # step, unlike the symmetric 2-stage gossip of collective_stages)
    stages = {"wagma": grouping.ilog2(max(S, 2)),
              "allreduce": 2 * (P - 1), "local_sgd": 2 * (P - 1),
              "dpsgd": 2, "sgp": 1, "adpsgd": 1,
              "eager": 2 * (P - 1)}[algo]
    return alpha_beta_time(wire, stages, n_buckets=n_buckets,
                           alpha=LATENCY, beta=1.0 / LINK_BW,
                           gamma=gamma, overlap=overlap)


@dataclass
class SimResult:
    algo: str
    P: int
    steps_per_hour: float
    mean_wait_frac: float


def simulate(algo: str, P: int, *, model_bytes: float, workload: str,
             steps: int = 200, S=None, tau: int = 10, seed: int = 0,
             n_buckets: int = 1) -> SimResult:
    rng = np.random.default_rng(seed)
    S = S or grouping.default_group_size(P)
    comp = compute_time_samples(rng, P, steps, workload)
    tcomm_group = comm_time(model_bytes, P, S, algo, n_buckets=n_buckets)
    tcomm_global = comm_time(model_bytes, P, S, "allreduce",
                             n_buckets=n_buckets)

    clock = np.zeros(P)             # per-worker local time
    waited = 0.0
    for t in range(steps):
        finish = clock + comp[t]
        if algo in ("allreduce", "eager") or \
           (algo == "local_sgd" and (t + 1) % 1 == 0):
            if algo == "eager":
                # majority collective: barrier at the median worker
                bar = np.quantile(finish, 0.5)
                new = np.maximum(finish, bar) + tcomm_global
            else:
                bar = finish.max()
                new = np.full(P, bar + tcomm_global)
            waited += float(np.sum(new - finish))
            clock = new
        elif algo in ("dpsgd", "sgp"):
            # paper Table I: D-PSGD/SGP are *synchronous* decentralized —
            # "processes advance synchronously with a single global clock";
            # only the communication itself is neighbour-local (cheap).
            bar = finish.max()
            new = np.full(P, bar + tcomm_group)
            waited += float(np.sum(new - finish))
            clock = new
        elif algo == "adpsgd":
            # fully asynchronous pairwise: no wait, overlapped comm
            new = finish + tcomm_group * 0.3
            waited += float(np.sum(new - finish))
            clock = new
        elif algo == "wagma":
            if (t + 1) % tau == 0:
                bar = finish.max()
                new = np.full(P, bar + tcomm_global)
            else:
                # wait-avoiding: the fastest group member *activates* the
                # exchange and every member's current send buffer is used —
                # nobody blocks (stragglers contribute stale weights and
                # merge late, Alg. 2 line 13). The only throughput cost of a
                # group step is the butterfly itself; staleness is bounded
                # by the tau-periodic barrier above.
                new = finish + tcomm_group
            waited += float(np.sum(new - finish))
            clock = new
        else:
            raise ValueError(algo)

    total = clock.max()
    return SimResult(algo, P, steps / total * 3600.0,
                     waited / (P * total))


def bucketing_win(P: int = 64, *, model_bytes: float = 50e6,
                  workload: str = "wmt", n_leaves: int = 300,
                  n_buckets: int = 4, steps: int = 200) -> dict:
    """Steps/hour with per-leaf vs bucketed collective launches.

    Models the averaging refactor at cluster scale: identical payload bytes,
    but the per-leaf schedule pays ``n_leaves`` collective latencies per
    butterfly stage where the bucketed path pays ``n_buckets``.
    """
    leaf = simulate("wagma", P, model_bytes=model_bytes, workload=workload,
                    steps=steps, n_buckets=n_leaves)
    bucketed = simulate("wagma", P, model_bytes=model_bytes,
                        workload=workload, steps=steps, n_buckets=n_buckets)
    return {"per_leaf_steps_per_hour": leaf.steps_per_hour,
            "bucketed_steps_per_hour": bucketed.steps_per_hour,
            "speedup": bucketed.steps_per_hour / leaf.steps_per_hour}


def hierarchical_comm_time(model_bytes: float, topology, S: int, *,
                           tau: int = 10, overlap: bool = True,
                           bucket_bytes=None) -> float:
    """Per-step averaging seconds on a multi-link-class topology.

    Delegates to the compiled-plan cost model
    (``plan.modeled_wagma_step_seconds``): each butterfly stage pays its own
    link class's alpha/beta/gamma at that class's bucket budget
    (modeled-optimal per class unless ``bucket_bytes`` forces one global
    budget), tau-amortised with the bottleneck-class ring sync.
    """
    return plan_mod.modeled_wagma_step_seconds(
        int(model_bytes), topology, S, tau=tau, overlap=overlap,
        bucket_bytes=bucket_bytes)["step_s"]


def hierarchical_win(P: int = 64, *, model_bytes: float = 245e6, S=None,
                     n_pods: int = 4, tau: int = 10) -> dict:
    """Modeled win of per-link-class budgets on a pod-aware topology.

    Builds the 2-class (pod x data) topology — intra-pod bits ride ICI,
    inter-pod bits ride DCN — and compares the step time with each class at
    its own ``choose_class_bucket_bytes`` argmin against the same topology
    forced onto one global 32 MiB budget (the pre-plan behaviour), plus the
    flat single-class model as the paper-scale reference.
    """
    S = S or grouping.default_group_size(P)
    n_data = P // n_pods
    topo = plan_mod.Topology.hierarchical(
        ("data", "pod"), (n_data, n_pods), dcn_axes=("pod",))
    flat = plan_mod.Topology.flat(("data", "pod"), (n_data, n_pods))
    per_class = hierarchical_comm_time(model_bytes, topo, S, tau=tau)
    single = hierarchical_comm_time(
        model_bytes, topo, S, tau=tau,
        bucket_bytes=bucketing.DEFAULT_BUCKET_BYTES)
    flat_s = hierarchical_comm_time(model_bytes, flat, S, tau=tau)
    budgets = {
        name: ent["bucket_bytes"] for name, ent in
        plan_mod.modeled_wagma_step_seconds(
            int(model_bytes), topo, S, tau=tau)["per_class"].items()}
    return {"per_class_budget_comm_s": per_class,
            "single_budget_comm_s": single,
            "flat_topology_comm_s": flat_s,
            "class_budgets": budgets,
            "speedup": single / per_class}


def fsdp_win(P: int = 64, *, model_bytes: float = 245e6, n_pods: int = 4,
             tau: int = 10, opt_bytes_ratio: float = 2.0) -> dict:
    """Modeled memory + step-time effect of FSDP-within-pod (DESIGN.md §10).

    Replicas inside a pod share weights sharded over the intra-pod (ICI)
    axis and act as one logical WAGMA worker: persistent per-device
    param+opt memory divides by the pod size, the pod-to-pod butterfly
    moves only each device's shard slice (DCN traffic also ÷ pod size),
    and every step pays the per-bucket parameter all-gather + gradient
    reduce-scatter on ICI.  Compared against the replicated hierarchical
    plan on the same (pod x data) topology.
    """
    from repro.core import grouping as _grouping
    from repro.launch.costmodel import replica_memory_bytes

    n_data = P // n_pods
    topo = plan_mod.Topology.hierarchical(
        ("data", "pod"), (n_data, n_pods), dcn_axes=("pod",))
    S_rep = _grouping.default_group_size(P)
    S_eff = _grouping.default_group_size(n_pods)
    replicated = plan_mod.modeled_wagma_step_seconds(
        int(model_bytes), topo, S_rep, tau=tau)
    fsdp = plan_mod.modeled_fsdp_step_seconds(
        int(model_bytes), topo, S_eff, shard_axis="data", tau=tau)
    mem = replica_memory_bytes(model_bytes, pod_size=n_data,
                               opt_bytes_ratio=opt_bytes_ratio)
    return {
        "pod_size": n_data, "n_pods": n_pods,
        "replicated_step_s": replicated["step_s"],
        "fsdp_step_s": fsdp["step_s"],
        "gather_scatter_s": fsdp["gather_scatter_s"],
        "step_ratio": fsdp["step_s"] / max(replicated["step_s"], 1e-30),
        **mem,
    }


def overlap_win(P: int = 64, *, model_bytes: float = 50e6, S=None,
                n_buckets: int = 4, gamma: float = COMBINE_SPB) -> dict:
    """Modeled per-step win of the overlapped bucket pipeline (DESIGN §8).

    Same payload, same launch count — the serial schedule pays
    ``wire + combine`` per butterfly stage, the wavefront schedule pays
    ``max(wire, combine)`` plus pipeline fill/drain, hiding the combine
    behind the wire whenever there is more than one bucket in flight.
    """
    S = S or grouping.default_group_size(P)
    serial = comm_time(model_bytes, P, S, "wagma", n_buckets=n_buckets,
                       gamma=gamma, overlap=False)
    overlapped = comm_time(model_bytes, P, S, "wagma", n_buckets=n_buckets,
                           gamma=gamma, overlap=True)
    return {"serial_comm_s": serial, "overlapped_comm_s": overlapped,
            "combine_hidden_s": serial - overlapped,
            "speedup": serial / overlapped}


# ---------------------------------------------------------------------------
# Elastic churn (DESIGN.md §12)
# ---------------------------------------------------------------------------

def churn_scenario(P: int = 64, *, model_bytes: float = 245e6,
                   workload: str = "wmt", steps: int = 3000, tau: int = 10,
                   S=None, mean_uptime_steps: float = 20000.0,
                   rejoin_delay_steps: float = 25.0, seed: int = 0,
                   recompile_s: float = 8.0, host_bw: float = 10e9,
                   restart_s: float = 120.0,
                   checkpoint_period_steps: int = 100) -> dict:
    """Preemption churn: elastic membership vs checkpoint-restart.

    A Poisson preemption process (each healthy worker fails with
    probability ``1/mean_uptime_steps`` per step, preempted workers
    return after an exponential ``rejoin_delay_steps``) drives ONE shared
    healthy-count trajectory, quantised to the butterfly's power-of-two
    world; both recovery policies replay it:

    * **elastic** (this repo's §12 protocol): a leave shrinks the world
      in place — pay one plan recompile plus the host-side state handoff
      (3x model bytes through host memory: params + two moment trees);
      rejoins regrow at the next tau-sync barrier, where the joiner
      clones the consensus over the wire.  No work is lost.
    * **restart** (the classical baseline): every world change is a full
      job restart — scheduler + init + compile ``restart_s``, plus
      recomputing the steps since the last periodic checkpoint.

    Goodput is worker-steps per wall-clock second (data-parallel sample
    throughput).  The CI gate bounds the elastic overhead fraction and
    requires elastic goodput to beat restart goodput.
    """
    rng = np.random.default_rng(seed)
    S = S or grouping.default_group_size(P)
    comp = compute_time_samples(rng, P, steps, workload)
    handoff_s = 3.0 * model_bytes / host_bw + model_bytes / LINK_BW
    _comm_cache: dict = {}

    def comm(w, kind):
        if (w, kind) not in _comm_cache:
            algo = "wagma" if kind == "group" else "allreduce"
            _comm_cache[(w, kind)] = comm_time(
                model_bytes, w, max(2, min(S, w)), algo, n_buckets=4)
        return _comm_cache[(w, kind)]

    # -- one shared world trajectory: healthy count -> pow2 active world --
    h = P
    returns: list = []
    active = largest_pow2(P)
    worlds = np.zeros(steps, np.int64)
    changes = []                       # (t, kind) world-change events
    n_preemptions = 0
    for t in range(steps):
        back = [r for r in returns if r <= t]
        returns = [r for r in returns if r > t]
        h += len(back)
        k = int(rng.binomial(h, 1.0 / mean_uptime_steps))
        if k:
            n_preemptions += k
            h = max(h - k, 2)          # the scheduler floor (min_world)
            returns.extend(t + 1 + rng.exponential(rejoin_delay_steps)
                           for _ in range(k))
        if largest_pow2(h) < active:
            active = max(2, largest_pow2(h))
            changes.append((t, "shrink"))
        elif (t + 1) % tau == 0 and largest_pow2(h) > active:
            # joins wait for the tau-sync barrier (zero-staleness adopt)
            active = largest_pow2(h)
            changes.append((t, "regrow"))
        worlds[t] = active

    def step_seconds(t, w):
        if (t + 1) % tau == 0:
            return comp[t, :w].max() + comm(w, "global")
        return comp[t, :w].mean() + comm(w, "group")

    base = np.array([step_seconds(t, int(worlds[t])) for t in range(steps)])
    work = float(worlds.sum())         # worker-steps of useful gradient work
    change_steps = {t: kind for t, kind in changes}

    # -- elastic: in-place recompile + handoff per change, no lost work --
    el_overhead = len(changes) * (recompile_s + handoff_s)
    el_wall = float(base.sum()) + el_overhead

    # -- restart: full restart + recompute since the last checkpoint --
    rs_wall = 0.0
    rs_overhead = 0.0
    for t in range(steps):
        if t in change_steps:
            lost = (t % checkpoint_period_steps) * float(base[:t].mean()
                                                         if t else 0.0)
            rs_overhead += restart_s + lost
        rs_wall += base[t]
    rs_wall += rs_overhead

    ideal_wall = float(np.array([step_seconds(t, P)
                                 for t in range(steps)]).sum())
    return {
        "P": P, "steps": steps, "tau": tau,
        "n_preemptions": n_preemptions,
        "n_world_changes": len(changes),
        "n_shrinks": sum(1 for _, k in changes if k == "shrink"),
        "n_regrows": sum(1 for _, k in changes if k == "regrow"),
        "min_world": int(worlds.min()), "mean_world": float(worlds.mean()),
        "recompile_s": recompile_s, "handoff_s": handoff_s,
        "elastic_overhead_s": el_overhead,
        "elastic_overhead_frac": el_overhead / el_wall,
        "restart_overhead_s": rs_overhead,
        "restart_overhead_frac": rs_overhead / rs_wall,
        "elastic_goodput": work / el_wall,
        "restart_goodput": work / rs_wall,
        "ideal_goodput": steps * P / ideal_wall,
        "goodput_speedup": (work / el_wall) / (work / rs_wall),
    }


def degraded_mode_scenario(P: int = 64, *, model_bytes: float = 245e6,
                           steps: int = 600, tau: int = 10, S=None,
                           seed: int = 0, straggler_ms: float = 320.0,
                           n_stragglers: int = 2,
                           collective_deadline_s: float = 0.05,
                           base_compute_s: float = 0.30,
                           jitter_s: float = 0.01) -> dict:
    """Degraded-mode rounds vs wait-for-all under the §V-B straggler trace.

    The same seeded `core.faults.FaultSchedule` the chaos tests replay —
    every step, ``n_stragglers`` workers finish ``straggler_ms`` late —
    is played against two synchronisation rules:

    * **wait-for-all** (synchronous allreduce): every step waits for the
      slowest worker, so each round eats the full 320 ms.
    * **degraded mode** (this PR's §13 execution rule): a group round
      waits at most the collective deadline for a late partner, then
      proceeds with the survivors — the straggler's contribution goes
      stale and is charged one round of staleness, repaid at the
      tau-sync barrier (which, per the paper, still waits for everyone).

    Staleness stays within ``max_staleness_bound(tau)`` by construction
    (the barrier resets every age); the CHECK-CHAOS gate requires the
    degraded-mode goodput to beat wait-for-all.
    """
    rng = np.random.default_rng(seed)
    S = S or grouping.default_group_size(P)
    schedule = FaultSchedule.straggler_trace(
        P, steps, ms=straggler_ms, n_stragglers=n_stragglers, seed=seed)
    comp = np.clip(rng.normal(base_compute_s, jitter_s, (steps, P)),
                   0.05, None)
    t_group = comm_time(model_bytes, P, max(2, min(S, P)), "wagma",
                        n_buckets=4)
    t_global = comm_time(model_bytes, P, max(2, min(S, P)), "allreduce",
                         n_buckets=4)

    ages = np.zeros(P, np.int64)
    peak_age = 0
    skipped = 0
    waitall_wall = 0.0
    degraded_wall = 0.0
    for t in range(steps):
        delays = schedule.delays_at(t)
        finish = comp[t].copy()
        for w, d in delays.items():
            finish[w] += d
        waitall_wall += finish.max() + t_global
        if (t + 1) % tau == 0:
            # the tau-sync barrier waits for everyone; all ages repay
            degraded_wall += finish.max() + t_global
            ages[:] = 0
        else:
            late = [w for w, d in delays.items()
                    if d > collective_deadline_s]
            on_time = np.ones(P, bool)
            on_time[late] = False
            wait = collective_deadline_s if late else 0.0
            degraded_wall += comp[t][on_time].mean() + wait + t_group
            skipped += len(late)
            ages[on_time] = 0
            for w in late:
                ages[w] += 1
                peak_age = max(peak_age, int(ages[w]))

    work = float(P * steps)   # every contribution is used, some stale
    return {
        "P": P, "steps": steps, "tau": tau, "S": S,
        "straggler_ms": straggler_ms, "n_stragglers": n_stragglers,
        "collective_deadline_s": collective_deadline_s,
        "schedule_fingerprint": schedule.fingerprint(),
        "skipped_contributions": skipped,
        "peak_staleness_age": peak_age,
        "staleness_bound": max_staleness_bound(tau),
        "staleness_bounded": peak_age <= max_staleness_bound(tau),
        "waitall_step_s": waitall_wall / steps,
        "degraded_step_s": degraded_wall / steps,
        "waitall_goodput": work / waitall_wall,
        "degraded_goodput": work / degraded_wall,
        "goodput_speedup": waitall_wall / degraded_wall,
    }


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--churn", action="store_true",
                    help="run the elastic-vs-restart churn gate")
    ap.add_argument("--degraded", action="store_true",
                    help="run the degraded-mode vs wait-for-all gate")
    ap.add_argument("--P", type=int, default=64)
    ap.add_argument("--steps", type=int, default=None,
                    help="simulated steps (default: 100 for the algo "
                    "table, 3000 for --churn)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-overhead-frac", type=float, default=0.10,
                    help="gate: elastic overhead fraction bound")
    args = ap.parse_args(argv)

    if args.degraded:
        rep = degraded_mode_scenario(args.P, steps=args.steps or 600,
                                     seed=args.seed)
        print(f"degraded-mode (§V-B trace {rep['schedule_fingerprint']}): "
              f"{rep['skipped_contributions']} skipped contributions, "
              f"peak staleness {rep['peak_staleness_age']} <= "
              f"{rep['staleness_bound']}")
        print(f"wait-for-all {rep['waitall_step_s']*1e3:7.1f} ms/step "
              f"({rep['waitall_goodput']:.1f} worker-steps/s)")
        print(f"degraded     {rep['degraded_step_s']*1e3:7.1f} ms/step "
              f"({rep['degraded_goodput']:.1f} worker-steps/s)")
        ok = rep["goodput_speedup"] > 1.0 and rep["staleness_bounded"]
        print(f"CHECK-DEGRADED {'PASS' if ok else 'FAIL'}: "
              f"degraded/wait-for-all goodput "
              f"{rep['goodput_speedup']:.2f}x, staleness bounded: "
              f"{rep['staleness_bounded']}")
        return 0 if ok else 1

    if not args.churn:
        for algo in ("allreduce", "dpsgd", "adpsgd", "eager", "wagma"):
            r = simulate(algo, args.P, model_bytes=50e6, workload="wmt",
                         steps=args.steps or 100, seed=args.seed,
                         n_buckets=4)
            print(f"{algo:>10s}  {r.steps_per_hour:9.1f} steps/h  "
                  f"wait {r.mean_wait_frac:5.1%}")
        return 0

    rep = churn_scenario(args.P, steps=args.steps or 3000, seed=args.seed)
    print(f"churn: {rep['n_preemptions']} preemptions -> "
          f"{rep['n_shrinks']} shrinks + {rep['n_regrows']} regrows, "
          f"world {rep['min_world']}..{rep['P']} "
          f"(mean {rep['mean_world']:.1f})")
    print(f"elastic: overhead {rep['elastic_overhead_s']:8.1f}s "
          f"({rep['elastic_overhead_frac']:5.1%}), goodput "
          f"{rep['elastic_goodput']:.1f} worker-steps/s")
    print(f"restart: overhead {rep['restart_overhead_s']:8.1f}s "
          f"({rep['restart_overhead_frac']:5.1%}), goodput "
          f"{rep['restart_goodput']:.1f} worker-steps/s")
    ok_bounded = rep["elastic_overhead_frac"] < args.max_overhead_frac
    ok_beats = rep["goodput_speedup"] > 1.0
    print(f"CHECK-CHURN {'PASS' if ok_bounded and ok_beats else 'FAIL'}: "
          f"overhead {rep['elastic_overhead_frac']:.1%} "
          f"{'<' if ok_bounded else '>='} {args.max_overhead_frac:.0%}, "
          f"elastic/restart goodput {rep['goodput_speedup']:.2f}x")
    return 0 if (ok_bounded and ok_beats) else 1


if __name__ == "__main__":
    raise SystemExit(main())
