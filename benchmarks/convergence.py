"""Convergence benchmark — paper Fig. 5 / Fig. 8 analogue + ablations 1-4.

Trains a small LM on the learnable synthetic task with P=16 simulated
workers under injected stragglers (2/iteration, paper §V-B), using the
*stacked* simulator so every variant runs the exact gossip matrix of the
algorithm (true directed-exponential SGP etc. — baselines.mixing_matrix).

Validates the paper's claims at laptop scale:
    1. WAGMA ~= Allreduce/local-SGD(H=1) final quality     (Fig. 5)
    2. ablation 1: tau-periodic local SGD w/o group avg is clearly worse
    3. ablation 2: FIXED groups worse than dynamic groups
    4. ablation 3: S=P (global) no better than S=sqrt(P), costs more comm
    5. ablation 4: S too small (2) worse than S=sqrt(P)
    6. gossip (D-PSGD / AD-PSGD-style pairwise) trails WAGMA

Emits CSV rows: variant, final_loss, mean_last10, comm_bytes_per_step.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.core import grouping, staleness
from repro.core.baselines import mixing_matrix
from repro.core.group_allreduce import collective_bytes_per_device
from repro.data import make_batch_fn
from repro.models.registry import build_model
from repro.optim import sgd

P, TAU, STEPS, LR, SEQ, LOCAL_B = 16, 10, 120, 0.4, 48, 2


def tiny_cfg() -> ModelConfig:
    return ModelConfig(name="bench-lm", family="dense", n_layers=2,
                       d_model=96, n_heads=4, n_kv_heads=2, d_ff=192,
                       vocab=256, dtype="float32")


def run_variant(name: str, *, S=None, dynamic=True, use_groups=True,
                stragglers=True, seed=0):
    cfg = tiny_cfg()
    model = build_model(cfg)
    opt = sgd(LR, momentum=0.9)
    params0 = model.init(jax.random.PRNGKey(seed))
    stacked = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (P,) + a.shape).astype(a.dtype),
        params0)
    opt_states = jax.vmap(opt.init)(stacked)
    state = staleness.init_state(stacked)
    shape = InputShape("bench", SEQ, P * LOCAL_B, "train")
    bf = make_batch_fn(cfg, shape, seed=seed)
    strag = staleness.StragglerModel(P, n_stragglers=2 if stragglers else 0,
                                     p_stall=0.25, seed=seed)
    S = S or grouping.default_group_size(P)

    def per_worker(p, st, tokens, labels):
        loss, g = jax.value_and_grad(
            lambda q: model.loss(q, {"tokens": tokens, "labels": labels})[0]
        )(p)
        newp, newst = opt.update(g, st, p)
        return newp, newst, loss

    upd = jax.jit(jax.vmap(per_worker))
    holder = {"opt": opt_states}
    losses = []

    for t in range(STEPS):
        nb = bf(t, 0, P * LOCAL_B)
        toks = jnp.asarray(nb["tokens"]).reshape(P, LOCAL_B, -1)
        labs = jnp.asarray(nb["labels"]).reshape(P, LOCAL_B, -1)

        def local_update(models):
            newp, newst, loss = upd(models, holder["opt"], toks, labs)
            holder["opt"] = newst
            holder["loss"] = loss
            return newp

        ready, completes = strag.sample()
        if name == "wagma":
            t_eff = t if dynamic else 0
            if use_groups:
                state = staleness.wagma_sim_step(
                    state, local_update, P=P, S=S, tau=TAU, ready=ready,
                    completes=completes, t=t_eff)
            else:   # ablation 1: only the tau-periodic sync
                newp = local_update(state.models)
                A = mixing_matrix("local_sgd", P, t, sync_period=TAU)
                newp = _mix(newp, A)
                state = state._replace(models=newp)
        else:
            newp = local_update(state.models)
            A = mixing_matrix(name, P, t, S=S, sync_period=1)
            newp = _mix(newp, A)
            state = state._replace(models=newp)
        losses.append(float(holder["loss"].mean()))
    return losses


def _mix(stacked, A):
    Aj = jnp.asarray(A)

    def mix_leaf(w):
        flat = w.reshape(P, -1).astype(jnp.float32)
        return (Aj @ flat).reshape(w.shape).astype(w.dtype)

    return jax.tree.map(mix_leaf, stacked)


def comm_bytes(name: str, S: int, model_bytes: float) -> float:
    algo = {"wagma": "wagma", "allreduce": "ring_allreduce",
            "local_sgd": "ring_allreduce", "dpsgd": "gossip",
            "sgp": "gossip", "adpsgd": "gossip"}.get(name, "wagma")
    b = collective_bytes_per_device(model_bytes, P, S, algo)
    if name == "local_sgd":
        b /= TAU
    return b


# (display, run_variant name, kwargs)
VARIANTS = [
    ("allreduce", "allreduce", {}),
    ("wagma", "wagma", {}),
    ("wagma_fixed_groups", "wagma", {"dynamic": False}),     # ablation 2
    ("wagma_S=P", "wagma", {"S": P}),                        # ablation 3
    ("wagma_S=2", "wagma", {"S": 2}),                        # ablation 4
    ("local_sgd_tau_only", "wagma", {"use_groups": False}),  # ablation 1
    ("dpsgd", "dpsgd", {}),
    ("sgp", "sgp", {}),
    ("adpsgd", "adpsgd", {}),
]


def main(seeds=(0,)):
    cfg = tiny_cfg()
    model_bytes = 4.0 * sum(
        int(np.prod(l.shape)) for l in jax.tree.leaves(
            jax.eval_shape(build_model(cfg).init, jax.random.PRNGKey(0))))
    rows = []
    for disp, name, kw in VARIANTS:
        finals = []
        for seed in seeds:
            ls = run_variant(name, seed=seed, **kw)
            finals.append(np.mean(ls[-10:]))
        S = kw.get("S", grouping.default_group_size(P))
        rows.append((disp, float(np.mean(finals)),
                     comm_bytes(name, S, model_bytes)))
        print(f"{disp:22s} mean(last10 loss)={rows[-1][1]:.4f} "
              f"comm/step={rows[-1][2]/1e6:.2f}MB", flush=True)

    by = {r[0]: r[1] for r in rows}
    checks = {
        "wagma ~= allreduce (<=3% gap)":
            by["wagma"] <= by["allreduce"] * 1.03,
        "ablation1 local-sgd-tau worse": by["local_sgd_tau_only"] > by["wagma"],
        "ablation2 fixed groups worse": by["wagma_fixed_groups"] >= by["wagma"] * 0.999,
        "ablation4 S=2 worse": by["wagma_S=2"] >= by["wagma"] * 0.999,
        "gossip dpsgd trails": by["dpsgd"] >= by["wagma"] * 0.999,
    }
    for k, v in checks.items():
        print(f"  [{'ok' if v else 'FAIL'}] {k}")
    return rows, checks


if __name__ == "__main__":
    main()
