"""Benchmark harness — one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:

  fig4_throughput_imagenet   cluster-sim steps/hour @P=256, derived = WAGMA
                             speedup over local SGD        (paper Fig. 4)
  fig7_throughput_wmt        same for the WMT workload     (paper Fig. 7)
  fig10_throughput_rl        same for the RL workload, P=1024 (paper Fig. 10)
  fig5_convergence_*         final-loss per SGD variant + ablations 1-4
                             (paper Fig. 5 / §V-B experiments)
  micro_group_allreduce      measured wall-time of the 8-device butterfly
                             group-average vs global psum (25.6M params,
                             ResNet-50-sized payload)      (paper §III)
  table1_collective_bytes    per-device bytes/step per algorithm for the
                             paper's three models           (paper Table I/§VI)
"""

from __future__ import annotations

import os
import subprocess
import sys
import time

import numpy as np

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def row(name: str, us: float, derived):
    print(f"{name},{us:.1f},{derived}", flush=True)


def bench_throughput():
    from benchmarks.cluster_sim import simulate
    model_bytes = {"imagenet": 25.56e6 * 4, "wmt": 61.36e6 * 4,
                   "rl": 8.48e6 * 4}
    setups = [("fig4_throughput_imagenet", "imagenet", 256),
              ("fig7_throughput_wmt", "wmt", 64),
              ("fig10_throughput_rl", "rl", 1024)]
    for name, wl, Pmax in setups:
        res = {}
        for algo in ("allreduce", "local_sgd", "dpsgd", "sgp", "adpsgd",
                     "eager", "wagma"):
            res[algo] = simulate(algo, Pmax, model_bytes=model_bytes[wl],
                                 workload=wl, steps=120)
        wag = res["wagma"].steps_per_hour
        base = res["local_sgd"].steps_per_hour
        us_per_step = 3600e6 / wag
        row(name, us_per_step, f"wagma_speedup_vs_localsgd={wag/base:.2f}x")
        for algo, r in res.items():
            row(f"  {name}.{algo}", 3600e6 / r.steps_per_hour,
                f"steps_per_hour={r.steps_per_hour:.1f}")


def bench_convergence():
    from benchmarks import convergence
    t0 = time.time()
    rows, checks = convergence.main()
    per = (time.time() - t0) * 1e6 / len(rows)
    for disp, loss, comm in rows:
        row(f"fig5_convergence_{disp}", per,
            f"final_loss={loss:.4f};comm_MB_per_step={comm/1e6:.2f}")
    row("fig5_claims_validated", 0.0,
        f"{sum(checks.values())}/{len(checks)}")


def bench_group_allreduce_micro():
    """Measured butterfly vs global allreduce on 8 forced-host devices."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, time
sys.path.insert(0, %r)
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core.wagma import WagmaAverager, WagmaConfig
from repro.core.group_allreduce import dp_axis_layout

mesh = jax.make_mesh((8,), ("data",))
names, sizes = dp_axis_layout(("data",), {"data": 8}, ("data",))
av = WagmaAverager(names, sizes, WagmaConfig(group_size=2))
N = 25_559_081 // 8  # ResNet-50 params, model-sharded 8-way
x = {"w": jnp.zeros((8, N), jnp.float32)}
group = jax.jit(compat.shard_map(lambda t: av.comm(t, 0), mesh=mesh,
                in_specs=P("data"), out_specs=P("data"), axis_names={"data"}))
glob = jax.jit(compat.shard_map(av.sync, mesh=mesh,
               in_specs=P("data"), out_specs=P("data"), axis_names={"data"}))
for f in (group, glob):
    f(x)["w"].block_until_ready()
def t(f, n=10):
    t0 = time.time()
    for _ in range(n):
        out = f(x)
    out["w"].block_until_ready()
    return (time.time() - t0) / n * 1e6
print(f"RESULT,{t(group):.1f},{t(glob):.1f}")
"""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script % (ROOT + "/src",)],
                         capture_output=True, text=True, env=env, timeout=300)
    for line in out.stdout.splitlines():
        if line.startswith("RESULT"):
            _, g, a = line.split(",")
            row("micro_group_allreduce_S2", float(g),
                f"global_psum_us={a};saving={float(a)/float(g):.2f}x")
            return
    row("micro_group_allreduce_S2", -1.0,
        f"subprocess_failed:{out.stderr[-200:]}")


def bench_collective_model():
    from repro.core.group_allreduce import collective_bytes_per_device
    models = {"resnet50": 25.56e6 * 4, "transformer": 61.36e6 * 4,
              "resnet_lstm": 8.48e6 * 4}
    for mname, nbytes in models.items():
        for P_ in (64, 1024):
            S = int(np.sqrt(P_))
            w = collective_bytes_per_device(nbytes, P_, S, "wagma")
            r = collective_bytes_per_device(nbytes, P_, S, "ring_allreduce")
            b = collective_bytes_per_device(nbytes, P_, S, "butterfly_global")
            row(f"table1_collective_bytes_{mname}_P{P_}", 0.0,
                f"wagma_MB={w/1e6:.1f};ring_MB={r/1e6:.1f};"
                f"butterfly_global_MB={b/1e6:.1f}")


def main() -> None:
    print("name,us_per_call,derived")
    bench_collective_model()
    bench_group_allreduce_micro()
    bench_throughput()
    bench_convergence()


if __name__ == "__main__":
    main()
