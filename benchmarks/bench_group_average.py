"""Microbenchmark: per-leaf vs bucketed vs bucketed+Pallas group averaging.

Measures the tentpole claim of the bucketed averaging subsystem on an 8-way
forced-host-device CPU mesh:

* **ppermute launches** per averaging step (traced from the jaxpr) drop from
  ``n_leaves * log2(S)`` to ``n_buckets * log2(S)``;
* wall time per step for the three realisations of the same math:
  per-leaf reference, bucketed + jnp combine, bucketed + fused Pallas
  combine (interpret mode off-TPU, so CPU timings measure the bucketing
  launch saving, not the kernel — run on a TPU backend for the HBM-floor
  combine numbers);
* the alpha-beta model's prediction for the same launch counts at cluster
  scale (LINK_BW/LATENCY from benchmarks/cluster_sim.py).

Usage:  python benchmarks/bench_group_average.py [--layers 24] [--d 512]
"""

import argparse
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import bucketing, grouping
from repro.core import group_allreduce as ga
from repro.launch.hlo_analysis import count_ppermutes


def transformer_like_tree(rng, n_dp: int, layers: int, d: int):
    """A params pytree with realistic leaf-count structure (per dp replica)."""
    tree = {"emb": jnp.asarray(rng.normal(size=(n_dp, 4 * d, d)) * 0.02,
                               jnp.float32)}
    for i in range(layers):
        tree[f"blk{i}"] = {
            "wq": jnp.asarray(rng.normal(size=(n_dp, d, d)), jnp.float32),
            "wk": jnp.asarray(rng.normal(size=(n_dp, d, d)), jnp.float32),
            "wv": jnp.asarray(rng.normal(size=(n_dp, d, d)), jnp.float32),
            "wo": jnp.asarray(rng.normal(size=(n_dp, d, d)), jnp.float32),
            "w1": jnp.asarray(rng.normal(size=(n_dp, d, 4 * d)), jnp.float32),
            "w2": jnp.asarray(rng.normal(size=(n_dp, 4 * d, d)), jnp.float32),
            "ln1": jnp.asarray(rng.normal(size=(n_dp, d)), jnp.float32),
            "ln2": jnp.asarray(rng.normal(size=(n_dp, d)), jnp.float32),
        }
    return tree


def bench(fn, tree, iters: int) -> float:
    out = jax.block_until_ready(fn(tree))          # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(tree))
    del out
    return (time.perf_counter() - t0) / iters


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--layers", type=int, default=12)
    ap.add_argument("--d", type=int, default=256)
    ap.add_argument("--S", type=int, default=4)
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--bucket-mb", type=int, default=32)
    args = ap.parse_args()

    n_dp, S = 8, args.S
    mesh = jax.make_mesh((n_dp,), ("data",))
    names, sizes = ga.dp_axis_layout(("data",), {"data": n_dp}, ("data",))
    rng = np.random.default_rng(0)
    tree = transformer_like_tree(rng, n_dp, args.layers, args.d)

    local = jax.tree.map(lambda a: a[:1], tree)
    n_leaves = len(jax.tree.leaves(tree))
    bucket_bytes = args.bucket_mb * 1024 * 1024
    layout = bucketing.layout_for(local, max_bucket_bytes=bucket_bytes)
    stages = grouping.ilog2(S)
    payload = sum(l.size * l.dtype.itemsize
                  for l in jax.tree.leaves(local))

    variants = {
        "per_leaf": dict(fused=False),
        "bucketed_jnp": dict(fused=True, use_pallas=False),
        "bucketed_pallas": dict(fused=True, use_pallas=True),
    }
    print(f"tree: {n_leaves} leaves, {payload / 1e6:.1f} MB/replica; "
          f"S={S} ({stages} butterfly stages); "
          f"layout: {layout.n_buckets} buckets {layout.describe()}")

    results = {}
    for name, kw in variants.items():
        f = jax.jit(compat.shard_map(
            lambda tr, kw=kw: ga.group_average(
                tr, offset=0, P=n_dp, S=S, axis_names=names, axis_sizes=sizes,
                average_dtype=jnp.float32, bucket_bytes=bucket_bytes, **kw),
            mesh=mesh, in_specs=P("data"), out_specs=P("data"),
            axis_names={"data"}))
        n_pp = count_ppermutes(jax.make_jaxpr(f)(tree).jaxpr)
        dt = bench(f, tree, args.iters)
        results[name] = (n_pp, dt)
        print(f"{name:16s} ppermutes/step {n_pp:5d}   wall {dt * 1e3:8.2f} ms")

    n_pp_leaf = results["per_leaf"][0]
    n_pp_fused = results["bucketed_pallas"][0]
    assert n_pp_leaf == n_leaves * stages
    assert n_pp_fused == layout.n_buckets * stages
    print(f"ppermute launches: {n_leaves} x log2(S) -> "
          f"{layout.n_buckets} x log2(S)  "
          f"({n_pp_leaf} -> {n_pp_fused}, {n_pp_leaf / n_pp_fused:.1f}x fewer)")

    # alpha-beta prediction at cluster scale (same launch counts)
    from cluster_sim import comm_time
    t_leaf = comm_time(payload, 64, S, "wagma", n_buckets=n_leaves)
    t_fused = comm_time(payload, 64, S, "wagma", n_buckets=layout.n_buckets)
    print(f"alpha-beta model @ P=64: per-leaf {t_leaf * 1e3:.2f} ms/step, "
          f"bucketed {t_fused * 1e3:.2f} ms/step "
          f"({t_leaf / t_fused:.1f}x)")


if __name__ == "__main__":
    main()
