"""Microbenchmark: per-leaf vs bucketed (serial) vs overlapped group averaging.

Measures the tentpole claims of the bucketed averaging subsystem on an 8-way
forced-host-device CPU mesh:

* **ppermute launches** per averaging step (traced from the jaxpr) drop from
  ``n_leaves * log2(S)`` to ``n_buckets * log2(S)`` — and stay there under
  the overlapped wavefront schedule (overlap reorders, never multiplies);
* wall time per step for the four realisations of the same math:
  per-leaf reference, bucketed + jnp combine, bucketed + fused Pallas
  combine, bucketed + overlapped pipeline (interpret mode off-TPU, so CPU
  timings measure the bucketing/launch saving, not the kernel — run on a
  TPU backend for the HBM-floor combine numbers);
* the alpha-beta-gamma model's prediction at cluster scale for the
  transformer_wmt config (the paper's own model): serial-bucketed step time
  (``wire + combine`` per stage, fixed 32 MiB budget) vs overlapped step
  time (``max(wire, combine) + fill`` at the modeled-optimal budget from
  ``bucketing.choose_bucket_bytes``).

Results land in ``BENCH_group_average.json`` at the repo root so the perf
trajectory is machine-trackable PR over PR.

A second modeled section covers the **hierarchical (2-link-class) topology**
(DESIGN.md §9): intra-pod butterfly stages priced at ICI constants, inter-pod
stages at DCN constants, each link class at its own
``plan.choose_class_bucket_bytes`` budget — recorded next to the same
topology forced onto one global 32 MiB budget and the flat-topology model.

Usage:
    python benchmarks/bench_group_average.py [--layers 24] [--d 512]
    python benchmarks/bench_group_average.py --check      # model-only, fast;
        exits non-zero unless overlapped < serial for transformer_wmt AND
        the hierarchical per-class budgets beat the single global budget
        with distinct per-class choices
"""

import argparse
import json
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import bucketing, grouping
from repro.core import group_allreduce as ga
from repro.launch.hlo_analysis import count_ppermutes

OUT_JSON = os.path.join(_ROOT, "BENCH_group_average.json")


def transformer_like_tree(rng, n_dp: int, layers: int, d: int):
    """A params pytree with realistic leaf-count structure (per dp replica)."""
    tree = {"emb": jnp.asarray(rng.normal(size=(n_dp, 4 * d, d)) * 0.02,
                               jnp.float32)}
    for i in range(layers):
        tree[f"blk{i}"] = {
            "wq": jnp.asarray(rng.normal(size=(n_dp, d, d)), jnp.float32),
            "wk": jnp.asarray(rng.normal(size=(n_dp, d, d)), jnp.float32),
            "wv": jnp.asarray(rng.normal(size=(n_dp, d, d)), jnp.float32),
            "wo": jnp.asarray(rng.normal(size=(n_dp, d, d)), jnp.float32),
            "w1": jnp.asarray(rng.normal(size=(n_dp, d, 4 * d)), jnp.float32),
            "w2": jnp.asarray(rng.normal(size=(n_dp, 4 * d, d)), jnp.float32),
            "ln1": jnp.asarray(rng.normal(size=(n_dp, d)), jnp.float32),
            "ln2": jnp.asarray(rng.normal(size=(n_dp, d)), jnp.float32),
        }
    return tree


def bench(fn, tree, iters: int) -> float:
    out = jax.block_until_ready(fn(tree))          # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(tree))
    del out
    return (time.perf_counter() - t0) / iters


def modeled_transformer_wmt(*, P_cluster: int = 64, tau: int = 10) -> dict:
    """Alpha-beta-gamma model for the paper's WMT transformer at scale.

    Serial baseline: fixed 32 MiB budget, per-stage ``wire + combine``.
    Overlapped: modeled-optimal budget, per-stage ``max(wire, combine)``
    plus pipeline fill/drain (core/overlap.py wavefront schedule).  The
    modeling itself is ``costmodel.averaging_comm_cost`` — this function
    only supplies the exact payload/leaf count from the real model's
    ``eval_shape`` and reshapes the CommReport into the tracked JSON.
    """
    from repro.configs import get_config
    from repro.launch.costmodel import averaging_comm_cost
    from repro.models.registry import build_model

    cfg = get_config("transformer-wmt")
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    n_leaves = len(jax.tree.leaves(
        shapes, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)))
    payload = bucketing.tree_payload_bytes(shapes)   # exact, real dtypes
    S = grouping.default_group_size(P_cluster)
    stages = grouping.ilog2(S)

    rep = averaging_comm_cost(cfg, P=P_cluster, S=S, tau=tau,
                              n_leaves=n_leaves, payload_bytes=payload)
    return {
        "config": cfg.name,
        "P": P_cluster, "S": S, "tau": tau,
        "payload_bytes": payload, "n_leaves": n_leaves,
        "alpha_s": ga.DEFAULT_ALPHA, "beta_s_per_byte": ga.DEFAULT_BETA,
        "gamma_s_per_byte": ga.DEFAULT_GAMMA,
        "serial": {"bucket_bytes": bucketing.DEFAULT_BUCKET_BYTES,
                   "n_buckets": rep.n_buckets,
                   "launches_per_group_step": rep.n_buckets * stages,
                   "modeled_step_s": rep.t_serial_gamma},
        "overlapped": {"bucket_bytes": rep.chosen_bucket_bytes,
                       "n_buckets": rep.n_buckets_overlapped,
                       "launches_per_group_step":
                           rep.n_buckets_overlapped * stages,
                       "modeled_step_s": rep.t_overlapped},
        "overlapped_same_budget_step_s": rep.t_overlapped_same_budget,
        "per_leaf_step_s": rep.t_per_leaf,
        "chosen_bucket_bytes": rep.chosen_bucket_bytes,
        "overlap_win": rep.overlap_speedup,
        "combine_hidden_s_per_step":
            rep.t_serial_gamma - rep.t_overlapped_same_budget,
    }


def modeled_hierarchical_wmt(*, P_cluster: int = 64, n_pods: int = 4,
                             tau: int = 10) -> dict:
    """Per-link-class model for the WMT transformer on a pod-aware topology.

    Builds the 2-class (pod x data) topology — intra-pod butterfly bits ride
    ICI, inter-pod bits ride DCN — and records the modeled step time three
    ways: per-class budgets (``plan.choose_class_bucket_bytes`` argmin per
    link class), the same topology forced onto one global 32 MiB budget
    (pre-plan behaviour), and the flat single-class model for reference.
    ``--check`` gates per-class <= single-budget: the per-class sweep must
    never lose to the global default it replaces.
    """
    from repro.configs import get_config
    from repro.core import plan as plan_mod
    from repro.models.registry import build_model

    cfg = get_config("transformer-wmt")
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    payload = bucketing.tree_payload_bytes(shapes)
    S = grouping.default_group_size(P_cluster)
    n_data = P_cluster // n_pods
    topo = plan_mod.Topology.hierarchical(("data", "pod"), (n_data, n_pods),
                                          dcn_axes=("pod",))
    hier = plan_mod.modeled_wagma_step_seconds(payload, topo, S, tau=tau)
    single = plan_mod.modeled_wagma_step_seconds(
        payload, topo, S, tau=tau,
        bucket_bytes=bucketing.DEFAULT_BUCKET_BYTES)
    flat = plan_mod.modeled_wagma_step_seconds(
        payload, plan_mod.Topology.flat(("data", "pod"), (n_data, n_pods)),
        S, tau=tau)
    return {
        "config": cfg.name,
        "P": P_cluster, "S": S, "tau": tau, "n_pods": n_pods,
        "payload_bytes": payload,
        "topology": topo.describe(),
        "per_class": hier["per_class"],
        "per_class_budget_step_s": hier["step_s"],
        "single_budget_step_s": single["step_s"],
        "flat_topology_step_s": flat["step_s"],
        "per_class_budget_win": single["step_s"] / hier["step_s"],
    }


def modeled_fsdp_wmt(*, P_cluster: int = 64, n_pods: int = 4,
                     tau: int = 10) -> dict:
    """FSDP-within-pod model for the WMT transformer (DESIGN.md §10).

    Replicas inside a pod share weights sharded over the intra-pod (data)
    axis: persistent per-device param+opt memory ÷ pod size, pod-to-pod
    butterfly on shard slices (DCN wire ÷ pod size), plus the per-step
    all-gather/reduce-scatter overhead on ICI.  ``--check`` gates
    (a) memory ratio >= pod size and (b) the modeled sharded step within
    10% of (i.e. not slower than 1.1x) the replicated hierarchical step.
    """
    from repro.configs import get_config
    from repro.core import plan as plan_mod
    from repro.launch.costmodel import replica_memory_bytes
    from repro.models.registry import build_model

    cfg = get_config("transformer-wmt")
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    payload = bucketing.tree_payload_bytes(shapes)
    n_data = P_cluster // n_pods
    topo = plan_mod.Topology.hierarchical(("data", "pod"), (n_data, n_pods),
                                          dcn_axes=("pod",))
    S_rep = grouping.default_group_size(P_cluster)
    S_eff = grouping.default_group_size(n_pods)
    replicated = plan_mod.modeled_wagma_step_seconds(payload, topo, S_rep,
                                                     tau=tau)
    fsdp = plan_mod.modeled_fsdp_step_seconds(payload, topo, S_eff,
                                              shard_axis="data", tau=tau)
    mem = replica_memory_bytes(payload, pod_size=n_data)
    return {
        "config": cfg.name,
        "P": P_cluster, "n_pods": n_pods, "pod_size": n_data,
        "S_replicated": S_rep, "S_pod_level": S_eff, "tau": tau,
        "payload_bytes": payload,
        "topology": topo.describe(),
        "per_class": fsdp["per_class"],
        "replicated_hier_step_s": replicated["step_s"],
        "fsdp_step_s": fsdp["step_s"],
        "gather_scatter_s": fsdp["gather_scatter_s"],
        "step_ratio": fsdp["step_s"] / replicated["step_s"],
        **mem,
    }


def modeled_streamed_fsdp(*, P_cluster: int = 64, n_pods: int = 4,
                          tau: int = 10) -> dict:
    """Layer-streamed FSDP model for the WMT transformer (DESIGN.md §11).

    The gather-all FSDP step (§10) pays the full-tree all-gather serially
    before the forward and pins the gathered tree through fwd/bwd; the
    streamed engine gathers span k+1 while span k computes and re-gathers
    in the backward, so per-step time is ``max(compute, gather)`` per span
    and peak transient memory is ~2 layer spans.  Span compute comes from
    the analytic train cost at the production chip's peak FLOP/s.
    ``--check`` gates (a) streamed peak gathered bytes < the full-tree
    gather and (b) streamed modeled step <= the gather-all step.
    """
    from repro.configs import SHAPES, get_config
    from repro.core import plan as plan_mod
    from repro.launch.costmodel import averaging_comm_cost, train_cost
    from repro.launch.mesh import PEAK_FLOPS
    from repro.models.registry import build_model

    cfg = get_config("transformer-wmt")
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    n_leaves = len(jax.tree.leaves(shapes))
    payload = bucketing.tree_payload_bytes(shapes)
    n_data = P_cluster // n_pods
    topo = plan_mod.Topology.hierarchical(("data", "pod"), (n_data, n_pods),
                                          dcn_axes=("pod",))
    # one span per (encoder or decoder) layer; fwd compute per span from
    # the analytic cost model (flops_per_device = 4x fwd incl. remat)
    n_spans = cfg.n_layers + cfg.encoder_layers
    cm = train_cost(cfg, SHAPES["train_4k"], n_dp=P_cluster, n_model=1)
    span_fwd_s = cm.flops_per_device / 4.0 / n_spans / PEAK_FLOPS
    rep = averaging_comm_cost(cfg, P=P_cluster,
                              S=grouping.default_group_size(P_cluster),
                              tau=tau, n_leaves=n_leaves,
                              payload_bytes=payload, topology=topo,
                              fsdp_shard_axis="data",
                              fsdp_streamed_spans=n_spans,
                              span_fwd_compute_s=span_fwd_s)
    return {
        "config": cfg.name,
        "P": P_cluster, "n_pods": n_pods, "pod_size": n_data,
        "tau": tau, "payload_bytes": payload, "n_spans": n_spans,
        "span_fwd_compute_s": span_fwd_s,
        "topology": topo.describe(),
        "peak_gathered_bytes_full": rep.peak_gathered_bytes,
        "peak_gathered_bytes_streamed": rep.peak_gathered_bytes_streamed,
        "peak_gathered_ratio": (rep.peak_gathered_bytes
                                / max(rep.peak_gathered_bytes_streamed, 1.0)),
        "streamed_step_s": rep.t_fsdp_streamed,
        "gather_all_step_s": rep.t_fsdp_gather_all,
        "streamed_win": rep.streamed_win,
        "fsdp_butterfly_step_s": rep.t_fsdp,
    }


def modeled_elastic_churn(*, P_cluster: int = 64, steps: int = 3000,
                          tau: int = 10, seed: int = 0) -> dict:
    """Elastic membership vs checkpoint-restart under preemption churn.

    Delegates to ``cluster_sim.churn_scenario`` (DESIGN.md §12): one
    Poisson preemption trace drives both recovery policies; elastic pays
    an in-place plan recompile + host-side state handoff per world
    change, restart pays the full job restart plus recomputation since
    the last periodic checkpoint.  ``--check`` gates (a) the elastic
    overhead fraction staying bounded and (b) elastic goodput beating
    restart goodput.
    """
    from cluster_sim import churn_scenario
    return churn_scenario(P_cluster, steps=steps, tau=tau, seed=seed)


def modeled_degraded_mode(*, P_cluster: int = 64, steps: int = 600,
                          tau: int = 10, seed: int = 0) -> dict:
    """Degraded-mode rounds vs wait-for-all under the §V-B 320 ms trace.

    Delegates to ``cluster_sim.degraded_mode_scenario`` (DESIGN.md §13):
    the same seeded `FaultSchedule` the chaos tests replay delays two
    workers per step by 320 ms; wait-for-all eats the full delay every
    round, degraded mode waits only the collective deadline and charges
    the late partner one round of staleness, repaid at the tau-sync.
    ``--check`` (CHECK-CHAOS) gates degraded goodput beating wait-for-all
    with the staleness bound intact.
    """
    from cluster_sim import degraded_mode_scenario
    return degraded_mode_scenario(P_cluster, steps=steps, tau=tau,
                                  seed=seed)


def live_mesh_bench(args) -> dict:
    """Wall-clock + launch-count measurement on the 8-device CPU mesh."""
    n_dp, S = 8, args.S
    mesh = jax.make_mesh((n_dp,), ("data",))
    names, sizes = ga.dp_axis_layout(("data",), {"data": n_dp}, ("data",))
    rng = np.random.default_rng(0)
    tree = transformer_like_tree(rng, n_dp, args.layers, args.d)

    local = jax.tree.map(lambda a: a[:1], tree)
    n_leaves = len(jax.tree.leaves(tree))
    bucket_bytes = args.bucket_mb * 1024 * 1024
    layout = bucketing.layout_for(local, max_bucket_bytes=bucket_bytes)
    stages = grouping.ilog2(S)
    payload = bucketing.tree_payload_bytes(local)

    variants = {
        "per_leaf": dict(fused=False),
        "bucketed_jnp": dict(fused=True, use_pallas=False, overlap=False),
        "bucketed_pallas": dict(fused=True, use_pallas=True, overlap=False),
        "overlapped_pallas": dict(fused=True, use_pallas=True, overlap=True),
    }
    print(f"tree: {n_leaves} leaves, {payload / 1e6:.1f} MB/replica; "
          f"S={S} ({stages} butterfly stages); "
          f"layout: {layout.n_buckets} buckets {layout.describe()}")

    from repro.core import plan as plan_mod
    topo = plan_mod.Topology.flat(names, sizes)
    results = {}
    for name, kw in variants.items():
        plan = plan_mod.compile_plan(
            topo, jax.tree.map(lambda a: a[0], tree),
            plan_mod.AveragingConfig(group_size=S, average_dtype="float32",
                                     bucket_bytes=bucket_bytes, **kw))
        f = jax.jit(compat.shard_map(
            lambda tr, plan=plan: plan.average_offset(tr, 0),
            mesh=mesh, in_specs=P("data"), out_specs=P("data"),
            axis_names={"data"}))
        n_pp = count_ppermutes(jax.make_jaxpr(f)(tree).jaxpr)
        dt = bench(f, tree, args.iters)
        results[name] = {"ppermutes_per_step": n_pp, "wall_s": dt}
        print(f"{name:18s} ppermutes/step {n_pp:5d}   wall {dt * 1e3:8.2f} ms")

    n_pp_leaf = results["per_leaf"]["ppermutes_per_step"]
    n_pp_fused = results["bucketed_pallas"]["ppermutes_per_step"]
    assert n_pp_leaf == n_leaves * stages
    assert n_pp_fused == layout.n_buckets * stages
    # the wavefront schedule reorders launches but never adds any
    assert results["overlapped_pallas"]["ppermutes_per_step"] == n_pp_fused
    print(f"ppermute launches: {n_leaves} x log2(S) -> "
          f"{layout.n_buckets} x log2(S)  "
          f"({n_pp_leaf} -> {n_pp_fused}, {n_pp_leaf / n_pp_fused:.1f}x fewer)")
    return {"n_leaves": n_leaves, "payload_bytes": payload,
            "S": S, "n_buckets": layout.n_buckets, "variants": results}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--layers", type=int, default=12)
    ap.add_argument("--d", type=int, default=256)
    ap.add_argument("--S", type=int, default=4)
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--bucket-mb", type=int, default=32)
    ap.add_argument("--check", action="store_true",
                    help="model-only: assert overlapped < serial for "
                         "transformer_wmt and write the JSON")
    ap.add_argument("--out", default=OUT_JSON)
    args = ap.parse_args()

    report = {"modeled_transformer_wmt": modeled_transformer_wmt(),
              "modeled_hierarchical_wmt": modeled_hierarchical_wmt(),
              "modeled_fsdp_wmt": modeled_fsdp_wmt(),
              "modeled_streamed_fsdp": modeled_streamed_fsdp(),
              "modeled_elastic_churn": modeled_elastic_churn(),
              "modeled_degraded_mode": modeled_degraded_mode()}
    m = report["modeled_transformer_wmt"]
    print(f"[model] transformer_wmt @ P={m['P']} S={m['S']}: "
          f"serial {m['serial']['modeled_step_s'] * 1e3:.3f} ms/step "
          f"({m['serial']['n_buckets']} x 32MiB buckets), overlapped "
          f"{m['overlapped']['modeled_step_s'] * 1e3:.3f} ms/step "
          f"({m['overlapped']['n_buckets']} x "
          f"{m['chosen_bucket_bytes'] // 2**20}MiB buckets, "
          f"{m['overlap_win']:.3f}x)")
    h = report["modeled_hierarchical_wmt"]
    budgets = {k: f"{v['bucket_bytes'] // 2**20}MiB"
               for k, v in h["per_class"].items()}
    print(f"[model] hierarchical (pod x data) @ P={h['P']} "
          f"pods={h['n_pods']}: per-class budgets {budgets} -> "
          f"{h['per_class_budget_step_s'] * 1e3:.3f} ms/step vs single "
          f"32MiB {h['single_budget_step_s'] * 1e3:.3f} ms/step "
          f"({h['per_class_budget_win']:.4f}x), flat-topology ref "
          f"{h['flat_topology_step_s'] * 1e3:.3f} ms/step")
    fd = report["modeled_fsdp_wmt"]
    print(f"[model] fsdp-within-pod @ P={fd['P']} pod_size="
          f"{fd['pod_size']}: mem/dev "
          f"{fd['mem_replicated'] / 2**20:.0f} -> "
          f"{fd['mem_fsdp_within_pod'] / 2**20:.0f} MiB "
          f"({fd['mem_ratio']:.1f}x), step "
          f"{fd['fsdp_step_s'] * 1e3:.3f} ms (incl. AG/RS "
          f"{fd['gather_scatter_s'] * 1e3:.3f} ms) vs replicated hier "
          f"{fd['replicated_hier_step_s'] * 1e3:.3f} ms "
          f"({fd['step_ratio']:.3f}x)")

    st = report["modeled_streamed_fsdp"]
    print(f"[model] streamed fsdp @ {st['n_spans']} spans: peak gathered "
          f"{st['peak_gathered_bytes_full'] / 2**20:.1f} -> "
          f"{st['peak_gathered_bytes_streamed'] / 2**20:.1f} MiB "
          f"({st['peak_gathered_ratio']:.1f}x), step "
          f"{st['gather_all_step_s'] * 1e3:.3f} (gather-all) -> "
          f"{st['streamed_step_s'] * 1e3:.3f} ms (streamed, "
          f"{st['streamed_win']:.3f}x)")

    el = report["modeled_elastic_churn"]
    print(f"[model] elastic churn @ P={el['P']} over {el['steps']} steps: "
          f"{el['n_preemptions']} preemptions -> {el['n_shrinks']} shrinks "
          f"+ {el['n_regrows']} regrows; overhead elastic "
          f"{el['elastic_overhead_frac']:.1%} vs restart "
          f"{el['restart_overhead_frac']:.1%}, goodput "
          f"{el['goodput_speedup']:.2f}x")

    dg = report["modeled_degraded_mode"]
    print(f"[model] degraded mode @ P={dg['P']} (§V-B trace "
          f"{dg['schedule_fingerprint']}): wait-for-all "
          f"{dg['waitall_step_s'] * 1e3:.1f} ms/step vs degraded "
          f"{dg['degraded_step_s'] * 1e3:.1f} ms/step "
          f"({dg['goodput_speedup']:.2f}x), "
          f"{dg['skipped_contributions']} skipped contributions, peak "
          f"staleness {dg['peak_staleness_age']} <= "
          f"{dg['staleness_bound']}")

    if not args.check:
        report["live_8dev_cpu"] = live_mesh_bench(args)

    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out}")

    ok = (m["overlapped"]["modeled_step_s"] < m["serial"]["modeled_step_s"])
    # hierarchical gate: per-class budgets must never lose to the single
    # global budget on the same 2-class topology, and the per-class cost
    # model must actually pick distinct budgets per link class
    ok_hier = (h["per_class_budget_step_s"] <= h["single_budget_step_s"]
               and len({v["bucket_bytes"] for v in h["per_class"].values()})
               == len(h["per_class"]))
    # fsdp gate: persistent per-device param+opt memory must divide by at
    # least the pod size, and the sharded step model must stay within 10%
    # of the replicated hierarchical step it replaces
    ok_fsdp = (fd["mem_ratio"] >= fd["pod_size"]
               and fd["step_ratio"] <= 1.10)
    # streamed gate: the layer-streamed engine must strictly shrink the
    # transient gathered footprint and never lose to gather-all on time
    ok_stream = (st["peak_gathered_bytes_streamed"]
                 < st["peak_gathered_bytes_full"]
                 and st["streamed_step_s"] <= st["gather_all_step_s"])
    # elastic gate: churn recovery must stay a bounded tax (recompile +
    # handoff under 10% of wall clock) and strictly beat the
    # checkpoint-restart baseline on goodput
    ok_elastic = (el["elastic_overhead_frac"] < 0.10
                  and el["goodput_speedup"] > 1.0
                  and el["n_world_changes"] >= 2)
    # chaos gate: under the paper's §V-B straggler trace, degraded-mode
    # rounds (deadline-bounded waits, staleness charged and repaid at the
    # tau-sync) must beat the wait-for-all baseline without ever
    # exceeding max_staleness_bound(tau)
    ok_chaos = (dg["goodput_speedup"] > 1.0 and dg["staleness_bounded"]
                and dg["skipped_contributions"] > 0)
    if args.check:
        print("CHECK", "PASS" if ok else "FAIL",
              f"(overlapped {m['overlapped']['modeled_step_s']:.6e} "
              f"< serial {m['serial']['modeled_step_s']:.6e})")
        print("CHECK-HIER", "PASS" if ok_hier else "FAIL",
              f"(per-class {h['per_class_budget_step_s']:.6e} <= single "
              f"{h['single_budget_step_s']:.6e}, budgets {budgets})")
        print("CHECK-FSDP", "PASS" if ok_fsdp else "FAIL",
              f"(mem ratio {fd['mem_ratio']:.1f} >= pod "
              f"{fd['pod_size']}, step ratio {fd['step_ratio']:.3f} "
              f"<= 1.10)")
        print("CHECK-STREAM", "PASS" if ok_stream else "FAIL",
              f"(peak gathered {st['peak_gathered_bytes_streamed']:.3e} < "
              f"full {st['peak_gathered_bytes_full']:.3e}, streamed "
              f"{st['streamed_step_s']:.6e} <= gather-all "
              f"{st['gather_all_step_s']:.6e})")
        print("CHECK-ELASTIC", "PASS" if ok_elastic else "FAIL",
              f"(overhead {el['elastic_overhead_frac']:.3f} < 0.10, "
              f"goodput {el['goodput_speedup']:.2f}x > 1, "
              f"{el['n_world_changes']} world changes)")
        print("CHECK-CHAOS", "PASS" if ok_chaos else "FAIL",
              f"(degraded/wait-for-all goodput "
              f"{dg['goodput_speedup']:.2f}x > 1, peak staleness "
              f"{dg['peak_staleness_age']} <= {dg['staleness_bound']}, "
              f"{dg['skipped_contributions']} skipped)")
        return 0 if (ok and ok_hier and ok_fsdp and ok_stream
                     and ok_elastic and ok_chaos) else 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
