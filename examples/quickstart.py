"""Quickstart: WAGMA-SGD on 8 (forced host) devices in ~a minute on CPU.

Trains the reduced tinyllama config with wait-avoiding group model averaging
(P_dp=4, S=2, tau=5) and compares the loss curve against Allreduce-SGD.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax

from repro import compat
from repro.configs import get_config
from repro.launch.train import Trainer


def main():
    # dp x tp needs lax.scan over auto-sharded xs inside a partially-manual
    # shard_map, which crashes the XLA bundled with JAX 0.4.x — fall back to
    # pure data parallelism there (see compat.PARTIAL_AUTO_SCAN_OK).
    n_model = 2 if compat.PARTIAL_AUTO_SCAN_OK else 1
    mesh = jax.make_mesh((4, n_model), ("data", "model"))
    cfg = get_config("tinyllama-1.1b", smoke=True)

    print("== WAGMA-SGD (S=2, tau=5) ==")
    wagma = Trainer(cfg, mesh, averager="wagma", group_size=2, tau=5,
                    learning_rate=0.3, seq_len=64, global_batch=16)
    h1 = wagma.run(steps=30, log_every=10)

    print("== Allreduce-SGD baseline ==")
    sync = Trainer(cfg, mesh, averager="allreduce", learning_rate=0.3,
                   seq_len=64, global_batch=16)
    h2 = sync.run(steps=30, log_every=10)

    print(f"\nWAGMA     first->last loss: {h1[0]:.3f} -> {h1[-1]:.3f}")
    print(f"Allreduce first->last loss: {h2[0]:.3f} -> {h2[-1]:.3f}")
    assert h1[-1] < h1[0] and h2[-1] < h2[0]
    print("both optimisers converge; WAGMA averages only within groups "
          "per step (global consensus every tau) — see DESIGN.md")


if __name__ == "__main__":
    main()
