"""Quickstart: WAGMA-SGD on 8 (forced host) devices in ~a minute on CPU.

Trains the reduced tinyllama config with wait-avoiding group model averaging
(2 pods x 2-4 workers, S=2, tau=5) on a **pod-aware hierarchical topology**
and compares the loss curve against Allreduce-SGD.

This is the intended surface of the averaging subsystem (DESIGN.md §9): map
the dp mesh axes onto link classes with a frozen ``Topology``, and let the
averager compile the collective once into an ``AveragingPlan`` — per-stage
ICI/DCN classification, one bucket budget per link class, wavefront
schedule.  The old ``group_average(offset=..., fused=..., bucket_bytes=...)``
kwarg pile is a deprecated shim over exactly this.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax

from repro import compat
from repro.configs import get_config
from repro.core.group_allreduce import dp_axis_layout
from repro.core.plan import Topology
from repro.launch.train import Trainer


def main():
    # dp x tp needs lax.scan over auto-sharded xs inside a partially-manual
    # shard_map, which crashes the XLA bundled with JAX 0.4.x — fall back to
    # pure data parallelism there (see compat.PARTIAL_AUTO_SCAN_OK).
    n_model = 2 if compat.PARTIAL_AUTO_SCAN_OK else 1
    n_data = 8 // (2 * n_model)
    mesh = jax.make_mesh((2, n_data, n_model), ("pod", "data", "model"))
    cfg = get_config("tinyllama-1.1b", smoke=True)

    # The topology is the compilation input: the 'data' axis rides intra-pod
    # ICI, the 'pod' axis rides inter-pod DCN — low butterfly bits classify
    # as ICI, high bits as DCN, each with its own bucket budget.
    names, sizes = dp_axis_layout(mesh.axis_names, dict(mesh.shape),
                                  ("pod", "data"))
    topology = Topology.hierarchical(names, sizes, dcn_axes=("pod",))
    print(f"topology: {topology.describe()}")

    print("== WAGMA-SGD (S=2, tau=5, pod-aware plan) ==")
    wagma = Trainer(cfg, mesh, averager="wagma", group_size=2, tau=5,
                    learning_rate=0.3, seq_len=64, global_batch=16,
                    topology=topology)
    # the plan the train step executes, compiled once per tree structure
    local = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype),
                         wagma.params)
    print(wagma.averager.plan_for(local).describe())
    h1 = wagma.run(steps=30, log_every=10)

    print("== Allreduce-SGD baseline ==")
    sync = Trainer(cfg, mesh, averager="allreduce", learning_rate=0.3,
                   seq_len=64, global_batch=16, topology=topology)
    h2 = sync.run(steps=30, log_every=10)

    print(f"\nWAGMA     first->last loss: {h1[0]:.3f} -> {h1[-1]:.3f}")
    print(f"Allreduce first->last loss: {h2[0]:.3f} -> {h2[-1]:.3f}")
    assert h1[-1] < h1[0] and h2[-1] < h2[0]
    print("both optimisers converge; WAGMA averages only within groups "
          "per step (global consensus every tau) — see DESIGN.md")


if __name__ == "__main__":
    main()
