"""End-to-end driver: train a ~100M-param llama-style model with WAGMA-SGD.

Full run (a few hundred steps, as the paper's training-kind dictates):

    PYTHONPATH=src python examples/train_100m.py --steps 300 --seq-len 1024

The default invocation is scaled down (CPU-friendly smoke: 30 steps, seq 128)
but exercises the identical production path: shard_map-manual dp butterfly,
GSPMD model axis, compiled step-variant cache, checkpointing, consolidation.
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse

import jax

from repro.configs.base import ModelConfig
from repro.launch.train import Trainer
from repro.checkpoint import save_checkpoint


def config_100m() -> ModelConfig:
    return ModelConfig(
        name="llama-100m", family="dense",
        n_layers=10, d_model=640, n_heads=10, n_kv_heads=5,
        d_ff=1792, vocab=32000, tie_embeddings=True,
        source="examples/train_100m.py (llama2-style ~100M)",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--tau", type=int, default=10)
    ap.add_argument("--group-size", type=int, default=2)
    ap.add_argument("--ckpt", default="/tmp/wagma_100m_ckpt")
    ap.add_argument("--sharding", default="replicated",
                    choices=["replicated", "fsdp"],
                    help="fsdp: FSDP-within-pod sharded replicas on a "
                         "(pod, data) dp mesh — params/opt shard over the "
                         "intra-pod data axis, group averaging runs "
                         "pod-to-pod (DESIGN.md §10)")
    args = ap.parse_args()

    if args.sharding == "fsdp":
        # fsdp needs a pod axis to average over once data carries shards;
        # the dp x tp combination needs the modern toolchain (see
        # compat.PARTIAL_AUTO_SCAN_OK) so JAX 0.4.x drops the model axis
        from repro import compat
        n_model = 2 if compat.PARTIAL_AUTO_SCAN_OK else 1
        mesh = jax.make_mesh((2, 8 // (2 * n_model), n_model),
                             ("pod", "data", "model"))
    else:
        mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = config_100m()
    import numpy as np
    n_params = None

    tr = Trainer(cfg, mesh, averager="wagma", group_size=args.group_size,
                 tau=args.tau, optimizer="sgd", learning_rate=0.2,
                 seq_len=args.seq_len, global_batch=args.global_batch,
                 sharding=args.sharding)
    print(tr.plan().describe())
    n_params = sum(int(np.prod(l.shape))
                   for l in jax.tree.leaves(
                       jax.eval_shape(tr.model.init, jax.random.PRNGKey(0))))
    print(f"model: {n_params/1e6:.1f}M params, P_dp={tr.n_dp}, "
          f"S={tr.averager.S}, tau={args.tau}")
    hist = tr.run(args.steps, log_every=max(args.steps // 10, 1))
    # at 100M params the loss visibly decreases over a few hundred steps
    # (full invocation in the module docstring); the smoke default only
    # checks the pipeline end-to-end.
    if args.steps >= 100:
        assert min(hist[-10:]) < hist[0], "loss must decrease"

    consolidated = tr.consolidated()
    save_checkpoint(args.ckpt, consolidated, step=args.steps,
                    metadata={"arch": cfg.name, "averager": "wagma"})
    print(f"consolidated (replica-averaged) checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
