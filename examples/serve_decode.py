"""Serving example: prefill a batched prompt, then greedy-decode with KV
caches (ring-buffer windows on local layers) on the gemma3-pattern model.

    PYTHONPATH=src python examples/serve_decode.py [--arch gemma3-12b]
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.registry import build_model
from repro.serve import build_serve_step
from repro import compat


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-12b")
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = get_config(args.arch, smoke=True)
    model = build_model(cfg)

    with compat.set_mesh(mesh):
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        prompt = jnp.asarray(
            rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)),
            jnp.int32)
        max_len = args.prompt_len + args.gen

        batch = {"tokens": prompt}
        if cfg.family == "audio":
            batch["frames"] = jnp.asarray(rng.standard_normal(
                (args.batch, cfg.encoder_frames, cfg.d_model)),
                jnp.float32) * 0.02
        logits, caches = jax.jit(
            lambda p, b: model.prefill(p, b, max_len))(params, batch)
        print(f"prefilled {args.prompt_len} tokens; cache leaves:",
              len(jax.tree.leaves(caches)))

        serve_step = build_serve_step(model, mesh)
        tok = jnp.argmax(logits[:, -1:, :cfg.vocab], -1).astype(jnp.int32)
        out = [tok]
        for pos in range(args.prompt_len, max_len - 1):
            tok, logits, caches = serve_step(params, caches, tok,
                                             jnp.asarray(pos))
            out.append(tok)
        gen = jnp.concatenate(out, axis=1)
        print("generated token ids (batch 0):", np.asarray(gen[0]))
        assert gen.shape == (args.batch, args.gen)
        assert (np.asarray(gen) < cfg.vocab).all()
        print("greedy decode OK — one serve_step per token against the cache")


if __name__ == "__main__":
    main()
