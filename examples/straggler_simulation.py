"""Wait-avoidance under stragglers (paper §V-B's simulated 320 ms delays).

Runs the functional staleness simulator (core/staleness.py) on a small LM:
every iteration two random workers are late to the collective (and sometimes
stall entirely), exactly the paper's injected-imbalance setting. Compares:

    WAGMA  (group averaging + line-13 late merge + tau sync)   [the paper]
    local SGD with sync period tau (= WAGMA minus group avg)   [ablation 1]
    Allreduce-SGD (forced global barrier; stragglers block)    [baseline]

The synchronisation collectives run through the compiled-plan surface
(DESIGN.md §9): a ``Topology`` over the simulated worker axis is compiled
once into an ``AveragingPlan`` whose stacked-simulator twins
(``plan.average_stacked`` / ``plan.sync_stacked``) share the group math
with the distributed path.

    PYTHONPATH=src python examples/straggler_simulation.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import staleness
from repro.core.plan import AveragingConfig, Topology, compile_plan
from repro.data import make_batch_fn
from repro.configs.base import InputShape
from repro.models.registry import build_model
from repro.optim import sgd

P, S, TAU, STEPS, LR = 8, 4, 5, 40, 0.3


def run(mode: str, seed: int = 0):
    cfg = get_config("tinyllama-1.1b", smoke=True)
    model = build_model(cfg)
    opt = sgd(LR, momentum=0.9)
    key = jax.random.PRNGKey(seed)
    params0 = model.init(key)
    stacked = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (P,) + a.shape), params0)
    opt_states = jax.vmap(opt.init)(stacked)
    state = staleness.init_state(stacked)
    # one compiled plan for the simulated worker axis; its stacked twins
    # (average_stacked / sync_stacked) are the simulator's collectives
    plan = compile_plan(
        Topology.flat(("workers",), (P,)), params0,
        AveragingConfig(group_size=S, tau=TAU))
    shape = InputShape("sim", 64, P * 4, "train")
    bf = make_batch_fn(cfg, shape, seed=seed)
    straggle = staleness.StragglerModel(P, n_stragglers=2, p_stall=0.3,
                                        seed=seed)
    opt_holder = {"st": opt_states}

    def local_update(models):
        def per_worker(p, st, tokens, labels):
            loss, g = jax.value_and_grad(
                lambda q: model.loss(q, {"tokens": tokens,
                                         "labels": labels})[0])(p)
            newp, newst = opt.update(g, st, p)
            return newp, newst, loss
        return per_worker

    losses = []
    upd = jax.jit(jax.vmap(local_update(None)))
    for t in range(STEPS):
        nb = bf(t, 0, P * 4)
        toks = jnp.asarray(nb["tokens"]).reshape(P, 4, -1)
        labs = jnp.asarray(nb["labels"]).reshape(P, 4, -1)

        produced = {}

        def do_update(models):
            newp, newst, loss = upd(models, opt_holder["st"], toks, labs)
            produced["opt"] = newst
            produced["loss"] = loss
            return newp

        ready, completes = straggle.sample()
        if mode == "wagma":
            state = staleness.wagma_sim_step(state, do_update, P=P, S=S,
                                             tau=TAU, ready=ready,
                                             completes=completes, t=t)
        elif mode == "local_sgd":
            newp = do_update(state.models)
            if (t + 1) % TAU == 0:
                newp = plan.sync_stacked(newp)
            state = state._replace(models=newp)
        else:  # allreduce: global barrier every step (stragglers just wait)
            newp = plan.sync_stacked(do_update(state.models))
            state = state._replace(models=newp)
        opt_holder["st"] = produced["opt"]
        losses.append(float(produced["loss"].mean()))
    return losses


def main():
    for mode in ("wagma", "local_sgd", "allreduce"):
        ls = run(mode)
        print(f"{mode:10s} loss {ls[0]:.3f} -> {ls[-1]:.3f} "
              f"(mean last5 {np.mean(ls[-5:]):.3f})")
    print("\nWAGMA tracks the Allreduce curve despite 2 stragglers/iter; "
          "tau-periodic local SGD (ablation 1) trails it.")


if __name__ == "__main__":
    main()
