"""Wait-avoidance / staleness simulator semantics (paper Alg. 2 lines 8-17)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import group_allreduce as ga
from repro.core import staleness


def _state(P, dim=6, seed=0):
    rng = np.random.default_rng(seed)
    W = {"w": jnp.asarray(rng.standard_normal((P, dim)), jnp.float32)}
    return staleness.init_state(W)


def _identity_update(W):
    return W


def test_no_stragglers_equals_group_average():
    P, S = 8, 4
    st_ = _state(P)
    ready = jnp.ones((P,), bool)
    out = staleness.wagma_sim_step(st_, _identity_update, P=P, S=S, tau=100,
                                   ready=ready, completes=ready, t=0)
    want = ga.group_average_stacked(st_.models, P=P, S=S, t=0)
    np.testing.assert_allclose(np.asarray(out.models["w"]),
                               np.asarray(want["w"]), rtol=1e-6)
    assert (np.asarray(out.age) == 0).all()


def test_sync_step_equalises_everything():
    P, S = 8, 4
    st_ = _state(P)
    ready = jnp.zeros((P,), bool)          # even with everyone late,
    out = staleness.wagma_sim_step(st_, _identity_update, P=P, S=S, tau=1,
                                   ready=ready, completes=ready, t=0)
    w = np.asarray(out.models["w"])
    np.testing.assert_allclose(w, np.broadcast_to(w.mean(0), w.shape),
                               rtol=1e-6)
    assert (np.asarray(out.age) == 0).all()


def test_straggler_contributes_stale_buffer():
    """A late worker's *buffer* (old model) enters the group sum, and the
    late worker merges per line 13: (Wsum + W')/(S+1)."""
    P, S = 4, 2
    st_ = _state(P, dim=1, seed=1)
    W0 = np.asarray(st_.models["w"]).copy()

    def upd(W):
        return jax.tree.map(lambda a: a + 1.0, W)

    ready = jnp.asarray([True, False, True, True])
    completes = jnp.ones((P,), bool)
    out = staleness.wagma_sim_step(st_, upd, P=P, S=S, tau=100,
                                   ready=ready, completes=completes, t=0)
    # groups at t=0 for P=4,S=2: {0,1},{2,3}
    w = np.asarray(out.models["w"])[:, 0]
    wp = W0[:, 0] + 1.0                     # everyone's W'
    wsum_01 = wp[0] + W0[1, 0]              # P1 contributed stale buffer
    assert np.isclose(w[0], wsum_01 / S)                       # line 11
    assert np.isclose(w[1], (wsum_01 + wp[1]) / (S + 1))       # line 13
    wsum_23 = wp[2] + wp[3]
    assert np.isclose(w[2], wsum_23 / S)
    assert np.isclose(w[3], wsum_23 / S)
    assert np.asarray(out.age)[1] == 1


def test_non_completing_worker_keeps_model_and_ages():
    P, S = 4, 2
    st_ = _state(P, dim=3, seed=2)
    W0 = np.asarray(st_.models["w"]).copy()

    def upd(W):
        return jax.tree.map(lambda a: a * 2.0, W)

    ready = jnp.asarray([True, False, True, True])
    completes = jnp.asarray([True, False, True, True])
    out = staleness.wagma_sim_step(st_, upd, P=P, S=S, tau=100,
                                   ready=ready, completes=completes, t=0)
    # stalled worker is mid-computation: model unchanged, buffer unchanged
    np.testing.assert_allclose(np.asarray(out.models["w"])[1], W0[1])
    np.testing.assert_allclose(np.asarray(out.buffers["w"])[1], W0[1])
    assert np.asarray(out.age)[1] == 1


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), n_straggle=st.integers(0, 3),
       p_stall=st.floats(0.0, 0.9))
def test_staleness_bounded_by_tau(seed, n_straggle, p_stall):
    """Theory Assumption 3: tau-periodic sync bounds buffer age by tau."""
    P, S, tau = 8, 4, 5
    st_ = _state(P, dim=4, seed=seed)
    model = staleness.StragglerModel(P, n_stragglers=n_straggle,
                                     p_stall=p_stall, seed=seed)

    def upd(W):
        return jax.tree.map(lambda a: a + 0.1, W)

    max_age = 0
    for t in range(3 * tau):
        ready, completes = model.sample()
        st_ = staleness.wagma_sim_step(st_, upd, P=P, S=S, tau=tau,
                                       ready=ready, completes=completes, t=t)
        max_age = max(max_age, int(np.asarray(st_.age).max()))
        if (t + 1) % tau == 0:
            assert int(np.asarray(st_.age).max()) == 0
    assert max_age <= staleness.max_staleness_bound(tau)


def test_straggler_model_no_stragglers_edge():
    """n_stragglers=0 must degenerate to the fully-synchronous schedule."""
    model = staleness.StragglerModel(8, n_stragglers=0, p_stall=1.0, seed=4)
    for _ in range(5):
        ready, completes = model.sample()
        assert np.asarray(ready).all() and np.asarray(completes).all()


def test_straggler_model_p_stall_one_edge():
    """p_stall=1.0: every drawn straggler also fails to complete."""
    model = staleness.StragglerModel(8, n_stragglers=3, p_stall=1.0, seed=5)
    for _ in range(10):
        ready, completes = model.sample()
        r, c = np.asarray(ready), np.asarray(completes)
        assert (~r).sum() == 3
        np.testing.assert_array_equal(r, c), \
            "a stalled straggler must not count as completing"


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000), tau=st.integers(2, 7),
       p_ready=st.floats(0.0, 1.0), p_complete=st.floats(0.0, 1.0))
def test_age_bounded_under_arbitrary_schedules(seed, tau, p_ready,
                                               p_complete):
    """The tau bound must hold for ANY ready/completes schedule, not just
    StragglerModel's (which draws a fixed straggler count per step): age
    resets at every sync and never exceeds max_staleness_bound(tau) in
    between, even when whole iterations have nobody ready."""
    P, S = 8, 4
    rng = np.random.default_rng(seed)
    st_ = _state(P, dim=3, seed=seed)

    def upd(W):
        return jax.tree.map(lambda a: a + 0.1, W)

    for t in range(3 * tau):
        ready = rng.random(P) < p_ready
        completes = np.logical_or(ready, rng.random(P) < p_complete)
        st_ = staleness.wagma_sim_step(st_, upd, P=P, S=S, tau=tau,
                                       ready=jnp.asarray(ready),
                                       completes=jnp.asarray(completes), t=t)
        ages = np.asarray(st_.age)
        assert ages.max() <= staleness.max_staleness_bound(tau), \
            (t, ages.tolist())
        if (t + 1) % tau == 0:
            assert ages.max() == 0, "sync must reset all staleness"


def test_mean_preserved_without_stragglers():
    P, S = 16, 4
    st_ = _state(P, dim=5, seed=3)
    mean0 = np.asarray(st_.models["w"]).mean(0)
    ready = jnp.ones((P,), bool)
    for t in range(7):
        st_ = staleness.wagma_sim_step(st_, _identity_update, P=P, S=S,
                                       tau=100, ready=ready, completes=ready,
                                       t=t)
    np.testing.assert_allclose(np.asarray(st_.models["w"]).mean(0), mean0,
                               rtol=1e-5, atol=1e-6)
