"""Deterministic fault injection & the chaos matrix (DESIGN.md §13).

Host-side tests pin the `core.faults` primitives: event validation, the
sorted/immutable `FaultSchedule`, fingerprint stability, the seeded
§V-B straggler trace, and the wall-clock `FaultInjector` effects
(delay sleeps, crash raises, hang raises after the watchdog grace).

The subprocess tests run the chaos matrix on the forced-host CPU mesh —
the same `run_under_faults` code path as the ``--chaos`` CI smoke.
Nothing in any test body calls ``leave()``: schedules only silence
workers, and every shrink/regrow below is detector-driven.

* hang-mid-round + double fault: two workers hang permanently in the
  same round; one suspect shrinks 4 -> 2, the batch-mate verdict drains
  the spare, both confirm dead, the world never regrows.
* crash-before-sync + rejoin, replayed twice: a worker crashes right
  before a tau-sync, is detected, rejoins at the next barrier — and the
  whole run replays **bit-identically** (state digest, events, losses).
* flapping worker: a straggler trips one shrink/rejoin cycle; the flap
  backoff doubles its suspect timeout so an identical second delay is
  absorbed without churning the membership again.
"""

import os
import sys

import numpy as np
import pytest

from subproc import run_sub as _run_sub

from repro.core import faults
from repro.core.faults import (FaultEvent, FaultInjector, FaultSchedule,
                               InjectedCrash, InjectedHang, crash, delay,
                               hang)


# ---------------------------------------------------------------------------
# FaultEvent validation + builders
# ---------------------------------------------------------------------------

def test_event_validation():
    with pytest.raises(ValueError):
        FaultEvent(0, 0, "melt")
    with pytest.raises(ValueError):
        FaultEvent(0, 0, faults.DELAY, ms=0.0)
    with pytest.raises(ValueError):
        FaultEvent(5, 0, faults.HANG, until=5)   # recovery must be later


def test_builders():
    d = delay(3, 7, 320.0)
    assert (d.step, d.worker, d.kind, d.ms) == (7, 3, faults.DELAY, 320.0)
    h = hang(1, 2, recover_after=3)
    assert (h.kind, h.until) == (faults.HANG, 5)
    assert hang(1, 2).until is None
    c = crash(0, 4, rejoin_after=2)
    assert (c.kind, c.until) == (faults.CRASH, 6)


# ---------------------------------------------------------------------------
# FaultSchedule: ordering, lookup, fingerprint determinism
# ---------------------------------------------------------------------------

def test_schedule_sorted_and_lookup():
    s = FaultSchedule.of(crash(0, 9), delay(2, 1, 10.0), hang(1, 1))
    assert [e.step for e in s] == [1, 1, 9]
    assert len(s) == 3 and s.max_step == 9
    assert {e.kind for e in s.at(1)} == {faults.DELAY, faults.HANG}
    assert s.at(5) == ()
    assert s.delays_at(1) == {2: 10.0 / 1e3}
    assert FaultSchedule().max_step == -1


def test_fingerprint_is_order_independent_and_content_sensitive():
    a = FaultSchedule.of(delay(2, 1, 10.0), hang(1, 3))
    b = FaultSchedule.of(hang(1, 3), delay(2, 1, 10.0))
    assert a.fingerprint() == b.fingerprint()
    c = FaultSchedule.of(hang(1, 3), delay(2, 1, 11.0))
    assert a.fingerprint() != c.fingerprint()
    assert a.fingerprint() in repr(a)


def test_straggler_trace_is_seed_deterministic():
    a = FaultSchedule.straggler_trace(16, 50, seed=7)
    b = FaultSchedule.straggler_trace(16, 50, seed=7)
    assert a.events == b.events
    assert a.fingerprint() == b.fingerprint()
    assert a.fingerprint() != FaultSchedule.straggler_trace(
        16, 50, seed=8).fingerprint()
    # every step: exactly n_stragglers distinct delayed workers
    for t in range(50):
        evs = a.at(t)
        assert len(evs) == 2 and len({e.worker for e in evs}) == 2
        assert all(e.kind == faults.DELAY and e.ms == 320.0 for e in evs)


def test_straggler_trace_clamps_to_world():
    s = FaultSchedule.straggler_trace(2, 4, n_stragglers=5)
    assert all(len(s.at(t)) == 2 for t in range(4))


# ---------------------------------------------------------------------------
# FaultInjector: wall-clock effects for one worker identity
# ---------------------------------------------------------------------------

def test_injector_delay_sleeps_scaled_and_ignores_other_workers():
    slept = []
    s = FaultSchedule.of(delay(0, 2, 100.0), delay(1, 2, 999.0))
    inj = FaultInjector(s, worker=0, time_scale=0.5, sleep=slept.append)
    inj.before_step(0)
    inj.before_step(2)
    assert slept == [pytest.approx(0.05)]     # 100 ms * 0.5, worker 1 skipped
    assert inj.delayed_ms == 100.0


def test_injector_crash_raises():
    inj = FaultInjector(FaultSchedule.of(crash(0, 3)), worker=0,
                        sleep=lambda _: None)
    inj.before_step(2)
    with pytest.raises(InjectedCrash):
        inj.before_step(3)


def test_injector_hang_sleeps_grace_then_raises():
    slept = []
    inj = FaultInjector(FaultSchedule.of(hang(0, 1)), worker=0,
                        hang_grace_s=0.02, sleep=slept.append)
    with pytest.raises(InjectedHang):
        inj.before_step(1)
    assert slept == [pytest.approx(0.02)]


# ---------------------------------------------------------------------------
# Degraded-mode cost model (cluster_sim) replays the same trace
# ---------------------------------------------------------------------------

def test_degraded_mode_scenario_beats_wait_for_all_and_stays_bounded():
    sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "benchmarks"))
    from cluster_sim import degraded_mode_scenario

    rep = degraded_mode_scenario(P=16, steps=200, tau=10, seed=0)
    assert rep["schedule_fingerprint"] == FaultSchedule.straggler_trace(
        16, 200, seed=0).fingerprint()
    assert rep["goodput_speedup"] > 1.0
    assert rep["staleness_bounded"]
    assert 0 < rep["peak_staleness_age"] <= rep["staleness_bound"] == 10
    assert rep["skipped_contributions"] > 0
    # deterministic: same seed, same numbers
    rep2 = degraded_mode_scenario(P=16, steps=200, tau=10, seed=0)
    assert rep2 == rep


# ---------------------------------------------------------------------------
# The chaos matrix (subprocess, detector-driven — no scripted leaves)
# ---------------------------------------------------------------------------

_PREAMBLE = """
    from repro.configs import get_config
    from repro.core import faults
    from repro.core.faults import FaultSchedule
    from repro.core.health import DetectorConfig
    from repro.launch.elastic import ElasticTrainer

    # Off-grid timeouts: the virtual clock lands on multiples of 0.05 s,
    # and the default 0.25/0.30 thresholds sit exactly on that grid, so
    # whether a boundary poll fires depends on float rounding of
    # t*0.1+0.05.  0.28/0.33 keep >=0.02 s of margin to every grid point,
    # making the suspect/confirm rounds clock-noise-proof.
    DET = DetectorConfig(suspect_timeout_s=0.28, confirm_timeout_s=0.33)

    def make_et(world=4, tau=4, seed=0):
        cfg = get_config("qwen3-0.6b", smoke=True)
        return ElasticTrainer(cfg, jax.devices()[:world], tau=tau,
                              group_size=2, seed=seed, learning_rate=0.05)

    def kinds(rep):
        return [e["kind"] for e in rep["events"]]
"""


def test_chaos_hang_mid_round_double_fault_confirms_dead():
    """Two workers hang permanently in the same round (double fault).
    The detector suspects both at one deadline: the first verdict
    shrinks 4 -> 2, the batch-mate verdict (re-stamped to the bumped
    epoch) drains the demoted spare, and both later confirm dead —
    after which the ledger stops aging them and the world stays 2."""
    out = _run_sub("""
        et = make_et()
        sched = FaultSchedule.of(faults.hang(1, 2), faults.hang(3, 2))
        rep = et.run_under_faults(10, sched, detector=DET)

        ks = kinds(rep)
        assert ks.count("hang") == 2 and ks.count("suspect") == 2, ks
        assert ks.count("shrink") == 1, ks          # batch-mate drains a spare
        assert ks.count("confirm-dead") == 2, ks
        for absent in ("recover", "wake", "regrow", "stale-verdict-rejected"):
            assert absent not in ks, ks
        assert [r["world"] for r in rep["records"]] == [4] * 4 + [2] * 6
        assert [e["kind"] for e in et.epoch_log] == ["shrink"]
        m = et.controller.membership
        assert m.world_size == 2 and not m.spares and not m.pending, m
        st = rep["staleness"]
        assert st["total_skipped"] == {1: 4} and st["ages"] == {}, st
        assert st["peak_age"] == 4 == et.tau, st
        assert np.isfinite([r["loss"] for r in rep["records"]]).all()
        print("CHAOS_DOUBLE_FAULT_OK")
    """, devices=8, timeout=600, preamble=_PREAMBLE)
    assert "CHAOS_DOUBLE_FAULT_OK" in out


def test_chaos_crash_before_sync_rejoins_and_replays_bit_identical():
    """A worker crashes right before a tau-sync; the barrier proceeds
    with the old world, detection shrinks it next round, the rejoin is
    promoted at the following barrier — and replaying the identical
    `FaultSchedule` on a fresh trainer reproduces the survivor state
    **bit-identically** (digest, events, per-step losses)."""
    out = _run_sub("""
        sched = FaultSchedule.of(faults.crash(1, 6, rejoin_after=3))

        def one_run():
            et = make_et()
            rep = et.run_under_faults(13, sched, detector=DET)
            return et, rep

        et, rep = one_run()
        ks = kinds(rep)
        for needed in ("crash", "suspect", "shrink", "wake", "recover",
                       "regrow"):
            assert needed in ks, ks
        assert [r["world"] for r in rep["records"]] == \\
            [4] * 8 + [2] * 4 + [4], [r["world"] for r in rep["records"]]
        assert [e["kind"] for e in et.epoch_log] == ["shrink", "regrow"]
        st = rep["staleness"]
        assert st["total_skipped"] == {1: 4} and st["ages"] == {}, st
        m = et.controller.membership
        assert m.world_size == 4 and not m.spares and not m.pending, m

        et2, rep2 = one_run()
        assert rep2["schedule_fingerprint"] == rep["schedule_fingerprint"]
        assert rep2["state_digest"] == rep["state_digest"], \\
            "replaying the same FaultSchedule must be bit-identical"
        assert rep2["events"] == rep["events"]
        assert rep2["staleness"] == rep["staleness"]
        assert [r["loss"] for r in rep2["records"]] == \\
            [r["loss"] for r in rep["records"]]
        print("CHAOS_REPLAY_OK")
    """, devices=8, timeout=600, preamble=_PREAMBLE)
    assert "CHAOS_REPLAY_OK" in out


def test_chaos_flapping_worker_backoff_absorbs_second_delay():
    """A 320 ms straggler trips suspect -> shrink -> recover -> regrow
    (one flap).  The flap doubles its suspect timeout, so the identical
    delay later is absorbed: silence peaks at 0.45 s — past the 0.25 s
    base timeout that caught it the first time, under the backed-off
    0.5 s — and the membership never churns again."""
    out = _run_sub("""
        et = make_et()
        sched = FaultSchedule.of(faults.delay(1, 2, 320.0),
                                 faults.delay(1, 9, 320.0))
        rep = et.run_under_faults(14, sched)

        ks = kinds(rep)
        assert ks.count("delay") == 2, ks
        assert ks.count("suspect") == 1, \\
            "backoff failed: the second identical delay was suspected again"
        assert ks.count("shrink") == 1 and ks.count("regrow") == 2, ks
        assert ks.count("recover") == 1 and "confirm-dead" not in ks, ks
        assert [r["world"] for r in rep["records"]] == \\
            [4] * 4 + [2] * 4 + [4] * 6, [r["world"] for r in rep["records"]]
        assert [e["kind"] for e in et.epoch_log] == ["shrink", "regrow"]
        st = rep["staleness"]
        assert st["total_skipped"] == {1: 4} and st["ages"] == {}, st
        m = et.controller.membership
        assert m.world_size == 4 and not m.spares and not m.pending, m
        assert np.isfinite([r["loss"] for r in rep["records"]]).all()
        print("CHAOS_FLAP_OK")
    """, devices=8, timeout=600, preamble=_PREAMBLE)
    assert "CHAOS_FLAP_OK" in out
