"""Heartbeat failure detection & staleness accounting (DESIGN.md §13).

Everything here is host-side: the detector runs on an explicit clock
(the chaos driver feeds it virtual time), so every transition is pinned
with hand-computed timestamps — ALIVE -> SUSPECT past the per-worker
suspect timeout, SUSPECT -> DEAD past the confirm timeout, RECOVERED on
a beat from a suspected/dead worker with the multiplicative flap
backoff.  The `apply_verdict` tests close the detection -> membership
loop, including the regression for the stale-epoch guard: a verdict
raised against an evicted (dead-epoch) topology must be rejected, not
shrink the current world.
"""

import dataclasses

import jax.numpy as jnp
import pytest

import jax
from repro.core import plan as plan_mod
from repro.core.elastic import MembershipController
from repro.core.health import (ALIVE, DEAD, RECOVERED, SUSPECT,
                               DetectorConfig, FailureDetector, Verdict)
from repro.core.plan import AveragingConfig, Topology, compile_plan
from repro.core.staleness import (SkipLedger, StalenessBoundExceeded,
                                  max_staleness_bound)

CFG = DetectorConfig(suspect_timeout_s=0.25, confirm_timeout_s=0.30,
                     backoff=2.0, max_backoff=8.0)


# ---------------------------------------------------------------------------
# DetectorConfig validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw", [
    dict(suspect_timeout_s=0.0), dict(suspect_timeout_s=-1.0),
    dict(confirm_timeout_s=0.0), dict(backoff=0.5),
])
def test_detector_config_rejects_bad_values(kw):
    with pytest.raises(ValueError):
        DetectorConfig(**kw)


# ---------------------------------------------------------------------------
# State machine: ALIVE -> SUSPECT -> DEAD, strict deadlines
# ---------------------------------------------------------------------------

def test_regular_heartbeats_keep_everyone_alive():
    det = FailureDetector(range(4), CFG)
    for t in range(10):
        now = t * 0.1
        for w in range(4):
            assert det.heartbeat(w, now) is None
        assert det.poll(now + 0.05) == []
    assert all(det.state(w) == ALIVE for w in range(4))


def test_suspect_fires_strictly_past_timeout():
    det = FailureDetector([0, 1], CFG)
    det.heartbeat(1, 0.1)           # worker 0's last beat stays at 0.0
    # silence == timeout exactly: not suspected (strict >)
    assert det.poll(0.25) == []
    out = det.poll(0.26)
    assert [(v.worker, v.state) for v in out] == [(0, SUSPECT)]
    assert out[0].silent_s == pytest.approx(0.26)
    assert det.state(0) == SUSPECT and det.state(1) == ALIVE


def test_confirm_dead_after_further_silence_then_poll_goes_quiet():
    det = FailureDetector([0], CFG)
    (v,) = det.poll(0.30)
    assert v.state == SUSPECT and v.at == 0.30
    # confirm window measured from suspected_at, strict >
    assert det.poll(0.60) == []
    (d,) = det.poll(0.61)
    assert d.state == DEAD and d.worker == 0
    assert det.state(0) == DEAD
    # a dead worker never re-fires
    assert det.poll(5.0) == []


def test_poll_reports_multiple_workers_sorted():
    det = FailureDetector([3, 1, 0, 2], CFG)
    det.heartbeat(0, 0.2)
    out = det.poll(0.30)
    assert [v.worker for v in out] == [1, 2, 3]
    assert all(v.state == SUSPECT for v in out)


# ---------------------------------------------------------------------------
# Recovery, flaps, and the multiplicative backoff
# ---------------------------------------------------------------------------

def test_recovery_from_suspect_counts_a_flap_and_backs_off():
    det = FailureDetector([0], CFG)
    det.poll(0.30)
    assert det.state(0) == SUSPECT
    v = det.heartbeat(0, 0.35)
    assert isinstance(v, Verdict)
    assert v.state == RECOVERED and v.silent_s == pytest.approx(0.35)
    assert det.state(0) == ALIVE
    # one flap doubles the suspect deadline: 0.25 -> 0.5
    assert det.suspect_timeout(0) == pytest.approx(0.50)
    assert det.poll(0.35 + 0.50) == []
    (s,) = det.poll(0.35 + 0.51)
    assert s.state == SUSPECT


def test_rejoin_after_dead_is_a_recovery_too():
    det = FailureDetector([0], CFG)
    det.poll(0.30)
    det.poll(0.61)
    assert det.state(0) == DEAD
    v = det.heartbeat(0, 1.0)
    assert v.state == RECOVERED and det.state(0) == ALIVE


def test_backoff_is_capped_at_max_backoff():
    det = FailureDetector([0], CFG)
    for flap in range(6):
        det.poll(det.records[0].last_beat + det.suspect_timeout(0) + 0.01)
        det.heartbeat(0, det.records[0].suspected_at or 0.0)
    assert det.records[0].flaps == 6
    # 2**6 = 64 would be 16 s; capped at 8 x 0.25 = 2 s
    assert det.suspect_timeout(0) == pytest.approx(0.25 * 8.0)


def test_unseen_worker_announcing_itself_is_not_a_recovery():
    det = FailureDetector([0], CFG)
    assert det.heartbeat(7, 0.4) is None
    assert det.state(7) == ALIVE
    # worker 0 is silent and gets suspected; the fresh worker 7 is fine
    assert [(v.worker, v.state) for v in det.poll(0.45)] == [(0, SUSPECT)]


# ---------------------------------------------------------------------------
# Epoch stamping
# ---------------------------------------------------------------------------

def test_verdicts_carry_the_detector_epoch():
    det = FailureDetector([0, 1], CFG, epoch=3)
    det.heartbeat(1, 0.2)
    (v,) = det.poll(0.30)
    assert v.worker == 0 and v.state == SUSPECT and v.epoch == 3
    det.set_epoch(5)            # the driver re-stamps after a shrink
    out = det.poll(0.61)        # 0 confirms dead, 1 turns suspect
    assert {(x.worker, x.state) for x in out} == {(0, DEAD), (1, SUSPECT)}
    assert all(x.epoch == 5 for x in out)
    r = det.heartbeat(0, 1.0)
    assert r.state == RECOVERED and r.epoch == 5


# ---------------------------------------------------------------------------
# apply_verdict: detection -> membership
# ---------------------------------------------------------------------------

def test_suspect_verdict_shrinks_like_a_scripted_leave():
    mc = MembershipController(range(8))
    ev = mc.apply_verdict(Verdict(3, SUSPECT, epoch=0, at=0.45, silent_s=0.35))
    assert ev.kind == "shrink" and mc.epoch == 1
    assert mc.membership.world_size == 4
    assert 3 not in mc.membership.active


def test_recovered_verdict_defers_to_the_barrier():
    mc = MembershipController(range(4))
    mc.apply_verdict(Verdict(1, SUSPECT, 0, 0.45, 0.35))
    ev = mc.apply_verdict(Verdict(1, RECOVERED, mc.epoch, 0.8, 0.5))
    assert ev.kind == "defer"
    assert mc.membership.pending == (1,)
    assert mc.at_sync_barrier().kind == "regrow"
    assert mc.membership.world_size == 4


def test_dead_verdict_for_already_removed_worker_is_a_noop():
    mc = MembershipController(range(4))
    mc.apply_verdict(Verdict(1, SUSPECT, 0, 0.45, 0.35))   # shrink, epoch 1
    ev = mc.apply_verdict(Verdict(1, DEAD, mc.epoch, 0.8, 0.7))
    assert ev.kind == "noop" and mc.membership.world_size == 2


def test_unactionable_verdict_state_raises():
    mc = MembershipController(range(4))
    with pytest.raises(ValueError):
        mc.apply_verdict(Verdict(1, ALIVE, 0, 0.1, 0.0))


def test_stale_epoch_verdict_rejected_after_topology_eviction():
    """Regression (DESIGN.md §13): a detector verdict raised against an
    evicted dead-epoch topology must be rejected — not shrink the world
    the cluster has since rebuilt.  The scenario that bit: worker 3 is
    suspected under epoch 0, the world shrinks (epoch 1, the epoch-0
    plans are evicted), and only then does the slow epoch-0 SUSPECT
    verdict for worker 1 arrive."""
    plan_mod.clear_plan_cache()
    tree = {"w": jax.ShapeDtypeStruct((256,), jnp.float32)}
    cfg = AveragingConfig(group_size=2, bucket_bytes=4096)
    old_topo = Topology.flat(("data",), (8,))
    compile_plan(old_topo, tree, cfg)

    mc = MembershipController(range(8))
    stale = Verdict(1, SUSPECT, epoch=0, at=0.45, silent_s=0.35)  # in flight
    assert mc.apply_verdict(Verdict(3, SUSPECT, 0, 0.45, 0.35)).kind == "shrink"
    assert plan_mod.evict_topology(old_topo) >= 1   # epoch-0 world retired

    before = mc.membership
    ev = mc.apply_verdict(stale)
    assert ev.kind == "rejected-stale-epoch"
    assert mc.membership == before          # world and epoch untouched
    assert 1 in mc.membership.active
    # re-stamped with the live epoch, the same indictment does act
    assert mc.apply_verdict(
        dataclasses.replace(stale, epoch=mc.epoch)).kind == "shrink"


# ---------------------------------------------------------------------------
# SkipLedger: host-side staleness accounting
# ---------------------------------------------------------------------------

def test_skip_ledger_charges_and_aborts_past_the_bound():
    led = SkipLedger(tau=3)
    assert [led.charge(1, t) for t in range(3)] == [1, 2, 3]
    assert led.max_age() == 3 == max_staleness_bound(3)
    with pytest.raises(StalenessBoundExceeded):
        led.charge(1, 3)


def test_skip_ledger_reset_on_rejoin_and_drop_on_death():
    led = SkipLedger(tau=2)
    led.charge(1, 0)
    led.charge(2, 0)
    led.charge(1, 1)
    led.reset(1)                      # rejoined at the barrier
    assert led.ages == {2: 1}
    led.charge(1, 2)                  # ages restart from zero
    assert led.ages[1] == 1
    led.drop(2)                       # confirmed dead
    assert 2 not in led.ages
    snap = led.snapshot()
    assert snap["total_skipped"] == {1: 3, 2: 1}
    assert snap["peak_age"] == 2
    led.charge(2, 3)                  # history survives drop, age restarts
    assert led.ages[2] == 1


def test_skip_ledger_empty_max_age():
    assert SkipLedger(tau=4).max_age() == 0
