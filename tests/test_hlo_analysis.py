"""Loop-aware HLO collective parser: synthetic fixtures + shape parsing."""

import textwrap

from repro.launch.hlo_analysis import (_tensor_bytes, collective_summary)


FIXTURE = textwrap.dedent("""\
    HloModule jit_step, entry_computation_layout={()->()}

    %add.1 (a: f32[], b: f32[]) -> f32[] {
      %a = f32[] parameter(0)
      %b = f32[] parameter(1)
      ROOT %r = f32[] add(%a, %b)
    }

    %body.1 (p: (s32[], f32[16,64])) -> (s32[], f32[16,64]) {
      %p = (s32[], f32[16,64]) parameter(0)
      %iv = s32[] get-tuple-element(%p), index=0
      %x = f32[16,64] get-tuple-element(%p), index=1
      %ar = f32[16,64]{1,0} all-reduce(%x), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add.1
      %one = s32[] constant(1)
      %iv2 = s32[] add(%iv, %one)
      ROOT %t = (s32[], f32[16,64]) tuple(%iv2, %ar)
    }

    %cond.1 (p: (s32[], f32[16,64])) -> pred[] {
      %p = (s32[], f32[16,64]) parameter(0)
      %iv = s32[] get-tuple-element(%p), index=0
      %n = s32[] constant(12)
      ROOT %lt = pred[] compare(%iv, %n), direction=LT
    }

    ENTRY %main (x: f32[16,64]) -> f32[16,64] {
      %x = f32[16,64]{1,0} parameter(0)
      %cp = f32[16,64]{1,0} collective-permute(%x), source_target_pairs={{0,1},{1,0}}
      %zero = s32[] constant(0)
      %t0 = (s32[], f32[16,64]) tuple(%zero, %cp)
      %w = (s32[], f32[16,64]) while(%t0), condition=%cond.1, body=%body.1
      %y = f32[16,64] get-tuple-element(%w), index=1
      %ag = f32[64,64]{1,0} all-gather(%y), replica_groups={{0,1,2,3}}, dimensions={0}
      ROOT %out = f32[16,64]{1,0} slice(%ag), slice={[0:16], [0:64]}
    }
""")


def test_tensor_bytes():
    assert _tensor_bytes("f32[16,64]") == 16 * 64 * 4
    assert _tensor_bytes("bf16[8,128]{1,0}") == 8 * 128 * 2
    assert _tensor_bytes("(f32[4], s32[2,2])") == 16 + 16
    assert _tensor_bytes("pred[]") == 0 or _tensor_bytes("pred[]") == 1


def test_collective_summary_loop_aware():
    s = collective_summary(FIXTURE)
    n = 16 * 64 * 4
    by = s["wire_bytes_by_kind"]
    counts = s["counts_by_kind"]
    # collective-permute: once, full payload
    assert by["collective-permute"] == n
    # all-reduce inside the while: 12 trips, group of 4 -> 2*N*(3/4) each
    assert counts["all-reduce"] == 12
    assert abs(by["all-reduce"] - 12 * 2 * n * 3 / 4) < 1e-6
    # all-gather of the 4x output: N_out*(g-1)/g once
    assert abs(by["all-gather"] - (64 * 64 * 4) * 3 / 4) < 1e-6
    assert not s["unknown_trip_counts"]


def test_unknown_trip_flagged():
    no_const = FIXTURE.replace("%n = s32[] constant(12)",
                               "%n = s32[] parameter(1)").replace(
        "(p: (s32[], f32[16,64])) -> pred[] {",
        "(p: (s32[], f32[16,64]), q: s32[]) -> pred[] {", 1)
    s = collective_summary(no_const)
    assert s["unknown_trip_counts"]
    assert s["counts_by_kind"]["all-reduce"] == 1


def test_tpu_adjusted_halves_allreduce():
    s = collective_summary(FIXTURE)
    ar = s["wire_bytes_by_kind"]["all-reduce"]
    assert abs(s["total_wire_bytes"] - s["total_wire_bytes_tpu_adjusted"]
               - ar / 2) < 1e-6
