"""slotmap MoE (§Perf iteration) must match the onehot_scatter baseline."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import moe
from repro.models.registry import build_model


@pytest.mark.parametrize("arch", ["kimi-k2-1t-a32b",
                                  "llama4-maverick-400b-a17b"])
def test_slotmap_matches_onehot_when_dropless(arch):
    cfg = get_config(arch, smoke=True).variant(dtype="float32",
                                               capacity_factor=64.0)
    key = jax.random.PRNGKey(0)
    params = moe.init_moe_ffn(cfg, key, jnp.float32)
    h = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32) * 0.5
    out_a, aux_a = moe.moe_ffn(cfg.variant(moe_impl="slotmap"), params, h)
    out_b, aux_b = moe.moe_ffn(cfg.variant(moe_impl="onehot_scatter"),
                               params, h)
    assert float(aux_a["dropped"]) == 0.0
    assert float(aux_b["dropped"]) == 0.0
    np.testing.assert_allclose(np.asarray(out_a), np.asarray(out_b),
                               rtol=2e-4, atol=2e-5)


def test_slotmap_respects_capacity_drops():
    cfg = get_config("kimi-k2-1t-a32b", smoke=True).variant(
        dtype="float32", capacity_factor=0.25)
    params = moe.init_moe_ffn(cfg, jax.random.PRNGKey(0), jnp.float32)
    h = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model),
                          jnp.float32)
    for impl in ("slotmap", "onehot_scatter"):
        out, aux = moe.moe_ffn(cfg.variant(moe_impl=impl), params, h)
        assert float(aux["dropped"]) > 0.0, impl
        assert np.isfinite(np.asarray(out)).all()
    # identical drop fraction (same first-come-first-served policy)
    _, aux_a = moe.moe_ffn(cfg.variant(moe_impl="slotmap"), params, h)
    _, aux_b = moe.moe_ffn(cfg.variant(moe_impl="onehot_scatter"), params, h)
    np.testing.assert_allclose(float(aux_a["dropped"]),
                               float(aux_b["dropped"]), rtol=1e-6)


def test_slotmap_grads_finite():
    cfg = get_config("llama4-maverick-400b-a17b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jnp.zeros((2, 16), jnp.int32)
    g = jax.grad(lambda p: model.loss(p, {"tokens": toks, "labels": toks})[0]
                 )(params)
    gn = sum(float(jnp.sum(jnp.square(l.astype(jnp.float32))))
             for l in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
