"""Overlapped bucket pipeline: schedule invariants + differential acceptance.

The wavefront scheduler (core/overlap.py, DESIGN.md §8) must (a) emit a
schedule that keeps every bucket's stage chain in order while issuing bucket
k+1's exchange before bucket k's combine, (b) produce bit-identical results
to the serial-bucketed and per-leaf paths on every phase offset of the
8-device CPU mesh (with the stacked simulator as the independent witness),
(c) never change the collective launch count — cross-checked both on the
jaxpr and against the compiled HLO via the bucket-layout-aware summary the
dry-run records.
"""

import numpy as np
import pytest

from subproc import run_sub as _run_sub

from repro.core import overlap


# ---------------------------------------------------------------------------
# Pure-python schedule properties (no mesh, fast)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_buckets,n_stages", [
    (1, 1), (1, 3), (2, 1), (2, 2), (3, 2), (4, 3), (5, 1), (7, 4), (16, 5)])
def test_schedule_invariants(n_buckets, n_stages):
    events = overlap.pipeline_schedule(n_buckets, n_stages)
    overlap.validate_schedule(events, n_buckets, n_stages)


def test_schedule_overlap_property_explicit():
    # the tentpole claim, spelled out: next bucket's wire before my combine
    events = overlap.pipeline_schedule(3, 2)
    pos = {e: i for i, e in enumerate(events)}
    for s in range(2):
        for k in range(2):
            assert pos[(overlap.EXCHANGE, k + 1, s)] < \
                pos[(overlap.COMBINE, k, s)]
    # and no stage barrier: bucket 0 exchanges stage 1 while bucket 2 has
    # not yet combined stage 0
    assert pos[(overlap.EXCHANGE, 0, 1)] < pos[(overlap.COMBINE, 2, 0)]


def test_combine_batches_cover_all_cells_once():
    events = overlap.pipeline_schedule(4, 3)
    batches = overlap.combine_batches(events)
    cells = [c for b in batches for c in b]
    assert sorted(cells) == [(k, s) for k in range(4) for s in range(3)]
    for batch in batches:   # batched combines must touch distinct buckets
        ks = [k for k, _ in batch]
        assert len(ks) == len(set(ks))


def test_empty_and_degenerate_schedules():
    assert overlap.pipeline_schedule(0, 3) == ()
    assert overlap.pipeline_schedule(3, 0) == ()
    overlap.validate_schedule(overlap.pipeline_schedule(1, 1), 1, 1)


def test_overlapped_stage_seconds_model():
    alpha, wire, combine = 1e-5, 10e-3, 3e-3
    serial = lambda b: b * alpha + wire + combine
    # one bucket: nothing to overlap, forms coincide
    np.testing.assert_allclose(
        overlap.overlapped_stage_seconds(wire, combine, 1, alpha), serial(1))
    # B >= 2 with nonzero combine: strictly cheaper than serial
    for b in (2, 4, 16):
        t = overlap.overlapped_stage_seconds(wire, combine, b, alpha)
        assert t < serial(b)
        # lower bound: can never beat the wire (plus launches + drain slot)
        assert t >= b * alpha + wire
    # wire-bound regime: combine fully hidden except the last bucket's drain
    t4 = overlap.overlapped_stage_seconds(wire, combine, 4, alpha)
    np.testing.assert_allclose(t4, 4 * alpha + wire + combine / 4)
    # combine-bound regime mirrors it
    t4c = overlap.overlapped_stage_seconds(combine, wire, 4, alpha)
    np.testing.assert_allclose(t4c, 4 * alpha + wire + combine / 4)


# ---------------------------------------------------------------------------
# Differential acceptance on the 8-device CPU mesh (subprocess)
# ---------------------------------------------------------------------------

_PREAMBLE = """
    from repro.core import bucketing, grouping
    from repro.core import group_allreduce as ga
    from repro.core import plan as plan_mod
    from repro.launch.hlo_analysis import collective_summary, count_ppermutes

    def flat_plan(local, names, sizes, S=None, **kw):
        return plan_mod.compile_plan(
            plan_mod.Topology.flat(names, sizes), local,
            plan_mod.AveragingConfig(group_size=S,
                                     average_dtype="float32", **kw))

    def mixed_tree(rng, P_dp):
        return {
            "emb": jnp.asarray(rng.normal(size=(P_dp, 33, 7)), jnp.float32),
            "w": jnp.asarray(rng.normal(size=(P_dp, 130)), jnp.float32),
            "s": jnp.asarray(rng.normal(size=(P_dp,)), jnp.float32),
            "h": jnp.asarray(rng.normal(size=(P_dp, 3, 5)),
                             jnp.float32).astype(jnp.bfloat16),
            "e": jnp.zeros((P_dp, 0, 4), jnp.float32),
        }
"""


def run_sub(body: str, devices: int = 8, timeout: int = 420):
    return _run_sub(body, devices=devices, timeout=timeout,
                    preamble=_PREAMBLE)


def test_overlapped_equals_serial_equals_per_leaf_every_offset():
    """Acceptance gate: overlapped == serial-bucketed == per-leaf == stacked
    simulator for every phase offset, bit-for-bit under fp32 accumulation."""
    out = run_sub("""
        P_dp, S = 8, 4
        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        names, sizes = ga.dp_axis_layout(("pod", "data"), dict(pod=2, data=4),
                                         ("pod", "data"))
        rng = np.random.default_rng(0)
        tree = mixed_tree(rng, P_dp)
        offsets = grouping.distinct_offsets(P_dp, S)
        assert len(offsets) > 1, offsets
        local = jax.tree.map(lambda a: a[0], tree)
        for t, off in enumerate(offsets):
            variants = {}
            for key, kw in [
                    ("overlap_pallas", dict(fused=True, use_pallas=True,
                                            overlap=True)),
                    ("overlap_jnp", dict(fused=True, use_pallas=False,
                                         overlap=True)),
                    ("serial_bucketed", dict(fused=True, use_pallas=True,
                                             overlap=False)),
                    ("per_leaf", dict(fused=False))]:
                pl = flat_plan(local, names, sizes, S=S, **kw)
                f = compat.shard_map(
                    lambda tr, pl=pl, off=off: pl.average_offset(tr, off),
                    mesh=mesh, in_specs=P(("pod", "data")),
                    out_specs=P(("pod", "data")),
                    axis_names={"pod", "data"})
                variants[key] = jax.jit(f)(tree)
            want = ga.group_average_stacked(tree, P=P_dp, S=S, t=t)
            for key, got in variants.items():
                for leaf in tree:
                    tol = 2e-2 if leaf == "h" else 1e-5
                    np.testing.assert_allclose(
                        np.asarray(got[leaf], np.float32),
                        np.asarray(want[leaf], np.float32),
                        rtol=tol, atol=tol,
                        err_msg=f"{key} vs stacked, offset {off}, {leaf}")
            # fp32-accumulation realisations agree bit-for-bit pairwise
            for key in ("overlap_pallas", "overlap_jnp", "serial_bucketed"):
                for leaf in tree:
                    np.testing.assert_array_equal(
                        np.asarray(variants[key][leaf], np.float32),
                        np.asarray(variants["per_leaf"][leaf], np.float32),
                        err_msg=f"{key} exactness, offset {off}, {leaf}")
        print("ALL_OFFSETS_MATCH", len(offsets))
    """)
    assert "ALL_OFFSETS_MATCH" in out


def test_overlap_preserves_launch_count_and_matches_hlo():
    """Wavefront reorders launches but never adds any: jaxpr ppermutes ==
    n_buckets * log2(S) under overlap, and the compiled HLO's
    collective-permute count matches the BucketLayout expectation (the
    dry-run cross-check, exercised end to end on a dp-only mesh)."""
    out = run_sub("""
        from repro.core import plan as plan_mod
        P_dp, S = 8, 4
        mesh = jax.make_mesh((8,), ("data",))
        names, sizes = ga.dp_axis_layout(("data",), {"data": 8}, ("data",))
        rng = np.random.default_rng(1)
        tree = {f"l{i}": jnp.asarray(rng.normal(size=(8, 40)), jnp.float32)
                for i in range(6)}
        tree["h"] = jnp.asarray(rng.normal(size=(8, 16)),
                                jnp.float32).astype(jnp.bfloat16)
        local = jax.tree.map(lambda a: a[0], tree)
        pl = plan_mod.compile_plan(
            plan_mod.Topology.flat(names, sizes), local,
            plan_mod.AveragingConfig(group_size=S, average_dtype="float32"))
        stages = grouping.ilog2(S)
        expected = pl.expected_ppermutes(offset=0)
        assert expected == pl.class_layout(0).n_buckets * stages

        def make(overlap):
            plv = flat_plan(local, names, sizes, S=S, fused=True,
                            overlap=overlap)
            return jax.jit(compat.shard_map(
                lambda tr: plv.average_offset(tr, 0),
                mesh=mesh, in_specs=P("data"), out_specs=P("data"),
                axis_names={"data"}))

        for ov in (True, False):
            n = count_ppermutes(jax.make_jaxpr(make(ov))(tree).jaxpr)
            assert n == expected, (ov, n, expected)

        hlo = make(True).lower(tree).compile().as_text()
        counts = collective_summary(hlo)["counts_by_kind"]
        assert counts.get("collective-permute", 0) == expected, counts

        from repro.launch.dryrun import bucket_collective_summary
        from repro.core.wagma import WagmaAverager, WagmaConfig
        av = WagmaAverager(names, sizes, WagmaConfig(group_size=S))
        summary = bucket_collective_summary(av, local,
                                            collective_summary(hlo))
        assert summary["expected_ppermutes"] == expected, summary
        assert summary["match"], summary
        print("LAUNCHES_OK", expected)
    """)
    assert "LAUNCHES_OK" in out


def test_wagma_averager_overlap_round_trip():
    """WagmaConfig(overlap=...) end to end through the averager + sync."""
    out = run_sub("""
        from repro.core.wagma import WagmaAverager, WagmaConfig
        mesh = jax.make_mesh((8,), ("data",))
        names, sizes = ga.dp_axis_layout(("data",), {"data": 8}, ("data",))
        rng = np.random.default_rng(4)
        tree = mixed_tree(rng, 8)
        results = {}
        for overlap in (True, False):
            av = WagmaAverager(names, sizes,
                               WagmaConfig(group_size=4, overlap=overlap))
            for ph in range(av.n_phases):
                f = compat.shard_map(lambda tr, p=ph, av=av: av.comm(tr, p),
                                     mesh=mesh, in_specs=P("data"),
                                     out_specs=P("data"), axis_names={"data"})
                results[(overlap, ph)] = jax.jit(f)(tree)
            g = compat.shard_map(av.sync, mesh=mesh, in_specs=P("data"),
                                 out_specs=P("data"), axis_names={"data"})
            results[(overlap, "sync")] = jax.jit(g)(tree)
        for key in [k for k in results if k[0]]:
            other = (False,) + key[1:]
            for name in tree:
                np.testing.assert_array_equal(
                    np.asarray(results[key][name], np.float32),
                    np.asarray(results[other][name], np.float32),
                    err_msg=str(key))
        print("WAGMA_OVERLAP_OK")
    """)
    assert "WAGMA_OVERLAP_OK" in out


@pytest.mark.parametrize("name", ["dpsgd", "sgp", "adpsgd", "allreduce"])
def test_baseline_averagers_overlap_matches_serial(name):
    out = run_sub(f"""
        from repro.core.baselines import make_averager
        mesh = jax.make_mesh((8,), ("data",))
        names, sizes = ga.dp_axis_layout(("data",), {{"data": 8}}, ("data",))
        rng = np.random.default_rng(3)
        tree = {{"w": jnp.asarray(rng.normal(size=(8, 40)), jnp.float32),
                 "b": jnp.asarray(rng.normal(size=(8, 5)), jnp.float32)}}
        for phase in range(2):
            got = {{}}
            for mode, kw in [("overlap", dict(fused=True, overlap=True)),
                             ("serial", dict(fused=True, overlap=False)),
                             ("per_leaf", dict(fused=False))]:
                av = make_averager({name!r}, names, sizes, **kw)
                f = compat.shard_map(
                    lambda tr, av=av, p=phase: av.comm(tr, p), mesh=mesh,
                    in_specs=P("data"), out_specs=P("data"),
                    axis_names={{"data"}})
                got[mode] = jax.jit(f)(tree)
            for k in tree:
                np.testing.assert_array_equal(
                    np.asarray(got["overlap"][k]),
                    np.asarray(got["serial"][k]))
                np.testing.assert_allclose(
                    np.asarray(got["overlap"][k]),
                    np.asarray(got["per_leaf"][k]), rtol=1e-5, atol=1e-6)
        print("BASELINE_OVERLAP_OK")
    """)
    assert "BASELINE_OVERLAP_OK" in out
