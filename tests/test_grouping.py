"""Algorithm 1 (dynamic grouping): paper worked examples + properties."""

import math

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import grouping


def test_paper_example_p8_s4():
    # paper §III-B worked example
    assert grouping.groups_for_iteration(8, 4, 0) == ((0, 1, 2, 3), (4, 5, 6, 7))
    assert grouping.groups_for_iteration(8, 4, 1) == ((0, 1, 4, 5), (2, 3, 6, 7))


def test_propagation_latency_matches_paper():
    # paper §V-B: P=64, S=8 -> log_S P = 2 iterations
    assert grouping.propagation_latency(64, 8) == 2
    # gossip-style pairwise: log2 P
    assert grouping.propagation_latency(64, 2) == 6


def test_default_group_size_sqrt_p():
    assert grouping.default_group_size(64) == 8
    assert grouping.default_group_size(256) == 16
    assert grouping.default_group_size(16) == 4


pw2 = st.sampled_from([2, 4, 8, 16, 32, 64, 128, 256])


@settings(max_examples=200, deadline=None)
@given(P=pw2, t=st.integers(0, 1000), data=st.data())
def test_partition_properties(P, t, data):
    ls_max = grouping.ilog2(P)
    S = 2 ** data.draw(st.integers(1, ls_max))
    groups = grouping.groups_for_iteration(P, S, t)
    # non-overlapping groups of exactly S covering range(P)
    flat = sorted(x for g in groups for x in g)
    assert flat == list(range(P))
    assert all(len(g) == S for g in groups)
    assert len(groups) == P // S


@settings(max_examples=100, deadline=None)
@given(P=pw2, t=st.integers(0, 200), data=st.data())
def test_averaging_matrix_doubly_stochastic(P, t, data):
    S = 2 ** data.draw(st.integers(1, grouping.ilog2(P)))
    A = np.asarray(grouping.averaging_matrix(P, S, t))
    np.testing.assert_allclose(A.sum(0), 1.0, rtol=1e-6)
    np.testing.assert_allclose(A.sum(1), 1.0, rtol=1e-6)
    np.testing.assert_allclose(A, A.T)
    # idempotent within an iteration: averaging twice changes nothing
    np.testing.assert_allclose(A @ A, A, atol=1e-6)


@settings(max_examples=100, deadline=None)
@given(P=pw2, data=st.data())
def test_dynamic_groups_propagate_globally(P, data):
    """After propagation_latency(P,S) iterations, one worker's update has
    influenced every worker (the paper's log_S P claim)."""
    S = 2 ** data.draw(st.integers(1, grouping.ilog2(P)))
    t0 = data.draw(st.integers(0, 50))
    influence = np.eye(P, dtype=np.float64)
    lat = grouping.propagation_latency(P, S)
    for t in range(t0, t0 + lat):
        A = np.asarray(grouping.averaging_matrix(P, S, t), np.float64)
        influence = A @ influence
    assert (influence[0] > 0).all(), f"P={P} S={S} lat={lat}"


@settings(max_examples=50, deadline=None)
@given(P=pw2, data=st.data())
def test_fixed_groups_do_not_propagate(P, data):
    """Ablation 2 rationale: with *fixed* groups (offset pinned), influence
    never leaves the initial group."""
    if P < 4:
        return
    S = 2 ** data.draw(st.integers(1, grouping.ilog2(P) - 1))
    A = np.asarray(grouping.averaging_matrix(P, S, 0), np.float64)
    influence = np.eye(P)
    for _ in range(10):
        influence = A @ influence
    assert (influence[0] > 0).sum() == S


def test_mask_bits_distinct_and_rotating():
    P, S = 256, 16
    b0 = grouping.mask_bits(P, S, 0)
    b1 = grouping.mask_bits(P, S, 1)
    assert len(set(b0)) == len(b0) == grouping.ilog2(S)
    assert b0 != b1


def test_phase_offsets_cycle():
    offs = grouping.distinct_offsets(16, 4)
    assert grouping.n_phases(16, 4) == len(offs) == 2
    for t in range(20):
        assert grouping.phase_offset(16, 4, t) in offs


def test_split_bit_over_axes():
    # data=16 minor, pod=2 major
    assert grouping.split_bit_over_axes(0, [16, 2]) == (0, 0)
    assert grouping.split_bit_over_axes(3, [16, 2]) == (0, 3)
    assert grouping.split_bit_over_axes(4, [16, 2]) == (1, 0)
    with pytest.raises(ValueError):
        grouping.split_bit_over_axes(5, [16, 2])
