"""Shared pytest setup: make tests/ sibling modules importable.

pytest's rootdir insertion usually handles this, but the explicit insert
keeps ``import hypothesis_compat`` working under any invocation style
(``pytest tests/...``, ``python -m pytest`` from a parent dir, IDE runners).
"""

import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(autouse=True)
def _clear_bucket_layout_cache():
    """Keep compile-time caches from leaking across tests.

    Layouts/plans are keyed on tree structure and retain PyTreeDefs, so
    parametrised mesh/model sweeps would otherwise accumulate entries for
    the whole session; clearing per test also keeps cache-hit assertions
    (tests/test_bucketing.py) independent of test order.
    ``plan.clear_plan_cache()`` is the single delegating entry point — it
    clears the plan/shard-struct caches, both budget sweeps, and
    ``bucketing``'s layout cache.
    """
    yield
    from repro.core import plan
    plan.clear_plan_cache()
