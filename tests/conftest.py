"""Shared pytest setup: make tests/ sibling modules importable.

pytest's rootdir insertion usually handles this, but the explicit insert
keeps ``import hypothesis_compat`` working under any invocation style
(``pytest tests/...``, ``python -m pytest`` from a parent dir, IDE runners).
"""

import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(autouse=True)
def _clear_bucket_layout_cache():
    """Keep ``bucketing._LAYOUT_CACHE`` from leaking across tests.

    Layouts are keyed on tree structure and retain PyTreeDefs, so
    parametrised mesh/model sweeps would otherwise accumulate entries for
    the whole session; clearing per test also keeps cache-hit assertions
    (tests/test_bucketing.py) independent of test order.
    """
    yield
    from repro.core import bucketing, plan
    bucketing.clear_layout_cache()
    plan.clear_plan_cache()
