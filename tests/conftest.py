"""Shared pytest setup: make tests/ sibling modules importable.

pytest's rootdir insertion usually handles this, but the explicit insert
keeps ``import hypothesis_compat`` working under any invocation style
(``pytest tests/...``, ``python -m pytest`` from a parent dir, IDE runners).
"""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
