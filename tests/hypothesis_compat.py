"""Optional-``hypothesis`` shim for the property-test modules.

``from hypothesis import given, settings, strategies as st`` made four test
modules fail *collection* outright on machines without hypothesis (it is a
dev-only dependency — see requirements-dev.txt).  Property-test modules
import the same names from here instead:

    from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

With hypothesis installed this re-exports the real thing.  Without it, the
stand-ins turn each ``@given`` test into a zero-argument test that calls
``pytest.skip`` at run time — collection always succeeds and every
non-property test in the module still runs.
"""

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import inspect

    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(f):
            def skipper():
                pytest.skip("hypothesis is not installed "
                            "(pip install -r requirements-dev.txt)")
            skipper.__name__ = getattr(f, "__name__", "property_test")
            skipper.__doc__ = getattr(f, "__doc__", None)
            # keep pytest from introspecting the original signature
            skipper.__signature__ = inspect.Signature()
            return skipper
        return deco

    def settings(*_args, **_kwargs):
        return lambda f: f

    class _StrategyStub:
        """Answers any ``st.<name>(...)`` chain without evaluating anything."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()
