"""Import hygiene for the launch tools (DESIGN.md §12 bugfix sweep).

``repro.launch.dryrun`` used to call ``os.environ.setdefault("XLA_FLAGS",
"--xla_force_host_platform_device_count=512")`` at module import, so any
tool importing it for :func:`resolve_config`/:func:`lower_pair` silently
pinned a 512-device view for its whole process.  The env setup now lives
behind the CLI entry point; these tests pin that imports stay
side-effect-free.  Fresh interpreters (the parent pytest process already
initialised jax), with any inherited XLA_FLAGS scrubbed.
"""

import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "src")


def _run(script: str) -> str:
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, env=env, timeout=240)
    assert out.returncode == 0, \
        f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    return out.stdout


def test_dryrun_import_has_no_side_effects():
    _run(f"""
import os, sys
sys.path.insert(0, {SRC!r})
import repro.launch.dryrun as dryrun
assert "XLA_FLAGS" not in os.environ, os.environ["XLA_FLAGS"]
import jax
assert jax.device_count() == 1, jax.device_count()
# the CLI entry is where the sweep's 512-device default comes from
dryrun._force_host_device_count()
assert "512" in os.environ["XLA_FLAGS"]
""")


def test_dryrun_cli_env_respects_caller_flags():
    """An explicit caller-supplied XLA_FLAGS (the CI smokes) must win."""
    _run(f"""
import os, sys
sys.path.insert(0, {SRC!r})
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import repro.launch.dryrun as dryrun
dryrun._force_host_device_count()
assert os.environ["XLA_FLAGS"].endswith("device_count=8")
""")


def test_launch_module_imports_leave_device_view_alone():
    """mesh/train/elastic stay importable without touching device state."""
    _run(f"""
import os, sys
sys.path.insert(0, {SRC!r})
import repro.launch.mesh
import repro.launch.train
import repro.launch.elastic
assert "XLA_FLAGS" not in os.environ
import jax
assert jax.device_count() == 1, jax.device_count()
""")
