"""End-to-end behaviour: WAGMA-SGD convergence vs Allreduce under stragglers
(the paper's central claim, laptop scale), trainer driver, serving loop."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.configs import get_config
from repro.configs.base import InputShape, ModelConfig
from repro.core import staleness
from repro.core.group_allreduce import global_average_stacked
from repro.data import make_batch_fn
from repro.models.registry import build_model
from repro.optim import sgd

P, S, TAU = 8, 4, 5


def _run_sim(mode: str, steps: int = 60, seed: int = 0, stragglers: int = 2):
    cfg = ModelConfig(name="sys-lm", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
                      dtype="float32")
    model = build_model(cfg)
    opt = sgd(0.4, momentum=0.9)
    p0 = model.init(jax.random.PRNGKey(seed))
    stacked = jax.tree.map(lambda a: jnp.broadcast_to(a[None], (P,) + a.shape),
                           p0)
    state = staleness.init_state(stacked)
    holder = {"opt": jax.vmap(opt.init)(stacked)}
    shape = InputShape("sys", 32, P * 2, "train")
    bf = make_batch_fn(cfg, shape, seed=seed)
    strag = staleness.StragglerModel(P, n_stragglers=stragglers, p_stall=0.25,
                                     seed=seed)

    def per_worker(p, st, tokens, labels):
        loss, g = jax.value_and_grad(
            lambda q: model.loss(q, {"tokens": tokens, "labels": labels})[0]
        )(p)
        newp, newst = opt.update(g, st, p)
        return newp, newst, loss

    upd = jax.jit(jax.vmap(per_worker))
    losses = []
    for t in range(steps):
        nb = bf(t, 0, P * 2)
        toks = jnp.asarray(nb["tokens"]).reshape(P, 2, -1)
        labs = jnp.asarray(nb["labels"]).reshape(P, 2, -1)

        def local_update(models):
            newp, newst, loss = upd(models, holder["opt"], toks, labs)
            holder["opt"] = newst
            holder["loss"] = loss
            return newp

        ready, completes = strag.sample()
        if mode == "wagma":
            state = staleness.wagma_sim_step(state, local_update, P=P, S=S,
                                             tau=TAU, ready=ready,
                                             completes=completes, t=t)
        else:
            newp = global_average_stacked(local_update(state.models), P=P)
            state = state._replace(models=newp)
        losses.append(float(holder["loss"].mean()))
    return losses


def test_wagma_converges_like_allreduce_under_stragglers():
    """Paper Fig. 5's claim at laptop scale: same-budget final quality of
    WAGMA within a few percent of the synchronous baseline."""
    wagma = _run_sim("wagma")
    allr = _run_sim("allreduce")
    f_w = float(np.mean(wagma[-8:]))
    f_a = float(np.mean(allr[-8:]))
    assert wagma[-1] < wagma[0] * 0.8
    assert f_w <= f_a * 1.06, (f_w, f_a)


def test_trainer_driver_end_to_end():
    """Single-device Trainer path (mesh 1x1): compiled-variant cache,
    metrics, consolidation."""
    from repro.launch.train import Trainer
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get_config("qwen3-0.6b", smoke=True)
    tr = Trainer(cfg, mesh, averager="wagma", group_size=1, tau=3,
                 learning_rate=0.3, seq_len=32, global_batch=4)
    hist = tr.run(6, log_every=0)
    assert len(hist) == 6 and np.isfinite(hist).all()
    cons = tr.consolidated()
    assert jax.tree.leaves(cons)[0].ndim == \
        jax.tree.leaves(tr.params)[0].ndim - 1


def test_serving_greedy_decode_deterministic():
    from repro.serve import build_serve_step
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get_config("tinyllama-1.1b", smoke=True)
    model = build_model(cfg)
    with compat.set_mesh(mesh):
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        prompt = jnp.asarray(rng.integers(0, cfg.vocab, (2, 8)), jnp.int32)
        _, caches = jax.jit(lambda p, b: model.prefill(p, b, 16))(
            params, {"tokens": prompt})
        serve = build_serve_step(model, mesh)
        caches2 = jax.tree.map(jnp.copy, caches)
        tok = jnp.zeros((2, 1), jnp.int32)
        t1, _, _ = serve(params, caches, tok, jnp.asarray(8))
        t2, _, _ = serve(params, caches2, tok, jnp.asarray(8))
        np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
        assert (np.asarray(t1) < cfg.vocab).all()
