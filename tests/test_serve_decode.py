"""serve/decode.py coverage: cache-sharding heuristics + 8-device decode.

``cache_shardings`` places each cache leaf's batch dim on the dp axes and
its head/channel dim on the model axis — and must now refuse (loudly) to
replicate a cache none of whose dims divide the dp extent.  NamedSharding
needs a real multi-device mesh, so every case runs on the forced 8-device
host platform via the subprocess harness; the decode smoke additionally
pins that a batch-sharded ``build_serve_step`` produces the same tokens
as the unsharded path.
"""

from subproc import run_sub


def test_cache_sharding_heuristics_8dev():
    out = run_sub("""
        from repro.serve.decode import cache_shardings

        mesh = jax.make_mesh((4, 2), ("data", "model"))

        def spec_of(shape, batch):
            leaf = jax.ShapeDtypeStruct(shape, jnp.float32)
            return cache_shardings(mesh, {"x": leaf}, batch)["x"].spec

        # KV leaf (n_sb, B, S, KH, hd): batch over dp, hd on model
        assert spec_of((2, 8, 64, 2, 16), 8) == P(None, "data", None, None,
                                                  "model")
        # batch == 1 long context: KV *sequence* dim takes the dp axes
        assert spec_of((2, 1, 64, 2, 16), 1) == P(None, None, "data", None,
                                                  "model")
        # ambiguous seq == batch: canonical position (dim 1) wins
        assert spec_of((2, 4, 4, 2, 16), 4) == P(None, "data", None, None,
                                                 "model")
        # rank-2 recurrent vector (B, C): batch at dim 0
        assert spec_of((8, 32), 8) == P("data", "model")
        # head-count dim sized exactly B must NOT be mistaken for batch
        assert spec_of((2, 4, 64, 4, 16), 4) == P(None, "data", None, None,
                                                  "model")

        # nothing divides the dp extent -> loud failure, not silent
        # replication
        try:
            spec_of((3, 5, 7, 5, 6), 5)
        except ValueError as e:
            assert "refusing to silently replicate" in str(e)
        else:
            raise AssertionError("indivisible cache leaf did not raise")

        # hierarchical dp: (pod, data) both carry the batch dim
        mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        leaf = jax.ShapeDtypeStruct((2, 8, 64, 2, 16), jnp.float32)
        spec = cache_shardings(mesh3, {"x": leaf}, 8)["x"].spec
        assert spec == P(None, ("pod", "data"), None, None, "model"), spec
        print("HEURISTICS-OK")
    """)
    assert "HEURISTICS-OK" in out


def test_serve_step_sharded_decode_8dev():
    out = run_sub("""
        from repro.configs import get_config
        from repro.models.registry import build_model
        from repro.serve.decode import (build_serve_step, cache_shardings,
                                        serve_param_shardings)

        mesh = jax.make_mesh((8, 1), ("data", "model"))
        cfg = get_config("qwen3-0.6b", smoke=True)
        model = build_model(cfg)
        B, S = 8, 32
        with compat.set_mesh(mesh):
            params = model.init(jax.random.PRNGKey(0))
            rng = np.random.default_rng(0)
            prompt = jnp.asarray(rng.integers(0, cfg.vocab, (B, 8)), jnp.int32)
            _, caches = jax.jit(lambda p, b: model.prefill(p, b, S))(
                params, {"tokens": prompt})
            serve = build_serve_step(model, mesh)
            tok = jnp.zeros((B, 1), jnp.int32)

            t_plain, _, _ = serve(params, jax.tree.map(jnp.copy, caches),
                                  tok, jnp.asarray(8))

            cshard = cache_shardings(mesh, jax.eval_shape(lambda: caches), B)
            pshard = serve_param_shardings(mesh,
                                           jax.eval_shape(lambda: params))
            caches_s = jax.device_put(jax.tree.map(jnp.copy, caches), cshard)
            params_s = jax.device_put(params, pshard)
            tok_s = jax.device_put(tok, NamedSharding(mesh, P("data")))
            t_shard, _, _ = serve(params_s, caches_s, tok_s, jnp.asarray(8))

            np.testing.assert_array_equal(np.asarray(t_plain),
                                          np.asarray(t_shard))
            assert (np.asarray(t_plain) < cfg.vocab).all()
            print("DECODE-OK")
    """)
    assert "DECODE-OK" in out
