"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def randn(*shape, dtype=jnp.float32):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


ATTN_CASES = [
    # (b, sq, sk, h, kh, hd, causal, window, dtype)
    (2, 128, 128, 4, 2, 64, True, None, jnp.float32),
    (1, 256, 256, 4, 4, 32, True, 64, jnp.float32),
    (2, 100, 100, 2, 1, 64, False, None, jnp.float32),
    (1, 128, 256, 4, 2, 128, True, None, jnp.float32),
    (1, 64, 64, 2, 2, 64, True, None, jnp.bfloat16),
    (1, 72, 72, 3, 1, 48, True, 16, jnp.float32),   #非-128-aligned
]


@pytest.mark.parametrize("case", ATTN_CASES)
def test_flash_attention_allclose(case):
    b, sq, sk, h, kh, hd, causal, window, dtype = case
    q = randn(b, sq, h, hd, dtype=dtype)
    k = randn(b, sk, kh, hd, dtype=dtype)
    v = randn(b, sk, kh, hd, dtype=dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_matches_model_blocked_attention():
    from repro.models.common import blocked_attention
    q = randn(2, 96, 4, 64)
    k = randn(2, 96, 2, 64)
    v = randn(2, 96, 2, 64)
    for window in (None, 32):
        a = ops.flash_attention(q, k, v, causal=True, window=window,
                                block_q=32, block_k=32)
        bopt = blocked_attention(q, k, v, causal=True, window=window,
                                 block_q=32, block_k=32)
        np.testing.assert_allclose(np.asarray(a), np.asarray(bopt),
                                   rtol=2e-4, atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 5000), inv_s=st.sampled_from([0.5, 0.25, 1 / 3.0]),
       seed=st.integers(0, 100))
def test_group_average_combine_property(n, inv_s, seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal(n), jnp.float32)
    r = jnp.asarray(rng.standard_normal(n), jnp.float32)
    out = ops.group_average_combine(w, r, inv_s)
    want = ref.group_average_ref(w, r, inv_s)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("shape,dtype", [
    ((3, 5), jnp.float32), ((33, 257), jnp.bfloat16), ((1,), jnp.float32),
    ((2, 3, 4, 5), jnp.float32)])
def test_group_average_combine_shapes(shape, dtype):
    w = randn(*shape, dtype=dtype)
    r = randn(*shape, dtype=dtype)
    out = ops.group_average_combine(w, r, 0.5)
    want = ref.group_average_ref(w, r, 0.5)
    assert out.shape == shape and out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), rtol=2e-2)


# -- group_average_combine: the fused butterfly-combine kernel --------------
# Direct interpret-mode sweeps (no TPU needed — marked `cpu` so CI always
# runs them): non-divisible sizes exercise the lane/row padding path,
# small block_rows forces multi-block grids, bf16 checks the fp32-accumulate
# + downcast contract, and inv_s sweeps the static scale.

from repro.kernels.group_average import group_average_combine as raw_combine

COMBINE_SIZES = [1, 5, 127, 128, 129, 1000, 8 * 128, 8 * 128 + 3, 4096 + 77]


@pytest.mark.cpu
@pytest.mark.parametrize("n", COMBINE_SIZES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_group_average_combine_interpret_padding_sweep(n, dtype):
    rng = np.random.default_rng(n)
    w = jnp.asarray(rng.standard_normal(n), jnp.float32).astype(dtype)
    r = jnp.asarray(rng.standard_normal(n), jnp.float32).astype(dtype)
    out = raw_combine(w, r, 0.5, block_rows=8, interpret=True)
    want = ref.group_average_ref(w, r, 0.5)
    assert out.shape == w.shape and out.dtype == dtype
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-6
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.cpu
@pytest.mark.parametrize("inv_s", [1.0, 0.5, 0.25, 1 / 3.0, 0.125])
def test_group_average_combine_inv_s_sweep(inv_s):
    rng = np.random.default_rng(7)
    w = jnp.asarray(rng.standard_normal(777), jnp.float32)
    r = jnp.asarray(rng.standard_normal(777), jnp.float32)
    out = raw_combine(w, r, inv_s, interpret=True)
    want = ref.group_average_ref(w, r, inv_s)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-6)


@pytest.mark.cpu
def test_group_average_combine_fp32_accumulation_beats_bf16():
    # large + tiny in bf16: accumulating in fp32 then rounding once must
    # match the fp32 reference rounded to bf16 (the kernel's whole point)
    w = jnp.full((256,), 256.0, jnp.bfloat16)
    r = jnp.full((256,), 0.75, jnp.bfloat16)
    out = raw_combine(w, r, 0.5, interpret=True)
    want = ((jnp.asarray(w, jnp.float32) + jnp.asarray(r, jnp.float32))
            * 0.5).astype(jnp.bfloat16)
    np.testing.assert_array_equal(np.asarray(out, np.float32),
                                  np.asarray(want, np.float32))


@pytest.mark.cpu
def test_group_average_combine_empty_and_nd_shapes():
    e = jnp.zeros((0, 4), jnp.float32)
    out = raw_combine(e, e, 0.5, interpret=True)
    assert out.shape == (0, 4) and out.dtype == jnp.float32
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((3, 5, 7)), jnp.float32)
    r = jnp.asarray(rng.standard_normal((3, 5, 7)), jnp.float32)
    out = raw_combine(w, r, 0.25, block_rows=8, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.group_average_ref(w, r, 0.25)),
                               rtol=1e-6)


# -- group_average_combine_multi: one launch per wavefront tick -------------
# The overlapped scheduler batches independent bucket combines into a single
# pallas_call whose grid walks buckets x row-tiles; ragged (lane-unaligned)
# bucket sizes exercise the per-bucket row padding.

from repro.kernels.group_average import group_average_combine_multi

RAGGED_BATCHES = [
    [1],                          # single bucket delegates to the pair kernel
    [1, 130, 128],                # unaligned / unaligned / aligned
    [5, 127, 129, 1000, 37],      # many small ragged buckets
    [8 * 128, 3, 4096 + 77],      # one multi-block + tiny + unaligned
]


@pytest.mark.cpu
@pytest.mark.parametrize("sizes", RAGGED_BATCHES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_group_average_combine_multi_ragged(sizes, dtype):
    rng = np.random.default_rng(sum(sizes))
    ws = [jnp.asarray(rng.standard_normal(n), jnp.float32).astype(dtype)
          for n in sizes]
    rs = [jnp.asarray(rng.standard_normal(n), jnp.float32).astype(dtype)
          for n in sizes]
    outs = group_average_combine_multi(ws, rs, 0.25, block_rows=8,
                                       interpret=True)
    assert len(outs) == len(ws)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-6
    for w, r, o in zip(ws, rs, outs):
        assert o.shape == w.shape and o.dtype == dtype
        np.testing.assert_allclose(
            np.asarray(o, np.float32),
            np.asarray(ref.group_average_ref(w, r, 0.25), np.float32),
            rtol=tol, atol=tol)


@pytest.mark.cpu
def test_group_average_combine_multi_matches_singles_bitwise():
    # batching must not change the math: same kernel body, same fp32
    # accumulate, so each bucket's result equals its solo-launch result
    rng = np.random.default_rng(11)
    sizes = [130, 999, 128]
    ws = [jnp.asarray(rng.standard_normal(n), jnp.float32) for n in sizes]
    rs = [jnp.asarray(rng.standard_normal(n), jnp.float32) for n in sizes]
    batched = group_average_combine_multi(ws, rs, 0.5, block_rows=8,
                                          interpret=True)
    for w, r, got in zip(ws, rs, batched):
        solo = raw_combine(w, r, 0.5, block_rows=8, interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(solo))


@pytest.mark.cpu
def test_group_average_combine_multi_rejects_mixed_dtypes():
    w32 = jnp.zeros((4,), jnp.float32)
    w16 = jnp.zeros((4,), jnp.bfloat16)
    with pytest.raises(ValueError):
        group_average_combine_multi([w32, w16], [w32, w16], 0.5,
                                    interpret=True)
    with pytest.raises(ValueError):
        group_average_combine_multi([], [], 0.5, interpret=True)


RGLRU_CASES = [
    (3, 200, 96, True), (1, 17, 130, False), (8, 128, 128, True),
    (2, 300, 64, False),
]


@pytest.mark.parametrize("case", RGLRU_CASES)
def test_rglru_scan_allclose(case):
    b, s, w, with_h0 = case
    rng = np.random.default_rng(hash(case) % 2**31)
    a = jnp.asarray(rng.uniform(0.5, 0.999, (b, s, w)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((b, s, w)) * 0.1, jnp.float32)
    h0 = jnp.asarray(rng.standard_normal((b, w)), jnp.float32) if with_h0 else None
    out = ops.rglru_scan(a, x, h0)
    want = ref.rglru_scan_ref(a, x, h0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_rglru_kernel_matches_model_associative_scan():
    from repro.models.rglru import rglru_scan as assoc
    rng = np.random.default_rng(7)
    a = jnp.asarray(rng.uniform(0.5, 0.99, (2, 64, 32)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 64, 32)) * 0.1, jnp.float32)
    h0 = jnp.asarray(rng.standard_normal((2, 32)), jnp.float32)
    np.testing.assert_allclose(np.asarray(ops.rglru_scan(a, x, h0)),
                               np.asarray(assoc(a, x, h0)),
                               rtol=1e-4, atol=1e-4)


def test_mlstm_sequential_reference_stability():
    """mLSTM oracle stays finite under extreme gate pre-activations."""
    b, s, h, dh = 1, 32, 2, 16
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((b, s, h, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, h, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, h, dh)), jnp.float32)
    i_pre = jnp.asarray(rng.uniform(-30, 30, (b, s, h)), jnp.float32)
    f_pre = jnp.asarray(rng.uniform(-30, 30, (b, s, h)), jnp.float32)
    out = ref.mlstm_chunk_ref(q, k, v, i_pre, f_pre)
    assert np.isfinite(np.asarray(out)).all()
