"""Analytic FLOP model validated against XLA cost_analysis.

XLA counts a scan body once, so validation uses n_layers small enough that
the layer scan has trip count 1 (exact) and checks the analytic per-token
forward FLOPs against the compiled forward within tolerance (XLA adds
elementwise/softmax flops the matmul-level model ignores).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.configs.base import InputShape, ModelConfig
from repro.core import group_allreduce as ga
from repro.launch.costmodel import (averaging_comm_cost, decode_cost,
                                    fwd_flops_per_token, param_count,
                                    train_cost)
from repro.models.registry import build_model


def one_layer_cfg(**kw):
    base = dict(name="cm-test", family="dense", n_layers=1, d_model=128,
                n_heads=4, n_kv_heads=2, d_ff=512, vocab=512,
                dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


@pytest.mark.parametrize("kw", [
    {},                                     # dense gated
    {"gated_mlp": False, "act": "gelu"},    # starcoder-style
    {"n_heads": 8, "n_kv_heads": 8},        # MHA
])
def test_dense_fwd_flops_vs_xla(kw):
    cfg = one_layer_cfg(**kw)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 64
    toks = jnp.zeros((B, S), jnp.int32)

    def fwd(p):
        return model.forward(p, {"tokens": toks}, remat=False)[0]

    ca = compat.cost_analysis(jax.jit(fwd).lower(params).compile())
    xla = ca["flops"]
    analytic = sum(fwd_flops_per_token(cfg, S).values()) * B * S
    # analytic counts matmuls only; XLA adds elementwise — expect within 35%
    assert 0.6 < analytic / xla < 1.35, (analytic, xla)


def test_param_count_matches_init():
    for arch_kw in [
        {},
        {"family": "moe", "n_experts": 4, "top_k": 2, "shared_expert": True,
         "first_dense": 1, "n_layers": 3},
    ]:
        cfg = one_layer_cfg(**arch_kw)
        model = build_model(cfg)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        actual = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
        est, _ = param_count(cfg)
        # vocab padding + norm scales are not in the estimate: within 12%
        assert abs(est - actual) / actual < 0.12, (cfg.family, est, actual)


def test_moe_active_params_scale_with_topk():
    cfg = one_layer_cfg(family="moe", n_layers=4, n_experts=8, top_k=2)
    total, active = param_count(cfg)
    assert active < total
    cfg2 = cfg.variant(top_k=4)
    _, active2 = param_count(cfg2)
    assert active2 > active


def test_train_cost_decomposition():
    cfg = one_layer_cfg(n_layers=12)
    shape = InputShape("t", 4096, 256, "train")
    rep = train_cost(cfg, shape, n_dp=16, n_model=16)
    assert rep.flops_per_device > 0 and rep.hbm_bytes_per_device > 0
    # remat multiplies forward by ~4/3 over no-remat
    rep2 = train_cost(cfg, shape, n_dp=16, n_model=16, remat=False)
    assert rep.flops_per_device > rep2.flops_per_device
    # model_flops <= hlo flops (padding/attention make HLO bigger)
    assert rep.model_flops <= rep.flops_per_device * 1.05


def test_decode_cost_cache_dominates_long_context():
    cfg = one_layer_cfg(n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
                        d_ff=4096, vocab=32000)
    shape = InputShape("d", 32768, 128, "decode")
    rep = decode_cost(cfg, shape, n_dp=16, n_model=16)
    assert rep.breakdown["cache_read"] > 0
    # with a sliding window the cache read shrinks
    cfgw = cfg.with_sliding_window(1024)
    repw = decode_cost(cfgw, shape, n_dp=16, n_model=16)
    assert repw.breakdown["cache_read"] < rep.breakdown["cache_read"] / 4


# -- alpha-beta collective latency model -------------------------------------

def test_collective_time_alpha_beta_decomposition():
    alpha, beta = 20e-6, 1.0 / 10e9
    n_bytes, P, S = 50e6, 64, 8
    base = ga.collective_time(n_bytes, P, S, "wagma", n_buckets=1,
                              alpha=alpha, beta=beta)
    # bytes term is launch-count independent; alpha term scales linearly
    t300 = ga.collective_time(n_bytes, P, S, "wagma", n_buckets=300,
                              alpha=alpha, beta=beta)
    stages = ga.collective_stages(P, S, "wagma")
    assert stages == 3
    np.testing.assert_allclose(t300 - base, stages * 299 * alpha, rtol=1e-9)
    wire = ga.collective_bytes_per_device(n_bytes, P, S, "wagma")
    np.testing.assert_allclose(base, stages * alpha + wire * beta, rtol=1e-9)
    # zero-latency network: bucketing is a no-op in the model
    assert ga.collective_time(n_bytes, P, S, "wagma", n_buckets=300,
                              alpha=0.0, beta=beta) == \
        ga.collective_time(n_bytes, P, S, "wagma", n_buckets=1,
                           alpha=0.0, beta=beta)


def test_collective_stages_ordering():
    # group butterfly must be latency-cheaper than any global collective
    P, S = 64, 8
    assert ga.collective_stages(P, S, "wagma") < \
        ga.collective_stages(P, S, "butterfly_global") < \
        ga.collective_stages(P, S, "ring_allreduce")


def test_averaging_comm_cost_bucketing_speedup():
    cfg = one_layer_cfg(n_layers=24)
    rep = averaging_comm_cost(cfg, P=64, S=8, n_leaves=290)
    assert rep.n_buckets < rep.n_leaves
    assert rep.t_bucketed < rep.t_per_leaf
    assert rep.speedup > 1.0
    # explicit bucket count wins more with fewer buckets
    rep1 = averaging_comm_cost(cfg, P=64, S=8, n_leaves=290, n_buckets=1)
    assert rep1.t_bucketed <= rep.t_bucketed


def test_alpha_beta_overlap_variant():
    alpha, beta, gamma = 20e-6, 1e-10, 4e-12
    wire, stages = 150e6, 3
    serial = ga.alpha_beta_time(wire, stages, n_buckets=4, alpha=alpha,
                                beta=beta, gamma=gamma)
    # serial form: launches + wire + combine, additive
    np.testing.assert_allclose(
        serial, stages * 4 * alpha + wire * (beta + gamma), rtol=1e-12)
    over = ga.alpha_beta_time(wire, stages, n_buckets=4, alpha=alpha,
                              beta=beta, gamma=gamma, overlap=True)
    # overlapped: strictly cheaper with >1 bucket and a nonzero combine...
    assert over < serial
    # ...never cheaper than the pure-network time (combine can hide, wire
    # cannot), and identical when there is nothing to hide
    assert over >= ga.alpha_beta_time(wire, stages, n_buckets=4, alpha=alpha,
                                      beta=beta)
    np.testing.assert_allclose(
        ga.alpha_beta_time(wire, stages, n_buckets=1, alpha=alpha, beta=beta,
                           gamma=gamma, overlap=True),
        ga.alpha_beta_time(wire, stages, n_buckets=1, alpha=alpha, beta=beta,
                           gamma=gamma), rtol=1e-12)
    # gamma=0 keeps the classic formula under both schedules
    np.testing.assert_allclose(
        ga.alpha_beta_time(wire, stages, n_buckets=4, alpha=alpha, beta=beta,
                           overlap=True),
        ga.alpha_beta_time(wire, stages, n_buckets=4, alpha=alpha, beta=beta),
        rtol=1e-12)


def test_wagma_step_time_overlap_strictly_wins():
    kw = dict(tau=10, n_buckets=8, gamma=ga.DEFAULT_GAMMA)
    serial = ga.wagma_step_time(245e6, 64, 8, overlap=False, **kw)
    over = ga.wagma_step_time(245e6, 64, 8, overlap=True, **kw)
    assert over < serial
    # the hidden time is bounded by the group combine term
    hidden = serial - over
    group_combine = ga.collective_bytes_per_device(245e6, 64, 8, "wagma") \
        * ga.DEFAULT_GAMMA * 9 / 10
    assert hidden <= group_combine + 1e-12


def test_choose_bucket_bytes_minimises_model():
    from repro.core import bucketing
    payload = 245_000_000
    chosen = bucketing.choose_bucket_bytes(payload, P=64, S=8)
    assert chosen in bucketing.BUCKET_BYTES_CANDIDATES
    t_chosen = ga.wagma_step_time(
        payload, 64, 8, tau=10, n_buckets=max(1, -(-payload // chosen)),
        gamma=ga.DEFAULT_GAMMA, overlap=True)
    for cand in bucketing.BUCKET_BYTES_CANDIDATES:
        t = ga.wagma_step_time(
            payload, 64, 8, tau=10, n_buckets=max(1, -(-payload // cand)),
            gamma=ga.DEFAULT_GAMMA, overlap=True)
        assert t_chosen <= t + 1e-15, (chosen, cand)
    # alpha-dominated network: one huge bucket must win
    lazy = bucketing.choose_bucket_bytes(payload, P=64, S=8, alpha=10.0,
                                         beta=0.0, gamma=0.0)
    assert lazy == max(bucketing.BUCKET_BYTES_CANDIDATES)


def test_averaging_comm_cost_overlap_fields():
    from repro.core import bucketing
    # big enough that every candidate budget still yields several buckets —
    # the regime the overlap win exists in
    cfg = one_layer_cfg(n_layers=24, d_model=1024, n_heads=8, n_kv_heads=8,
                        d_ff=4096, vocab=32000)
    rep = averaging_comm_cost(cfg, P=64, S=8, n_leaves=290)
    assert rep.t_overlapped > 0
    assert rep.overlap_speedup > 1.0
    assert rep.chosen_bucket_bytes in bucketing.BUCKET_BYTES_CANDIDATES
    assert rep.n_buckets_overlapped >= 1
    # tiny payload: a single bucket, nothing to hide, speedup ~1 — the
    # report must degrade gracefully rather than promise a win
    small = averaging_comm_cost(one_layer_cfg(), P=64, S=8, n_leaves=10)
    assert small.n_buckets_overlapped == 1
    np.testing.assert_allclose(small.overlap_speedup, 1.0, rtol=1e-9)


def test_cluster_sim_overlap_win():
    import os, sys
    sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "benchmarks"))
    from cluster_sim import overlap_win
    win = overlap_win(P=64, model_bytes=245e6, n_buckets=8)
    assert win["speedup"] > 1.0
    assert win["combine_hidden_s"] > 0.0
    assert win["overlapped_comm_s"] < win["serial_comm_s"]


def test_cluster_sim_bucketing_win():
    import os, sys
    sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "benchmarks"))
    from cluster_sim import bucketing_win, comm_time
    win = bucketing_win(P=64, n_leaves=300, n_buckets=4)
    assert win["speedup"] > 1.0
    # same payload, fewer launches -> strictly cheaper step in the model
    assert comm_time(50e6, 64, 8, "wagma", n_buckets=4) < \
        comm_time(50e6, 64, 8, "wagma", n_buckets=300)
