"""Analytic FLOP model validated against XLA cost_analysis.

XLA counts a scan body once, so validation uses n_layers small enough that
the layer scan has trip count 1 (exact) and checks the analytic per-token
forward FLOPs against the compiled forward within tolerance (XLA adds
elementwise/softmax flops the matmul-level model ignores).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import InputShape, ModelConfig
from repro.launch.costmodel import (decode_cost, fwd_flops_per_token,
                                    param_count, train_cost)
from repro.models.registry import build_model


def one_layer_cfg(**kw):
    base = dict(name="cm-test", family="dense", n_layers=1, d_model=128,
                n_heads=4, n_kv_heads=2, d_ff=512, vocab=512,
                dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


@pytest.mark.parametrize("kw", [
    {},                                     # dense gated
    {"gated_mlp": False, "act": "gelu"},    # starcoder-style
    {"n_heads": 8, "n_kv_heads": 8},        # MHA
])
def test_dense_fwd_flops_vs_xla(kw):
    cfg = one_layer_cfg(**kw)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 64
    toks = jnp.zeros((B, S), jnp.int32)

    def fwd(p):
        return model.forward(p, {"tokens": toks}, remat=False)[0]

    ca = jax.jit(fwd).lower(params).compile().cost_analysis()
    xla = ca["flops"]
    analytic = sum(fwd_flops_per_token(cfg, S).values()) * B * S
    # analytic counts matmuls only; XLA adds elementwise — expect within 35%
    assert 0.6 < analytic / xla < 1.35, (analytic, xla)


def test_param_count_matches_init():
    for arch_kw in [
        {},
        {"family": "moe", "n_experts": 4, "top_k": 2, "shared_expert": True,
         "first_dense": 1, "n_layers": 3},
    ]:
        cfg = one_layer_cfg(**arch_kw)
        model = build_model(cfg)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        actual = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
        est, _ = param_count(cfg)
        # vocab padding + norm scales are not in the estimate: within 12%
        assert abs(est - actual) / actual < 0.12, (cfg.family, est, actual)


def test_moe_active_params_scale_with_topk():
    cfg = one_layer_cfg(family="moe", n_layers=4, n_experts=8, top_k=2)
    total, active = param_count(cfg)
    assert active < total
    cfg2 = cfg.variant(top_k=4)
    _, active2 = param_count(cfg2)
    assert active2 > active


def test_train_cost_decomposition():
    cfg = one_layer_cfg(n_layers=12)
    shape = InputShape("t", 4096, 256, "train")
    rep = train_cost(cfg, shape, n_dp=16, n_model=16)
    assert rep.flops_per_device > 0 and rep.hbm_bytes_per_device > 0
    # remat multiplies forward by ~4/3 over no-remat
    rep2 = train_cost(cfg, shape, n_dp=16, n_model=16, remat=False)
    assert rep.flops_per_device > rep2.flops_per_device
    # model_flops <= hlo flops (padding/attention make HLO bigger)
    assert rep.model_flops <= rep.flops_per_device * 1.05


def test_decode_cost_cache_dominates_long_context():
    cfg = one_layer_cfg(n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
                        d_ff=4096, vocab=32000)
    shape = InputShape("d", 32768, 128, "decode")
    rep = decode_cost(cfg, shape, n_dp=16, n_model=16)
    assert rep.breakdown["cache_read"] > 0
    # with a sliding window the cache read shrinks
    cfgw = cfg.with_sliding_window(1024)
    repw = decode_cost(cfgw, shape, n_dp=16, n_model=16)
    assert repw.breakdown["cache_read"] < rep.breakdown["cache_read"] / 4
