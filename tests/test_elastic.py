"""Elastic topology & membership (DESIGN.md §12).

Host-side tests pin the pure machinery: power-of-two quantisation,
topology diffing (membership changes only resize dp axes), the
epoch-stamped :class:`MembershipController` state machine (leave ->
immediate shrink + spares, join -> deferred to the tau-sync barrier,
epoch audit trail, min-world floor), checkpoint-free state handoff in
both layouts (replicated row selection; FSDP pod rows unpacked through
the old plan's shard layout and repacked through the new one's), and
plan-cache eviction of dropped topologies.

The subprocess test runs the full kill/rejoin protocol on the forced-host
CPU mesh — the SAME code path as the CI smoke
(``python -m repro.launch.elastic``): a worker leaves mid-training, the
dp mesh shrinks and the plan recompiles without a restart, and the
rejoined worker's replica row is bit-identical to the survivors' at the
first post-rejoin tau-sync.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from subproc import run_sub as _run_sub

from repro.core import bucketing
from repro.core import plan as plan_mod
from repro.core import replica
from repro.core.elastic import (MembershipController, diff_topology,
                                handoff_state, largest_pow2,
                                regrow_replica_state, resize_topology,
                                select_replica_rows)
from repro.core.plan import AveragingConfig, Topology, compile_plan
from repro.core.replica import (ReplicaState, ShardingPolicy,
                                effective_rank_map)
from repro.optim import sgd

TREE = {"emb": jax.ShapeDtypeStruct((33, 70), jnp.float32),
        "w": jax.ShapeDtypeStruct((1300,), jnp.float32),
        "h": jax.ShapeDtypeStruct((300,), jnp.bfloat16)}
FSDP = ShardingPolicy.fsdp_within_pod("data")


# ---------------------------------------------------------------------------
# Quantisation + topology diffing
# ---------------------------------------------------------------------------

def test_largest_pow2():
    assert [largest_pow2(n) for n in (0, 1, 2, 3, 4, 5, 7, 8, 9)] == \
        [0, 1, 2, 2, 4, 4, 4, 8, 8]
    assert largest_pow2(-3) == 0
    assert largest_pow2(1 << 20) == 1 << 20


def test_diff_topology_resize_only():
    old = Topology.hierarchical(("data", "pod"), (4, 2))
    new = resize_topology(old, "data", 2)
    d = diff_topology(old, new)
    assert d.requires_recompile
    assert d.resized == (("data", 4, 2),)
    assert "data: 4 -> 2" in d.describe()
    same = diff_topology(old, old)
    assert not same.requires_recompile
    assert same.describe() == "topology unchanged"


def test_diff_topology_rejects_structural_changes():
    old = Topology.hierarchical(("data", "pod"), (4, 2))
    renamed = Topology.hierarchical(("data", "node"), (4, 2))
    with pytest.raises(ValueError, match="axis names"):
        diff_topology(old, renamed)
    flat = Topology.flat(("data", "pod"), (4, 2))
    with pytest.raises(ValueError, match="link-class"):
        diff_topology(old, flat)


def test_resize_topology_validation():
    topo = Topology.hierarchical(("data", "pod"), (4, 2))
    assert resize_topology(topo, "pod", 4).axis_sizes == (4, 4)
    with pytest.raises(ValueError, match="no axis"):
        resize_topology(topo, "nope", 2)
    with pytest.raises(ValueError):
        resize_topology(topo, "data", 3)       # Topology enforces pow2


# ---------------------------------------------------------------------------
# MembershipController state machine
# ---------------------------------------------------------------------------

def test_controller_quantizes_shrinks_and_regrows():
    c = MembershipController(range(6))
    m = c.membership
    assert m.active == (0, 1, 2, 3) and m.spares == (4, 5)
    assert m.epoch == 0 and m.world_size == 4

    # active leave: immediate shrink, demoted survivor becomes a spare
    ev = c.leave(1)
    assert ev.kind == "shrink" and ev.epoch == 1
    assert ev.world == (0, 2) and ev.keep_rows == (0, 2)
    assert c.membership.spares == (4, 5, 3)

    # spare leave is a noop (no collective rides on it)
    assert c.leave(4).kind == "noop"
    assert c.membership.spares == (5, 3)

    # joins defer to the barrier; duplicates are noops
    assert c.join(1).kind == "defer"
    assert c.join(1).kind == "noop"
    assert c.membership.pending == (1,)

    # barrier: spares + joiners promote up to the next power of two
    ev = c.at_sync_barrier()
    assert ev.kind == "regrow" and ev.epoch == 2 and ev.n_joined == 2
    assert ev.world == (0, 2, 5, 3)
    assert c.membership.pending == (1,)      # no room for it yet
    assert c.at_sync_barrier().kind == "noop"

    # the audit trail records every epoch
    assert [m.epoch for m in c.history] == [0, 1, 2]
    assert c.history[1].active == (0, 2)


def test_controller_min_world_floor():
    with pytest.raises(ValueError, match="at least"):
        MembershipController([0], min_world=2)
    c = MembershipController([0, 1])
    with pytest.raises(RuntimeError, match="survivors"):
        c.leave(0)
    with pytest.raises(ValueError, match="unknown worker"):
        c.leave(9)
    with pytest.raises(ValueError, match="duplicate"):
        MembershipController([0, 0, 1])


# ---------------------------------------------------------------------------
# Checkpoint-free state handoff
# ---------------------------------------------------------------------------

def _stacked_state(n_rows: int, seed: int = 0) -> ReplicaState:
    rng = np.random.default_rng(seed)
    params = {"w": jnp.asarray(rng.normal(size=(n_rows, 5)), jnp.float32),
              "b": jnp.asarray(rng.normal(size=(n_rows, 3)), jnp.float32)}
    opt = jax.vmap(sgd(0.1).init)(params)
    opt = replica.map_opt_state(
        opt,
        lambda t: jax.tree.map(lambda m, p: 0.5 * p.astype(jnp.float32),
                               t, params),
        lambda c: jnp.arange(n_rows, dtype=c.dtype))
    return ReplicaState.create(params, opt, step=7, phase=1)


def test_select_replica_rows_and_regrow():
    st = _stacked_state(4)
    rows = [2, 0]
    sel = select_replica_rows(st, rows)
    for got, src in zip(jax.tree.leaves((sel.params, sel.opt_state)),
                        jax.tree.leaves((st.params, st.opt_state))):
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(src)[rows])
    assert int(sel.step) == 7 and int(sel.phase) == 1

    # regrow clones the consensus row for the appended joiners
    grown = regrow_replica_state(sel, 4, source_row=0)
    w = np.asarray(grown.params["w"])
    assert w.shape[0] == 4
    np.testing.assert_array_equal(w[2], w[0])
    np.testing.assert_array_equal(w[3], w[0])
    np.testing.assert_array_equal(np.asarray(grown.opt_state.count),
                                  np.asarray(sel.opt_state.count)[[0, 1, 0, 0]])
    with pytest.raises(ValueError, match="regrow"):
        regrow_replica_state(grown, 2)


def test_handoff_replicated_is_row_selection():
    st = _stacked_state(4)
    a = handoff_state(st, [1, 3])
    b = select_replica_rows(st, [1, 3])
    for x, y in zip(jax.tree.leaves((a.params, a.opt_state)),
                    jax.tree.leaves((b.params, b.opt_state))):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _pod_state(pod_models, topo, plan) -> ReplicaState:
    """Stack per-pod models to full dp rows and convert to the fsdp layout."""
    eff = effective_rank_map(topo.axis_sizes,
                             topo.axis_names.index(plan.sharding.shard_axis))
    stacked = jax.tree.map(
        lambda *ls: jnp.stack([np.asarray(ls[e]) for e in eff]), *pod_models)
    opt = jax.vmap(sgd(0.1).init)(stacked)
    opt = replica.map_opt_state(
        opt,
        lambda t: jax.tree.map(
            lambda m, p: (0.5 * p.astype(jnp.float32)), t, stacked),
        lambda c: c)
    st_rep = ReplicaState.create(stacked, opt, step=7, phase=1)
    return replica.replicated_to_fsdp_state(st_rep, plan)


def test_handoff_fsdp_pod_shrink_bit_exact():
    """Pods 4 -> 2: unpack through the old layout, repack through the new.

    The two plans choose their own bucket budgets, so the layouts need
    not match — the handoff must still be bit-exact, equal to building
    the surviving pods' state under the new plan directly.
    """
    rng = np.random.default_rng(1)
    old_topo = Topology.hierarchical(("data", "pod"), (4, 4))
    new_topo = resize_topology(old_topo, "pod", 2)
    cfg = AveragingConfig(group_size=2, bucket_bytes=4096)
    old_plan = compile_plan(old_topo, TREE, cfg, FSDP)
    new_plan = compile_plan(new_topo, TREE, cfg, FSDP)
    assert old_plan.P_eff == 4 and new_plan.P_eff == 2

    pods = [{"emb": jnp.asarray(rng.normal(size=(33, 70)), jnp.float32),
             "w": jnp.asarray(rng.normal(size=(1300,)), jnp.float32),
             "h": jnp.asarray(rng.normal(size=(300,)),
                              jnp.float32).astype(jnp.bfloat16)}
            for _ in range(old_plan.P_eff)]
    st_old = _pod_state(pods, old_topo, old_plan)

    keep = [0, 2]
    moved = handoff_state(st_old, keep, old_plan=old_plan,
                          new_plan=new_plan)
    want = _pod_state([pods[i] for i in keep], new_topo, new_plan)
    for got, exp in zip(jax.tree.leaves((moved.params, moved.opt_state)),
                        jax.tree.leaves((want.params, want.opt_state))):
        np.testing.assert_array_equal(np.asarray(got, np.float32),
                                      np.asarray(exp, np.float32))
    assert int(moved.step) == 7 and int(moved.phase) == 1


def test_handoff_rejects_policy_and_layout_crossings():
    topo = Topology.hierarchical(("data", "pod"), (4, 2))
    cfg = AveragingConfig(group_size=2, bucket_bytes=4096)
    plan_all = compile_plan(topo, TREE, cfg, FSDP)
    st = _pod_state([jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), TREE)] * plan_all.P_eff,
        topo, plan_all)
    with pytest.raises(ValueError, match="cross sharding policies"):
        handoff_state(st, [0], old_plan=plan_all, new_plan=None)
    stream = ShardingPolicy.fsdp_within_pod("data", streamed=True)
    ltree = {"stem": {"emb": TREE["emb"]},
             "layers": ({"w": jax.ShapeDtypeStruct((650,), jnp.float32)},
                        {"w": jax.ShapeDtypeStruct((650,), jnp.float32)}),
             "head": {"h": TREE["h"]}}
    plan_stream = compile_plan(topo, ltree, cfg, stream)
    with pytest.raises(ValueError, match="streamed"):
        handoff_state(st, [0, 1], old_plan=plan_all, new_plan=plan_stream)
    with pytest.raises(ValueError, match="P_eff"):
        handoff_state(st, [0], old_plan=plan_all, new_plan=plan_all)


# ---------------------------------------------------------------------------
# Plan-cache hygiene on membership change
# ---------------------------------------------------------------------------

def test_evict_topology_drops_only_the_dead_world():
    topo_a = Topology.hierarchical(("data", "pod"), (4, 2))
    topo_b = resize_topology(topo_a, "data", 2)
    cfg = AveragingConfig(group_size=2, bucket_bytes=4096)
    pa = compile_plan(topo_a, TREE, cfg)
    pa_f = compile_plan(topo_a, TREE, cfg, FSDP)
    pb = compile_plan(topo_b, TREE, cfg)
    assert compile_plan(topo_a, TREE, cfg) is pa
    assert plan_mod.evict_topology(topo_a) >= 2     # plan + shard structs
    assert compile_plan(topo_a, TREE, cfg) is not pa
    assert compile_plan(topo_a, TREE, cfg, FSDP) is not pa_f
    assert compile_plan(topo_b, TREE, cfg) is pb    # survivor untouched
    assert plan_mod.evict_topology(topo_a) >= 1     # the recompiles above


def test_clear_plan_cache_delegates_to_layout_cache():
    bucketing.layout_for(TREE, max_bucket_bytes=4096)
    assert bucketing._LAYOUT_CACHE
    plan_mod.clear_plan_cache()
    assert not bucketing._LAYOUT_CACHE
    assert not plan_mod._PLAN_CACHE


# ---------------------------------------------------------------------------
# The kill/rejoin protocol on the CPU mesh (subprocess)
# ---------------------------------------------------------------------------

def test_kill_rejoin_training_survives_and_rejoiner_bit_identical():
    """A worker dies at t=2, announces its rejoin, the world shrinks 4->2
    and training continues; at the t=3 tau-sync the world regrows; at the
    final tau-sync the rejoiner's replica row is bit-identical to every
    survivor's.  Same code path as the ``python -m repro.launch.elastic``
    CI smoke."""
    out = _run_sub("""
        from repro.launch.elastic import kill_rejoin_demo

        rep = kill_rejoin_demo(log_every=0)
        assert rep["rejoin_bit_identical"]
        worlds = [r["world"] for r in rep["history"]]
        assert worlds == [4, 4, 2, 2, 4, 4, 4, 4], worlds
        epochs = [r["epoch"] for r in rep["history"]]
        assert epochs == [0, 0, 1, 1, 2, 2, 2, 2], epochs
        kinds = [e["kind"] for e in rep["epoch_log"]]
        assert kinds == ["shrink", "regrow"], kinds
        assert all(e["plans_evicted"] >= 1 for e in rep["epoch_log"])
        print("ELASTIC_KILL_REJOIN_OK")
    """, devices=8, timeout=600)
    assert "ELASTIC_KILL_REJOIN_OK" in out


# ---------------------------------------------------------------------------
# Property: controller invariants under adversarial interleavings
# ---------------------------------------------------------------------------

from hypothesis_compat import given, settings, st  # noqa: E402

_OPS = ("leave", "join", "barrier")


def _drive_controller(ops, pool):
    """Replay an arbitrary leave/join/barrier interleaving and check every
    invariant the launch layer leans on after each op:

    * the active world is always a power of two >= min_world;
    * active/spares/pending are disjoint, no worker duplicated;
    * ``join`` never promotes — the active set only grows at the barrier;
    * a shrink's ``keep_rows`` maps old active rows onto the new world;
    * the epoch bumps exactly when the active set changes, and the
      history holds one snapshot per epoch;
    * rejected ops (unknown worker, below-min-world shrink) leave the
      controller untouched.
    """
    mc = MembershipController(range(pool), min_world=2)
    last_epoch = mc.epoch
    for op, w in ops:
        before = mc.membership
        try:
            if op == "leave":
                ev = mc.leave(w)
            elif op == "join":
                ev = mc.join(w)
                assert ev.kind in ("defer", "noop")
                assert mc.membership.active == before.active, \
                    "join promoted outside the sync barrier"
            else:
                ev = mc.at_sync_barrier()
        except (ValueError, RuntimeError):
            assert mc.membership == before, \
                "a rejected op must not mutate membership"
            continue
        m = mc.membership
        n = m.world_size
        assert n >= mc.min_world and n & (n - 1) == 0, m
        seen = list(m.active) + list(m.spares) + list(m.pending)
        assert len(seen) == len(set(seen)), m
        if ev.kind == "shrink":
            assert [before.active[i] for i in ev.keep_rows] == list(m.active)
        assert mc.epoch >= last_epoch
        if set(m.active) != set(before.active):
            assert mc.epoch == last_epoch + 1
            assert ev.kind in ("shrink", "regrow"), ev
        else:
            assert mc.epoch == last_epoch
        last_epoch = mc.epoch
    assert [h.epoch for h in mc.history] == list(range(mc.epoch + 1))


@given(ops=st.lists(st.tuples(st.sampled_from(_OPS), st.integers(0, 13)),
                    max_size=50),
       pool=st.integers(4, 12))
@settings(max_examples=80, deadline=None)
def test_membership_invariants_property(ops, pool):
    _drive_controller(ops, pool)


@pytest.mark.parametrize("seed", range(6))
def test_membership_invariants_seeded_interleavings(seed):
    """Deterministic stand-in for the property test when hypothesis is
    unavailable: seeded random 60-op interleavings over a 4..12 pool."""
    rng = np.random.default_rng(seed)
    pool = int(rng.integers(4, 13))
    ops = [(_OPS[int(rng.integers(3))], int(rng.integers(14)))
           for _ in range(60)]
    _drive_controller(ops, pool)
