"""Disaggregated prefill/decode KV transfer (DESIGN.md §14).

Pins the transfer layer's whole contract: the connector's pack/unpack
round-trip is verbatim, message sizes respect the link's modeled budget,
``TransferStats`` prices transfers exactly as ``plan.link_transfer_seconds``
does, and — the claim that matters — a :class:`DisaggregatedScheduler`
(prefill on a separate worker, KV blocks shipped through the connector)
produces **bit-identical** outputs to the colocated scheduler.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import bucketing
from repro.core import plan as plan_mod
from repro.models.registry import build_model
from repro.serve.kv_transfer import (DisaggregatedScheduler, InProcessTransport,
                                     LinkCostedConnector, kv_payload_bytes)
from repro.serve.scheduler import Request, ServeScheduler


@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_config("qwen3-0.6b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


RAGGED = [(3, 6), (7, 4), (5, 9), (12, 5)]


def _run(sched_cls, model, params, **kw):
    sched = sched_cls(model, params, n_blocks=64, block_size=4,
                      max_blocks_per_req=8, max_batch=4, **kw)
    rng = np.random.default_rng(1)
    for i, (l, n) in enumerate(RAGGED):
        sched.submit(Request(i, rng.integers(0, model.cfg.vocab,
                                             (l,)).astype(np.int32), n))
    return sched, sched.run()


def test_disaggregated_bit_exact_vs_colocated(smoke_model):
    model, params = smoke_model
    _, colo = _run(ServeScheduler, model, params)
    sched, disagg = _run(DisaggregatedScheduler, model, params)
    assert disagg == colo
    stats = sched.connector.stats
    assert stats.requests == len(RAGGED)
    # each request ships ceil((prompt_len + 1) / block_size) blocks
    assert stats.blocks == sum(-(-(l + 1) // 4) for l, _ in RAGGED)
    assert stats.payload_bytes > 0 and stats.messages >= stats.requests
    assert stats.modeled_seconds > 0


def test_connector_round_trip_and_budget():
    rng = np.random.default_rng(0)
    tree = {"k": rng.standard_normal((2, 3, 4, 2, 8)).astype(np.float32),
            "v": rng.standard_normal((2, 3, 4, 2, 8)).astype(np.float32)}
    transport = InProcessTransport()
    conn = LinkCostedConnector(link=plan_mod.DCN, transport=transport)
    conn.insert("r0", tree, {"first": 7})
    with pytest.raises(KeyError):
        conn.insert("r0", tree, {})                # duplicate rid
    got, meta = conn.select("r0")
    assert meta["first"] == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), tree, got)
    assert conn.select("r0") is None               # taken exactly once
    # the wire saw >= the payload (pack may pad), in budget-sized messages
    payload = bucketing.tree_payload_bytes(tree)
    budget = conn.budget_for(payload)
    assert transport.bytes_sent >= payload
    assert transport.messages_sent == conn.stats.messages
    assert conn.stats.modeled_seconds == pytest.approx(
        plan_mod.link_transfer_seconds(payload, plan_mod.DCN,
                                       message_bytes=budget))


def test_message_bytes_override_splits_messages():
    rng = np.random.default_rng(2)
    tree = {"k": rng.standard_normal((4, 1024)).astype(np.float32)}
    small = LinkCostedConnector(link=plan_mod.DCN, message_bytes=4096)
    small.insert("r", tree, {})
    assert small.stats.messages >= 4               # 16 KiB / 4 KiB budget
    got, _ = small.select("r")
    np.testing.assert_array_equal(got["k"], tree["k"])


def test_kv_payload_bytes_matches_cache():
    cfg = get_config("qwen3-0.6b", smoke=True)
    model = build_model(cfg)
    caches = jax.eval_shape(lambda: model.init_caches(1, 16))
    assert kv_payload_bytes(cfg, 16) == bucketing.tree_payload_bytes(caches)


def test_link_transfer_seconds_model():
    link = plan_mod.LinkClass("t", alpha=1e-3, beta=1e-9)
    assert plan_mod.link_transfer_seconds(0, link) == 0.0
    # explicit budget: 2 messages of alpha + wire time
    t = plan_mod.link_transfer_seconds(2 * 1024, link, message_bytes=1024)
    assert t == pytest.approx(2 * 1e-3 + 2 * 1024 * 1e-9)
    # modeled budget picks fewer, larger messages for an alpha-heavy link
    assert plan_mod.link_transfer_seconds(int(64e6), link) < \
        plan_mod.link_transfer_seconds(int(64e6), link, message_bytes=1 << 16)
