"""Paged KV cache + continuous-batching scheduler (DESIGN.md §14).

Host-side tests pin the BlockPool allocator invariants (unit + hypothesis
property sweep).  Single-process model tests pin the core serving claim:
the paged scheduler's outputs — ragged admission, bucket-padded decode
batches, recompute preemption under block pressure — are **bit-identical**
to each request decoded alone against the dense reference path
(``model.prefill`` + ``model.decode_step``).  The subprocess test repeats
the end-to-end claim on the 8-device host mesh and additionally checks the
sharded paged decode step (batch over dp, pool replicated) against the
unsharded one.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from subproc import run_sub

from repro.configs import get_config
from repro.models import common as cm
from repro.models.registry import build_model
from repro.serve.kv_cache import NULL_BLOCK, BlockPool, OutOfBlocks
from repro.serve.scheduler import FINISHED, Request, ServeScheduler


# ---------------------------------------------------------------------------
# BlockPool allocator
# ---------------------------------------------------------------------------

def test_block_pool_alloc_free_evict():
    pool = BlockPool(n_blocks=8, block_size=4)
    assert pool.n_free == 7                       # block 0 reserved
    tbl = pool.allocate("a", 9)                   # ceil(9/4) = 3 blocks
    assert len(tbl) == 3 and NULL_BLOCK not in tbl
    assert pool.tokens_covered("a") == 9
    # growing to the same coverage takes nothing; never shrinks
    assert pool.allocate("a", 5) == tbl
    assert pool.tokens_covered("a") == 9
    pool.allocate("b", 16)
    assert pool.n_free == 0
    assert not pool.can_allocate("c", 1)
    with pytest.raises(OutOfBlocks):
        pool.allocate("c", 1)
    assert "c" not in pool._tables                # atomic: nothing taken
    assert pool.evict("b") == 4 and pool.evictions == 1
    assert pool.free("a") == 3
    assert pool.n_free == 7
    pool.check_invariants()


def test_block_pool_padded_table_and_validation():
    pool = BlockPool(n_blocks=6, block_size=2)
    pool.allocate(0, 3)
    padded = pool.padded_table(0, 4)
    assert padded.shape == (4,) and padded.dtype == np.int32
    assert list(padded[:2]) == pool.table(0)
    assert (padded[2:] == NULL_BLOCK).all()
    with pytest.raises(ValueError):
        pool.padded_table(0, 1)                   # table wider than max
    with pytest.raises(ValueError):
        BlockPool(n_blocks=1, block_size=4)       # no room beside null
    with pytest.raises(ValueError):
        BlockPool(n_blocks=4, block_size=0)


@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 5), st.integers(0, 3),
                          st.integers(1, 40)), max_size=60),
       st.integers(2, 12), st.integers(1, 5))
def test_block_pool_property(ops, n_blocks, block_size):
    """Arbitrary allocate/free/evict interleavings keep every invariant:
    no double ownership, the null block never handed out, freed blocks
    return, and each live table covers exactly its request's tokens."""
    pool = BlockPool(n_blocks=n_blocks, block_size=block_size)
    for rid, op, n_tokens in ops:
        if op == 0:
            try:
                tbl = pool.allocate(rid, n_tokens)
                assert len(tbl) == pool.blocks_for(pool.tokens_covered(rid))
            except OutOfBlocks:
                pass
        elif op == 1:
            pool.free(rid)
            assert pool.tokens_covered(rid) == 0 and pool.table(rid) == []
        else:
            pool.evict(rid)
        pool.check_invariants()
    for rid in list(pool._tables):
        pool.free(rid)
    assert pool.n_free == n_blocks - 1


# ---------------------------------------------------------------------------
# Scheduler vs the uncontended dense reference
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_config("qwen3-0.6b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def reference_decode(model, params, prompt, max_new, s_view):
    """Per-request uncontended greedy decode on the dense cache path —
    the bit-exactness oracle (same masked argmax as the paged builders)."""
    vocab = model.cfg.vocab
    pf = jax.jit(lambda p, b: model.prefill(p, b, s_view))
    step = jax.jit(model.decode_step)

    def pick(logits):
        lg = logits[0, -1]
        lg = jnp.where(jnp.arange(lg.shape[-1]) < vocab, lg, cm.NEG_INF)
        return int(jnp.argmax(lg))

    logits, caches = pf(params, {"tokens": jnp.asarray(prompt[None])})
    out = [pick(logits)]
    while len(out) < max_new:
        pos = prompt.shape[0] + len(out) - 1
        logits, caches = step(params, caches,
                              jnp.asarray([[out[-1]]], jnp.int32),
                              jnp.asarray(pos))
        out.append(pick(logits))
    return out


RAGGED = [(3, 6), (7, 4), (5, 9), (12, 5)]        # (prompt_len, max_new)


def _prompts(cfg, lens, seed=1):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, (l,)).astype(np.int32) for l in lens]


def test_scheduler_bit_exact_and_bucketed(smoke_model):
    model, params = smoke_model
    bs, max_blocks = 4, 8
    sched = ServeScheduler(model, params, n_blocks=64, block_size=bs,
                           max_blocks_per_req=max_blocks, max_batch=4)
    prompts = _prompts(model.cfg, [l for l, _ in RAGGED])
    for i, (p, (_, n)) in enumerate(zip(prompts, RAGGED)):
        sched.submit(Request(i, p, n))
    outs = sched.run()
    assert sorted(outs) == [0, 1, 2, 3]
    for i, (p, (_, n)) in enumerate(zip(prompts, RAGGED)):
        ref = reference_decode(model, params, p, n, max_blocks * bs)
        assert outs[i] == ref, f"request {i} diverged from dense reference"
        assert sched.finished[i].state == FINISHED
    # decode only ever compiled at bucket-padded batch shapes
    assert sched.decode_shapes_compiled <= \
        {(b, max_blocks) for b in sched.batch_buckets}
    # everything returned to the pool
    assert sched.blocks.n_free == 63
    sched.blocks.check_invariants()


def test_scheduler_preemption_recompute_bit_exact(smoke_model):
    """Three requests whose joint footprint exceeds the pool: the LIFO
    recompute preemption must evict/re-admit and still produce bit-exact
    outputs (greedy decode is deterministic)."""
    model, params = smoke_model
    bs, max_blocks = 4, 8
    lens = [(9, 12), (8, 13), (10, 11)]
    sched = ServeScheduler(model, params, n_blocks=14, block_size=bs,
                           max_blocks_per_req=max_blocks, max_batch=4)
    prompts = _prompts(model.cfg, [l for l, _ in lens], seed=2)
    for i, (p, (_, n)) in enumerate(zip(prompts, lens)):
        sched.submit(Request(i, p, n))
    outs = sched.run()
    assert sched.blocks.evictions > 0, "pool pressure never triggered"
    assert any(r.preemptions > 0 for r in sched.finished.values())
    for i, (p, (_, n)) in enumerate(zip(prompts, lens)):
        ref = reference_decode(model, params, p, n, max_blocks * bs)
        assert outs[i] == ref, f"request {i} diverged after preemption"
    assert sched.blocks.n_free == 13
    sched.blocks.check_invariants()


def test_scheduler_eos_and_validation(smoke_model):
    model, params = smoke_model
    sched = ServeScheduler(model, params, n_blocks=16, block_size=4,
                           max_blocks_per_req=4, max_batch=2)
    with pytest.raises(ValueError):                # exceeds max context
        sched.submit(Request("big", np.zeros(10, np.int32), 8))
    p = _prompts(model.cfg, [5])[0]
    ref = reference_decode(model, params, p, 6, 16)
    eos = ref[2]                                   # force an early stop
    sched.submit(Request("e", p, 6, eos_id=eos))
    outs = sched.run()
    assert outs["e"] == ref[:3]
    # a single request bigger than the whole pool fails loudly
    sched2 = ServeScheduler(model, params, n_blocks=3, block_size=4,
                            max_blocks_per_req=4, max_batch=2)
    sched2.submit(Request("x", np.zeros(9, np.int32), 2))
    with pytest.raises(OutOfBlocks):
        sched2.run()


# ---------------------------------------------------------------------------
# 8-device end-to-end (acceptance): scheduler on the host mesh
# ---------------------------------------------------------------------------

def test_serving_e2e_8dev_bit_exact():
    out = run_sub("""
        from repro.configs import get_config
        from repro.models import common as cm
        from repro.models.registry import build_model
        from repro.serve import kv_cache
        from repro.serve.scheduler import Request, ServeScheduler

        mesh = jax.make_mesh((8, 1), ("data", "model"))
        cfg = get_config("qwen3-0.6b", smoke=True)
        model = build_model(cfg)
        bs, max_blocks = 4, 8
        with compat.set_mesh(mesh):
            params = model.init(jax.random.PRNGKey(0))
            rng = np.random.default_rng(3)
            lens = [(3, 6), (7, 4), (5, 9), (12, 5), (9, 3), (4, 7)]
            prompts = [rng.integers(0, cfg.vocab, (l,)).astype(np.int32)
                       for l, _ in lens]
            sched = ServeScheduler(model, params, n_blocks=64, block_size=bs,
                                   max_blocks_per_req=max_blocks, max_batch=8)
            for i, (p, (_, n)) in enumerate(zip(prompts, lens)):
                sched.submit(Request(i, p, n))
            outs = sched.run()

            s_view = max_blocks * bs
            pf = jax.jit(lambda p, b: model.prefill(p, b, s_view))
            step = jax.jit(model.decode_step)
            def pick(logits):
                lg = logits[0, -1]
                lg = jnp.where(jnp.arange(lg.shape[-1]) < cfg.vocab, lg,
                               cm.NEG_INF)
                return int(jnp.argmax(lg))
            for i, (p, (_, n)) in enumerate(zip(prompts, lens)):
                logits, caches = pf(params, {"tokens": jnp.asarray(p[None])})
                ref = [pick(logits)]
                while len(ref) < n:
                    pos = len(p) + len(ref) - 1
                    logits, caches = step(params, caches,
                                          jnp.asarray([[ref[-1]]], jnp.int32),
                                          jnp.asarray(pos))
                    ref.append(pick(logits))
                assert outs[i] == ref, (i, outs[i], ref)
            assert sched.decode_shapes_compiled <= \\
                {(b, max_blocks) for b in sched.batch_buckets}, \\
                sched.decode_shapes_compiled

            # sharded paged decode (batch over dp, pool replicated) must
            # match the unsharded step bit-for-bit
            decode = kv_cache.build_paged_decode(model, block_size=bs)
            pool = kv_cache.init_paged_pool(model, 32, bs)
            blocks = kv_cache.BlockPool(32, bs)
            tables = np.zeros((8, max_blocks), np.int32)
            tokens = np.zeros((8,), np.int32)
            positions = np.zeros((8,), np.int32)
            prefill = kv_cache.build_paged_prefill(model, block_size=bs)
            for i in range(8):
                p = rng.integers(0, cfg.vocab, (3 + i,)).astype(np.int32)
                blocks.allocate(i, len(p) + 1)
                tables[i] = blocks.padded_table(i, max_blocks)
                pool, first = prefill(params, pool, jnp.asarray(p[None]),
                                      jnp.asarray(tables[i]))
                tokens[i] = int(first)
                positions[i] = len(p)
            rep = NamedSharding(mesh, P())
            dp = NamedSharding(mesh, P("data"))
            pool_a = jax.tree.map(jnp.copy, pool)
            pool_b = jax.device_put(jax.tree.map(jnp.copy, pool), rep)
            _, nxt_plain = decode(params, pool_a, jnp.asarray(tables),
                                  jnp.asarray(tokens), jnp.asarray(positions))
            _, nxt_shard = decode(jax.device_put(params, rep), pool_b,
                                  jax.device_put(jnp.asarray(tables), dp),
                                  jax.device_put(jnp.asarray(tokens), dp),
                                  jax.device_put(jnp.asarray(positions), dp))
            np.testing.assert_array_equal(np.asarray(nxt_plain),
                                          np.asarray(nxt_shard))
            print("E2E-OK", sorted(sched.decode_shapes_compiled))
    """)
    assert "E2E-OK" in out
