"""Distributed-path tests: run in subprocesses with forced host devices so
the main pytest process keeps the real single-device CPU view (the dry-run
flag must never be set globally — see the system design notes)."""

import sys

import pytest

from subproc import SRC, run_sub


def test_butterfly_group_average_equals_stacked_simulator():
    out = run_sub("""
        from repro.core import group_allreduce as ga
        from repro.core.wagma import WagmaAverager, WagmaConfig
        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        names, sizes = ga.dp_axis_layout(("pod", "data"), dict(pod=2, data=4),
                                         ("pod", "data"))
        av = WagmaAverager(names, sizes, WagmaConfig(group_size=4))
        W = np.random.default_rng(0).normal(size=(8, 6, 5)).astype(np.float32)
        tree = {"w": jnp.asarray(W)}
        for t in range(5):
            ph = av.phase_for_step(t)
            f = compat.shard_map(lambda tr: av.comm(tr, ph), mesh=mesh,
                              in_specs=P(("pod", "data")),
                              out_specs=P(("pod", "data")),
                              axis_names={"pod", "data"})
            got = np.asarray(jax.jit(f)(tree)["w"])
            want = np.asarray(ga.group_average_stacked(tree, P=8, S=4, t=t)["w"])
            np.testing.assert_allclose(got, want, rtol=1e-5)
        print("MATCH")
    """)
    assert "MATCH" in out


def _partial_auto_scan_ok():
    import sys
    sys.path.insert(0, SRC)
    from repro import compat
    return compat.PARTIAL_AUTO_SCAN_OK


@pytest.mark.skipif(not _partial_auto_scan_ok(), reason=(
    "JAX 0.4.x XLA crashes (IsManualSubgroup check) on lax.scan over "
    "auto-axis-sharded xs inside a partially-manual shard_map; the dp x tp "
    "train step needs a newer JAX"))
def test_wagma_train_step_loss_decreases_and_sync_equalises():
    out = run_sub("""
        from repro.configs import get_config, SHAPES
        from repro.models.registry import build_model
        from repro.data import make_batch_fn
        from repro.optim import sgd
        from repro.core.baselines import make_averager
        from repro.core.group_allreduce import dp_axis_layout
        from repro.train import build_train_step, init_replica_state

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = get_config("qwen3-0.6b", smoke=True)
        model = build_model(cfg)
        names, sizes = dp_axis_layout(mesh.axis_names, dict(mesh.shape),
                                      ("data",))
        av = make_averager("wagma", names, sizes, group_size=2, tau=4)
        opt = sgd(0.3, momentum=0.9)
        with compat.set_mesh(mesh):
            state = init_replica_state(model, opt, av, mesh,
                                       jax.random.PRNGKey(0))
            bf = make_batch_fn(cfg, SHAPES["train_4k"], seed=0)
            steps, losses = {}, []
            for t in range(8):
                key = (av.phase_for_step(t), av.sync_due(t))
                if key not in steps:
                    steps[key] = build_train_step(model, opt, av, mesh,
                                                  phase=key[0], sync=key[1])
                nb = {k: jnp.asarray(v)[:, :32] for k, v in bf(t, 0, 8).items()}
                batch = {k: jax.device_put(v, NamedSharding(mesh, P("data", None)))
                         for k, v in nb.items()}
                state, m = steps[key](state, batch)
                losses.append(float(m["loss"]))
            assert int(state.step) == 8
            w = np.asarray(jax.tree.leaves(state.params)[0], np.float32)
            assert np.abs(w - w[0:1]).max() < 1e-4, "sync must equalise replicas"
            assert losses[-1] < losses[0], losses
            print("LOSSES", ["%.3f" % l for l in losses])
    """)
    assert "LOSSES" in out


def test_all_baseline_averagers_compile_and_preserve_mean():
    out = run_sub("""
        from repro.core.baselines import make_averager
        from repro.core.group_allreduce import dp_axis_layout
        mesh = jax.make_mesh((8,), ("data",))
        names, sizes = dp_axis_layout(("data",), {"data": 8}, ("data",))
        W = np.random.default_rng(1).normal(size=(8, 40)).astype(np.float32)
        tree = {"w": jnp.asarray(W)}
        for name in ("dpsgd", "sgp", "adpsgd", "wagma"):
            av = make_averager(name, names, sizes)
            for ph in range(min(av.n_phases, 3)):
                f = compat.shard_map(lambda tr, p=ph: av.comm(tr, p), mesh=mesh,
                                  in_specs=P("data"), out_specs=P("data"),
                                  axis_names={"data"})
                got = np.asarray(jax.jit(f)(tree)["w"])
                np.testing.assert_allclose(got.mean(0), W.mean(0),
                                           rtol=1e-4, atol=1e-5)
        print("MEAN_OK")
    """)
    assert "MEAN_OK" in out


def test_grad_averager_allreduce_matches_single_worker_equivalent():
    """Allreduce-SGD with P replicas on the same data == single worker."""
    out = run_sub("""
        from repro.configs import get_config, SHAPES
        from repro.models.registry import build_model
        from repro.optim import sgd
        from repro.core.baselines import make_averager
        from repro.core.group_allreduce import dp_axis_layout
        from repro.train import build_train_step, init_replica_state

        mesh = jax.make_mesh((4, 1), ("data", "model"))
        cfg = get_config("tinyllama-1.1b", smoke=True).variant(dtype="float32")
        model = build_model(cfg)
        names, sizes = dp_axis_layout(mesh.axis_names, dict(mesh.shape), ("data",))
        av = make_averager("allreduce", names, sizes)
        opt = sgd(0.1, momentum=0.9)
        rng = np.random.default_rng(0)
        toks = rng.integers(0, cfg.vocab, (1, 32)).astype(np.int32)
        # identical batch on every replica -> pmean(grads) == local grads
        batch_np = {"tokens": np.repeat(toks, 4, 0), "labels": np.repeat(toks, 4, 0)}
        with compat.set_mesh(mesh):
            state = init_replica_state(model, opt, av, mesh,
                                       jax.random.PRNGKey(0))
            step = build_train_step(model, opt, av, mesh, phase=0, sync=False)
            batch = {k: jax.device_put(jnp.asarray(v),
                                       NamedSharding(mesh, P("data", None)))
                     for k, v in batch_np.items()}
            state, _ = step(state, batch)
            w = np.asarray(jax.tree.leaves(state.params)[0])
        # single worker reference
        p0 = model.init(jax.random.PRNGKey(0))
        st0 = opt.init(p0)
        g = jax.grad(lambda p: model.loss(p, {"tokens": jnp.asarray(toks),
                                              "labels": jnp.asarray(toks)})[0])(p0)
        p1, _ = opt.update(g, st0, p0)
        ref = np.asarray(jax.tree.leaves(p1)[0])
        np.testing.assert_allclose(w[0], ref, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(w[1], ref, rtol=1e-4, atol=1e-5)
        print("EQUIV_OK")
    """)
    assert "EQUIV_OK" in out
