"""ReplicaState & ShardingPolicy differentials (DESIGN.md §10).

Host-side tests pin the pure pieces: policy validation, the shard-aligned
bucket layout, plan-cache keying on the policy, effective-rank mapping,
host-side cross-policy state conversion, and the FSDP memory/step cost
model.  Subprocess tests pin the sharded execution on the 8-device CPU
mesh: ``fsdp_within_pod`` plan execution must be bit-identical to the
replicated plan and the stacked simulator on EVERY phase offset (flat and
hierarchical topologies), shard ownership must round-trip, per-class
launch counts must be unchanged by sharding, the sharded train step's
all-gathers must ride the intra-pod axis only, and a checkpoint written
by a sharded run must restore into a replicated run (and vice versa) with
``consolidate`` agreeing bit-for-bit.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from subproc import run_sub as _run_sub

from repro.core import bucketing, grouping
from repro.core import plan as plan_mod
from repro.core import replica
from repro.core.plan import AveragingConfig, LinkClass, Topology, compile_plan
from repro.core.replica import (ReplicaState, ShardingPolicy,
                                effective_rank_map)
from repro.optim import sgd


# ---------------------------------------------------------------------------
# Policy + state basics
# ---------------------------------------------------------------------------

def test_sharding_policy_validation():
    assert ShardingPolicy.replicated().kind == "replicated"
    pol = ShardingPolicy.fsdp_within_pod("data")
    assert pol.is_sharded and pol.shard_axis == "data"
    with pytest.raises(ValueError):
        ShardingPolicy("zero3")
    with pytest.raises(ValueError):
        ShardingPolicy("fsdp_within_pod")          # no shard axis
    with pytest.raises(ValueError):
        ShardingPolicy("replicated", "data")       # spurious shard axis


def test_replica_state_is_a_pytree():
    params = {"w": jnp.arange(4.0)}
    opt = sgd(0.1).init(params)
    st = ReplicaState.create(params, opt, step=3, phase=1)
    leaves, treedef = jax.tree_util.tree_flatten(st)
    st2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert int(st2.step) == 3 and int(st2.phase) == 1
    bumped = jax.jit(lambda s: ReplicaState(s.params, s.opt_state,
                                            s.step + 1, s.phase))(st)
    assert int(bumped.step) == 4


# ---------------------------------------------------------------------------
# Sharded plan compilation
# ---------------------------------------------------------------------------

TREE = {"emb": jax.ShapeDtypeStruct((33, 70), jnp.float32),
        "w": jax.ShapeDtypeStruct((1300,), jnp.float32),
        "h": jax.ShapeDtypeStruct((300,), jnp.bfloat16),
        "e": jax.ShapeDtypeStruct((0, 4), jnp.float32)}
FSDP = ShardingPolicy.fsdp_within_pod("data")


def test_shard_layout_alignment_and_struct():
    topo = Topology.hierarchical(("data", "pod"), (4, 2))
    plan = compile_plan(topo, TREE, AveragingConfig(group_size=2,
                                                    bucket_bytes=4096), FSDP)
    k = plan.shard_size
    assert k == 4 and plan.P_eff == 2
    lay = plan.shard_layout
    for size in lay.bucket_sizes:
        assert size % (k * 128) == 0, "buckets must split into lane-aligned shards"
    for sds, size, dt in zip(plan.shard_struct(), lay.bucket_sizes,
                             lay.bucket_dtypes):
        assert sds.shape == (size // k,) and sds.dtype == dt
    # storage dtypes survive (bf16 stays bf16 between averaging steps)
    assert np.dtype(jnp.bfloat16) in set(lay.bucket_dtypes)


def test_plan_cache_keyed_on_sharding_and_shard_struct_registry():
    topo = Topology.hierarchical(("data", "pod"), (4, 2))
    cfg = AveragingConfig(group_size=2)
    p_rep = compile_plan(topo, TREE, cfg)
    p_fsdp = compile_plan(topo, TREE, cfg, FSDP)
    assert p_rep is not p_fsdp
    assert compile_plan(topo, TREE, cfg, FSDP) is p_fsdp
    # the shard-buffer structure resolves back to the same plan (the train
    # step holds shards, not the full tree)
    assert compile_plan(topo, p_fsdp.shard_struct(), cfg, FSDP) is p_fsdp


def test_fsdp_validation():
    topo = Topology.hierarchical(("data", "pod"), (4, 2))
    with pytest.raises(ValueError, match="bottleneck"):
        compile_plan(topo, TREE, AveragingConfig(group_size=2),
                     ShardingPolicy.fsdp_within_pod("pod"))
    with pytest.raises(ValueError, match="not a dp axis"):
        compile_plan(topo, TREE, AveragingConfig(group_size=2),
                     ShardingPolicy.fsdp_within_pod("model"))
    with pytest.raises(ValueError):
        Topology.flat(("data",), (8,)).drop_axis("data")
    # group size is bounded by the logical (pod) world, not the dp world
    with pytest.raises(ValueError, match="replica world"):
        compile_plan(topo, TREE, AveragingConfig(group_size=4), FSDP)


def test_effective_rank_map():
    # minor-to-major (data=4, pod=2); dp rank = pod*4 + data
    eff = effective_rank_map((4, 2), 0)
    np.testing.assert_array_equal(eff, [0, 0, 0, 0, 1, 1, 1, 1])
    # sharding over the major axis keeps the minor coordinate
    eff2 = effective_rank_map((4, 2), 1)
    np.testing.assert_array_equal(eff2, [0, 1, 2, 3, 0, 1, 2, 3])


def test_launch_counts_unchanged_by_sharding():
    """One ppermute per bucket per stage — sharding never multiplies the
    launch count by the shard count, and an all-f32 tree lays out into the
    same bucket count as the replicated plan at the same budget."""
    tree = {f"l{i}": jax.ShapeDtypeStruct((700,), jnp.float32)
            for i in range(6)}
    topo = Topology.flat(("data", "pod"), (4, 2),
                         link=LinkClass("link", bucket_bytes=4096))
    cfg = AveragingConfig(group_size=2, bucket_bytes=4096)
    p_fsdp = compile_plan(topo, tree, cfg, FSDP)
    p_rep_eff = compile_plan(Topology.flat(("pod",), (2,),
                                           link=LinkClass("link")),
                             tree, cfg)
    n = p_fsdp.shard_layout.n_buckets
    assert n == p_rep_eff.class_layout(0).n_buckets > 1
    for off in p_fsdp.offsets:
        stages = len(grouping.mask_bits_for_offset(p_fsdp.P_eff, p_fsdp.S,
                                                   off))
        assert p_fsdp.expected_ppermutes(off) == n * stages
        assert p_fsdp.expected_ppermutes(off) == \
            p_rep_eff.expected_ppermutes(off)


# ---------------------------------------------------------------------------
# Host-side cross-policy conversion
# ---------------------------------------------------------------------------

def _pod_identical_stacked_state(topo, plan, seed=0):
    """(P_dp, ...)-stacked state whose pod members hold identical weights."""
    rng = np.random.default_rng(seed)
    eff = effective_rank_map(topo.axis_sizes, plan.shard_axis_index)
    pod_models = [
        {"emb": jnp.asarray(rng.normal(size=(33, 70)), jnp.float32),
         "w": jnp.asarray(rng.normal(size=(1300,)), jnp.float32),
         "h": jnp.asarray(rng.normal(size=(300,)),
                          jnp.float32).astype(jnp.bfloat16),
         "e": jnp.zeros((0, 4), jnp.float32)}
        for _ in range(plan.P_eff)]
    stacked = jax.tree.map(
        lambda *ls: jnp.stack([np.asarray(ls[e]) for e in eff]), *pod_models)
    opt = jax.vmap(sgd(0.1).init)(stacked)
    return ReplicaState.create(stacked, opt, step=7, phase=1)


def test_cross_policy_conversion_round_trip_exact():
    topo = Topology.hierarchical(("data", "pod"), (4, 2))
    plan = compile_plan(topo, TREE, AveragingConfig(group_size=2,
                                                    bucket_bytes=4096), FSDP)
    st_rep = _pod_identical_stacked_state(topo, plan)
    st_fsdp = replica.replicated_to_fsdp_state(st_rep, plan)
    assert isinstance(st_fsdp.params, tuple)
    assert all(b.shape[0] == plan.P_eff for b in st_fsdp.params)
    back = replica.fsdp_to_replicated_state(st_fsdp, plan)
    for a, b in zip(jax.tree.leaves(st_rep.params),
                    jax.tree.leaves(back.params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    for a, b in zip(jax.tree.leaves(st_rep.opt_state),
                    jax.tree.leaves(back.opt_state)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert int(back.step) == 7 and int(back.phase) == 1
    # consolidation agrees across layouts (summation order differs --
    # mean over P_dp duplicated rows vs mean over P_eff pod rows)
    cons_rep = replica.consolidate_state(st_rep)
    cons_fsdp = replica.consolidate_state(st_fsdp, plan)
    for k in TREE:
        tol = 2e-2 if k == "h" else 1e-6
        np.testing.assert_allclose(np.asarray(cons_rep[k], np.float32),
                                   np.asarray(cons_fsdp[k], np.float32),
                                   rtol=tol, atol=tol)


def test_state_templates_match_converted_shapes():
    topo = Topology.hierarchical(("data", "pod"), (4, 2))
    plan = compile_plan(topo, TREE, AveragingConfig(group_size=2,
                                                    bucket_bytes=4096), FSDP)
    st_rep = _pod_identical_stacked_state(topo, plan)
    st_fsdp = replica.replicated_to_fsdp_state(st_rep, plan)
    tpl_s = replica.sharded_state_template(plan, st_rep.opt_state)
    tpl_r = replica.replicated_state_template(plan, st_fsdp.opt_state)
    for got, want in ((st_fsdp, tpl_s), (st_rep, tpl_r)):
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            assert tuple(np.shape(a)) == tuple(b.shape), (np.shape(a), b)


# ---------------------------------------------------------------------------
# Cost model: memory ÷ pod size, gather/scatter overhead
# ---------------------------------------------------------------------------

def test_costmodel_fsdp_memory_and_step_fields():
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "benchmarks"))
    from repro.configs.base import ModelConfig
    from repro.launch.costmodel import averaging_comm_cost
    cfg = ModelConfig(name="cm", family="dense", n_layers=24, d_model=1024,
                      n_heads=8, n_kv_heads=8, d_ff=4096, vocab=32000,
                      dtype="float32")
    topo = Topology.hierarchical(("data", "pod"), (16, 4))
    rep = averaging_comm_cost(cfg, P=64, S=8, n_leaves=290, topology=topo,
                              fsdp_shard_axis="data")
    assert rep.fsdp_pod_size == 16
    assert rep.mem_ratio >= rep.fsdp_pod_size
    assert rep.mem_fsdp_within_pod * rep.fsdp_pod_size == \
        pytest.approx(rep.mem_replicated)
    assert rep.t_fsdp > 0 and rep.gather_scatter_s > 0
    assert rep.gather_scatter_s < rep.t_fsdp
    from cluster_sim import fsdp_win
    win = fsdp_win(P=64, model_bytes=245e6, n_pods=4)
    assert win["mem_ratio"] >= win["pod_size"]
    assert win["step_ratio"] <= 1.10, win


def test_modeled_fsdp_wire_scales_with_pod_size():
    topo = Topology.hierarchical(("data", "pod"), (16, 4))
    small = plan_mod.modeled_fsdp_step_seconds(
        245_000_000, topo, 2, shard_axis="data")
    rep = plan_mod.modeled_wagma_step_seconds(245_000_000, topo, 2)
    # the sharded butterfly moves 1/16 of the payload per stage
    assert small["group_s"] < rep["group_s"]
    assert small["pod_size"] == 16 and small["P_eff"] == 4


def test_collective_axis_counts_classifies_synthetic_hlo():
    from repro.launch.hlo_analysis import collective_axis_counts
    # mesh ('pod','data') = (2,4): id = pod*4 + data
    hlo = """
ENTRY %main (p: f32[16]) -> f32[16] {
  %ag = f32[16] all-gather(%p), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}
  %rs = f32[4] reduce-scatter(%ag), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}
  %bad = f32[16] all-gather(%ag), replica_groups={{0,4},{1,5},{2,6},{3,7}}, dimensions={0}
  %mix = f32[16] all-gather(%ag), replica_groups={{0,5},{1,4},{2,7},{3,6}}, dimensions={0}
}
"""
    counts = collective_axis_counts(hlo, ("pod", "data"), (2, 4))
    assert counts["all-gather"] == {"data": 1, "pod": 1, "mixed": 1}
    assert counts["reduce-scatter"] == {"data": 1}


# ---------------------------------------------------------------------------
# Differential acceptance on the 8-device CPU mesh (subprocess)
# ---------------------------------------------------------------------------

_PREAMBLE = """
    from repro.core import bucketing, grouping
    from repro.core import group_allreduce as ga
    from repro.core import plan as plan_mod
    from repro.core import replica as replica_mod
    from repro.core.replica import ReplicaState, ShardingPolicy
    from repro.launch.hlo_analysis import (collective_axis_counts,
                                           collective_summary,
                                           count_ppermutes,
                                           permute_axis_counts)

    FSDP = ShardingPolicy.fsdp_within_pod("data")

    def pod_tree(rng):
        return {
            "emb": jnp.asarray(rng.normal(size=(33, 70)), jnp.float32),
            "w": jnp.asarray(rng.normal(size=(1300,)), jnp.float32),
            "h": jnp.asarray(rng.normal(size=(300,)),
                             jnp.float32).astype(jnp.bfloat16),
            "e": jnp.zeros((0, 4), jnp.float32),
        }

    # 4 pods x 2 shards: P_eff=4 with S=2 walks TWO phase offsets; tiny
    # pinned budgets force multi-bucket sharded plans on test trees
    TOPO_HIER = plan_mod.Topology(
        ("data", "pod"), (2, 4),
        (plan_mod.LinkClass("ici", alpha=1e-6, beta=1e-11, bucket_bytes=4096),
         plan_mod.LinkClass("dcn", alpha=5e-5, beta=1e-10, bucket_bytes=4096)),
        (0, 1))
    TOPO_FLAT = plan_mod.Topology.flat(
        ("data", "pod"), (2, 4),
        link=plan_mod.LinkClass("link", bucket_bytes=4096))

    def sharded_buffers(plan, pods, mesh):
        packed = [bucketing.pack(t, plan.shard_layout) for t in pods]
        spec = P("pod", "data")
        return tuple(jax.device_put(
            jnp.stack([packed[e][b] for e in range(len(pods))]),
            NamedSharding(mesh, spec)) for b in range(
                plan.shard_layout.n_buckets))
"""


def run_sub(body: str, devices: int = 8, timeout: int = 420):
    return _run_sub(body, devices=devices, timeout=timeout,
                    preamble=_PREAMBLE)


def test_fsdp_average_bit_identical_to_replicated_every_offset():
    """Acceptance gate: sharded plan execution == the replicated plan on
    the pod axis == the stacked simulator, bit-for-bit, on every phase
    offset, for flat AND hierarchical topologies and for the overlapped,
    serial, and jnp-combine realisations."""
    out = run_sub("""
        mesh = jax.make_mesh((4, 2), ("pod", "data"))
        rng = np.random.default_rng(0)
        pods = [pod_tree(rng) for _ in range(4)]
        stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *pods)

        for topo in (TOPO_FLAT, TOPO_HIER):
            cfgs = {
                "overlap": plan_mod.AveragingConfig(group_size=2),
                "serial": plan_mod.AveragingConfig(group_size=2,
                                                   overlap=False),
                "jnp": plan_mod.AveragingConfig(group_size=2,
                                                use_pallas=False),
            }
            plans = {k: plan_mod.compile_plan(topo, pods[0], c, FSDP)
                     for k, c in cfgs.items()}
            pl = plans["overlap"]
            assert pl.shard_layout.n_buckets > 1, "budget must force buckets"
            bufs = sharded_buffers(pl, pods, mesh)

            assert len(pl.offsets) > 1, "must walk several phase offsets"
            # replicated reference: same butterfly over the pod axis only,
            # executed on the pod-stacked full tree (data members identical)
            rep_plan = plan_mod.compile_plan(
                plan_mod.Topology.flat(("pod",), (4,)), pods[0],
                plan_mod.AveragingConfig(group_size=2))

            for ph, off in enumerate(pl.offsets):
                got = {}
                for key, p in plans.items():
                    f = compat.shard_map(
                        lambda sh, p=p, ph=ph: tuple(
                            o[None] for o in p.average(
                                tuple(s[0] for s in sh), ph)),
                        mesh=mesh, in_specs=(P("pod", "data"),),
                        out_specs=P("pod", "data"),
                        axis_names={"pod", "data"})
                    got[key] = jax.jit(f)(bufs)
                g = compat.shard_map(
                    lambda tr, ph=ph: rep_plan.average(tr, ph), mesh=mesh,
                    in_specs=P("pod"), out_specs=P("pod"),
                    axis_names={"pod", "data"})
                rep_out = jax.jit(g)(stacked)
                want = ga.group_average_stacked(stacked, P=4, S=2, t=ph)
                for key, res in got.items():
                    for e in range(4):
                        tree_e = bucketing.unpack(
                            tuple(np.asarray(b)[e] for b in res),
                            pl.shard_layout)
                        for leaf in pods[0]:
                            np.testing.assert_array_equal(
                                np.asarray(tree_e[leaf], np.float32),
                                np.asarray(want[leaf], np.float32)[e],
                                err_msg=f"{key} vs stacked, offset {off}")
                            np.testing.assert_array_equal(
                                np.asarray(tree_e[leaf], np.float32),
                                np.asarray(rep_out[leaf], np.float32)[e],
                                err_msg=f"{key} vs replicated, offset {off}")
        print("FSDP_BIT_EXACT_OK")
    """)
    assert "FSDP_BIT_EXACT_OK" in out


def test_fsdp_shard_round_trip_sync_and_launch_counts():
    """Shard ownership round-trips (shard -> all-gather -> shard is the
    identity), sync equalises pods without touching shard neighbours, and
    the jaxpr ppermute count equals the plan expectation on every offset
    (launch counts unchanged by sharding)."""
    out = run_sub("""
        mesh = jax.make_mesh((4, 2), ("pod", "data"))
        rng = np.random.default_rng(3)
        pods = [pod_tree(rng) for _ in range(4)]
        stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *pods)
        plan = plan_mod.compile_plan(
            TOPO_HIER, pods[0], plan_mod.AveragingConfig(group_size=2), FSDP)
        bufs = sharded_buffers(plan, pods, mesh)

        def rt(sh):
            local = tuple(s[0] for s in sh)
            back = plan.shard_tree(plan.unshard_tree(local))
            return tuple(b[None] for b in back)
        got = jax.jit(compat.shard_map(
            rt, mesh=mesh, in_specs=(P("pod", "data"),),
            out_specs=P("pod", "data"), axis_names={"pod", "data"}))(bufs)
        for a, b in zip(got, bufs):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        def sync(sh):
            return tuple(o[None] for o in plan.sync(
                tuple(s[0] for s in sh)))
        sy = jax.jit(compat.shard_map(
            sync, mesh=mesh, in_specs=(P("pod", "data"),),
            out_specs=P("pod", "data"), axis_names={"pod", "data"}))(bufs)
        want = ga.global_average_stacked(stacked, P=4)
        for e in range(4):
            tree_e = bucketing.unpack(tuple(np.asarray(b)[e] for b in sy),
                                      plan.shard_layout)
            for leaf in ("emb", "w"):
                np.testing.assert_allclose(
                    np.asarray(tree_e[leaf]),
                    np.asarray(want[leaf], np.float32)[e],
                    rtol=1e-6, atol=1e-6)

        for ph, off in enumerate(plan.offsets):
            f = jax.jit(compat.shard_map(
                lambda sh, ph=ph: tuple(o[None] for o in plan.average(
                    tuple(s[0] for s in sh), ph)),
                mesh=mesh, in_specs=(P("pod", "data"),),
                out_specs=P("pod", "data"), axis_names={"pod", "data"}))
            n = count_ppermutes(jax.make_jaxpr(f)(bufs).jaxpr)
            assert n == plan.expected_ppermutes(off), (off, n)
            # every butterfly launch rides the pod (DCN) axis
            hlo = f.lower(bufs).compile().as_text()
            per_axis = permute_axis_counts(hlo, ("pod", "data"), (4, 2))
            assert per_axis.get("data", 0) == 0, per_axis
            assert per_axis.get("pod", 0) == plan.expected_ppermutes(off)
        print("FSDP_STRUCTURE_OK")
    """)
    assert "FSDP_STRUCTURE_OK" in out


def test_fsdp_train_step_wagma_and_allreduce():
    """End to end on the dp x (model=1) mesh: the FSDP wagma step trains
    (loss decreases, tau-sync equalises pods), the FSDP allreduce step on
    identical batches matches the single-worker reference, and the
    compiled step's all-gathers/reduce-scatters ride the intra-pod shard
    axis only (no DCN leaks)."""
    out = run_sub("""
        from repro.configs import get_config, SHAPES
        from repro.models.registry import build_model
        from repro.data import make_batch_fn
        from repro.optim import sgd
        from repro.core.baselines import make_averager
        from repro.core.group_allreduce import dp_axis_layout
        from repro.train import build_train_step, init_replica_state

        mesh = jax.make_mesh((2, 4, 1), ("pod", "data", "model"))
        cfg = get_config("qwen3-0.6b", smoke=True)
        model = build_model(cfg)
        names, sizes = dp_axis_layout(mesh.axis_names, dict(mesh.shape),
                                      ("pod", "data"))
        topo = plan_mod.Topology.hierarchical(names, sizes,
                                              dcn_axes=("pod",))
        av = make_averager("wagma", names, sizes, group_size=2, tau=4,
                           topology=topo, sharding=FSDP)
        opt = sgd(0.3, momentum=0.9)
        with compat.set_mesh(mesh):
            state = init_replica_state(model, opt, av, mesh,
                                       jax.random.PRNGKey(0))
            bf = make_batch_fn(cfg, SHAPES["train_4k"], seed=0)
            steps, losses = {}, []
            for t in range(8):
                key = (av.phase_for_step(t), av.sync_due(t))
                if key not in steps:
                    steps[key] = build_train_step(model, opt, av, mesh,
                                                  phase=key[0], sync=key[1])
                nb = {k: jnp.asarray(v)[:, :32]
                      for k, v in bf(t, 0, 8).items()}
                batch = {k: jax.device_put(
                    v, NamedSharding(mesh, P(("pod", "data"), None)))
                    for k, v in nb.items()}
                state, m = steps[key](state, batch)
                losses.append(float(m["loss"]))
            assert int(state.step) == 8
            b0 = np.asarray(state.params[0])
            assert np.abs(b0 - b0[0:1]).max() < 1e-6, "sync equalises pods"
            assert losses[-1] < losses[0], losses

            # all-gathers/reduce-scatters must ride the shard (data) axis
            hlo = steps[(0, False)].lower(state, batch).compile().as_text()
            ag = collective_axis_counts(
                hlo, ("pod", "data", "model"), (2, 4, 1))
            assert ag.get("all-gather", {}).get("data", 0) > 0, ag
            for kind in ("all-gather", "reduce-scatter"):
                leaks = {a: n for a, n in ag.get(kind, {}).items()
                         if a != "data"}
                assert not leaks, (kind, ag)

        # allreduce under FSDP == classic ZeRO data parallelism: identical
        # batches on every device -> matches the single-worker reference
        cfg32 = get_config("tinyllama-1.1b", smoke=True).variant(
            dtype="float32")
        model32 = build_model(cfg32)
        av2 = make_averager("allreduce", names, sizes, topology=topo,
                            sharding=FSDP)
        opt2 = sgd(0.1, momentum=0.9)
        rng = np.random.default_rng(0)
        toks = rng.integers(0, cfg32.vocab, (1, 32)).astype(np.int32)
        batch_np = {"tokens": np.repeat(toks, 8, 0),
                    "labels": np.repeat(toks, 8, 0)}
        with compat.set_mesh(mesh):
            st2 = init_replica_state(model32, opt2, av2, mesh,
                                     jax.random.PRNGKey(0))
            step2 = build_train_step(model32, opt2, av2, mesh, phase=0,
                                     sync=False)
            batch = {k: jax.device_put(
                jnp.asarray(v), NamedSharding(mesh, P(("pod", "data"), None)))
                for k, v in batch_np.items()}
            st2, _ = step2(st2, batch)
            plan2 = av2.plan_for(jax.eval_shape(model32.init,
                                                jax.random.PRNGKey(0)))
            got = replica_mod.consolidate_state(jax.device_get(st2), plan2)
        p0 = model32.init(jax.random.PRNGKey(0))
        g = jax.grad(lambda p: model32.loss(
            p, {"tokens": jnp.asarray(toks),
                "labels": jnp.asarray(toks)})[0])(p0)
        p1, _ = opt2.update(g, opt2.init(p0), p0)
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(p1)):
            if a.size:
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-4, atol=1e-5)
        print("FSDP_TRAIN_OK")
    """, timeout=600)
    assert "FSDP_TRAIN_OK" in out


def test_fsdp_checkpoint_cross_policy_restore_and_consolidate():
    """Satellite: save from a sharded run, restore into a replicated run
    (and vice versa); step/phase bookkeeping round-trips and consolidate
    agrees bit-for-bit across the conversion."""
    out = run_sub("""
        import tempfile
        from repro.checkpoint import (checkpoint_sharding,
                                      load_replica_state,
                                      save_replica_state)
        from repro.optim import sgd

        rng = np.random.default_rng(5)
        pods = [pod_tree(rng) for _ in range(4)]
        plan = plan_mod.compile_plan(
            TOPO_HIER, pods[0], plan_mod.AveragingConfig(group_size=2), FSDP)
        opt = sgd(0.1)

        # a 'trained' sharded state: distinct pod weights, warm momentum
        bufs = tuple(jnp.stack([bucketing.pack(pods[e], plan.shard_layout)[b]
                                for e in range(4)])
                     for b in range(plan.shard_layout.n_buckets))
        opt_state = jax.vmap(opt.init)(bufs)
        # warm momentum, packed from leaves so pad regions stay zero (pad
        # elements are not state and do not survive cross-policy round trips)
        mom_tree = jax.tree.map(lambda a: jnp.full(a.shape, 0.25,
                                                   jnp.float32), pods[0])
        mom_row = bucketing.pack(mom_tree, plan.shard_layout,
                                 dtype=jnp.float32)
        mom = tuple(jnp.broadcast_to(m[None], (4,) + m.shape)
                    for m in mom_row)
        opt_state = type(opt_state)(momentum=mom,
                                    count=opt_state.count + 3)
        st_fsdp = ReplicaState.create(bufs, opt_state, step=11, phase=1)

        with tempfile.TemporaryDirectory() as d:
            save_replica_state(d, st_fsdp, sharding=FSDP,
                               metadata={"arch": "test"})
            assert checkpoint_sharding(d).is_sharded

            # sharded checkpoint -> replicated run
            tpl_rep = replica_mod.replicated_state_template(
                plan, st_fsdp.opt_state)
            st_rep = load_replica_state(d, tpl_rep, plan=plan)
            assert int(st_rep.step) == 11 and int(st_rep.phase) == 1
            eff = replica_mod.effective_rank_map(
                plan.topology.axis_sizes, plan.shard_axis_index)
            for leaf in pods[0]:
                want = np.stack([np.asarray(pods[e][leaf], np.float32)
                                 for e in eff])
                np.testing.assert_array_equal(
                    np.asarray(st_rep.params[leaf], np.float32), want)

            cons_a = replica_mod.consolidate_state(st_fsdp, plan)
            cons_b = replica_mod.consolidate_state(st_rep)
            for leaf in pods[0]:
                tol = 2e-2 if leaf == "h" else 1e-6
                np.testing.assert_allclose(
                    np.asarray(cons_a[leaf], np.float32),
                    np.asarray(cons_b[leaf], np.float32),
                    rtol=tol, atol=tol)

        # replicated checkpoint -> sharded run (round trip back to shards)
        with tempfile.TemporaryDirectory() as d:
            save_replica_state(d, st_rep)
            tpl_s = replica_mod.sharded_state_template(
                plan, st_rep.opt_state)
            st_back = load_replica_state(d, tpl_s, sharding=FSDP, plan=plan)
            for a, b in zip(st_back.params, st_fsdp.params):
                np.testing.assert_array_equal(
                    np.asarray(a, np.float32), np.asarray(b, np.float32))
            for a, b in zip(jax.tree.leaves(st_back.opt_state),
                            jax.tree.leaves(st_fsdp.opt_state)):
                np.testing.assert_array_equal(np.asarray(a, np.float32),
                                              np.asarray(b, np.float32))
        print("CKPT_CROSS_POLICY_OK")
    """)
    assert "CKPT_CROSS_POLICY_OK" in out
