"""Train-to-serve weight handoff (DESIGN.md §14).

A post-barrier training state (every replica row holding the synced
consensus) must hand the serving engine the SAME weights — bit-for-bit —
no matter which sharding policy the trainer ran under: replicated rows,
FSDP shard buffers, or the layer-streamed FSDP layout.  Also pins the
checkpoint route (``serving_weights_from_checkpoint``): a serving fleet
reads the manifest's policy and consolidates without being told how the
trainer sharded.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.core import replica
from repro.core.plan import AveragingConfig, Topology, compile_plan
from repro.core.replica import ReplicaState, ShardingPolicy
from repro.models.registry import build_model
from repro.optim import sgd
from repro.serve.handoff import (serving_weights_from_checkpoint,
                                 serving_weights_from_state)

P = 4


@pytest.fixture(scope="module")
def trained():
    cfg = get_config("qwen3-0.6b", smoke=True)
    model = build_model(cfg)
    p0 = model.init(jax.random.PRNGKey(0))
    stacked = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (P,) + a.shape), p0)
    opt = jax.vmap(sgd(0.1, momentum=0.9).init)(stacked)
    state = ReplicaState.create(stacked, opt, step=7, phase=2)
    return model, p0, state


def _assert_tree_equal(a, b):
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), a, b)


def _topo():
    return Topology.hierarchical(("data", "pod"), (2, 2))


def test_handoff_replicated(trained):
    model, p0, state = trained
    _assert_tree_equal(serving_weights_from_state(state), p0)


def test_handoff_fsdp(trained):
    model, p0, state = trained
    struct = jax.eval_shape(lambda: p0)
    plan = compile_plan(_topo(), struct, AveragingConfig(group_size=2),
                        ShardingPolicy.fsdp_within_pod("data"))
    fsdp = replica.replicated_to_fsdp_state(state, plan)
    assert isinstance(fsdp.params, tuple)          # shard buffers
    _assert_tree_equal(
        serving_weights_from_state(fsdp, plan=plan, model=model), p0)


def test_handoff_streamed_fsdp(trained):
    model, p0, state = trained
    layered_struct = jax.eval_shape(model.layered.split, p0)
    plan = compile_plan(_topo(), layered_struct,
                        AveragingConfig(group_size=2),
                        ShardingPolicy.fsdp_within_pod("data", streamed=True))
    streamed = replica.replicated_to_fsdp_state(
        replica.split_layered_state(state, model.layered), plan)
    weights = serving_weights_from_state(streamed, plan=plan, model=model)
    _assert_tree_equal(weights, p0)                # merged back to canonical
    # a streamed state without the model to merge it fails loudly
    with pytest.raises(ValueError, match="layered"):
        serving_weights_from_state(streamed, plan=plan)


def test_handoff_weights_serve_identically(trained):
    model, p0, state = trained
    weights = serving_weights_from_state(state)
    prompt = jnp.asarray(
        np.random.default_rng(0).integers(0, model.cfg.vocab, (1, 6)),
        jnp.int32)
    pf = jax.jit(lambda p, b: model.prefill(p, b, 8))
    la, _ = pf(p0, {"tokens": prompt})
    lb, _ = pf(weights, {"tokens": prompt})
    np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_handoff_from_checkpoint_both_policies(trained, tmp_path):
    model, p0, state = trained
    # replicated checkpoint: the manifest says replicated, no plan needed
    rep_dir = str(tmp_path / "rep")
    ckpt.save_replica_state(rep_dir, state)
    template = jax.eval_shape(lambda: state)
    _assert_tree_equal(
        serving_weights_from_checkpoint(rep_dir, template), p0)

    # FSDP checkpoint: policy read from the manifest routes consolidation
    # through the plan's shard layout
    struct = jax.eval_shape(lambda: p0)
    pol = ShardingPolicy.fsdp_within_pod("data")
    plan = compile_plan(_topo(), struct, AveragingConfig(group_size=2), pol)
    fsdp = replica.replicated_to_fsdp_state(state, plan)
    fsdp_dir = str(tmp_path / "fsdp")
    ckpt.save_replica_state(fsdp_dir, fsdp, sharding=pol)
    assert ckpt.checkpoint_sharding(fsdp_dir).is_sharded
    template = replica.sharded_state_template(plan, fsdp.opt_state)
    _assert_tree_equal(
        serving_weights_from_checkpoint(fsdp_dir, template, plan=plan,
                                        model=model), p0)
