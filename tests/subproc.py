"""Shared subprocess harness for forced-host-device distributed tests.

The main pytest process must keep the real single-device CPU view, so every
test needing an N-device mesh runs its body in a fresh interpreter with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (and any inherited
flag scrubbed from the parent env).  Used by tests/test_distributed.py and
tests/test_group_average_fused.py.
"""

import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "src")


def run_sub(body: str, devices: int = 8, timeout: int = 420,
            preamble: str = "") -> str:
    """Run dedented ``body`` on ``devices`` forced host devices.

    The script sees jax/jnp/np, PartitionSpec P, NamedSharding, and
    ``repro.compat`` pre-imported; ``preamble`` (also dedented) can add
    test-module-specific helpers before the body runs.
    """
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import sys
        sys.path.insert(0, {SRC!r})
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro import compat
    """) + textwrap.dedent(preamble) + textwrap.dedent(body)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, env=env, timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    return out.stdout
