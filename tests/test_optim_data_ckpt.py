"""Optimisers, data pipeline, checkpointing."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.checkpoint import consolidate, load_checkpoint, save_checkpoint
from repro.configs import SHAPES, get_config
from repro.data import SyntheticTask, make_batch_fn
from repro.optim import adamw, cosine_warmup, sgd


# -- optimisers --------------------------------------------------------------

def test_sgd_momentum_matches_reference():
    opt = sgd(0.1, momentum=0.9)
    p = {"w": jnp.asarray([1.0, -2.0])}
    st_ = opt.init(p)
    g = {"w": jnp.asarray([0.5, 0.5])}
    m = np.zeros(2)
    w = np.asarray([1.0, -2.0])
    for _ in range(5):
        p, st_ = opt.update(g, st_, p)
        m = 0.9 * m + np.asarray([0.5, 0.5])
        w = w - 0.1 * m
    np.testing.assert_allclose(np.asarray(p["w"]), w, rtol=1e-6)


@pytest.mark.parametrize("make", [lambda: sgd(0.05, momentum=0.9),
                                  lambda: adamw(0.05)])
def test_optimizers_minimise_quadratic(make):
    opt = make()
    p = {"w": jnp.asarray(np.random.default_rng(0).standard_normal(8),
                          jnp.float32)}
    st_ = opt.init(p)
    for _ in range(200):
        g = jax.grad(lambda q: jnp.sum(jnp.square(q["w"])))(p)
        p, st_ = opt.update(g, st_, p)
    assert float(jnp.sum(jnp.square(p["w"]))) < 1e-3


def test_momentum_state_is_fp32_under_bf16_params():
    opt = sgd(0.1)
    p = {"w": jnp.zeros((4,), jnp.bfloat16)}
    st_ = opt.init(p)
    assert st_.momentum["w"].dtype == jnp.float32
    p2, _ = opt.update({"w": jnp.ones((4,), jnp.bfloat16)}, st_, p)
    assert p2["w"].dtype == jnp.bfloat16


def test_cosine_warmup_schedule():
    fn = cosine_warmup(1.0, warmup_steps=10, total_steps=100)
    assert float(fn(jnp.asarray(0))) < float(fn(jnp.asarray(9)))
    assert abs(float(fn(jnp.asarray(10))) - 1.0) < 0.12
    assert float(fn(jnp.asarray(99))) < 0.2


# -- data --------------------------------------------------------------------

def test_batches_deterministic_per_step_and_worker():
    t = SyntheticTask(vocab=128, seq_len=32, seed=7)
    a = t.batch(3, 1, 4)
    b = t.batch(3, 1, 4)
    c = t.batch(4, 1, 4)
    d = t.batch(3, 2, 4)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert not np.array_equal(a["tokens"], d["tokens"])


def test_teacher_task_is_learnable():
    """labels follow perm[token] ~75% of the time — predictable structure."""
    t = SyntheticTask(vocab=64, seq_len=128, seed=0, order_mix=0.75)
    b = t.batch(0, 0, 16)
    pred = t.perm[b["tokens"]]
    acc = (pred == b["labels"]).mean()
    assert 0.6 < acc < 0.9


def test_imbalanced_lengths_distribution():
    t = SyntheticTask(vocab=64, seq_len=256, seed=0)
    b = t.imbalanced_batch(0, 0, 256)
    lens = b["lengths"]
    assert lens.min() >= 4 and lens.max() <= 256
    assert lens.std() / lens.mean() > 0.3      # genuinely imbalanced
    assert b["mask"].shape == b["tokens"].shape
    np.testing.assert_array_equal(b["mask"].sum(1), lens)


@settings(max_examples=10, deadline=None)
@given(step=st.integers(0, 100), worker=st.integers(0, 31))
def test_make_batch_fn_family_extras(step, worker):
    cfg = get_config("internvl2-2b", smoke=True)
    fn = make_batch_fn(cfg, SHAPES["train_4k"], seed=0)
    b = fn(step, worker, 2)
    assert b["patches"].shape == (2, cfg.n_patches, cfg.d_model)
    assert b["tokens"].shape[1] == SHAPES["train_4k"].seq_len - cfg.n_patches


# -- checkpoint --------------------------------------------------------------

def test_checkpoint_roundtrip_and_consolidate():
    tree = {
        "emb": jnp.asarray(np.random.default_rng(0).standard_normal((4, 8)),
                           jnp.bfloat16),
        "blocks": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4)},
    }
    opt = {"m": jnp.ones((4, 8), jnp.float32)}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, tree, opt_state=opt, step=42,
                        metadata={"arch": "test"})
        restored, ropt, step = load_checkpoint(d, tree, opt)
        assert step == 42
        for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(tree)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
        np.testing.assert_array_equal(np.asarray(ropt["m"]), np.asarray(opt["m"]))

    stacked = {"w": jnp.stack([jnp.zeros((3,)), jnp.ones((3,)) * 2.0])}
    cons = consolidate(stacked)
    np.testing.assert_allclose(np.asarray(cons["w"]), [1.0, 1.0, 1.0])


# -- atomic checkpointing (DESIGN.md §13) ------------------------------------

def _tiny_ckpt():
    params = {"w": jnp.arange(6, dtype=jnp.float32),
              "b": {"x": jnp.ones((2, 3), jnp.bfloat16)}}
    opt = {"m": jnp.zeros((6,), jnp.float32)}
    return params, opt


def test_atomic_save_leaves_no_tmp_files():
    params, opt = _tiny_ckpt()
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, params, opt_state=opt, step=1)
        assert not [f for f in os.listdir(d) if f.endswith(".tmp")]
        assert sorted(os.listdir(d)) == ["manifest.json", "opt_state.npz",
                                         "params.npz"]


def test_corrupted_leaf_bytes_fail_the_checksum():
    from repro.checkpoint import ChecksumError

    params, opt = _tiny_ckpt()
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, params, opt_state=opt, step=1)
        # bit rot / torn write: data no longer matches the manifest crc32
        stored = dict(np.load(os.path.join(d, "params.npz")))
        stored["w"] = stored["w"] + 1
        np.savez(os.path.join(d, "params.npz"), **stored)
        with pytest.raises(ChecksumError, match="torn or corrupted"):
            load_checkpoint(d, params, opt)


def test_crash_before_manifest_commit_preserves_previous_checkpoint():
    """Kill the writer between the data rename and the manifest rename
    (the `core.faults.InjectedCrash` the chaos harness schedules): the
    directory then holds NEW data under the OLD manifest.  Loading must
    refuse the torn combination, and after the stale data is discarded
    the previous complete checkpoint is still intact — a crash mid-save
    never loads silently wrong state."""
    from repro.checkpoint import ChecksumError
    from repro.checkpoint import ckpt as ckpt_mod
    from repro.core.faults import InjectedCrash

    params, opt = _tiny_ckpt()
    newer = jax.tree.map(lambda a: a * 3 + 1, params)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, params, opt_state=opt, step=1)
        real_replace = ckpt_mod._replace

        def crash_on_manifest(src, dst):
            if dst.endswith("manifest.json"):
                raise InjectedCrash("killed between data and manifest rename")
            real_replace(src, dst)

        ckpt_mod._replace = crash_on_manifest
        try:
            with pytest.raises(InjectedCrash):
                save_checkpoint(d, newer, opt_state=opt, step=2)
        finally:
            ckpt_mod._replace = real_replace

        # torn: step-2 data under the step-1 manifest -> refused
        with pytest.raises(ChecksumError):
            load_checkpoint(d, params, opt)

        # a retried save commits atomically and wins
        save_checkpoint(d, newer, opt_state=opt, step=2)
        restored, _, step = load_checkpoint(d, params, opt)
        assert step == 2
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(newer["w"]))


def test_crash_before_any_rename_leaves_no_checkpoint_at_all():
    from repro.checkpoint import ckpt as ckpt_mod
    from repro.core.faults import InjectedCrash

    params, opt = _tiny_ckpt()
    with tempfile.TemporaryDirectory() as d:
        real_replace = ckpt_mod._replace
        ckpt_mod._replace = lambda s, t: (_ for _ in ()).throw(
            InjectedCrash("killed before the first rename"))
        try:
            with pytest.raises(InjectedCrash):
                save_checkpoint(d, params, opt_state=opt, step=1)
        finally:
            ckpt_mod._replace = real_replace
        # only a .tmp remains; a reader sees "no checkpoint", never garbage
        assert all(f.endswith(".tmp") for f in os.listdir(d))
        with pytest.raises(FileNotFoundError):
            load_checkpoint(d, params, opt)


def test_pre_checksum_checkpoints_still_load():
    """Manifests written before this PR carry no checksums; they load
    unverified rather than erroring (backward compatibility)."""
    import json

    params, opt = _tiny_ckpt()
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, params, opt_state=opt, step=7)
        mpath = os.path.join(d, "manifest.json")
        with open(mpath) as f:
            manifest = json.load(f)
        manifest.pop("checksums")
        manifest.pop("opt_checksums")
        with open(mpath, "w") as f:
            json.dump(manifest, f)
        restored, ropt, step = load_checkpoint(d, params, opt)
        assert step == 7
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(params["w"]))


def test_replica_state_checkpoint_is_checksum_verified_too():
    """`load_replica_state` routes through the same checksummed rebuild,
    so a torn replica-state save is refused as well."""
    from repro.checkpoint import (ChecksumError, load_replica_state,
                                  save_replica_state)
    from repro.core.replica import ReplicaState

    params, opt = _tiny_ckpt()
    state = ReplicaState.create(params, opt, step=3)
    with tempfile.TemporaryDirectory() as d:
        save_replica_state(d, state)
        back = load_replica_state(d, state)
        assert int(back.step) == 3
        np.testing.assert_array_equal(np.asarray(back.params["w"]),
                                      np.asarray(params["w"]))
        stored = dict(np.load(os.path.join(d, "params.npz")))
        stored["w"] = stored["w"] * 2
        np.savez(os.path.join(d, "params.npz"), **stored)
        with pytest.raises(ChecksumError):
            load_replica_state(d, state)
