"""Non-finite gradient guard (DESIGN.md §13).

Host-side tests pin `guarded_update` exactly: bit-exact equal to the
bare ``optimizer.update`` when the gradients are finite, bit-exact
passthrough of params AND optimiser state when any leaf carries a
NaN/Inf, and the explicit ``finite`` override (the hook the fsdp step
uses after pmin-reducing the verdict over the shard axis).

The subprocess test drives the guard end-to-end through a real
`Trainer` on a 2-replica mesh with the identity-comm ``local_sgd``
averager (sync pushed past the horizon), so replicas never exchange
state: one replica's weights are poisoned with NaN, and every step it
alone skips its update — its row stays bit-frozen, the healthy row
keeps training, and ``skipped_nonfinite`` surfaces through the metrics
(0.5 = 1 of 2 replicas) and the Trainer's running counter.  The same
subprocess then arms a `FaultInjector` on the live Trainer and checks
the scheduled `InjectedCrash` fires inside ``step_once``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from subproc import run_sub as _run_sub

from repro.optim import sgd
from repro.train import guarded_update, tree_all_finite


def _bit_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        x, y = np.ascontiguousarray(x), np.ascontiguousarray(y)
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(x.reshape(-1).view(np.uint8),
                                      y.reshape(-1).view(np.uint8))


def test_tree_all_finite():
    assert bool(tree_all_finite({"a": jnp.ones(3), "b": jnp.zeros(2)}))
    assert not bool(tree_all_finite({"a": jnp.array([1.0, np.nan])}))
    assert not bool(tree_all_finite({"a": jnp.array([np.inf])}))
    assert bool(tree_all_finite({}))  # empty tree is vacuously finite


def test_guarded_update_is_bit_exact_when_finite():
    opt = sgd(0.1, momentum=0.9)
    params = {"w": jnp.arange(4.0), "b": jnp.ones((2,), jnp.bfloat16)}
    state = opt.init(params)
    grads = {"w": jnp.ones(4), "b": jnp.full((2,), 0.5, jnp.bfloat16)}
    new_p, new_o, skipped = guarded_update(opt, grads, state, params)
    ref_p, ref_o = opt.update(grads, state, params)
    assert not bool(skipped)
    _bit_equal(new_p, ref_p)
    _bit_equal(new_o, ref_o)


def test_guarded_update_passes_through_on_nan_and_inf():
    opt = sgd(0.1, momentum=0.9)
    params = {"w": jnp.arange(4.0)}
    state = opt.init(params)
    # momentum non-zero so an unguarded update would visibly change it
    _, state = opt.update({"w": jnp.ones(4)}, state, params)
    for poison in (np.nan, np.inf, -np.inf):
        grads = {"w": jnp.array([1.0, poison, 1.0, 1.0])}
        new_p, new_o, skipped = guarded_update(opt, grads, state, params)
        assert bool(skipped)
        _bit_equal(new_p, params)
        _bit_equal(new_o, state)


def test_guarded_update_explicit_finite_override():
    opt = sgd(0.1)
    params = {"w": jnp.arange(4.0)}
    state = opt.init(params)
    grads = {"w": jnp.ones(4)}   # finite, but the pod voted to skip
    new_p, new_o, skipped = guarded_update(opt, grads, state, params,
                                           finite=jnp.asarray(False))
    assert bool(skipped)
    _bit_equal(new_p, params)


def test_poisoned_replica_skips_alone_and_injector_crashes_trainer():
    out = _run_sub("""
        from repro.configs import get_config
        from repro.core.faults import (FaultInjector, FaultSchedule,
                                       InjectedCrash, crash)
        from repro.core.replica import ReplicaState
        from repro.launch.mesh import mesh_over
        from repro.launch.train import Trainer

        cfg = get_config("qwen3-0.6b", smoke=True)
        mesh = mesh_over(jax.devices()[:2], (2, 1), ("data", "model"))
        # identity comm: sync_period far past the run, no grad averaging
        tr = Trainer(cfg, mesh, averager="local_sgd", tau=10_000,
                     learning_rate=0.1, seed=0)
        host = jax.device_get(tr.state)

        def poison(a):
            a = np.array(a)
            a[1] = np.nan
            return a

        bad_params = jax.tree.map(poison, host.params)
        tr = Trainer(cfg, mesh, averager="local_sgd", tau=10_000,
                     learning_rate=0.1, seed=0,
                     init_state=ReplicaState(bad_params, host.opt_state,
                                             host.step, host.phase))
        with compat.set_mesh(mesh):
            for t in range(3):
                tr.step_once(t)
        assert tr.last_metrics["skipped_nonfinite"] == 0.5, tr.last_metrics
        assert tr.skipped_nonfinite == 3.0, tr.skipped_nonfinite

        after = jax.device_get(tr.state)
        for leaf, bad in zip(jax.tree.leaves(after.params),
                             jax.tree.leaves(bad_params)):
            a = np.asarray(leaf, np.float32)
            assert np.isnan(a[1]).all(), "poisoned row must stay frozen"
            assert np.isfinite(a[0]).all(), "healthy row must keep training"
        for leaf, init in zip(jax.tree.leaves(after.opt_state),
                              jax.tree.leaves(host.opt_state)):
            np.testing.assert_array_equal(np.asarray(leaf)[1],
                                          np.asarray(init)[1])
        assert int(after.step) == 3   # step counter advances regardless

        # the wall-clock injector hooks the same step_once
        tr.fault_injector = FaultInjector(
            FaultSchedule.of(crash(0, 4)), worker=0)
        with compat.set_mesh(mesh):
            tr.step_once(3)           # no fault scheduled here
            try:
                tr.step_once(4)
                raise SystemExit("InjectedCrash did not fire")
            except InjectedCrash:
                pass
        print("NONFINITE_GUARD_OK")
    """, devices=8, timeout=420)
    assert "NONFINITE_GUARD_OK" in out
