"""Per-architecture smoke tests (reduced configs) + decode==forward checks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, arch_names, get_config
from repro.data import make_batch_fn
from repro.models.registry import build_model
from repro.optim import sgd

ALL_ARCHS = arch_names() + ["transformer-wmt"]


def small_batch(cfg, bsz=2, seq=32):
    bf = make_batch_fn(cfg, SHAPES["train_4k"], seed=0)
    b = bf(0, 0, bsz)
    out = {}
    for k, v in b.items():
        v = jnp.asarray(v)
        if v.ndim == 2 and v.shape[1] > seq:
            v = v[:, :seq]
        out[k] = v
    return out


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch):
    """Reduced variant: one forward/train step, output shapes, no NaNs."""
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = small_batch(cfg)

    loss, metrics = jax.jit(lambda p, b: model.loss(p, b))(params, batch)
    assert np.isfinite(float(loss)), arch
    logits, _ = model.forward(params, batch)
    assert logits.shape[0] == batch["tokens"].shape[0]
    assert logits.shape[-1] >= cfg.vocab
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    grads = jax.jit(jax.grad(lambda p, b: model.loss(p, b)[0]))(params, batch)
    gn = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, arch

    opt = sgd(0.01)
    state = opt.init(params)
    new_params, _ = jax.jit(opt.update)(grads, state, params)
    delta = sum(float(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)).sum())
                for a, b in zip(jax.tree.leaves(new_params),
                                jax.tree.leaves(params)))
    assert delta > 0, arch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, max_len = 2, 16
    caches = model.init_caches(B, max_len)
    tok = jnp.zeros((B, 1), jnp.int32)
    step = jax.jit(model.decode_step)
    for pos in range(3):
        logits, caches = step(params, caches, tok, jnp.asarray(pos))
        assert logits.shape[:2] == (B, 1)
        assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
        tok = jnp.argmax(logits[:, :, :cfg.vocab], -1).astype(jnp.int32)


DECODE_MATCH_ARCHS = ["tinyllama-1.1b", "qwen3-0.6b", "gemma3-12b",
                      "xlstm-350m", "recurrentgemma-2b", "kimi-k2-1t-a32b",
                      "whisper-medium"]


@pytest.mark.parametrize("arch", DECODE_MATCH_ARCHS)
def test_decode_matches_forward(arch):
    """prefill(T0) + decode steps reproduce the teacher-forced forward logits
    (fp32 smoke variant for tight tolerance). This pins KV-cache layout,
    ring-buffer windows, RoPE offsets, and recurrent-state handoff."""
    cfg = get_config(arch, smoke=True).variant(dtype="float32")
    if cfg.family == "moe":
        # exact-match check needs drop-free routing: capacity drops legally
        # differ between the full forward (T-token pool) and prefill/decode
        cfg = cfg.variant(capacity_factor=64.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    T, T0 = 12, 8
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, T)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "audio":
        if cfg.encoder_frames:
            batch["frames"] = jnp.asarray(
                rng.standard_normal((2, cfg.encoder_frames, cfg.d_model)),
                jnp.float32) * 0.02
        else:
            batch["src"] = jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)),
                                       jnp.int32)

    full_logits, _ = model.forward(params, batch, remat=False)

    pre_batch = dict(batch, tokens=toks[:, :T0])
    logits0, caches = model.prefill(params, pre_batch, T, remat=False)
    np.testing.assert_allclose(
        np.asarray(logits0[:, 0]), np.asarray(full_logits[:, T0 - 1]),
        rtol=2e-3, atol=2e-3)

    for pos in range(T0, T):
        logits, caches = model.decode_step(params, caches,
                                           toks[:, pos:pos + 1],
                                           jnp.asarray(pos))
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full_logits[:, pos]),
            rtol=2e-3, atol=2e-3, err_msg=f"{arch} pos={pos}")


def test_vlm_prefix_changes_text_logits():
    cfg = get_config("internvl2-2b", smoke=True).variant(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 8)), jnp.int32)
    p1 = jnp.asarray(rng.standard_normal((1, cfg.n_patches, cfg.d_model)),
                     jnp.float32) * 0.5
    p2 = -p1
    l1, _ = model.forward(params, {"tokens": toks, "patches": p1})
    l2, _ = model.forward(params, {"tokens": toks, "patches": p2})
    assert l1.shape[1] == cfg.n_patches + 8
    assert not np.allclose(np.asarray(l1[:, -1]), np.asarray(l2[:, -1]))


def test_sliding_window_variant_limits_context():
    """+swa variant: token beyond the window no longer influences logits."""
    cfg = get_config("tinyllama-1.1b", smoke=True).variant(dtype="float32")
    cfgw = cfg.with_sliding_window(4)
    model = build_model(cfgw)
    params = model.init(jax.random.PRNGKey(4))
    rng = np.random.default_rng(2)
    toks = np.asarray(rng.integers(0, cfg.vocab, (1, 10)), np.int32)
    toks2 = toks.copy()
    toks2[0, 0] = (toks2[0, 0] + 7) % cfg.vocab   # outside window of last pos
    l1, _ = model.forward(params, {"tokens": jnp.asarray(toks)})
    l2, _ = model.forward(params, {"tokens": jnp.asarray(toks2)})
    np.testing.assert_allclose(np.asarray(l1[0, -1]), np.asarray(l2[0, -1]),
                               rtol=1e-4, atol=1e-5)
    assert not np.allclose(np.asarray(l1[0, 1]), np.asarray(l2[0, 1]))


def test_moe_routing_load_balance_metrics():
    cfg = get_config("kimi-k2-1t-a32b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(5))
    batch = small_batch(cfg)
    _, metrics = model.loss(params, batch)
    assert "load_balance" in metrics and float(metrics["load_balance"]) >= 1.0
    assert 0.0 <= float(metrics["moe_dropped"]) <= 0.6
