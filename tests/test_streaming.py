"""Layer-streamed FSDP engine differentials (DESIGN.md §11).

Host-side tests pin the pure pieces: layer-aware (grouped) bucket layouts
— group-pure contiguous buckets, the layer<->bucket map, the
oversize-layer edge case, cache keying — the streamed schedule invariants
(gather k+1 before compute k, bounded in-flight spans), streamed plan
compilation (sublayout views, accounting, describe output), the streamed
cost-model fields, and cross-policy checkpoint restore when the sharded
side uses a layer-aware layout.

Subprocess tests pin the execution on the 8-device CPU mesh: the streamed
(layer-aware) plan's butterfly must stay bit-identical to the replicated
plan and the stacked simulator on EVERY phase offset (flat and
hierarchical), and the streamed train step must be bit-identical to the
gather-all FSDP step — same losses, same resulting logical parameters —
across steps covering every phase offset and a tau-sync, while compiling
exactly the scheduled number of shard-axis all-gathers.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from subproc import run_sub as _run_sub

from repro.core import bucketing, streaming
from repro.core import plan as plan_mod
from repro.core import replica
from repro.core.plan import AveragingConfig, LinkClass, Topology, compile_plan
from repro.core.replica import ReplicaState, ShardingPolicy
from repro.models import common as cm
from repro.optim import sgd

# synthetic layered trees double as their own "canonical" layout; the
# real merge/split round trip is pinned by the qwen3 test below
_IDENTITY_LAYERED = cm.LayeredModel(
    n_spans=2, split=lambda t: t, merge=lambda t: t,
    stem=None, span=None, head_loss=None)


# ---------------------------------------------------------------------------
# Layer-aware bucket layouts
# ---------------------------------------------------------------------------

def _grouped_tree():
    # canonical dict order interleaves groups on purpose: "head" < "layers"
    # < "stem" alphabetically, but groups order stem(0) < spans < head
    return {
        "stem": {"emb": jax.ShapeDtypeStruct((33, 70), jnp.float32)},
        "layers": (
            {"w": jax.ShapeDtypeStruct((1300,), jnp.float32),
             "h": jax.ShapeDtypeStruct((300,), jnp.bfloat16)},
            {"w": jax.ShapeDtypeStruct((1300,), jnp.float32),
             "h": jax.ShapeDtypeStruct((300,), jnp.bfloat16)},
        ),
        "head": {"out": jax.ShapeDtypeStruct((40,), jnp.float32),
                 "e": jax.ShapeDtypeStruct((0, 4), jnp.float32)},
    }


def test_grouped_layout_group_pure_ordered_buckets():
    tree = _grouped_tree()
    groups = streaming.layered_leaf_groups(tree)
    lay = bucketing.build_layout(tree, max_bucket_bytes=4096, groups=groups)
    assert lay.grouped
    # buckets ordered by group, each bucket exactly one group
    assert list(lay.bucket_groups) == sorted(lay.bucket_groups)
    # every group's buckets are contiguous
    gmap = lay.group_bucket_map()
    for g, idxs in gmap.items():
        assert list(idxs) == list(range(idxs[0], idxs[-1] + 1)), (g, idxs)
    assert set(gmap) == {0, 1, 2, 3}
    # leaves land in their own group's buckets only
    for slot, g in zip(lay.slots, groups):
        assert lay.bucket_groups[slot.bucket] == g
    # group_bytes sums the padded bucket bytes
    total = sum(lay.group_bytes(g) for g in gmap)
    assert total == sum(s * d.itemsize for s, d in
                        zip(lay.bucket_sizes, lay.bucket_dtypes))
    assert "->" in lay.describe_groups()
    # pack/unpack round trip through the grouped layout
    rng = np.random.default_rng(0)
    conc = jax.tree.map(
        lambda s: jnp.asarray(rng.normal(size=s.shape),
                              jnp.float32).astype(s.dtype), tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    back = bucketing.unpack(bucketing.pack(conc, lay), lay)
    for a, b in zip(jax.tree.leaves(conc), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_grouped_layout_matches_per_group_sublayouts():
    """The global grouped layout restarts its fill per group, so each
    group's slice equals the layout of the group's sub-tree alone — the
    invariant the plan's sublayout views (stream_unshard) rely on."""
    tree = _grouped_tree()
    groups = streaming.layered_leaf_groups(tree)
    lay = bucketing.build_layout(tree, max_bucket_bytes=4096, groups=groups)
    subtrees = {0: tree["stem"], 1: tree["layers"][0],
                2: tree["layers"][1], 3: tree["head"]}
    for g, sub in subtrees.items():
        sublay = bucketing.build_layout(sub, max_bucket_bytes=4096)
        idxs = lay.group_bucket_indices(g)
        assert sublay.n_buckets == len(idxs)
        assert tuple(sublay.bucket_sizes) == tuple(
            lay.bucket_sizes[i] for i in idxs)
        assert tuple(sublay.bucket_dtypes) == tuple(
            lay.bucket_dtypes[i] for i in idxs)
        # within-bucket slot offsets agree too
        glob_slots = [(s.offset, s.size) for s, gg in
                      zip(lay.slots, groups) if gg == g]
        sub_slots = [(s.offset, s.size) for s in sublay.slots]
        assert glob_slots == sub_slots


def test_grouped_layout_oversize_layer_edge_case():
    """A single layer larger than the class budget still gets buckets of
    its own (oversize leaves are never split, never shared across
    groups), and small neighbouring layers do not merge into it."""
    big = 4096    # bytes budget; the span below is ~5x that
    tree = {
        "stem": {"s": jax.ShapeDtypeStruct((8,), jnp.float32)},
        "layers": (
            {"a": jax.ShapeDtypeStruct((3000,), jnp.float32),   # 12000 B
             "b": jax.ShapeDtypeStruct((900,), jnp.float32),
             "c": jax.ShapeDtypeStruct((900,), jnp.float32)},
            {"t": jax.ShapeDtypeStruct((8,), jnp.float32)},
        ),
        "head": {"h": jax.ShapeDtypeStruct((8,), jnp.float32)},
    }
    groups = streaming.layered_leaf_groups(tree)
    lay = bucketing.build_layout(tree, max_bucket_bytes=big, groups=groups)
    gmap = lay.group_bucket_map()
    # the oversize span split into several buckets, all its own
    assert len(gmap[1]) >= 2
    for bi in gmap[1]:
        assert lay.bucket_groups[bi] == 1
    # the tiny span/stem/head did not ride along in the big span's buckets
    assert len(gmap[0]) == len(gmap[2]) == len(gmap[3]) == 1
    assert set(gmap[2]).isdisjoint(gmap[1])
    # contiguity survives the split
    assert list(lay.bucket_groups) == sorted(lay.bucket_groups)


def test_layout_cache_keyed_on_groups():
    tree = _grouped_tree()
    groups = streaming.layered_leaf_groups(tree)
    a = bucketing.layout_for(tree, max_bucket_bytes=4096)
    b = bucketing.layout_for(tree, max_bucket_bytes=4096, groups=groups)
    c = bucketing.layout_for(tree, max_bucket_bytes=4096, groups=groups)
    assert a is not b and b is c
    assert not a.grouped and b.grouped
    # layer-aware spans differ from budget-only spans on this tree
    assert a.n_buckets != b.n_buckets or \
        tuple(a.bucket_sizes) != tuple(b.bucket_sizes)


def test_layered_leaf_groups_validation():
    with pytest.raises(ValueError, match="layered param tree"):
        streaming.layered_leaf_groups({"a": jnp.zeros(3)})
    with pytest.raises(ValueError, match="layered param tree"):
        streaming.layered_leaf_groups((jnp.zeros(3),))
    groups = streaming.layered_leaf_groups(_grouped_tree())
    assert sorted(set(groups)) == [0, 1, 2, 3]


# ---------------------------------------------------------------------------
# Streamed schedule
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_spans", [1, 2, 3, 6, 13])
def test_stream_schedule_invariants(n_spans):
    events = streaming.stream_schedule(n_spans)
    streaming.validate_stream_schedule(events, n_spans)


def test_stream_schedule_peak_bytes_two_spans():
    """With uniform span bytes the liveness peak is stem + head + 2 spans
    — the two-layer-span in-flight bound the CI smoke enforces."""
    n = 8
    span_b, stem_b, head_b = 100, 7, 11
    gb = {0: stem_b, **{k + 1: span_b for k in range(n)},
          streaming.head_group(n): head_b}
    peak = streaming.max_in_flight_gathered_bytes(gb, n)
    assert peak <= stem_b + head_b + 2 * span_b
    assert peak >= 2 * span_b
    full = sum(gb.values())
    assert peak < full


# ---------------------------------------------------------------------------
# Streamed plan compilation
# ---------------------------------------------------------------------------

STREAM = ShardingPolicy.fsdp_within_pod("data", streamed=True)


def test_sharding_policy_streamed_validation():
    assert STREAM.streamed and STREAM.is_sharded
    assert "streamed" in STREAM.describe()
    with pytest.raises(ValueError, match="streamed"):
        ShardingPolicy("replicated", None, True)
    # distinct from the gather-all policy in the plan cache key
    assert STREAM != ShardingPolicy.fsdp_within_pod("data")


def test_streamed_plan_compile_and_accounting():
    topo = Topology.hierarchical(("data", "pod"), (4, 2))
    cfg = AveragingConfig(group_size=2, bucket_bytes=4096)
    tree = _grouped_tree()
    plan = compile_plan(topo, tree, cfg, STREAM)
    assert plan.n_stream_spans == 2
    lay = plan.shard_layout
    assert lay.grouped
    for size in lay.bucket_sizes:
        assert size % (4 * 128) == 0
    # sublayout views agree with the global layout (asserted inside) and
    # templates point at the right sub-SDS-trees
    for g in sorted(set(lay.bucket_groups)):
        plan.stream_sublayout(g)
    assert set(plan.stream_group_template(0)) == {"emb"}
    assert set(plan.stream_group_template(3)) == {"out", "e"}
    # accounting: peak under the 2-span bound, strictly below full tree
    gb = plan.stream_group_bytes()
    assert plan.stream_peak_gathered_bytes() <= \
        gb[0] + gb[3] + 2 * max(gb[1], gb[2])
    assert plan.stream_peak_gathered_bytes() < plan.full_gathered_bytes()
    assert streaming.expected_stream_gathers(plan) > lay.n_buckets
    # describe surfaces the layer map + layout-cache stats (satellite)
    desc = plan.describe()
    assert "layer map" in desc and "layout cache" in desc
    assert "streamed coverage" in desc
    # a non-layered tree must fail at compile time
    with pytest.raises(ValueError, match="layered param tree"):
        compile_plan(topo, {"w": jax.ShapeDtypeStruct((64,), jnp.float32)},
                     cfg, STREAM)
    # the fp32 grad-shard structure resolves back to the same plan (the
    # averagers are handed the grad tuple inside the step)
    grad_struct = tuple(
        jax.ShapeDtypeStruct(s.shape, np.dtype(np.float32))
        for s in plan.shard_struct())
    assert compile_plan(topo, grad_struct, cfg, STREAM) is plan


def test_streamed_plan_distinct_from_gather_all_plan():
    topo = Topology.hierarchical(("data", "pod"), (4, 2))
    cfg = AveragingConfig(group_size=2, bucket_bytes=4096)
    tree = _grouped_tree()
    p_stream = compile_plan(topo, tree, cfg, STREAM)
    p_all = compile_plan(topo, tree, cfg,
                         ShardingPolicy.fsdp_within_pod("data"))
    assert p_stream is not p_all
    assert not p_all.shard_layout.grouped
    with pytest.raises(ValueError, match="stream_"):
        p_all.stream_unshard((), 0)


# ---------------------------------------------------------------------------
# Cost model: streamed fields
# ---------------------------------------------------------------------------

def test_costmodel_streamed_fields_and_bounds():
    from repro.configs.base import ModelConfig
    from repro.launch.costmodel import averaging_comm_cost
    cfg = ModelConfig(name="cm", family="dense", n_layers=24, d_model=1024,
                      n_heads=8, n_kv_heads=8, d_ff=4096, vocab=32000,
                      dtype="float32")
    topo = Topology.hierarchical(("data", "pod"), (16, 4))
    rep = averaging_comm_cost(cfg, P=64, S=8, n_leaves=290, topology=topo,
                              fsdp_shard_axis="data",
                              fsdp_streamed_spans=24,
                              span_fwd_compute_s=2e-3)
    assert rep.peak_gathered_bytes > 0
    assert 0 < rep.peak_gathered_bytes_streamed < rep.peak_gathered_bytes
    assert rep.t_fsdp_streamed > 0
    # compute covers the span gather here -> streaming hides the wire
    assert rep.t_fsdp_streamed <= rep.t_fsdp_gather_all
    assert rep.streamed_win >= 1.0
    # comm-bound regime: the backward re-gather is honest in the model —
    # streaming can LOSE when span compute cannot cover the span gather
    starved = averaging_comm_cost(cfg, P=64, S=8, n_leaves=290,
                                  topology=topo, fsdp_shard_axis="data",
                                  fsdp_streamed_spans=24,
                                  span_fwd_compute_s=1e-6)
    assert starved.streamed_win < 1.0
    # degenerate single span: "two spans in flight" IS the whole tree —
    # the modeled peak clamps at the full payload, never above it
    one = plan_mod.modeled_streamed_fsdp_step_seconds(
        245_000_000, topo, 2, shard_axis="data", n_spans=1,
        span_fwd_compute_s=1e-3)
    assert one["peak_gathered_bytes_streamed"] == \
        one["peak_gathered_bytes_full"]


def test_topology_with_measured(tmp_path):
    import json
    path = tmp_path / "LINK_CONSTANTS.json"
    path.write_text(json.dumps({
        "backend": "cpu",
        "axes": {"data": {"alpha": 2e-6, "beta": 3e-11, "gamma": 1e-10,
                          "ag_alpha": 1e-6, "ag_beta": 5e-11},
                 "pod": {"alpha": 9e-5, "beta": 2e-10}},
    }))
    topo = Topology.hierarchical(("data", "pod"), (4, 2))
    m = topo.with_measured(str(path))
    ici, dcn = m.link_classes
    # the class takes the slower of the ppermute and all-gather rates
    assert ici.alpha == 2e-6 and ici.beta == 5e-11 and ici.gamma == 1e-10
    assert dcn.alpha == 9e-5 and dcn.beta == 2e-10
    assert dcn.gamma == topo.link_classes[1].gamma     # unmeasured: default
    assert "@measured" in m.describe()
    # partial files leave unmeasured classes untouched
    path.write_text(json.dumps({"axes": {"data": {"alpha": 1e-6,
                                                  "beta": 1e-11}}}))
    m2 = topo.with_measured(str(path))
    assert m2.link_classes[1] == topo.link_classes[1]


# ---------------------------------------------------------------------------
# Cross-policy checkpoint restore with a layer-aware layout (satellite)
# ---------------------------------------------------------------------------

def _concrete_layered(rng, oversize=False):
    span = lambda: {
        "w": jnp.asarray(rng.normal(size=(3000 if oversize else 1300,)),
                         jnp.float32),
        "h": jnp.asarray(rng.normal(size=(300,)),
                         jnp.float32).astype(jnp.bfloat16)}
    return {"stem": {"emb": jnp.asarray(rng.normal(size=(33, 70)),
                                        jnp.float32)},
            "layers": (span(), span()),
            "head": {"out": jnp.asarray(rng.normal(size=(40,)), jnp.float32),
                     "e": jnp.zeros((0, 4), jnp.float32)}}


def test_streamed_checkpoint_cross_policy_restore(tmp_path):
    """Save from a layer-aware sharded run, restore into a replicated run
    and back; one span exceeds the bucket budget (layer spans != budget
    spans) to pin the conversion against the grouped layout."""
    from repro.checkpoint import (checkpoint_sharding, load_replica_state,
                                  save_replica_state)
    topo = Topology.hierarchical(("data", "pod"), (4, 2))
    cfg = AveragingConfig(group_size=2, bucket_bytes=4096)
    rng = np.random.default_rng(3)
    pods = [_concrete_layered(rng, oversize=True) for _ in range(2)]
    plan = compile_plan(topo, pods[0], cfg, STREAM)
    assert len(plan.shard_layout.group_bucket_map()[1]) >= 2  # oversize span
    opt = sgd(0.1)

    bufs = tuple(jnp.stack([bucketing.pack(pods[e], plan.shard_layout)[b]
                            for e in range(2)])
                 for b in range(plan.shard_layout.n_buckets))
    st_fsdp = ReplicaState.create(bufs, jax.vmap(opt.init)(bufs),
                                  step=5, phase=1)
    d = str(tmp_path / "ck")
    save_replica_state(d, st_fsdp, sharding=STREAM)
    pol = checkpoint_sharding(d)
    assert pol.streamed and pol.shard_axis == "data"

    tpl_rep = replica.replicated_state_template(plan, st_fsdp.opt_state)
    # crossing layered <-> canonical requires the decomposition
    with pytest.raises(ValueError, match="layered"):
        load_replica_state(d, tpl_rep, plan=plan)
    st_rep = load_replica_state(d, tpl_rep, plan=plan,
                                layered=_IDENTITY_LAYERED)
    assert int(st_rep.step) == 5 and int(st_rep.phase) == 1
    eff = replica.effective_rank_map(topo.axis_sizes, plan.shard_axis_index)
    for (path, leaf) in jax.tree_util.tree_flatten_with_path(pods[0])[0]:
        got = _leaf_by_path(st_rep.params, path)
        want = np.stack([np.asarray(_leaf_by_path(pods[e], path), np.float32)
                         for e in eff])
        np.testing.assert_array_equal(np.asarray(got, np.float32), want)

    # round trip back into the streamed layout
    d2 = str(tmp_path / "ck2")
    save_replica_state(d2, st_rep)
    tpl_s = replica.sharded_state_template(plan, st_rep.opt_state)
    st_back = load_replica_state(d2, tpl_s, sharding=STREAM, plan=plan,
                                 layered=_IDENTITY_LAYERED)
    for a, b in zip(st_back.params, st_fsdp.params):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))

    # streamed <-> gather-all restore auto-routes through the canonical
    # replicated conversion path (it used to fail loudly); here BOTH
    # plans compile over the same layered tree, so no layered= needed.
    # The destination plan must be supplied though — npz keys are flat
    # bucket indices, so mixing layouts without it would be silent
    # corruption.
    plan_all = compile_plan(topo, pods[0], cfg,
                            ShardingPolicy.fsdp_within_pod("data"))
    tpl_all = replica.sharded_state_template(plan_all, st_fsdp.opt_state)
    with pytest.raises(ValueError, match="pass the compiled plan"):
        load_replica_state(d, tpl_all,
                           sharding=ShardingPolicy.fsdp_within_pod("data"))
    st_all = load_replica_state(d, tpl_all,
                                sharding=ShardingPolicy.fsdp_within_pod(
                                    "data"),
                                plan=plan_all)
    assert int(st_all.step) == 5 and int(st_all.phase) == 1
    # bit-exact across the layout change: unpack both and compare leaves
    got_tree = replica._unpack_rows(st_all.params, plan_all.shard_layout)
    want_tree = replica._unpack_rows(st_fsdp.params, plan.shard_layout)
    for (path, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(got_tree)[0],
            jax.tree_util.tree_flatten_with_path(want_tree)[0]):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32),
                                      err_msg=str(path))

    # and back: a gather-all checkpoint restores into the streamed layout
    d3 = str(tmp_path / "ck3")
    save_replica_state(d3, st_all,
                       sharding=ShardingPolicy.fsdp_within_pod("data"))
    st_round = load_replica_state(d3, replica.sharded_state_template(
        plan, st_fsdp.opt_state), sharding=STREAM, plan=plan)
    for a, b in zip(st_round.params, st_fsdp.params):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def _leaf_by_path(tree, path):
    for k in path:
        key = getattr(k, "key", getattr(k, "idx", None))
        tree = tree[key]
    return tree


def test_streamed_checkpoint_restores_into_canonical_replicated(tmp_path):
    """The prescribed migration path works end to end on a real model: a
    streamed-fsdp checkpoint restores into a CANONICAL replicated state
    (layered rows merged via ModelAPI.layered), and a canonical replicated
    checkpoint restores back into the streamed layout — bit-exact both
    ways."""
    from repro.checkpoint import load_replica_state, save_replica_state
    from repro.configs import get_config
    from repro.models.registry import build_model

    cfg = get_config("qwen3-0.6b", smoke=True).variant(dtype="float32")
    model = build_model(cfg)
    topo = Topology.hierarchical(("data", "pod"), (2, 2))
    p0 = model.init(jax.random.PRNGKey(0))
    lt = model.layered.split(p0)
    plan = compile_plan(topo, lt, AveragingConfig(group_size=2), STREAM)
    packed = bucketing.pack(lt, plan.shard_layout)
    bufs = tuple(jnp.broadcast_to(b[None], (plan.P_eff,) + b.shape)
                 for b in packed)
    opt = sgd(0.1)
    st = ReplicaState.create(bufs, jax.vmap(opt.init)(bufs), step=2,
                             phase=0)
    d = str(tmp_path / "stream_ck")
    save_replica_state(d, st, sharding=STREAM)

    tpl_rep = replica.replicated_state_template(plan, st.opt_state)
    with pytest.raises(ValueError, match="layered"):
        load_replica_state(d, tpl_rep, plan=plan)
    st_rep = load_replica_state(d, tpl_rep, plan=plan,
                                layered=model.layered)
    assert "blocks" in st_rep.params, "canonical structure restored"
    for path, a in jax.tree_util.tree_flatten_with_path(p0)[0]:
        got = np.asarray(_leaf_by_path(st_rep.params, path), np.float32)
        want = np.asarray(a, np.float32)
        for r in range(plan.P):
            np.testing.assert_array_equal(got[r], want, err_msg=str(path))

    # canonical replicated checkpoint -> streamed run, bit-exact round trip
    d2 = str(tmp_path / "rep_ck")
    save_replica_state(d2, st_rep)
    tpl_s = replica.sharded_state_template(plan, st_rep.opt_state)
    st_back = load_replica_state(d2, tpl_s, sharding=STREAM, plan=plan,
                                 layered=model.layered)
    for a, b in zip(st_back.params, st.params):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert int(st_back.step) == 2 and int(st_back.phase) == 0


# ---------------------------------------------------------------------------
# Differential acceptance on the 8-device CPU mesh (subprocess)
# ---------------------------------------------------------------------------

_PREAMBLE = """
    from repro.core import bucketing, grouping, streaming
    from repro.core import group_allreduce as ga
    from repro.core import plan as plan_mod
    from repro.core.replica import ShardingPolicy
    from repro.launch.hlo_analysis import count_ppermutes

    STREAM = ShardingPolicy.fsdp_within_pod("data", streamed=True)

    def layered_tree(rng):
        span = lambda: {
            "w": jnp.asarray(rng.normal(size=(1300,)), jnp.float32),
            "h": jnp.asarray(rng.normal(size=(300,)),
                             jnp.float32).astype(jnp.bfloat16)}
        return {"stem": {"emb": jnp.asarray(rng.normal(size=(33, 70)),
                                            jnp.float32)},
                "layers": (span(), span()),
                "head": {"out": jnp.asarray(rng.normal(size=(40,)),
                                            jnp.float32),
                         "e": jnp.zeros((0, 4), jnp.float32)}}

    # 4 pods x 2 shards: P_eff=4 with S=2 walks TWO phase offsets; tiny
    # pinned budgets force multi-bucket groups
    TOPO_HIER = plan_mod.Topology(
        ("data", "pod"), (2, 4),
        (plan_mod.LinkClass("ici", alpha=1e-6, beta=1e-11,
                            bucket_bytes=4096),
         plan_mod.LinkClass("dcn", alpha=5e-5, beta=1e-10,
                            bucket_bytes=4096)),
        (0, 1))
    TOPO_FLAT = plan_mod.Topology.flat(
        ("data", "pod"), (2, 4),
        link=plan_mod.LinkClass("link", bucket_bytes=4096))

    def sharded_buffers(plan, pods, mesh):
        packed = [bucketing.pack(t, plan.shard_layout) for t in pods]
        return tuple(jax.device_put(
            jnp.stack([packed[e][b] for e in range(len(pods))]),
            NamedSharding(mesh, P("pod", "data"))) for b in range(
                plan.shard_layout.n_buckets))
"""


def run_sub(body: str, devices: int = 8, timeout: int = 600):
    return _run_sub(body, devices=devices, timeout=timeout,
                    preamble=_PREAMBLE)


def test_streamed_plan_average_bit_identical_every_offset():
    """The butterfly over the layer-aware (grouped) shard layout must stay
    bit-identical to the replicated plan on the pod axis and the stacked
    simulator, on every phase offset, flat AND hierarchical."""
    out = run_sub("""
        mesh = jax.make_mesh((4, 2), ("pod", "data"))
        rng = np.random.default_rng(0)
        pods = [layered_tree(rng) for _ in range(4)]
        stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *pods)

        for topo in (TOPO_FLAT, TOPO_HIER):
            pl = plan_mod.compile_plan(
                topo, pods[0], plan_mod.AveragingConfig(group_size=2),
                STREAM)
            assert pl.shard_layout.grouped
            assert pl.shard_layout.n_buckets > 3
            bufs = sharded_buffers(pl, pods, mesh)
            assert len(pl.offsets) > 1
            rep_plan = plan_mod.compile_plan(
                plan_mod.Topology.flat(("pod",), (4,)), pods[0],
                plan_mod.AveragingConfig(group_size=2))
            for ph, off in enumerate(pl.offsets):
                f = compat.shard_map(
                    lambda sh, ph=ph: tuple(
                        o[None] for o in pl.average(
                            tuple(s[0] for s in sh), ph)),
                    mesh=mesh, in_specs=(P("pod", "data"),),
                    out_specs=P("pod", "data"),
                    axis_names={"pod", "data"})
                got = jax.jit(f)(bufs)
                n = count_ppermutes(jax.make_jaxpr(jax.jit(f))(bufs).jaxpr)
                assert n == pl.expected_ppermutes(off), (off, n)
                g = compat.shard_map(
                    lambda tr, ph=ph: rep_plan.average(tr, ph), mesh=mesh,
                    in_specs=P("pod"), out_specs=P("pod"),
                    axis_names={"pod", "data"})
                rep_out = jax.jit(g)(stacked)
                want = ga.group_average_stacked(stacked, P=4, S=2, t=ph)
                for e in range(4):
                    tree_e = bucketing.unpack(
                        tuple(np.asarray(b)[e] for b in got),
                        pl.shard_layout)
                    flat_e = jax.tree_util.tree_flatten_with_path(tree_e)[0]
                    flat_w = jax.tree_util.tree_flatten_with_path(want)[0]
                    flat_r = jax.tree_util.tree_flatten_with_path(rep_out)[0]
                    for (pa, a), (_, w), (_, r) in zip(flat_e, flat_w,
                                                       flat_r):
                        np.testing.assert_array_equal(
                            np.asarray(a, np.float32),
                            np.asarray(w, np.float32)[e],
                            err_msg=f"vs stacked {pa} off {off}")
                        np.testing.assert_array_equal(
                            np.asarray(a, np.float32),
                            np.asarray(r, np.float32)[e],
                            err_msg=f"vs replicated {pa} off {off}")
        print("STREAMED_AVG_BIT_EXACT_OK")
    """)
    assert "STREAMED_AVG_BIT_EXACT_OK" in out


def test_streamed_train_step_bit_exact_vs_gather_all():
    """Acceptance gate: the layer-streamed train step == the gather-all
    FSDP step bit-for-bit — losses and resulting logical params — across
    steps covering every phase offset and the tau-sync, on flat AND
    hierarchical topologies; its compiled HLO contains exactly the
    scheduled number of shard-axis all-gathers; and the microbatched
    gather-all path (re-gather per microbatch, shard-space fp32
    accumulation) agrees with the single-batch step."""
    out = run_sub("""
        from repro.configs import SHAPES, get_config
        from repro.core.baselines import make_averager
        from repro.core.group_allreduce import dp_axis_layout
        from repro.data import make_batch_fn
        from repro.launch.hlo_analysis import grouped_collective_details
        from repro.models.registry import build_model
        from repro.optim import sgd
        from repro.train import build_train_step, init_replica_state
        from repro.train.train_step import _plan_of

        mesh = jax.make_mesh((4, 2, 1), ("pod", "data", "model"))
        cfg = get_config("qwen3-0.6b", smoke=True).variant(dtype="float32")
        model = build_model(cfg)
        names, sizes = dp_axis_layout(mesh.axis_names, dict(mesh.shape),
                                      ("pod", "data"))
        bf = make_batch_fn(cfg, SHAPES["train_4k"], seed=0)
        FSDP = ShardingPolicy.fsdp_within_pod("data")

        def logical(model, av, state):
            plan = _plan_of(model, av)
            out = []
            for e in range(plan.P_eff):
                tree = bucketing.unpack(
                    tuple(np.asarray(b)[e] for b in state.params),
                    plan.shard_layout)
                if av.sharding.streamed:
                    tree = model.layered.merge(tree)
                out.append(tree)
            return out

        for topo_name, topo in (
                ("hier", plan_mod.Topology.hierarchical(
                    names, sizes, dcn_axes=("pod",))),
                ("flat", plan_mod.Topology.flat(names, sizes))):
            runs = {}
            with compat.set_mesh(mesh):
                for tag, pol in (("gather_all", FSDP), ("streamed", STREAM)):
                    av = make_averager("wagma", names, sizes, group_size=2,
                                       tau=4, topology=topo, sharding=pol)
                    assert av.n_phases == 2
                    opt = sgd(0.3, momentum=0.9)
                    runs[tag] = dict(
                        av=av, opt=opt,
                        state=init_replica_state(model, opt, av, mesh,
                                                 jax.random.PRNGKey(0)))
                steps, losses = {}, {}
                for t in range(5):
                    nb = {k: jnp.asarray(v)[:, :32]
                          for k, v in bf(t, 0, 8).items()}
                    batch = {k: jax.device_put(v, NamedSharding(
                        mesh, P(("pod", "data"), None)))
                        for k, v in nb.items()}
                    for tag, r in runs.items():
                        key = (tag, r["av"].phase_for_step(t),
                               r["av"].sync_due(t))
                        if key not in steps:
                            steps[key] = build_train_step(
                                model, r["opt"], r["av"], mesh,
                                phase=key[1], sync=key[2])
                        r["state"], m = steps[key](r["state"], batch)
                        losses[tag] = float(m["loss"])
                    assert losses["streamed"] == losses["gather_all"], losses
                    pa = logical(model, runs["gather_all"]["av"],
                                 runs["gather_all"]["state"])
                    pb = logical(model, runs["streamed"]["av"],
                                 runs["streamed"]["state"])
                    for e, (ta, tb) in enumerate(zip(pa, pb)):
                        for a, b in zip(jax.tree.leaves(ta),
                                        jax.tree.leaves(tb)):
                            np.testing.assert_array_equal(
                                np.asarray(a, np.float32),
                                np.asarray(b, np.float32),
                                err_msg=f"{topo_name} t={t} pod={e}")
                print(topo_name, "bit-exact over 5 steps (2 offsets + sync)")

                # HLO cross-check on the streamed group step: exactly the
                # scheduled shard-axis all-gathers, none bigger than one
                # layer-span bucket
                r = runs["streamed"]
                plan = _plan_of(model, r["av"])
                hlo = steps[("streamed", 0, False)].lower(
                    r["state"], batch).compile().as_text()
                det = grouped_collective_details(
                    hlo, ("pod", "data", "model"), (4, 2, 1))
                ags = [d for d in det if d["kind"] == "all-gather"
                       and d["axis"] == "data"]
                assert len(ags) == streaming.expected_stream_gathers(plan), (
                    len(ags), streaming.expected_stream_gathers(plan))
                lay = plan.shard_layout
                max_bucket = max(s * max(d.itemsize, 4) for s, d in
                                 zip(lay.bucket_sizes, lay.bucket_dtypes))
                assert all(d["tensor_bytes"] <= max_bucket for d in ags)
                assert plan.stream_peak_gathered_bytes() < \
                    plan.full_gathered_bytes()

        # S2 bugfix check: the microbatched gather-all step (re-gather per
        # microbatch, fp32 shard-space accumulation) matches the
        # single-batch step closely (summation order differs)
        with compat.set_mesh(mesh):
            av = runs["gather_all"]["av"]
            opt = sgd(0.3, momentum=0.9)
            st_a = init_replica_state(model, opt, av, mesh,
                                      jax.random.PRNGKey(0))
            st_b = init_replica_state(model, opt, av, mesh,
                                      jax.random.PRNGKey(0))
            step_a = build_train_step(model, opt, av, mesh, phase=0,
                                      sync=False)
            step_b = build_train_step(model, opt, av, mesh, phase=0,
                                      sync=False, microbatch=2)
            nb = {k: jnp.asarray(v)[:, :32] for k, v in bf(0, 0, 16).items()}
            batch = {k: jax.device_put(v, NamedSharding(
                mesh, P(("pod", "data"), None))) for k, v in nb.items()}
            st_a, ma = step_a(st_a, batch)
            st_b, mb = step_b(st_b, batch)
            for a, b in zip(st_a.params, st_b.params):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=2e-5, atol=2e-6)
        print("MICROBATCH_FSDP_OK")
        print("STREAMED_STEP_BIT_EXACT_OK")
    """, timeout=900)
    assert "STREAMED_STEP_BIT_EXACT_OK" in out
    assert "MICROBATCH_FSDP_OK" in out
