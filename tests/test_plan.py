"""Compiled AveragingPlan: topology classification, per-class budgets,
compile caching, and differential acceptance (DESIGN.md §9).

Host-side tests pin the pure compilation pipeline — bit → axis → link class,
per-class ``choose_class_bucket_bytes`` argmins, stage-run splitting, plan
caching, the per-class step model.  Subprocess tests pin the execution
semantics on the 8-device CPU mesh: ``plan.average`` must be bit-identical
to the legacy fused shim, the serial-bucketed and per-leaf paths, and the
stacked simulator on EVERY phase offset — including hierarchical (2-link-
class) topologies whose butterflies repack between ICI and DCN stage runs —
and the per-class launch accounting must match both the jaxpr and the
compiled HLO's axis-classified collective-permutes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from subproc import run_sub as _run_sub

from repro.core import bucketing, grouping
from repro.core import plan as plan_mod
from repro.core.plan import (AveragingConfig, DCN, ICI, LinkClass, Topology,
                             choose_class_bucket_bytes, class_stage_seconds,
                             compile_plan, modeled_wagma_step_seconds)


# ---------------------------------------------------------------------------
# Topology: bit -> axis -> link class
# ---------------------------------------------------------------------------

def test_hierarchical_low_bits_ici_high_bits_dcn():
    # minor-to-major (data, pod): data=16 owns bits 0..3, pod=4 bits 4..5
    topo = Topology.hierarchical(("data", "pod"), (16, 4), dcn_axes=("pod",))
    assert topo.P == 64
    assert [topo.link_of_bit(b).name for b in range(6)] == \
        ["ici"] * 4 + ["dcn"] * 2
    assert [topo.axis_of_bit(b) for b in range(6)] == \
        ["data"] * 4 + ["pod"] * 2
    assert topo.bottleneck().name == "dcn"
    with pytest.raises(ValueError):
        topo.class_of_bit(6)


def test_flat_topology_single_class_everywhere():
    topo = Topology.flat(("data",), (8,))
    assert topo.classes_in_use() == (0,)
    assert all(topo.link_of_bit(b).name == "link" for b in range(3))
    # hierarchical with no matching dcn axis degrades to flat ICI
    t2 = Topology.hierarchical(("data",), (8,), dcn_axes=("pod",))
    assert t2.link_classes == (ICI,)


def test_topology_validation():
    with pytest.raises(ValueError):
        Topology(("data",), (6,), (ICI,), (0,))        # not a power of two
    with pytest.raises(ValueError):
        Topology(("data",), (8,), (ICI,), (1,))        # class out of range
    with pytest.raises(ValueError):
        Topology(("data", "pod"), (8,), (ICI,), (0,))  # length mismatch


# ---------------------------------------------------------------------------
# Per-class budgets
# ---------------------------------------------------------------------------

BIG = {"w": jax.ShapeDtypeStruct((64, 1024, 1024), jnp.float32)}   # 256 MiB


def test_per_class_budgets_distinct_and_argmin():
    plan = compile_plan(Topology.hierarchical(("data", "pod"), (16, 4)),
                        BIG, AveragingConfig(group_size=8))
    b_ici, b_dcn = plan.class_bucket_bytes[0], plan.class_bucket_bytes[1]
    assert b_ici != b_dcn, "2-class topology must pick distinct budgets"
    payload = plan.payload_bytes
    for budget, link in ((b_ici, ICI), (b_dcn, DCN)):
        assert budget in bucketing.BUCKET_BYTES_CANDIDATES
        t_star = class_stage_seconds(payload, link,
                                     -(-payload // budget), overlap=True)
        for cand in bucketing.BUCKET_BYTES_CANDIDATES:
            t = class_stage_seconds(payload, link,
                                    -(-payload // cand), overlap=True)
            assert t_star <= t + 1e-15, (link.name, budget, cand)
    # cheap-launch ICI pipelines finer than expensive-launch DCN
    assert b_ici < b_dcn


def test_pinned_link_budget_and_global_override():
    pinned = LinkClass("ici", alpha=1e-6, beta=1e-11, bucket_bytes=4096)
    assert choose_class_bucket_bytes(10**9, pinned) == 4096
    topo = Topology.hierarchical(("data", "pod"), (16, 4))
    plan = compile_plan(topo, BIG, AveragingConfig(group_size=8,
                                                   bucket_bytes=2**20))
    assert set(plan.class_bucket_bytes.values()) == {2**20}


def test_mix_bucket_bytes_follows_link_class():
    plan = compile_plan(Topology.hierarchical(("data", "pod"), (16, 4)),
                        BIG, AveragingConfig(group_size=8))
    ici_b = choose_class_bucket_bytes(plan.payload_bytes, ICI)
    dcn_b = choose_class_bucket_bytes(plan.payload_bytes, DCN)
    assert plan.mix_bucket_bytes((0,)) == ici_b      # minor-axis ring
    assert plan.mix_bucket_bytes((5,)) == dcn_b      # pod-crossing bit
    assert plan.mix_bucket_bytes((0, 5)) == dcn_b    # bound by slowest wire
    assert plan.mix_bucket_bytes(()) == dcn_b        # global collective


# ---------------------------------------------------------------------------
# Stage runs + plan accounting
# ---------------------------------------------------------------------------

def test_stage_runs_split_by_class():
    topo = Topology.hierarchical(("data", "pod"), (16, 4))
    plan = compile_plan(topo, BIG, AveragingConfig(group_size=8))  # ls=3
    assert [(r.class_index, r.bits) for r in plan.runs_for_offset(0)] == \
        [(0, (0, 1, 2))]
    # offset 3: bit 3 still data/ICI, bits 4-5 pod/DCN -> two runs
    assert [(r.class_index, r.bits) for r in plan.runs_for_offset(3)] == \
        [(0, (3,)), (1, (4, 5))]
    # wrap-around offset: DCN then ICI
    assert [(r.class_index, r.bits) for r in plan.runs_for_offset(4)] == \
        [(1, (4, 5)), (0, (0,))]


def test_expected_ppermutes_and_describe():
    topo = Topology.hierarchical(("data", "pod"), (16, 4))
    plan = compile_plan(topo, BIG, AveragingConfig(group_size=8))
    for off in plan.offsets:
        per_class = plan.per_class_expected(off)
        total = sum(e["ppermutes"] for e in per_class.values())
        assert total == plan.expected_ppermutes(off)
        for ent in per_class.values():
            assert ent["ppermutes"] == ent["stages"] * ent["n_buckets"]
    text = plan.describe()
    assert "ici" in text and "dcn" in text and "phase" in text
    for bb in plan.class_bucket_bytes.values():
        assert f"{bb / 2**20:.0f}MiB" in text


# ---------------------------------------------------------------------------
# Compile caching (satellite: no re-derivation when only the phase changes)
# ---------------------------------------------------------------------------

def test_compile_plan_cached_across_structures_and_phases():
    topo = Topology.flat(("data",), (8,))
    cfg = AveragingConfig(group_size=4)
    t1 = {"a": jnp.zeros((3, 4), jnp.float32), "b": jnp.ones((5,), jnp.bfloat16)}
    t2 = {"a": jnp.full((3, 4), 9.0, jnp.float32),
          "b": jnp.zeros((5,), jnp.bfloat16)}
    p1 = compile_plan(topo, t1, cfg)
    assert compile_plan(topo, t2, cfg) is p1            # same structure
    sds = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), t1)
    assert compile_plan(topo, sds, cfg) is p1           # arrays == structs
    assert compile_plan(topo, t1, AveragingConfig(group_size=2)) is not p1
    # walking every phase offset reuses ONE cached layout: only the first
    # class_layout call may miss, later offsets/classes hit
    assert len(p1.offsets) > 1
    p1.class_layout(0)
    stats0 = bucketing.layout_cache_stats()
    for off in p1.offsets:
        for run in p1.runs_for_offset(off):
            p1.class_layout(run.class_index)
    stats1 = bucketing.layout_cache_stats()
    assert stats1["misses"] == stats0["misses"], (stats0, stats1)
    assert stats1["hits"] > stats0["hits"]


def test_choose_bucket_bytes_sweep_is_cached():
    bucketing.choose_bucket_bytes.cache_clear()
    kw = dict(P=64, S=8, tau=10)
    bucketing.choose_bucket_bytes(245_000_000, **kw)
    h0 = bucketing.choose_bucket_bytes.cache_info().hits
    bucketing.choose_bucket_bytes(245_000_000, **kw)
    assert bucketing.choose_bucket_bytes.cache_info().hits == h0 + 1


# ---------------------------------------------------------------------------
# Per-class step model (costmodel / bench / cluster_sim composition)
# ---------------------------------------------------------------------------

def test_modeled_hierarchical_step_per_class_budgets_win():
    topo = Topology.hierarchical(("data", "pod"), (16, 4))
    payload = 245_000_000
    hier = modeled_wagma_step_seconds(payload, topo, 8, tau=10)
    single = modeled_wagma_step_seconds(payload, topo, 8, tau=10,
                                        bucket_bytes=32 * 2**20)
    assert set(hier["per_class"]) == {"ici", "dcn"}
    assert hier["per_class"]["ici"]["bucket_bytes"] != \
        hier["per_class"]["dcn"]["bucket_bytes"]
    assert hier["step_s"] <= single["step_s"]
    assert hier["step_s"] > 0 and hier["sync_s"] > 0
    # a slower DCN can only make the step slower than all-ICI
    all_ici = modeled_wagma_step_seconds(
        payload, Topology.flat(("data", "pod"), (16, 4), link=ICI), 8, tau=10)
    assert hier["group_s"] >= all_ici["group_s"]


def test_costmodel_commreport_per_class_fields():
    import sys, os
    sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "benchmarks"))
    from repro.launch.costmodel import averaging_comm_cost
    from repro.configs.base import ModelConfig
    cfg = ModelConfig(name="cm", family="dense", n_layers=24, d_model=1024,
                      n_heads=8, n_kv_heads=8, d_ff=4096, vocab=32000,
                      dtype="float32")
    topo = Topology.hierarchical(("data", "pod"), (16, 4))
    rep = averaging_comm_cost(cfg, P=64, S=8, n_leaves=290, topology=topo)
    assert set(rep.per_class) == {"ici", "dcn"}
    assert rep.t_hierarchical > 0
    assert rep.t_hierarchical <= rep.t_hierarchical_flat_budget
    assert rep.hierarchical_budget_win >= 1.0
    from cluster_sim import hierarchical_win
    win = hierarchical_win(P=64, model_bytes=245e6)
    assert win["speedup"] >= 1.0
    assert win["class_budgets"]["ici"] != win["class_budgets"]["dcn"]


def test_removed_shims_hard_error_with_plan_pointer():
    """The deprecated kwarg entry points completed their deprecation cycle:
    calling them is a hard error pointing at the plan API (ROADMAP item)."""
    from repro.core import group_allreduce as ga
    for fn, kwargs in [
            (ga.group_average, dict(offset=0, P=8, S=4,
                                    axis_names=("data",), axis_sizes=(8,))),
            (ga.global_average, dict(axis_names=("data",))),
            (ga.resolve_bucket_bytes, dict(bucket_bytes=None, P=8, S=4))]:
        with pytest.raises(RuntimeError, match="compile_plan"):
            fn({"w": jnp.zeros((4,))}, **kwargs)
    # the constants and the stacked simulator legitimately remain
    assert ga.DEFAULT_ALPHA > 0 and ga.DEFAULT_BETA > 0
    assert callable(ga.group_average_stacked)


def test_permute_axis_counts_classifies_synthetic_hlo():
    from repro.launch.hlo_analysis import permute_axis_counts
    # mesh ('pod','data') = (2,4): id = pod*4 + data
    hlo = """
ENTRY %main (p: f32[8]) -> f32[8] {
  %cp1 = f32[8] collective-permute(%p), source_target_pairs={{0,1},{1,0},{2,3},{3,2},{4,5},{5,4},{6,7},{7,6}}
  %cp2 = f32[8] collective-permute-start(%cp1), source_target_pairs={{0,4},{4,0},{1,5},{5,1},{2,6},{6,2},{3,7},{7,3}}
  %cp3 = f32[8] collective-permute(%cp2), source_target_pairs={{0,2},{2,0},{1,3},{3,1},{4,6},{6,4},{5,7},{7,5}}
}
"""
    counts = permute_axis_counts(hlo, ("pod", "data"), (2, 4))
    assert counts == {"data": 2, "pod": 1}


# ---------------------------------------------------------------------------
# Differential acceptance on the 8-device CPU mesh (subprocess)
# ---------------------------------------------------------------------------

_PREAMBLE = """
    from repro.core import bucketing, grouping
    from repro.core import group_allreduce as ga
    from repro.core import plan as plan_mod
    from repro.launch.hlo_analysis import (collective_summary,
                                           count_ppermutes,
                                           permute_axis_counts)

    def mixed_tree(rng, P_dp):
        return {
            "emb": jnp.asarray(rng.normal(size=(P_dp, 33, 70)), jnp.float32),
            "w": jnp.asarray(rng.normal(size=(P_dp, 1300)), jnp.float32),
            "s": jnp.asarray(rng.normal(size=(P_dp,)), jnp.float32),
            "h": jnp.asarray(rng.normal(size=(P_dp, 300)),
                             jnp.float32).astype(jnp.bfloat16),
            "e": jnp.zeros((P_dp, 0, 4), jnp.float32),
        }

    # tiny pinned budgets force multi-bucket, multi-run plans on test trees
    TOPO_HIER = plan_mod.Topology(
        ("data", "pod"), (4, 2),
        (plan_mod.LinkClass("ici", alpha=1e-6, beta=1e-11, bucket_bytes=4096),
         plan_mod.LinkClass("dcn", alpha=5e-5, beta=1e-10, bucket_bytes=8192)),
        (0, 1))
"""


def run_sub(body: str, devices: int = 8, timeout: int = 420):
    return _run_sub(body, devices=devices, timeout=timeout,
                    preamble=_PREAMBLE)


def test_plan_average_bit_identical_to_legacy_paths_every_offset():
    """Acceptance gate: the overlapped plan == serial-bucketed == per-leaf
    == stacked simulator, bit-for-bit, on every phase offset (the removed
    kwarg shims' realisations, now expressed as plan configs)."""
    out = run_sub("""
        P_dp, S = 8, 4
        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        names, sizes = ga.dp_axis_layout(("pod", "data"), dict(pod=2, data=4),
                                         ("pod", "data"))
        rng = np.random.default_rng(0)
        tree = mixed_tree(rng, P_dp)
        local = jax.tree.map(lambda a: a[0], tree)
        topo = plan_mod.Topology.flat(names, sizes)
        plan = plan_mod.compile_plan(
            topo, local,
            plan_mod.AveragingConfig(group_size=S, average_dtype="float32"))
        offsets = grouping.distinct_offsets(P_dp, S)
        assert plan.offsets == offsets and len(offsets) > 1
        for ph, off in enumerate(offsets):
            variants = {}
            f = compat.shard_map(
                lambda tr, p=ph: plan.average(tr, p),
                mesh=mesh, in_specs=P(("pod", "data")),
                out_specs=P(("pod", "data")), axis_names={"pod", "data"})
            variants["plan"] = jax.jit(f)(tree)
            for key, kw in [
                    ("legacy_fused", dict(fused=True)),
                    ("serial_bucketed", dict(fused=True, overlap=False)),
                    ("per_leaf", dict(fused=False))]:
                pv = plan_mod.compile_plan(
                    topo, local,
                    plan_mod.AveragingConfig(group_size=S,
                                             average_dtype="float32", **kw))
                g = compat.shard_map(
                    lambda tr, pv=pv, off=off: pv.average_offset(tr, off),
                    mesh=mesh, in_specs=P(("pod", "data")),
                    out_specs=P(("pod", "data")),
                    axis_names={"pod", "data"})
                variants[key] = jax.jit(g)(tree)
            want = ga.group_average_stacked(tree, P=P_dp, S=S, t=ph)
            for key, got in variants.items():
                for leaf in tree:
                    tol = 2e-2 if leaf == "h" else 1e-5
                    np.testing.assert_allclose(
                        np.asarray(got[leaf], np.float32),
                        np.asarray(want[leaf], np.float32), rtol=tol,
                        atol=tol, err_msg=f"{key} vs stacked, offset {off}")
                for leaf in tree:    # exactness across realisations
                    np.testing.assert_array_equal(
                        np.asarray(got[leaf], np.float32),
                        np.asarray(variants["per_leaf"][leaf], np.float32),
                        err_msg=f"{key} exactness, offset {off}, {leaf}")
        print("PLAN_OFFSETS_MATCH", len(offsets))
    """)
    assert "PLAN_OFFSETS_MATCH" in out


def test_hierarchical_plan_bit_identical_every_offset():
    """2-link-class butterflies repack between ICI and DCN stage runs with
    distinct budgets — still bit-identical to per-leaf and the stacked
    simulator on every phase offset (fp32 continuity across runs)."""
    out = run_sub("""
        P_dp, S = 8, 4
        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        names, sizes = ga.dp_axis_layout(("pod", "data"), dict(pod=2, data=4),
                                         ("pod", "data"))
        rng = np.random.default_rng(7)
        tree = mixed_tree(rng, P_dp)
        local = jax.tree.map(lambda a: a[0], tree)
        cfgs = {
            "hier_overlap": plan_mod.AveragingConfig(group_size=S),
            "hier_serial": plan_mod.AveragingConfig(group_size=S,
                                                    overlap=False),
            "hier_jnp": plan_mod.AveragingConfig(group_size=S,
                                                 use_pallas=False),
            "per_leaf": plan_mod.AveragingConfig(group_size=S, fused=False),
        }
        plans = {k: plan_mod.compile_plan(TOPO_HIER, local, c)
                 for k, c in cfgs.items()}
        pl = plans["hier_overlap"]
        assert pl.class_bucket_bytes == {0: 4096, 1: 8192}
        assert pl.class_layout(0).n_buckets > 1, "budget must force buckets"
        # at least one offset must mix classes within one butterfly
        assert any(len(pl.runs_for_offset(o)) > 1 for o in pl.offsets)
        for ph, off in enumerate(pl.offsets):
            got = {}
            for key, p in plans.items():
                f = compat.shard_map(
                    lambda tr, p=p, ph=ph: p.average(tr, ph), mesh=mesh,
                    in_specs=P(("pod", "data")),
                    out_specs=P(("pod", "data")),
                    axis_names={"pod", "data"})
                got[key] = jax.jit(f)(tree)
            want = ga.group_average_stacked(tree, P=P_dp, S=S, t=ph)
            for key, res in got.items():
                for leaf in tree:
                    tol = 2e-2 if leaf == "h" else 1e-5
                    np.testing.assert_allclose(
                        np.asarray(res[leaf], np.float32),
                        np.asarray(want[leaf], np.float32), rtol=tol,
                        atol=tol, err_msg=f"{key} vs stacked, offset {off}")
                    np.testing.assert_array_equal(
                        np.asarray(res[leaf], np.float32),
                        np.asarray(got["per_leaf"][leaf], np.float32),
                        err_msg=f"{key} exactness, offset {off}, {leaf}")
        print("HIER_OFFSETS_MATCH", len(pl.offsets))
    """)
    assert "HIER_OFFSETS_MATCH" in out


def test_hierarchical_launch_counts_per_class_match_jaxpr_and_hlo():
    """Per-class accounting: jaxpr ppermutes == plan expectation per offset,
    and the compiled HLO's axis-classified collective-permutes match the
    per-class split (ICI launches on 'data', DCN launches on 'pod')."""
    out = run_sub("""
        P_dp, S = 8, 4
        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        names, sizes = ga.dp_axis_layout(("pod", "data"), dict(pod=2, data=4),
                                         ("pod", "data"))
        rng = np.random.default_rng(1)
        tree = mixed_tree(rng, P_dp)
        local = jax.tree.map(lambda a: a[0], tree)
        plan = plan_mod.compile_plan(
            TOPO_HIER, local, plan_mod.AveragingConfig(group_size=S))

        def make(ph):
            return jax.jit(compat.shard_map(
                lambda tr: plan.average(tr, ph), mesh=mesh,
                in_specs=P(("pod", "data")), out_specs=P(("pod", "data")),
                axis_names={"pod", "data"}))

        for ph, off in enumerate(plan.offsets):
            expected = plan.expected_ppermutes(off)
            n = count_ppermutes(jax.make_jaxpr(make(ph))(tree).jaxpr)
            assert n == expected, (off, n, expected)

        # HLO per-class cross-check on the class-mixing offset
        ph = next(i for i, o in enumerate(plan.offsets)
                  if len(plan.runs_for_offset(o)) > 1)
        off = plan.offsets[ph]
        hlo = make(ph).lower(tree).compile().as_text()
        per_axis = permute_axis_counts(hlo, ("pod", "data"), (2, 4))
        per_class = plan.per_class_expected(off)
        assert per_axis.get("data", 0) == per_class["ici"]["ppermutes"], \\
            (per_axis, per_class)
        assert per_axis.get("pod", 0) == per_class["dcn"]["ppermutes"], \\
            (per_axis, per_class)
        counts = collective_summary(hlo)["counts_by_kind"]
        assert counts.get("collective-permute", 0) == \\
            plan.expected_ppermutes(off)
        print("PER_CLASS_LAUNCHES_OK")
    """)
    assert "PER_CLASS_LAUNCHES_OK" in out


def test_wagma_averager_with_topology_and_dryrun_summary():
    """WagmaAverager(topology=...) end to end: comm matches the stacked
    simulator per phase, sync equalises, and the dryrun plan summary
    reports per-class expectations that match the compiled HLO."""
    out = run_sub("""
        from repro.core.wagma import WagmaAverager, WagmaConfig
        from repro.launch.dryrun import bucket_collective_summary
        P_dp, S = 8, 4
        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        names, sizes = ga.dp_axis_layout(("pod", "data"), dict(pod=2, data=4),
                                         ("pod", "data"))
        rng = np.random.default_rng(4)
        tree = mixed_tree(rng, P_dp)
        local = jax.tree.map(lambda a: a[0], tree)
        av = WagmaAverager(names, sizes, WagmaConfig(group_size=S),
                           topology=TOPO_HIER)
        for ph in range(av.n_phases):
            f = compat.shard_map(lambda tr, p=ph: av.comm(tr, p), mesh=mesh,
                                 in_specs=P(("pod", "data")),
                                 out_specs=P(("pod", "data")),
                                 axis_names={"pod", "data"})
            got = jax.jit(f)(tree)
            want = ga.group_average_stacked(tree, P=P_dp, S=S, t=ph)
            for leaf in tree:
                tol = 2e-2 if leaf == "h" else 1e-5
                np.testing.assert_allclose(
                    np.asarray(got[leaf], np.float32),
                    np.asarray(want[leaf], np.float32), rtol=tol, atol=tol)
        g = compat.shard_map(av.sync, mesh=mesh, in_specs=P(("pod", "data")),
                             out_specs=P(("pod", "data")),
                             axis_names={"pod", "data"})
        synced = jax.jit(g)(tree)
        for leaf in ("emb", "w", "s"):
            want = np.asarray(tree[leaf], np.float32).mean(0)
            np.testing.assert_allclose(
                np.asarray(synced[leaf], np.float32),
                np.broadcast_to(want, synced[leaf].shape), rtol=1e-5,
                atol=1e-5)

        # dryrun summary: phase-0 expectations vs compiled phase-0 HLO
        f0 = jax.jit(compat.shard_map(
            lambda tr: av.comm(tr, 0), mesh=mesh,
            in_specs=P(("pod", "data")), out_specs=P(("pod", "data")),
            axis_names={"pod", "data"}))
        hlo = f0.lower(tree).compile().as_text()
        summary = bucket_collective_summary(
            av, local, collective_summary(hlo), mesh=mesh, hlo_text=hlo)
        assert summary["match"], summary
        assert all(summary["per_class_match"].values()), summary
        assert "ici" in summary["plan_summary"]
        assert "dcn" in summary["plan_summary"]
        print("WAGMA_TOPOLOGY_OK")
    """)
    assert "WAGMA_TOPOLOGY_OK" in out


def test_baseline_plans_use_class_budgets():
    """Baselines hold plans: D-PSGD's minor-axis ring buckets at the ICI
    budget while the global allreduce buckets at the DCN (bottleneck)
    budget; results still match the per-leaf reference."""
    out = run_sub("""
        from repro.core.baselines import make_averager
        P_dp = 8
        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        names, sizes = ga.dp_axis_layout(("pod", "data"), dict(pod=2, data=4),
                                         ("pod", "data"))
        rng = np.random.default_rng(3)
        tree = {"w": jnp.asarray(rng.normal(size=(8, 1300)), jnp.float32),
                "b": jnp.asarray(rng.normal(size=(8, 5)), jnp.float32)}
        local = jax.tree.map(lambda a: a[0], tree)
        for name in ("dpsgd", "allreduce", "sgp", "adpsgd"):
            got = {}
            for mode, kw in [("fused", dict(fused=True, bucket_bytes=None)),
                             ("per_leaf", dict(fused=False))]:
                av = make_averager(name, names, sizes, topology=TOPO_HIER,
                                   **kw)
                f = compat.shard_map(
                    lambda tr, av=av: av.comm(tr, 0), mesh=mesh,
                    in_specs=P(("pod", "data")),
                    out_specs=P(("pod", "data")),
                    axis_names={"pod", "data"})
                got[mode] = jax.jit(f)(tree)
            for k in tree:
                np.testing.assert_allclose(
                    np.asarray(got["fused"][k]),
                    np.asarray(got["per_leaf"][k]), rtol=1e-5, atol=1e-6,
                    err_msg=name)
        av = make_averager("dpsgd", names, sizes, topology=TOPO_HIER,
                           bucket_bytes=None)
        plan = av.plan_for(local)
        assert plan.mix_bucket_bytes((0,)) == 4096      # ring: ICI budget
        assert plan.mix_bucket_bytes(()) == 8192        # global: bottleneck
        print("BASELINE_PLAN_OK")
    """)
    assert "BASELINE_PLAN_OK" in out