"""Pack/unpack layout invariants for the bucketed averaging path.

The bucketed fused collective path is only sound if pack -> unpack is an
*exact* round trip for any params pytree the trainers produce — mixed
dtypes, scalars, empty leaves, nested containers — and if the layout obeys
its contract (dtype-homogeneous buckets, byte budget, lane padding).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bucketing

RNG = np.random.default_rng(0)


def _tree_mixed():
    return {
        "emb": jnp.asarray(RNG.standard_normal((33, 7)), jnp.float32),
        "blocks": [
            {"w": jnp.asarray(RNG.standard_normal((4, 5, 6)), jnp.bfloat16),
             "b": jnp.asarray(RNG.standard_normal((6,)), jnp.bfloat16)},
            {"w": jnp.asarray(RNG.standard_normal((2, 3)), jnp.float32),
             "b": jnp.asarray(RNG.standard_normal((3,)), jnp.float32)},
        ],
        "scalar": jnp.asarray(3.5, jnp.float32),
        "count": jnp.asarray(7, jnp.int32),
        "empty": jnp.zeros((0, 4), jnp.float32),
    }


def _assert_trees_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert jax.tree_util.tree_structure(a) == jax.tree_util.tree_structure(b)
    for x, y in zip(la, lb):
        assert x.shape == y.shape and x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_round_trip_mixed_dtype_scalar_empty():
    tree = _tree_mixed()
    layout = bucketing.layout_for(tree)
    buckets = bucketing.pack(tree, layout)
    _assert_trees_equal(bucketing.unpack(buckets, layout), tree)


def test_round_trip_under_jit():
    tree = _tree_mixed()
    layout = bucketing.layout_for(tree)

    @jax.jit
    def rt(t):
        return bucketing.unpack(bucketing.pack(t, layout), layout)

    _assert_trees_equal(rt(tree), tree)


def test_buckets_are_dtype_homogeneous_and_lane_padded():
    tree = _tree_mixed()
    layout = bucketing.layout_for(tree)
    buckets = bucketing.pack(tree, layout)
    assert len(buckets) == layout.n_buckets
    for buf, size, dtype in zip(buckets, layout.bucket_sizes,
                                layout.bucket_dtypes):
        assert buf.dtype == dtype and buf.shape == (size,)
        assert size % 128 == 0
    for slot in layout.slots:
        assert slot.dtype == layout.bucket_dtypes[slot.bucket]


def test_bucket_budget_respected_and_oversize_leaf_isolated():
    # 10 leaves of 1000 f32 (4 KB each) with a 10 KB budget -> 2 per bucket;
    # one 100 KB leaf must land alone in its own bucket.
    tree = {f"l{i}": jnp.zeros((1000,), jnp.float32) for i in range(10)}
    tree["big"] = jnp.zeros((25_000,), jnp.float32)
    layout = bucketing.layout_for(tree, max_bucket_bytes=10_000)
    per_bucket = {}
    for slot in layout.slots:
        per_bucket.setdefault(slot.bucket, 0)
        per_bucket[slot.bucket] += slot.size
    big_slot = layout.slots[sorted(tree).index("big")]
    assert per_bucket[big_slot.bucket] == 25_000
    for bi, total in per_bucket.items():
        if bi != big_slot.bucket:
            assert total * 4 <= 10_000
    buckets = bucketing.pack(tree, layout)
    _assert_trees_equal(bucketing.unpack(buckets, layout), tree)


def test_single_bucket_when_budget_is_large():
    tree = {f"l{i}": jnp.zeros((100,), jnp.float32) for i in range(20)}
    layout = bucketing.layout_for(tree)
    assert layout.n_buckets == 1
    assert layout.bucket_sizes[0] == -(-2000 // 128) * 128


def test_layout_cache_hits_on_equal_structure():
    t1 = {"a": jnp.zeros((3, 4), jnp.float32), "b": jnp.ones((5,), jnp.bfloat16)}
    t2 = {"a": jnp.full((3, 4), 9.0, jnp.float32),
          "b": jnp.zeros((5,), jnp.bfloat16)}
    assert bucketing.layout_for(t1) is bucketing.layout_for(t2)
    t3 = {"a": jnp.zeros((3, 5), jnp.float32), "b": jnp.ones((5,), jnp.bfloat16)}
    assert bucketing.layout_for(t1) is not bucketing.layout_for(t3)


def test_layout_from_shape_dtype_structs_matches_arrays():
    tree = _tree_mixed()
    shapes = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)
    assert bucketing.layout_for(shapes) is bucketing.layout_for(tree)


def test_all_empty_dtype_group():
    tree = {"e1": jnp.zeros((0,), jnp.float32),
            "e2": jnp.zeros((2, 0), jnp.float32),
            "x": jnp.ones((4,), jnp.int32)}
    layout = bucketing.layout_for(tree)
    buckets = bucketing.pack(tree, layout)
    _assert_trees_equal(bucketing.unpack(buckets, layout), tree)


def test_clear_layout_cache_drops_entries():
    t1 = {"a": jnp.zeros((3, 4), jnp.float32)}
    first = bucketing.layout_for(t1)
    assert bucketing.layout_for(t1) is first
    bucketing.clear_layout_cache()
    assert not bucketing._LAYOUT_CACHE
    again = bucketing.layout_for(t1)
    assert again is not first                  # fresh object, same plan
    assert again.bucket_sizes == first.bucket_sizes


def test_tree_map_buckets_sees_whole_bucket_list():
    tree = _tree_mixed()
    layout = bucketing.layout_for(tree)
    seen = {}

    def fn(bufs):
        seen["n"] = len(bufs)
        seen["dtypes"] = [b.dtype for b in bufs if b.size]
        return [b * 2.0 if b.size else b for b in bufs]

    out = bucketing.tree_map_buckets(fn, tree, compute_dtype=jnp.float32)
    assert seen["n"] == layout.n_buckets
    assert all(d == jnp.float32 for d in seen["dtypes"])
    np.testing.assert_allclose(np.asarray(out["emb"]),
                               np.asarray(tree["emb"]) * 2.0, rtol=1e-6)
    assert out["count"].dtype == jnp.int32     # cast back to storage dtype


def test_tree_map_buckets_rejects_wrong_arity():
    tree = _tree_mixed()
    with pytest.raises(ValueError):
        bucketing.tree_map_buckets(lambda bufs: bufs[:-1], tree)


@pytest.mark.parametrize("compute_dtype", [jnp.float32, None])
def test_tree_map_bucketed_identity_is_exact(compute_dtype):
    tree = _tree_mixed()
    out = bucketing.tree_map_bucketed(lambda b: b, tree,
                                      compute_dtype=compute_dtype)
    _assert_trees_equal(out, tree)


def test_tree_map_bucketed_applies_in_compute_dtype():
    tree = {"w": jnp.asarray(RNG.standard_normal((64,)), jnp.bfloat16)}
    seen = {}

    def probe(buf):
        seen["dtype"] = buf.dtype
        return buf * 2.0

    out = bucketing.tree_map_bucketed(probe, tree, compute_dtype=jnp.float32)
    assert seen["dtype"] == jnp.float32
    assert out["w"].dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out["w"], np.float32),
                               np.asarray(tree["w"], np.float32) * 2.0,
                               rtol=1e-2)


def test_pad_region_stays_zero_through_mix():
    # averaging-style mixes must keep the lane pad at zero; verify the pack
    # pad really is zero and an elementwise scale keeps the round trip exact
    tree = {"w": jnp.asarray(RNG.standard_normal((130,)), jnp.float32)}
    layout = bucketing.layout_for(tree)
    (buf,) = bucketing.pack(tree, layout)
    assert buf.shape == (256,)
    np.testing.assert_array_equal(np.asarray(buf[130:]), 0.0)
    out = bucketing.tree_map_bucketed(lambda b: b * 0.5, tree)
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(tree["w"]) * 0.5, rtol=1e-6)
