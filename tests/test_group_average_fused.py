"""Differential tests for the bucketed fused group-averaging path.

Three independent realisations of the same math must agree on an 8-way
forced-host-device CPU mesh, for **every** phase offset of the butterfly:

    fused bucketed (Pallas combine)  ==  fused bucketed (jnp combine)
        ==  per-leaf reference  ==  stacked-simulator averaging matrix

plus the structural claim that makes the fused path worth having: ppermute
launches per step drop from ``n_leaves * log2(S)`` to ``n_buckets * log2(S)``.

Subprocess pattern (see tests/test_distributed.py): the forced device count
must not leak into the main pytest process.
"""

import pytest

from subproc import run_sub as _run_sub

_PREAMBLE = """
    from repro.core import bucketing, grouping
    from repro.core import group_allreduce as ga
    from repro.core import plan as plan_mod
    from repro.launch.hlo_analysis import count_ppermutes

    def flat_plan(local, names, sizes, S=None, **kw):
        return plan_mod.compile_plan(
            plan_mod.Topology.flat(names, sizes), local,
            plan_mod.AveragingConfig(group_size=S,
                                     average_dtype="float32", **kw))

    def mixed_tree(rng, P_dp):
        # mixed dtypes, a >1-lane leaf, a scalar-ish leaf, an empty leaf
        return {
            "emb": jnp.asarray(rng.normal(size=(P_dp, 33, 7)), jnp.float32),
            "w": jnp.asarray(rng.normal(size=(P_dp, 130)), jnp.float32),
            "s": jnp.asarray(rng.normal(size=(P_dp,)), jnp.float32),
            "h": jnp.asarray(rng.normal(size=(P_dp, 3, 5)),
                             jnp.float32).astype(jnp.bfloat16),
            "e": jnp.zeros((P_dp, 0, 4), jnp.float32),
        }
"""


def run_sub(body: str, devices: int = 8, timeout: int = 420):
    return _run_sub(body, devices=devices, timeout=timeout,
                    preamble=_PREAMBLE)


def test_fused_equals_per_leaf_equals_stacked_every_offset():
    """The acceptance gate: all realisations agree on every phase offset."""
    out = run_sub("""
        P_dp, S = 8, 4
        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        names, sizes = ga.dp_axis_layout(("pod", "data"), dict(pod=2, data=4),
                                         ("pod", "data"))
        rng = np.random.default_rng(0)
        tree = mixed_tree(rng, P_dp)
        offsets = grouping.distinct_offsets(P_dp, S)
        assert len(offsets) > 1, offsets
        local = jax.tree.map(lambda a: a[0], tree)
        for t, off in enumerate(offsets):
            variants = {}
            for key, kw in [
                    ("fused_pallas", dict(fused=True, use_pallas=True)),
                    ("fused_jnp", dict(fused=True, use_pallas=False)),
                    ("per_leaf", dict(fused=False))]:
                pl = flat_plan(local, names, sizes, S=S, **kw)
                f = compat.shard_map(
                    lambda tr, pl=pl, off=off: pl.average_offset(tr, off),
                    mesh=mesh, in_specs=P(("pod", "data")),
                    out_specs=P(("pod", "data")),
                    axis_names={"pod", "data"})
                variants[key] = jax.jit(f)(tree)
            want = ga.group_average_stacked(tree, P=P_dp, S=S, t=t)
            for key, got in variants.items():
                for leaf_name in tree:
                    tol = 2e-2 if leaf_name == "h" else 1e-5
                    np.testing.assert_allclose(
                        np.asarray(got[leaf_name], np.float32),
                        np.asarray(want[leaf_name], np.float32),
                        rtol=tol, atol=tol,
                        err_msg=f"{key} vs stacked, offset {off}, {leaf_name}")
            # fp32-accumulation paths agree bit-for-bit with each other
            for leaf_name in tree:
                np.testing.assert_array_equal(
                    np.asarray(variants["fused_pallas"][leaf_name], np.float32),
                    np.asarray(variants["per_leaf"][leaf_name], np.float32),
                    err_msg=f"fused vs per-leaf exactness, offset {off}")
        print("ALL_OFFSETS_MATCH", len(offsets))
    """)
    assert "ALL_OFFSETS_MATCH" in out


def test_ppermute_count_drops_to_buckets_times_stages():
    out = run_sub("""
        from repro.core import plan as plan_mod
        P_dp, S = 8, 4
        mesh = jax.make_mesh((8,), ("data",))
        names, sizes = ga.dp_axis_layout(("data",), {"data": 8}, ("data",))
        rng = np.random.default_rng(1)
        tree = {f"l{i}": jnp.asarray(rng.normal(size=(8, 40)), jnp.float32)
                for i in range(6)}
        tree["h"] = jnp.asarray(rng.normal(size=(8, 16)),
                                jnp.float32).astype(jnp.bfloat16)
        # launch accounting now comes from the compiled plan: buckets are
        # laid out over the fp32-cast (accumulation-dtype) tree
        pl = plan_mod.compile_plan(
            plan_mod.Topology.flat(names, sizes),
            jax.tree.map(lambda a: a[0], tree),
            plan_mod.AveragingConfig(group_size=S, average_dtype="float32"))
        n_leaves = len(jax.tree.leaves(tree))
        stages = grouping.ilog2(S)

        def make(fused):
            plf = flat_plan(jax.tree.map(lambda a: a[0], tree), names, sizes,
                            S=S, fused=fused)
            return compat.shard_map(
                lambda tr: plf.average_offset(tr, 0),
                mesh=mesh, in_specs=P("data"), out_specs=P("data"),
                axis_names={"data"})

        n_fused = count_ppermutes(jax.make_jaxpr(make(True))(tree).jaxpr)
        n_leaf = count_ppermutes(jax.make_jaxpr(make(False))(tree).jaxpr)
        assert n_leaf == n_leaves * stages, (n_leaf, n_leaves, stages)
        assert n_fused == pl.expected_ppermutes(offset=0), \\
            (n_fused, pl.expected_ppermutes(offset=0))
        n_buckets = pl.class_layout(0).n_buckets
        assert n_fused == n_buckets * stages, (n_fused, n_buckets)
        assert n_buckets < n_leaves
        print("PPERMUTES", n_leaf, "->", n_fused)
    """)
    assert "PPERMUTES" in out


def test_global_average_fused_matches_per_leaf():
    out = run_sub("""
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(2)
        tree = mixed_tree(rng, 8)
        local = jax.tree.map(lambda a: a[0], tree)
        got = {}
        for fused in (True, False):
            pl = flat_plan(local, ("data",), (8,), fused=fused)
            f = compat.shard_map(
                lambda tr, pl=pl: pl.sync(tr),
                mesh=mesh, in_specs=P("data"), out_specs=P("data"),
                axis_names={"data"})
            got[fused] = jax.jit(f)(tree)
        for name in tree:
            a = np.asarray(got[True][name], np.float32)
            b = np.asarray(got[False][name], np.float32)
            tol = 2e-2 if name == "h" else 1e-6
            np.testing.assert_allclose(a, b, rtol=tol, atol=tol)
            if name != "e":
                want = np.asarray(tree[name], np.float32).mean(0)
                np.testing.assert_allclose(
                    a, np.broadcast_to(want, a.shape), rtol=tol, atol=tol)
        print("GLOBAL_OK")
    """)
    assert "GLOBAL_OK" in out


@pytest.mark.parametrize("name", ["dpsgd", "sgp", "adpsgd", "allreduce"])
def test_baseline_averagers_fused_matches_per_leaf(name):
    out = run_sub(f"""
        from repro.core.baselines import make_averager
        mesh = jax.make_mesh((8,), ("data",))
        names, sizes = ga.dp_axis_layout(("data",), {{"data": 8}}, ("data",))
        rng = np.random.default_rng(3)
        tree = {{"w": jnp.asarray(rng.normal(size=(8, 40)), jnp.float32),
                 "b": jnp.asarray(rng.normal(size=(8, 5)), jnp.float32)}}
        for phase in range(2):
            got = {{}}
            for fused in (True, False):
                av = make_averager({name!r}, names, sizes, fused=fused)
                f = compat.shard_map(
                    lambda tr, av=av, p=phase: av.comm(tr, p), mesh=mesh,
                    in_specs=P("data"), out_specs=P("data"),
                    axis_names={{"data"}})
                got[fused] = jax.jit(f)(tree)
            for k in tree:
                np.testing.assert_allclose(
                    np.asarray(got[True][k]), np.asarray(got[False][k]),
                    rtol=1e-5, atol=1e-6)
        print("BASELINE_OK")
    """)
    assert "BASELINE_OK" in out


def test_wagma_averager_fused_config_round_trip():
    """WagmaConfig(fused=...) end to end through the averager, incl. sync."""
    out = run_sub("""
        from repro.core.wagma import WagmaAverager, WagmaConfig
        mesh = jax.make_mesh((8,), ("data",))
        names, sizes = ga.dp_axis_layout(("data",), {"data": 8}, ("data",))
        rng = np.random.default_rng(4)
        tree = mixed_tree(rng, 8)
        results = {}
        for fused in (True, False):
            av = WagmaAverager(names, sizes,
                               WagmaConfig(group_size=4, fused=fused))
            for ph in range(av.n_phases):
                f = compat.shard_map(lambda tr, p=ph, av=av: av.comm(tr, p),
                                     mesh=mesh, in_specs=P("data"),
                                     out_specs=P("data"), axis_names={"data"})
                results[(fused, ph)] = jax.jit(f)(tree)
            g = compat.shard_map(av.sync, mesh=mesh, in_specs=P("data"),
                                 out_specs=P("data"), axis_names={"data"})
            results[(fused, "sync")] = jax.jit(g)(tree)
        for key in [k for k in results if k[0]]:
            other = (False,) + key[1:]
            for name in tree:
                tol = 2e-2 if name == "h" else 1e-5
                np.testing.assert_allclose(
                    np.asarray(results[key][name], np.float32),
                    np.asarray(results[other][name], np.float32),
                    rtol=tol, atol=tol, err_msg=str(key))
        print("WAGMA_CFG_OK")
    """)
    assert "WAGMA_CFG_OK" in out
